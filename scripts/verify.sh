#!/usr/bin/env bash
# Tier-1 verification + lint gate for the fp8_flow_moe crate.
#
#   build   cargo build --release
#   test    cargo test -q
#   fmt     cargo fmt --check      (skipped with a warning if rustfmt is absent)
#   clippy  cargo clippy -D warnings (skipped with a warning if clippy is absent)
#   lint    cargo run -- lint --recipe all  (scale-lineage static analyzer;
#           nonzero exit on any error-severity diagnostic, writes runs/lint.json)
#
# Run from the repository root or from rust/. Fails fast on the first error.

set -euo pipefail

cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "WARN: rustfmt not installed; skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "WARN: clippy not installed; skipping cargo clippy" >&2
fi

echo "== lint gate: scale-lineage static analyzer =="
cargo run --release -q -p fp8_flow_moe -- lint --recipe all
test -f rust/runs/lint.json

echo "== overlap smoke: epshard --overlap on --chunks 2 (bit-identity gated) =="
cargo run --release -q -p fp8_flow_moe -- \
    epshard --ranks 2 --recipe fp8flow --tokens 256 --overlap on --chunks 2
test -f rust/runs/epshard_r2.json

echo "== serve smoke: tiny config, 2 ranks, both arrival modes (bit-identity gated) =="
cargo run --release -q -p fp8_flow_moe -- \
    serve --ranks 2 --requests 24 --arrivals poisson --d-model 64 --ffn 64
cargo run --release -q -p fp8_flow_moe -- \
    serve --ranks 2 --requests 24 --arrivals bursty --d-model 64 --ffn 64
test -f rust/runs/serve_r2.json

echo "== trace smoke: --trace emission, counter cross-check gate, validation, calibration =="
# The drivers exit nonzero if any recorded counter diverges from the
# analytic ExecPrediction/wire accounting, so the cross-check gates here.
cargo run --release -q -p fp8_flow_moe -- \
    epshard --ranks 4 --chunks 2 --overlap on --tokens 256 --trace rust/runs/trace_epshard.json
cargo run --release -q -p fp8_flow_moe -- \
    serve --ranks 2 --requests 24 --arrivals poisson --d-model 64 --ffn 64 \
    --trace rust/runs/trace_serve.json
cargo run --release -q -p fp8_flow_moe -- \
    trace rust/runs/trace_epshard.json rust/runs/trace_serve.json
cargo run --release -q -p fp8_flow_moe -- calibrate rust/runs/trace_epshard.json
test -f rust/runs/calibrate.json

echo "== CLI error contract: malformed flags exit 2, no panic =="
# Each malformed invocation must print `error: ...` to stderr and exit 2
# (the arg-validation contract); a panic would exit 101 and fail the gate.
for bad in "epshard --ranks 0" "epshard --chunks 0" "epshard --tokens -3" "serve --cf nan"; do
    set +e
    # shellcheck disable=SC2086  # intentional word-splitting of the arg list
    cargo run --release -q -p fp8_flow_moe -- ${bad} >/dev/null 2>&1
    rc=$?
    set -e
    if [ "${rc}" -ne 2 ]; then
        echo "FAIL: '${bad}' exited ${rc}, expected 2" >&2
        exit 1
    fi
done

echo "== chaos smoke: crash+resume train, corrupted-wire serve tick, recovery counters =="
# Runs the seeded fault-injection matrix: CRC-checksummed wire recovery
# (bitwise-clean EP forward under flips/drops), degraded serving under a
# rank crash (drop ledger balances), and crash+resume training (bitwise
# replay). The command itself exits nonzero if any recovery gate fails;
# we additionally assert the recovery counters landed in the run doc and
# that the doc passes `trace` schema validation.
cargo run --release -q -p fp8_flow_moe -- chaos --ranks 2
test -f rust/runs/chaos_r2.json
grep -q '"wire_checksum_fail"' rust/runs/chaos_r2.json
grep -q '"a2a_retries"' rust/runs/chaos_r2.json
grep -q '"failovers"' rust/runs/chaos_r2.json
grep -q '"bit_identical":true' rust/runs/chaos_r2.json
cargo run --release -q -p fp8_flow_moe -- trace rust/runs/chaos_r2.json

echo "verify OK"
