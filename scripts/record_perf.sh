#!/usr/bin/env bash
# Regenerate the §Perf scaling numbers and the executed-EP per-stage
# numbers, and append them to rust/EXPERIMENTS.md.
# Usage: scripts/record_perf.sh [machine-label]

set -euo pipefail
cd "$(dirname "$0")/.."

label="${1:-$(hostname)}"
out="rust/EXPERIMENTS.md"

echo "running perf_kernels (this takes a minute)..."
bench_output="$(cargo bench --bench perf_kernels 2>&1)"

echo "running epshard (2 ranks, all recipes; per-stage JSON)..."
epshard_output="$(cargo run --release -p fp8_flow_moe -- epshard --ranks 2 2>&1)"

echo "running epshard overlapped (2 ranks, 2 chunks; overlap efficiency)..."
overlap_output="$(cargo run --release -p fp8_flow_moe -- \
    epshard --ranks 2 --overlap on --chunks 2 2>&1)"

echo "running bwd bench (fwd/bwd wall-clock + bwd/fwd ratio)..."
bwd_bench_output="$(cargo bench --bench bwd 2>&1)"

echo "running bwd (2 ranks, all recipes; backward per-stage JSON)..."
bwd_output="$(cargo run --release -p fp8_flow_moe -- bwd --ranks 2 2>&1)"

echo "running train_step bench (per-stage fwd/bwd/opt + step/fwd ratio)..."
train_bench_output="$(cargo bench --bench train_step 2>&1)"

echo "running native train (three recipes, 100 steps; convergence + steps/s)..."
train_output="$(cargo run --release -p fp8_flow_moe -- train --recipe all --steps 100 --log-every 25 2>&1)"

echo "running serve (2 ranks, capacity-factor sweep, bursty arrivals)..."
serve_output="$(cargo run --release -p fp8_flow_moe -- \
    serve --ranks 2 --recipe all --arrivals bursty --sweep 2>&1)"

echo "running chaos (fault injection: wire recovery, degraded serving, crash+resume)..."
chaos_output="$(
    cargo run --release -p fp8_flow_moe -- chaos --ranks 2 2>&1
    cargo run --release -p fp8_flow_moe -- trace rust/runs/chaos_r2.json 2>&1
)"

echo "running traced epshard + serve (cross-check gate), trace validate, calibrate..."
trace_output="$(
    cargo run --release -p fp8_flow_moe -- \
        epshard --ranks 4 --chunks 2 --overlap on --trace rust/runs/trace_epshard.json 2>&1
    cargo run --release -p fp8_flow_moe -- \
        serve --ranks 2 --trace rust/runs/trace_serve.json 2>&1
    cargo run --release -p fp8_flow_moe -- \
        trace rust/runs/trace_epshard.json rust/runs/trace_serve.json 2>&1
    cargo run --release -p fp8_flow_moe -- calibrate rust/runs/trace_epshard.json 2>&1
)"

{
    echo ""
    echo "### §Perf run: ${label} ($(date -u +%Y-%m-%dT%H:%M:%SZ))"
    echo ""
    echo '```'
    echo "${bench_output}" | grep -E '^(ROW|SPEEDUP|threads:|fp8_matmul:)'
    echo '```'
    echo ""
    echo "#### Executed EP dispatch (epshard --ranks 2, per-stage measured vs modeled)"
    echo ""
    echo '```'
    echo "${epshard_output}" | grep -E '^(== epshard|ROW|    (route|wire|per-rank)|epshard:|wrote)'
    echo '```'
    if [ -f rust/runs/epshard_r2.json ]; then
        echo ""
        echo "Per-stage JSON: \`rust/runs/epshard_r2.json\`"
    fi
    echo ""
    echo "#### Overlapped EP dispatch (epshard --overlap on --chunks 2, measured vs modeled)"
    echo ""
    echo '```'
    echo "${overlap_output}" | grep -E '^(== overlap|ROW|    (hideable|per-slot|bit-identity)|wrote)'
    echo '```'
    echo ""
    echo "#### Executed backward (bench bwd: fwd/bwd wall-clock + ratio)"
    echo ""
    echo '```'
    echo "${bwd_bench_output}" | grep -E '^(ROW|RATIO|threads:)'
    echo '```'
    echo ""
    echo "#### Executed backward per-stage (bwd --ranks 2, cast audit)"
    echo ""
    echo '```'
    echo "${bwd_output}" | grep -E '^(== bwd|ROW|    (casts|vs bf16)|bwd:|wrote)'
    echo '```'
    if [ -f rust/runs/bwd_r2.json ]; then
        echo ""
        echo "Backward per-stage JSON: \`rust/runs/bwd_r2.json\`"
    fi
    echo ""
    echo "#### Native training step (bench train_step: fwd/bwd/opt + step/fwd ratio)"
    echo ""
    echo '```'
    echo "${train_bench_output}" | grep -E '^(ROW|RATIO|train_step/|threads:)'
    echo '```'
    echo ""
    echo "#### Native convergence run (train --recipe all, steps/s + final losses)"
    echo ""
    echo '```'
    echo "${train_output}" | grep -E '^(native train|\[(bf16|blockwise|fp8flow)\]|==|  *(bf16|blockwise|fp8flow):|wrote)'
    echo '```'
    if [ -f rust/runs/train_fp8flow.json ]; then
        echo ""
        echo "Per-recipe run JSON: \`rust/runs/train_<recipe>.json\`"
    fi
    echo ""
    echo "#### Serving (serve --ranks 2 --sweep: tokens/s, p50/p99, drop/imbalance per cf)"
    echo ""
    echo '```'
    echo "${serve_output}" | grep -E '^(== serve|ROW|    (per-rank|bit-identity)|serve:|wrote)'
    echo '```'
    if [ -f rust/runs/serve_r2.json ]; then
        echo ""
        echo "Serving sweep JSON: \`rust/runs/serve_r2.json\`"
    fi
    echo ""
    echo "#### Trace (traced epshard + serve, counter cross-check, calibration fit)"
    echo ""
    echo '```'
    echo "${trace_output}" | grep -E '^(== (epshard|serve|trace|calibrate)|OK|ROW|wrote|counter cross-check|    (command|busy|counters|residual|route|quant|pack|a2a|assemble|ffn|combine))'
    echo '```'
    if [ -f rust/runs/calibrate.json ]; then
        echo ""
        echo "Fitted cost table + residuals: \`rust/runs/calibrate.json\`"
    fi
    echo ""
    echo "#### Chaos (chaos --ranks 2: wire recovery, degraded serving, crash+resume)"
    echo ""
    echo '```'
    echo "${chaos_output}" | grep -E '^(chaos:|  (epshard|serve|train)|OK|wrote)'
    echo '```'
    if [ -f rust/runs/chaos_r2.json ]; then
        echo ""
        echo "Recovery counters + resume bit-identity: \`rust/runs/chaos_r2.json\`"
    fi
} >> "${out}"

echo "appended §Perf run '${label}' to ${out}"
