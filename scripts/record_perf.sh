#!/usr/bin/env bash
# Regenerate the §Perf scaling numbers and append them to rust/EXPERIMENTS.md.
# Usage: scripts/record_perf.sh [machine-label]

set -euo pipefail
cd "$(dirname "$0")/.."

label="${1:-$(hostname)}"
out="rust/EXPERIMENTS.md"

echo "running perf_kernels (this takes a minute)..."
bench_output="$(cargo bench --bench perf_kernels 2>&1)"

{
    echo ""
    echo "### §Perf run: ${label} ($(date -u +%Y-%m-%dT%H:%M:%SZ))"
    echo ""
    echo '```'
    echo "${bench_output}" | grep -E '^(ROW|SPEEDUP|threads:|fp8_matmul:)'
    echo '```'
} >> "${out}"

echo "appended §Perf run '${label}' to ${out}"
