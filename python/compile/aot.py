"""AOT lowering — the single build-time Python entry point.

Lowers every L2 computation to **HLO text** (never serialized protos: jax
≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids — see /opt/xla-example/README.md) and writes a
manifest the Rust artifact registry reads.

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; the
Makefile guards freshness).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref, swiglu as k_swiglu, transpose as k_transpose, quantize as k_quantize

# Kernel microbench shapes — scaled-down analogues of the paper's Fig. 1/5
# shapes (paper: M ∈ {24576, 32768}, N ∈ {2048, 5120, 7168} on H100; CPU
# testbed uses smaller M at the same aspect ratios, DESIGN.md §Hardware-
# Adaptation).
KERNEL_SHAPES = [(1024, 2048), (2048, 2048), (2048, 5120)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "s32", "uint8": "u8", "uint32": "u32"}[str(dt)]


def lower_and_save(outdir, name, fn, specs, manifest):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_avals = jax.tree.leaves(lowered.out_info)
    manifest[name] = {
        "file": f"{name}.hlo.txt",
        "inputs": [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)} for s in jax.tree.leaves(specs)],
        "outputs": [{"shape": list(o.shape), "dtype": _dtype_name(o.dtype)} for o in out_avals],
    }
    print(f"  wrote {name}: {len(text) / 1024:.0f} KiB, "
          f"{len(manifest[name]['inputs'])} in / {len(manifest[name]['outputs'])} out")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_specs(cfg: model.Config):
    shapes, _ = model.param_structure(cfg)
    params = [f32(*s) for s in shapes]
    return tuple(params * 3) + (i32(), i32(cfg.batch, cfg.seq))


def moe_fwd_specs(cfg: model.Config):
    d, h, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return (f32(cfg.tokens, d), f32(d, e), f32(e, d, h), f32(e, d, h), f32(e, h, d))


def emit_model_artifacts(outdir, manifest):
    for cfg_name, cfg, recipes in (
        ("tiny", model.TINY, model.RECIPES),
        ("small", model.SMALL, ("bf16", "fp8flow", "blockwise")),
    ):
        for recipe in recipes:
            lower_and_save(
                outdir, f"train_step_{recipe}_{cfg_name}",
                model.flat_train_step(cfg, recipe), train_specs(cfg), manifest,
            )
        lower_and_save(outdir, f"init_{cfg_name}", model.flat_init(cfg), (jax.ShapeDtypeStruct((), jnp.uint32),), manifest)
        for recipe in recipes:
            lower_and_save(
                outdir, f"moe_fwd_{recipe}_{cfg_name}",
                model.flat_moe_fwd(cfg, recipe), moe_fwd_specs(cfg), manifest,
            )


def emit_kernel_artifacts(outdir, manifest):
    """Per-kernel executables (Pallas lowered in-graph) for the runtime
    integration tests and the HLO-level Fig. 1/5 benches."""
    for (m, n) in KERNEL_SHAPES:
        nt = n // 128
        lower_and_save(
            outdir, f"k_direct_transpose_{m}x{n}",
            lambda c, e: k_transpose.direct_transpose(c, e),
            (u8(m, n), i32(m, nt)), manifest,
        )
        lower_and_save(
            outdir, f"k_naive_transpose_{m}x{n}",
            lambda c, s: k_transpose.naive_transpose(c, s),
            (u8(m, n), f32(m, nt)), manifest,
        )
        lower_and_save(
            outdir, f"k_quantize_{m}x{n}",
            lambda x: k_quantize.quantize_rowwise(x, "po2"),
            (f32(m, n),), manifest,
        )
        lower_and_save(
            outdir, f"k_swiglu_quant_{m}x{n}",
            lambda g, u: k_swiglu.swiglu_quant(g, u, "po2"),
            (f32(m, n), f32(m, n)), manifest,
        )
        lower_and_save(
            outdir, f"k_swiglu_{m}x{n}",
            lambda g, u: k_swiglu.swiglu(g, u),
            (f32(m, n), f32(m, n)), manifest,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact groups: model|kernels")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {}
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    if args.only in (None, "model"):
        print("== model artifacts ==")
        emit_model_artifacts(args.out, manifest)
    if args.only in (None, "kernels"):
        print("== kernel artifacts ==")
        emit_kernel_artifacts(args.out, manifest)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {manifest_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
