"""L1 Pallas kernel: **fused SwiGLU + FP8 quantization** (§3.3.2).

The paper's observation: after the first grouped GEMM, the activation must
be quantized before the second FP8 GEMM. Executing SwiGLU and quantization
as separate kernels costs an extra HBM round-trip of the BF16 activation —
the fusion computes ``silu(gate) ⊙ up`` in VMEM and emits FP8 payload +
per-tile scales directly, with latency ≈ the standalone SwiGLU (Fig. 5).

Backward fusion (``swiglu_bwd_quant``) likewise fuses the SwiGLU gradient
with the row-wise quantization of ``d_gate``/``d_up`` for the Wgrad path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8_codec as codec

TILE = codec.TILE
BM = 128


def _swiglu_kernel(gate_ref, up_ref, out_ref):
    g = gate_ref[...].astype(jnp.float32)
    u = up_ref[...].astype(jnp.float32)
    out_ref[...] = g * jax.nn.sigmoid(g) * u


@jax.jit
def swiglu(gate, up):
    """Unfused SwiGLU (the Fig. 5 baseline): silu(gate) ⊙ up."""
    m, n = gate.shape
    assert m % BM == 0 and n % TILE == 0
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(m // BM, n // TILE),
        in_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(gate, up)


def _swiglu_quant_kernel(gate_ref, up_ref, codes_ref, scales_ref, sexp_ref, *, mode):
    g = gate_ref[...].astype(jnp.float32)
    u = up_ref[...].astype(jnp.float32)
    y = g * jax.nn.sigmoid(g) * u  # stays in VMEM — never hits HBM
    amax = jnp.max(jnp.abs(y), axis=-1)
    if mode == "po2":
        scale, sexp = codec.tile_scale_po2(amax)
    else:
        scale = codec.tile_scale_float(amax)
        sexp = jnp.zeros_like(scale, dtype=jnp.int32)
    codes_ref[...] = codec.encode(y / scale[:, None])
    scales_ref[...] = scale[:, None]
    sexp_ref[...] = sexp[:, None]


@functools.partial(jax.jit, static_argnames=("mode",))
def swiglu_quant(gate, up, mode: str = "po2"):
    """Fused SwiGLU + row-wise FP8 quantization.

    Contract: bitwise-identical to ``quantize(swiglu(gate, up))`` but with
    a single HBM pass. Returns ``(codes, scales, sexp)``.
    """
    m, n = gate.shape
    assert m % BM == 0 and n % TILE == 0
    return pl.pallas_call(
        functools.partial(_swiglu_quant_kernel, mode=mode),
        grid=(m // BM, n // TILE),
        in_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.float32),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.int32),
        ],
        interpret=True,
    )(gate, up)


def _swiglu_bwd_quant_kernel(
    gate_ref, up_ref, dy_ref,
    dg_codes_ref, dg_scales_ref, dg_sexp_ref,
    du_codes_ref, du_scales_ref, du_sexp_ref,
):
    g = gate_ref[...].astype(jnp.float32)
    u = up_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    sig = jax.nn.sigmoid(g)
    silu = g * sig
    dsilu = sig * (1.0 + g * (1.0 - sig))
    dg = dy * u * dsilu
    du = dy * silu
    for val, cref, sref, eref in (
        (dg, dg_codes_ref, dg_scales_ref, dg_sexp_ref),
        (du, du_codes_ref, du_scales_ref, du_sexp_ref),
    ):
        amax = jnp.max(jnp.abs(val), axis=-1)
        scale, sexp = codec.tile_scale_po2(amax)
        cref[...] = codec.encode(val / scale[:, None])
        sref[...] = scale[:, None]
        eref[...] = sexp[:, None]


@jax.jit
def swiglu_bwd_quant(gate, up, dy):
    """Fused SwiGLU backward + FP8 quantization of both input gradients.

    Returns ``((dg_codes, dg_scales, dg_sexp), (du_codes, du_scales,
    du_sexp))`` — the FP8 operands the Dgrad grouped GEMM consumes.
    """
    m, n = gate.shape
    assert m % BM == 0 and n % TILE == 0
    out = pl.pallas_call(
        _swiglu_bwd_quant_kernel,
        grid=(m // BM, n // TILE),
        in_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.float32),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.float32),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.int32),
        ],
        interpret=True,
    )(gate, up, dy)
    return tuple(out[:3]), tuple(out[3:])
