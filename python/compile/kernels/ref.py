"""Pure-jnp oracles for every L1 kernel — the correctness reference the
pytest suite checks each Pallas kernel against (and the functional spec the
Rust native kernels mirror).

Conventions (same as ``rust/src/fp8``):

* a quantized tensor is a triple ``(codes u8 [R, C], scales f32 [R, C/128],
  sexp i32 [R, C/128])`` — row-wise 1×128 tiles (Eq. 2);
* the column-wise layout of ``X`` is represented as the row-wise layout of
  ``Xᵀ``;
* shapes fed to the tiled kernels are multiples of 128 (the MoE pipeline
  pads, §3.3.1); these jnp oracles additionally accept ragged shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fp8_codec as codec

TILE = codec.TILE


# ---------------------------------------------------------------------------
# quantization (Eq. 2–3)
# ---------------------------------------------------------------------------

def quantize_rowwise(x, mode: str = "po2"):
    """Row-wise per-tile quantization. Returns (codes, scales, sexp)."""
    r, c = x.shape
    pad = (-c) % TILE
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    tiles = xp.reshape(r, -1, TILE)
    amax = jnp.max(jnp.abs(tiles), axis=-1)
    if mode == "po2":
        scales, sexp = codec.tile_scale_po2(amax)
    elif mode == "float":
        scales = codec.tile_scale_float(amax)
        sexp = jnp.zeros_like(scales, dtype=jnp.int32)
    else:
        raise ValueError(f"unknown scale mode {mode!r}")
    q = codec.encode(tiles / scales[..., None])
    codes = q.reshape(r, -1)[:, :c]
    return codes, scales, sexp


def quantize_colwise(x, mode: str = "po2"):
    """Column-wise quantization of X ≡ row-wise quantization of Xᵀ."""
    return quantize_rowwise(x.T, mode)


def dequantize_rowwise(codes, scales):
    """D(·): decode codes and apply per-tile scales."""
    r, c = codes.shape
    pad = (-c) % TILE
    cp = jnp.pad(codes, ((0, 0), (0, pad)))
    vals = codec.decode_native(cp).reshape(r, -1, TILE) * scales[..., None]
    return vals.reshape(r, -1)[:, :c]


# ---------------------------------------------------------------------------
# transpose strategies (§3.1)
# ---------------------------------------------------------------------------

def naive_transpose(codes, scales, mode: str = "po2"):
    """Strategy 1 of Fig. 1: dequantize → transpose → requantize.

    Introduces the double quantization error (two roundings)."""
    return quantize_rowwise(dequantize_rowwise(codes, scales).T, mode)


def direct_transpose(codes, sexp):
    """Strategy 2 (ours / Alg. 1): scaling-aware direct transpose.

    Po2 scales only. For each 128×128 block, align scales to the block max
    and shift payload exponents; no dequantize/requantize rounding."""
    m, n = codes.shape
    assert m % TILE == 0 and n % TILE == 0, "direct transpose expects 128-aligned shapes"
    bm, bn = m // TILE, n // TILE
    # blocks[i_blk, j_blk, i_in, j_in]
    blocks = codes.reshape(bm, TILE, bn, TILE).transpose(0, 2, 1, 3)
    se = sexp.reshape(bm, TILE, bn).transpose(0, 2, 1)  # [bm, bn, 128 rows]
    emax = jnp.max(se, axis=-1)  # [bm, bn]
    k = (emax[..., None] - se).astype(jnp.int32)  # [bm, bn, 128 rows]
    shifted = codec.scale_down_code(blocks, k[..., None])
    out_blocks = shifted.transpose(0, 1, 3, 2)  # transpose within block
    # reassemble: output [n, m]; out block (j_blk, i_blk)
    out = out_blocks.transpose(1, 2, 0, 3).reshape(n, m)
    out_sexp = jnp.repeat(emax.T, TILE, axis=0)  # [n, bm]
    out_scales = codec.exp2i(out_sexp)
    return out, out_scales, out_sexp


# ---------------------------------------------------------------------------
# SwiGLU (+ fused quantization, §3.3.2)
# ---------------------------------------------------------------------------

def swiglu(gate, up):
    """SwiGLU: silu(gate) ⊙ up (the nonlinearity between fc1 and fc2)."""
    return jax.nn.silu(gate) * up


def swiglu_bwd(gate, up, dy):
    """Gradients of swiglu wrt (gate, up)."""
    sig = jax.nn.sigmoid(gate)
    silu = gate * sig
    dsilu = sig * (1.0 + gate * (1.0 - sig))
    return dy * up * dsilu, dy * silu


def swiglu_quant(gate, up, mode: str = "po2"):
    """Fused SwiGLU + row-wise quantization (one pass; the fused kernel's
    contract: bitwise-identical to quantize_rowwise(swiglu(...)))."""
    return quantize_rowwise(swiglu(gate, up), mode)


# ---------------------------------------------------------------------------
# permute / padding (§3.3.1)
# ---------------------------------------------------------------------------

def permute_pad_plan(expert_of, n_experts: int, capacity: int):
    """Row plan for the fused permute+pad: for each destination row of the
    [n_experts*capacity, H] buffer, the source token index or -1 (padding).

    Tokens beyond an expert's capacity are dropped (standard MoE capacity
    semantics); the plan is computed once per batch by the router."""
    t = expert_of.shape[0]
    order = jnp.argsort(expert_of, stable=True)
    sorted_e = expert_of[order]
    # rank of each token within its expert group
    rank = jnp.arange(t) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    dest = sorted_e[jnp.arange(t)] * capacity + rank
    valid = rank < capacity
    plan = jnp.full(n_experts * capacity, -1, dtype=jnp.int32)
    plan = plan.at[jnp.where(valid, dest, n_experts * capacity)].set(
        order.astype(jnp.int32), mode="drop"
    )
    return plan


def permute_pad(x, plan):
    """Apply a permute+pad plan: out[d] = x[plan[d]] or 0 where plan[d]<0.

    Works on f32 activations and u8 codes alike (padding rows are zeros —
    exact in both domains)."""
    gathered = jnp.take(x, jnp.clip(plan, 0, x.shape[0] - 1), axis=0)
    return jnp.where((plan >= 0)[:, None], gathered, jnp.zeros_like(gathered))


def unpermute_unpad(y, plan, n_tokens: int):
    """Inverse of permute_pad: scatter rows back to token order (dropped
    tokens receive zeros)."""
    out = jnp.zeros((n_tokens, y.shape[1]), y.dtype)
    src = jnp.where(plan >= 0, plan, n_tokens)
    return out.at[src].add(y, mode="drop")


# ---------------------------------------------------------------------------
# grouped GEMM over FP8 operands (DeepGEMM-style fine-grained scaling)
# ---------------------------------------------------------------------------

def fp8_matmul(a_codes, a_scales, b_codes, b_scales):
    """``A @ Bᵀ`` with per-tile scaled FP8 operands, f32 accumulation.

    ``a``: row-wise [M, K] (scales [M, K/128]); ``b``: row-wise of Bᵀ
    [N, K] (scales [N, K/128]) — the layout the direct transpose produces.
    Per k-tile the partial product is scaled by the outer product of the
    tile scales (DeepGEMM's fine-grained scaling), accumulated in f32.
    """
    m, kk = a_codes.shape
    n, kk2 = b_codes.shape
    assert kk == kk2 and kk % TILE == 0
    kt = kk // TILE
    af = codec.decode_native(a_codes).reshape(m, kt, TILE)
    bf = codec.decode_native(b_codes).reshape(n, kt, TILE)
    # partial[m, n, k_tile]
    partial = jnp.einsum("mkt,nkt->mnk", af, bf, preferred_element_type=jnp.float32)
    scaled = partial * a_scales[:, None, :] * b_scales[None, :, :]
    return jnp.sum(scaled, axis=-1)


def grouped_fp8_matmul(a_codes, a_scales, b_codes, b_scales):
    """Batched-over-experts fp8_matmul: a [E, C, K], b [E, N, K]."""
    return jax.vmap(fp8_matmul)(a_codes, a_scales, b_codes, b_scales)
