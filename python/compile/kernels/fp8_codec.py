"""Software FP8 E4M3 codec as jnp bit ops — the numeric-format core shared
by every L1 kernel and the L2 recipes.

Two interchangeable implementations:

* the *native* path uses jnp's ``float8_e4m3fn`` dtype (convert/bitcast) —
  fastest, and what the lowered HLO uses internally;
* the *bitop* path implements the same semantics with integer ops only —
  the executable specification, bit-exact against both ml_dtypes and the
  Rust codec (``rust/src/fp8/e4m3.rs``); it is also the form used where a
  kernel must manipulate *encodings* (the scaling-aware transpose).

All functions are shape-polymorphic and jit/pallas-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

E4M3_MAX = 448.0
E4M3_NAN = 0x7F
TILE = 128


# ---------------------------------------------------------------------------
# native path (convert through the f8e4m3fn dtype)
# ---------------------------------------------------------------------------

def encode_native(x: jax.Array) -> jax.Array:
    """f32 → u8 E4M3 codes via the dtype cast.

    WARNING: only for tests on the build-time jax runtime. Older XLA
    runtimes (the 0.5.1 CPU backend the Rust layer embeds) lower this
    convert through an f16 intermediate — a double rounding that flips
    ~0.4% of codes at tie points. Kernels that feed AOT artifacts MUST use
    :func:`encode_bitop`, whose integer-only rounding is runtime-independent
    (and bit-exact vs ml_dtypes and the Rust codec)."""
    f8 = x.astype(jnp.float8_e4m3fn)
    return jax.lax.bitcast_convert_type(f8, jnp.uint8)



def decode_native(c: jax.Array) -> jax.Array:
    """u8 E4M3 codes → f32 via the dtype cast."""
    f8 = jax.lax.bitcast_convert_type(c.astype(jnp.uint8), jnp.float8_e4m3fn)
    return f8.astype(jnp.float32)


# ---------------------------------------------------------------------------
# bit-op path (integer ops only; executable specification)
# ---------------------------------------------------------------------------

def _exp2i_decode(e: jax.Array) -> jax.Array:
    """Exact 2^e by f32 exponent-field assembly (decode helper)."""
    bits = ((jnp.clip(e, -126, 127) + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def decode_bitop(c: jax.Array) -> jax.Array:
    """u8 E4M3 codes → f32 with integer ops + one exp2 (no f8 dtype)."""
    c = c.astype(jnp.int32)
    sign = jnp.where(c & 0x80 != 0, -1.0, 1.0).astype(jnp.float32)
    e = (c >> 3) & 0xF
    m = (c & 0x7).astype(jnp.float32)
    is_nan = (c & 0x7F) == 0x7F
    sub = (m / 8.0) * jnp.float32(2.0**-6)
    norm = (1.0 + m / 8.0) * _exp2i_decode(e - 7)
    v = sign * jnp.where(e == 0, sub, norm)
    return jnp.where(is_nan, jnp.float32(jnp.nan), v)


def encode_bitop(x: jax.Array) -> jax.Array:
    """f32 → u8 E4M3 with integer ops (RNE; overflow→NaN; ml_dtypes parity)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32).astype(jnp.int32)
    sign = ((bits >> 24) & 0x80).astype(jnp.int32)
    abs_bits = bits & 0x7FFFFFFF
    f32_exp = abs_bits >> 23
    f32_man = abs_bits & 0x7FFFFF
    ue = f32_exp - 127

    # normal-range candidate: RNE 23→3 mantissa bits
    m3 = f32_man >> 20
    low = f32_man & 0xFFFFF
    half = 0x80000
    round_up = (low > half) | ((low == half) & (m3 & 1 == 1))
    m3 = m3 + round_up.astype(jnp.int32)
    carry = m3 == 8
    m3 = jnp.where(carry, 0, m3)
    ue_n = ue + carry.astype(jnp.int32)
    overflow = (ue_n > 8) | ((ue_n == 8) & (m3 == 7))
    code_norm = sign | ((ue_n + 7) << 3) | m3

    # subnormal range (|x| < 2^-6): RNE onto the 2^-9 grid; x*512 exact
    ax = jax.lax.bitcast_convert_type(abs_bits.astype(jnp.uint32), jnp.float32)
    q = jnp.round(ax * 512.0).astype(jnp.int32)  # jnp.round is RNE
    code_sub = sign | q

    is_nan = jnp.isnan(x)
    is_inf = jnp.isinf(x)
    is_zero = abs_bits == 0
    f32_subnormal = f32_exp == 0

    code = jnp.where(ue >= -6, code_norm, code_sub)
    code = jnp.where(overflow & (ue >= -6), sign | E4M3_NAN, code)
    code = jnp.where(is_zero | f32_subnormal, sign, code)
    code = jnp.where(is_nan | is_inf, sign | E4M3_NAN, code)
    return code.astype(jnp.uint8)


def scale_down_code(c: jax.Array, k: jax.Array) -> jax.Array:
    """Multiply E4M3 codes by 2^-k (k ≥ 0, integer) exactly in code space.

    The inner operation of the scaling-aware direct transpose (Alg. 1):
    exponent-field subtraction while the value stays normal, RNE mantissa
    shift once it crosses into the subnormal grid. Bit-exact against
    ``rust/src/fp8/e4m3.rs::scale_down_code``.
    """
    c = c.astype(jnp.int32)
    k = jnp.broadcast_to(jnp.asarray(k, jnp.int32), c.shape)
    sign = c & 0x80
    e = (c >> 3) & 0xF
    m = c & 0x7
    is_nan = (c & 0x7F) == 0x7F

    stays_normal = e > k
    code_norm = sign | ((e - k) << 3) | m

    # subnormal landing: value in units of 2^-9 then RNE-shift right
    q0 = jnp.where(e == 0, m, 8 + m)
    shift = jnp.where(e == 0, k, k - (e - 1))
    shift = jnp.clip(shift, 0, 8)  # q0 ≤ 15 ⇒ shift ≥ 5 already yields 0
    floor = q0 >> shift
    rem = q0 & ((1 << shift) - 1)
    half = 1 << jnp.maximum(shift - 1, 0)  # guarded: only used when shift > 0
    has_shift = shift > 0
    round_up = has_shift & ((rem > half) | ((rem == half) & (floor & 1 == 1)))
    q = floor + round_up.astype(jnp.int32)
    code_sub = sign | q

    out = jnp.where(stays_normal, code_norm, code_sub)
    out = jnp.where((k == 0) | is_nan, c, out)
    return out.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# scales
# ---------------------------------------------------------------------------

def ceil_log2(s: jax.Array) -> jax.Array:
    """Exact ``ceil(log2(s))`` for positive normal f32, from the bits
    (no libm rounding risk — parity with ``rust/src/fp8/ue8m0.rs``)."""
    bits = jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.uint32).astype(jnp.int32)
    exp = ((bits >> 23) & 0xFF) - 127
    man = bits & 0x7FFFFF
    return jnp.where(man == 0, exp, exp + 1)


def exp2i(e: jax.Array) -> jax.Array:
    """Exact ``2^e`` for integer ``e`` ∈ [-126, 127], by assembling the f32
    exponent field directly. ``jnp.exp2`` must NOT be used for scales: some
    runtimes (e.g. XLA 0.5.1's CPU backend) evaluate it via libm with
    off-by-one-ulp results (0.24999998 for 2^-2), which silently corrupts
    the quantization grid."""
    e = jnp.clip(jnp.asarray(e, jnp.int32), -126, 127)
    bits = ((e + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def tile_scale_po2(amax: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Power-of-two tile scale (UE8M0 recipe): s = 2^ceil(log2(amax/448)).

    Returns ``(scale_f32, exponent_i32)``; zero tiles get scale 1 (exp 0).
    """
    q = amax / jnp.float32(E4M3_MAX)
    e = ceil_log2(jnp.maximum(q, jnp.float32(1e-38)))
    e = jnp.where(amax > 0, e, 0)
    return exp2i(e), e


def tile_scale_float(amax: jax.Array) -> jax.Array:
    """Float tile scale: s = amax/448 exactly; zero tiles get 1."""
    return jnp.where(amax > 0, amax / jnp.float32(E4M3_MAX), jnp.float32(1.0))


# runtime-independent canonical encoder (see encode_native warning)
encode = encode_bitop
