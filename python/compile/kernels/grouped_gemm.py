"""L1 Pallas kernel: **grouped FP8 GEMM** with DeepGEMM-style fine-grained
scaling — the expert-computation workhorse (§3.2).

Each expert's tokens are a padded ``[C, K]`` FP8 buffer (capacity C,
row-wise 1×128 scales); weights are stored transposed-quantized ``[N, K]``
(the layout the scaling-aware transpose produces), so both operands stream
K-major. Per 128-wide k-tile the MXU-shaped partial product is rescaled by
the outer product of the two operands' tile scales and accumulated in f32
(exactly DeepGEMM's per-tile scaling, adapted from warp-tiles to
BlockSpecs — DESIGN.md §Hardware-Adaptation).

Grid: ``(experts, C/128, N/128)``; each program keeps a ``[128, K]`` strip
of both operands plus the f32 accumulator in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8_codec as codec

TILE = codec.TILE
BM = 128
BN = 128


def _grouped_gemm_kernel(a_ref, sa_ref, b_ref, sb_ref, out_ref, *, kt: int):
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for t in range(kt):
        a = codec.decode_native(a_ref[0, :, t * TILE:(t + 1) * TILE])
        b = codec.decode_native(b_ref[0, :, t * TILE:(t + 1) * TILE])
        partial = jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc = acc + partial[None] * (sa_ref[0, :, t][:, None] * sb_ref[0, :, t][None, :])
    out_ref[...] = acc


@jax.jit
def grouped_fp8_matmul(a_codes, a_scales, b_codes, b_scales):
    """Grouped ``A @ Bᵀ`` over FP8 operands.

    ``a_codes``: u8 ``[E, C, K]`` (+ scales f32 ``[E, C, K/128]``);
    ``b_codes``: u8 ``[E, N, K]`` (+ scales f32 ``[E, N, K/128]``).
    Returns f32 ``[E, C, N]``. Matches ``ref.grouped_fp8_matmul`` to f32
    accumulation-order tolerance.
    """
    e, c, k = a_codes.shape
    e2, n, k2 = b_codes.shape
    assert e == e2 and k == k2 and c % BM == 0 and n % BN == 0 and k % TILE == 0
    kt = k // TILE
    return pl.pallas_call(
        functools.partial(_grouped_gemm_kernel, kt=kt),
        grid=(e, c // BM, n // BN),
        in_specs=[
            pl.BlockSpec((1, BM, k), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, BM, kt), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, BN, k), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, BN, kt), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BM, BN), lambda g, i, j: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, n), jnp.float32),
        interpret=True,
    )(a_codes, a_scales, b_codes, b_scales)
