"""L1 Pallas kernel: per-tile row-wise FP8 quantization (Eq. 2–3).

Tiling (TPU thinking, adapted from the paper's CUDA kernels — see DESIGN.md
§Hardware-Adaptation): the grid walks (row-block, 128-col tile); each
program holds a ``(BM, 128)`` block in VMEM, computes the per-row amax over
its 128-wide tile (the scale tile of Eq. 2), derives the po2/float scale,
and writes FP8 codes + scales in one pass — one HBM read, two writes, no
intermediate buffer.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is analysed statically (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8_codec as codec

TILE = codec.TILE
BM = 128  # row-block: 128×128 VMEM blocks = MXU-native tile


def _quantize_kernel(x_ref, codes_ref, scales_ref, sexp_ref, *, mode: str):
    x = x_ref[...].astype(jnp.float32)  # (BM, TILE)
    amax = jnp.max(jnp.abs(x), axis=-1)  # (BM,)
    if mode == "po2":
        scale, sexp = codec.tile_scale_po2(amax)
    else:
        scale = codec.tile_scale_float(amax)
        sexp = jnp.zeros_like(scale, dtype=jnp.int32)
    codes_ref[...] = codec.encode(x / scale[:, None])
    scales_ref[...] = scale[:, None]
    sexp_ref[...] = sexp[:, None]


@functools.partial(jax.jit, static_argnames=("mode",))
def quantize_rowwise(x, mode: str = "po2"):
    """Pallas row-wise per-tile quantizer.

    ``x``: f32/bf16 ``[M, N]`` with ``M % 128 == 0`` and ``N % 128 == 0``.
    Returns ``(codes u8 [M, N], scales f32 [M, N/128], sexp i32 [M, N/128])``
    — bitwise-identical to ``ref.quantize_rowwise``.
    """
    m, n = x.shape
    assert m % BM == 0 and n % TILE == 0, f"shape {x.shape} must be 128-aligned"
    grid = (m // BM, n // TILE)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, mode=mode),
        grid=grid,
        in_specs=[pl.BlockSpec((BM, TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.float32),
            jax.ShapeDtypeStruct((m, n // TILE), jnp.int32),
        ],
        interpret=True,
    )(x)


def _dequantize_kernel(codes_ref, scales_ref, out_ref):
    out_ref[...] = codec.decode_native(codes_ref[...]) * scales_ref[...]


@jax.jit
def dequantize_rowwise(codes, scales):
    """Pallas dequantizer: ``D(·)`` — codes × per-tile scales."""
    m, n = codes.shape
    assert m % BM == 0 and n % TILE == 0
    grid = (m // BM, n // TILE)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((BM, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BM, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(codes, scales)
