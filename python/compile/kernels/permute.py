"""L1 Pallas kernels: **fused permute+padding** and **unpermute+unpadding**
(§3.3.1).

Separately executed, the permute (expert-wise token reordering) and padding
(alignment of each expert segment for the grouped GEMM) each make a full
HBM round-trip over the token buffer. Both are element-wise row moves, so
the fusion computes the destination offset once per row and streams each
token exactly once (paper: up to 1.7× fwd, 6.6× bwd).

The kernel consumes a *row plan* (`ref.permute_pad_plan`): plan[d] = source
token of destination row d, or -1 for a padding row. The plan is built by
the router once per batch; the data movement is the hot path.

Both f32 activations and u8 FP8 payload+scales move through the same
kernel — the FP8 variant is what makes the dataflow casting-free (the
dispatch output is already quantized; permutation happens in code space).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8_codec as codec

TILE = codec.TILE
BR = 128  # destination rows per program


def _permute_pad_kernel(plan_ref, x_ref, out_ref):
    # x_ref: whole source buffer (ANY memory space); out_ref: (BR, H) block.
    plan = plan_ref[...]  # (BR, 1) i32

    def body(r, _):
        src = plan[r, 0]
        row = jax.lax.dynamic_slice(
            x_ref[...], (jnp.maximum(src, 0), 0), (1, out_ref.shape[1])
        )
        row = jnp.where(src >= 0, row, jnp.zeros_like(row))
        out_ref[pl.dslice(r, 1), :] = row.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, BR, body, 0)


@jax.jit
def permute_pad(x, plan):
    """Fused permute+pad: ``out[d] = x[plan[d]]`` (0 for plan[d] < 0).

    ``x``: ``[T, H]`` (f32 or u8), ``plan``: ``[D]`` i32 with ``D % 128 ==
    0``. One streamed pass; bitwise-identical to ``ref.permute_pad``.
    """
    t, h = x.shape
    d = plan.shape[0]
    assert d % BR == 0, f"plan length {d} must be 128-aligned (capacity padding)"
    return pl.pallas_call(
        _permute_pad_kernel,
        grid=(d // BR,),
        in_specs=[
            pl.BlockSpec((BR, 1), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),  # full source resident
        ],
        out_specs=pl.BlockSpec((BR, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, h), x.dtype),
        interpret=True,
    )(plan[:, None], x)


def unpermute_unpad(y, plan, n_tokens: int):
    """Fused unpermute+unpad (backward of permute_pad): scatter expert rows
    back to token order, dropping padding rows.

    Scatter-add semantics (a token routed to k experts receives the sum —
    the combine step). Implemented with jnp scatter (single fused XLA
    scatter kernel) rather than a Pallas loop: in interpret mode a Pallas
    scatter would serialize; the XLA scatter is the fused one-pass form.
    """
    out = jnp.zeros((n_tokens, y.shape[1]), y.dtype)
    src = jnp.where(plan >= 0, plan, n_tokens)
    return out.at[src].add(y, mode="drop")


# ---------------------------------------------------------------------------
# unfused baselines (Fig. 3/4): permute and pad as two separate passes
# ---------------------------------------------------------------------------

def _gather_kernel(plan_ref, x_ref, out_ref):
    plan = plan_ref[...]

    def body(r, _):
        src = plan[r, 0]
        row = jax.lax.dynamic_slice(
            x_ref[...], (jnp.maximum(src, 0), 0), (1, out_ref.shape[1])
        )
        row = jnp.where(src >= 0, row, jnp.zeros_like(row))
        out_ref[pl.dslice(r, 1), :] = row.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, BR, body, 0)


def _pad_scatter_kernel(plan_ref, x_ref, out_ref):
    _gather_kernel(plan_ref, x_ref, out_ref)


@jax.jit
def permute_then_pad(x, compact_plan, pad_plan):
    """Unfused baseline: pass 1 permutes tokens into a compact
    expert-sorted buffer; pass 2 re-reads it and inserts padding rows —
    two full HBM round-trips (what the fusion eliminates)."""
    t, h = x.shape
    dc = compact_plan.shape[0]
    dp = pad_plan.shape[0]
    assert dc % BR == 0 and dp % BR == 0
    compact = pl.pallas_call(
        _gather_kernel,
        grid=(dc // BR,),
        in_specs=[
            pl.BlockSpec((BR, 1), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BR, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dc, h), x.dtype),
        interpret=True,
    )(compact_plan[:, None], x)
    return pl.pallas_call(
        _pad_scatter_kernel,
        grid=(dp // BR,),
        in_specs=[
            pl.BlockSpec((BR, 1), lambda i: (i, 0)),
            pl.BlockSpec(compact.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BR, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp, h), x.dtype),
        interpret=True,
    )(pad_plan[:, None], compact)


def split_plans(plan, counts_padded_to: int = BR):
    """Split a fused plan into the two unfused plans (compact permutation +
    pad-insertion) for the Fig. 3/4 baseline. Returns (compact, padexp)."""
    import numpy as np

    plan = np.asarray(plan)
    valid = plan >= 0
    compact = plan[valid]
    # pad compact to BR alignment
    pad_len = (-len(compact)) % counts_padded_to
    compact_padded = np.concatenate([compact, np.full(pad_len, -1, plan.dtype)])
    # pass 2: destination d takes compact row index or -1
    padexp = np.full(len(plan), -1, plan.dtype)
    padexp[valid] = np.arange(len(compact), dtype=plan.dtype)
    return (
        jnp.asarray(compact_padded, jnp.int32),
        jnp.asarray(padexp, jnp.int32),
    )
