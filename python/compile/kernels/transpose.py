"""L1 Pallas kernel: the **scaling-aware direct FP8 transpose** (Alg. 1).

Strategy (per 128×128 block, one grid program each):

1. read the block's 128 row-scale exponents (VMEM-resident, 512 B);
2. ``emax = max(sexp)`` — the block's aligned scale `S_max` (align *up* so
   payloads only shrink → no overflow, the paper's argument);
3. shift every payload code's exponent field by ``k = emax − sexp[row]``
   (``scale_down_code`` — pure integer ops on the u8 encodings, RNE only if
   a value crosses into the subnormal grid);
4. write the transposed block and the broadcast scale.

No dequantize, no requantize, no float math on the payload — this is what
makes it 2–3× faster than the naive path (Fig. 1) and bitwise lossless.

The naive baseline (dequant → transpose → requant) is also provided for the
Fig. 1 comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp8_codec as codec

TILE = codec.TILE


def _direct_transpose_kernel(codes_ref, sexp_ref, out_ref, oscale_ref, osexp_ref):
    block = codes_ref[...]  # (TILE, TILE) u8 — rows of X
    se = sexp_ref[...][:, 0]  # (TILE,) i32 — row-scale exponents
    emax = jnp.max(se)
    k = emax - se  # (TILE,)
    shifted = codec.scale_down_code(block, k[:, None])
    out_ref[...] = shifted.T
    oscale_ref[...] = jnp.full_like(oscale_ref, codec.exp2i(emax))
    osexp_ref[...] = jnp.full_like(osexp_ref, emax)


@jax.jit
def direct_transpose(codes, sexp):
    """Pallas scaling-aware transpose.

    Input: row-wise quantized ``X``: codes u8 ``[M, N]``, sexp i32
    ``[M, N/128]`` (po2 recipe). Output: row-wise quantized ``Xᵀ``:
    ``(codes u8 [N, M], scales f32 [N, M/128], sexp i32 [N, M/128])`` —
    bitwise-identical to ``ref.direct_transpose``.
    """
    m, n = codes.shape
    assert m % TILE == 0 and n % TILE == 0
    grid = (n // TILE, m // TILE)  # one program per OUTPUT 128×128 block
    return pl.pallas_call(
        _direct_transpose_kernel,
        grid=grid,
        in_specs=[
            # output block (bj, bi) consumes input block (bi, bj)
            pl.BlockSpec((TILE, TILE), lambda bj, bi: (bi, bj)),
            pl.BlockSpec((TILE, 1), lambda bj, bi: (bi, bj)),
        ],
        out_specs=[
            pl.BlockSpec((TILE, TILE), lambda bj, bi: (bj, bi)),
            pl.BlockSpec((TILE, 1), lambda bj, bi: (bj, bi)),
            pl.BlockSpec((TILE, 1), lambda bj, bi: (bj, bi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.uint8),
            jax.ShapeDtypeStruct((n, m // TILE), jnp.float32),
            jax.ShapeDtypeStruct((n, m // TILE), jnp.int32),
        ],
        interpret=True,
    )(codes, sexp)


# ---------------------------------------------------------------------------
# naive baseline (Fig. 1 strategy 1) as Pallas kernels: dequantize kernel →
# XLA transpose → requantize kernel. Three HBM round-trips + two roundings.
# ---------------------------------------------------------------------------

def _dequant_kernel(codes_ref, scales_ref, out_ref):
    out_ref[...] = codec.decode_native(codes_ref[...]) * scales_ref[...]


def _requant_kernel(x_ref, codes_ref, scales_ref, sexp_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale, sexp = codec.tile_scale_po2(amax)
    codes_ref[...] = codec.encode(x / scale[:, None])
    scales_ref[...] = scale[:, None]
    sexp_ref[...] = sexp[:, None]


@jax.jit
def naive_transpose(codes, scales):
    """Fig. 1 strategy 1: dequantize → transpose → requantize (po2 scales)."""
    m, n = codes.shape
    assert m % TILE == 0 and n % TILE == 0
    dq = pl.pallas_call(
        _dequant_kernel,
        grid=(m // TILE, n // TILE),
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((TILE, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(codes, scales)
    dq_t = dq.T
    return pl.pallas_call(
        _requant_kernel,
        grid=(n // TILE, m // TILE),
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((TILE, 1), lambda i, j: (i, j)),
            pl.BlockSpec((TILE, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.uint8),
            jax.ShapeDtypeStruct((n, m // TILE), jnp.float32),
            jax.ShapeDtypeStruct((n, m // TILE), jnp.int32),
        ],
        interpret=True,
    )(dq_t)
