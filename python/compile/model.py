"""L2 — the MoE transformer model, its three precision recipes, and the
training step. Authored in JAX, calling the L1 kernels; lowered once by
``aot.py`` to HLO text and driven from Rust thereafter.

Recipes (the paper's Fig. 2 variants, §3.2):

* ``bf16``      — baseline: no quantization anywhere.
* ``blockwise`` — TE-style: FP8 confined to the grouped GEMMs, **float**
  per-tile scales, Q/DQ at every GEMM boundary; the Wgrad operand is
  re-quantized column-wise from the dequantized activation (the naive
  dequantize→transpose→requantize path → **double quantization error**).
* ``fp8flow``   — the paper's recipe: **po2** scales, quantize once at the
  MoE entry, scaling-aware direct transpose for the Wgrad operand, fused
  SwiGLU+quant; FP8 persists across the expert path except the two BF16
  islands (fc1-out→activation and fc2-dgrad→combine).

Quantization is *emulated* (quantize–dequantize around each GEMM) so that
the numerics are exactly those of FP8 execution while the GEMM itself runs
in f32 on the CPU PJRT backend — the standard methodology for precision
studies (paper §2.2 "simulated FP8 GPT-3 training").
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref

TILE = 128

RECIPES = ("bf16", "blockwise", "fp8flow")


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

class Config(NamedTuple):
    """Model/config hyperparameters (static at lowering time)."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 384          # per-expert hidden (SwiGLU)
    n_experts: int = 4
    top_k: int = 2
    capacity: int = 256      # per-expert token capacity (128-aligned)
    seq: int = 128
    batch: int = 8
    lr: float = 3e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    wd: float = 0.01

    @property
    def tokens(self) -> int:
        return self.seq * self.batch


TINY = Config(vocab=64, d_model=128, n_layers=1, n_heads=2, d_ff=128,
              n_experts=2, top_k=1, capacity=128, seq=32, batch=4)
SMALL = Config()


# ---------------------------------------------------------------------------
# FP8 emulation helpers (value-space; exact per-recipe semantics)
# ---------------------------------------------------------------------------

def _qdq_row(x, mode):
    """quantize→dequantize row-wise (tiles along the last axis)."""
    c, s, _ = ref.quantize_rowwise(x, mode)
    return ref.dequantize_rowwise(c, s)


def _qdq_wgrad_operand(x, recipe):
    """The Wgrad-side operand of an activation `x` quantized row-wise over
    its last dim, now needed column-wise (transposed layout) — THE place
    the two recipes diverge (§3.1):

    * blockwise: dequantize → transpose → requantize with float scales
      (double quantization error);
    * fp8flow: scaling-aware direct transpose of the po2 codes (exact).
    """
    if recipe == "blockwise":
        xq = _qdq_row(x, "float")  # what the fwd GEMM actually consumed
        return _qdq_row(xq.T, "float")  # second, inconsistent quantization
    elif recipe == "fp8flow":
        c, s, e = ref.quantize_rowwise(x, "po2")
        tc, ts, _ = ref.direct_transpose(c, e)
        return ref.dequantize_rowwise(tc, ts)
    raise ValueError(recipe)


def _mode(recipe):
    return "float" if recipe == "blockwise" else "po2"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fp8_linear(x, w, recipe):
    """``x @ w`` with recipe-faithful FP8 numerics in all three GEMMs
    (Fprop/Dgrad/Wgrad). ``x: [m, k]``, ``w: [k, n]``.

    All quantization is 1×128-tiled along the GEMM contraction dim, as the
    grouped kernels require (row-wise activations, transposed-quantized
    weights)."""
    if recipe == "bf16":
        return x @ w
    m = _mode(recipe)
    xq = _qdq_row(x, m)              # row-wise over k
    wq = _qdq_row(w.T, m).T          # weight transposed-quantized over k
    return xq @ wq


def _fp8_linear_fwd(x, w, recipe):
    return fp8_linear(x, w, recipe), (x, w)


def _fp8_linear_bwd(recipe, res, dy):
    x, w = res
    if recipe == "bf16":
        return dy @ w.T, x.T @ dy
    m = _mode(recipe)
    # Dgrad: dx = dy @ wᵀ — dy row-wise over n, w quantized over n.
    dyq = _qdq_row(dy, m)
    wq_n = _qdq_row(w, m)            # tiles along n (wᵀ transposed-quantized)
    dx = dyq @ wq_n.T
    # Wgrad: dw = xᵀ @ dy — xᵀ needs column-wise x (the transpose story);
    # dy needs column-wise quantization over m.
    xt = _qdq_wgrad_operand(x, recipe)           # [k, m] value-space
    dy_c = _qdq_wgrad_operand(dy, recipe)        # [n, m]
    dw = xt @ dy_c.T
    return dx, dw


fp8_linear.defvjp(_fp8_linear_fwd, _fp8_linear_bwd)


# ---------------------------------------------------------------------------
# model components
# ---------------------------------------------------------------------------

def rms_norm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def attention(x, wqkv, wo, n_heads):
    """Plain causal multi-head attention (f32 — the paper quantizes only
    the MoE path; attention stays in the AMP domain)."""
    t, d = x.shape
    qkv = x @ wqkv  # [t, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads
    q = q.reshape(t, n_heads, hd).transpose(1, 0, 2)
    k = k.reshape(t, n_heads, hd).transpose(1, 0, 2)
    v = v.reshape(t, n_heads, hd).transpose(1, 0, 2)
    att = (q @ k.transpose(0, 2, 1)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(1, 0, 2).reshape(t, d)
    return y @ wo


def _topk_by_argmax(probs, k):
    """Iterative-argmax top-k (k ≤ 2 here). ``jax.lax.top_k`` lowers to an
    HLO `topk(..., largest=true)` attribute the 0.5.1 parser rejects; the
    argmax form lowers to plain reduces."""
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        vals.append(jnp.take_along_axis(p, i[:, None], axis=-1)[:, 0])
        idxs.append(i.astype(jnp.int32))
        p = p - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype) * jnp.float32(1e9)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def router(x, wr, top_k):
    """Top-k softmax router. Returns (expert indices [t, k], gates [t, k],
    aux load-balancing loss)."""
    logits = x @ wr  # [t, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = _topk_by_argmax(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style aux loss: E · Σ_e f_e · p_e
    e = wr.shape[1]
    me = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * pe)
    return idx, gates, aux


def moe_ffn(x, params, cfg: Config, recipe: str):
    """The full MoE layer (§3.2 stages): route → dispatch(permute+pad) →
    grouped fc1 → SwiGLU → grouped fc2 → unpermute → combine.

    In the fp8flow recipe the dispatch buffer is conceptually FP8 (the
    dispatch all-to-all moves codes+scales — half the bytes, accounted in
    the cluster sim); numerically we emulate by quantizing at MoE entry.
    """
    t, d = x.shape
    e, k, cap = cfg.n_experts, cfg.top_k, cfg.capacity
    idx, gates, aux = router(x, params["router"], k)

    # entry quantization (the fp8flow recipe's single entry cast):
    if recipe == "fp8flow":
        x_in = _qdq_row(x, "po2")
    elif recipe == "blockwise":
        x_in = x  # blockwise dispatches in BF16, quantizes inside GEMMs
    else:
        x_in = x

    y = jnp.zeros_like(x)
    for kk in range(k):
        plan = ref.permute_pad_plan(idx[:, kk], e, cap)  # [e*cap]
        xg = ref.permute_pad(x_in, plan).reshape(e, cap, d)

        def expert_ffn(xe, w1, w3, w2):
            gate = fp8_linear(xe, w1, recipe)  # fc1 gate  [cap, h]
            up = fp8_linear(xe, w3, recipe)    # fc1 up    [cap, h]
            act = ref.swiglu(gate, up)         # BF16 island #1
            return fp8_linear(act, w2, recipe)  # fc2      [cap, d]

        ye = jax.vmap(expert_ffn)(xg, params["w1"], params["w3"], params["w2"])
        yk = ref.unpermute_unpad(ye.reshape(e * cap, d), plan, t)
        y = y + gates[:, kk:kk + 1] * yk
    return y, aux


def block(x, p, cfg: Config, recipe: str):
    h = x + attention(rms_norm(x, p["ln1"]), p["wqkv"], p["wo"], cfg.n_heads)
    ff, aux = moe_ffn(rms_norm(h, p["ln2"]), p, cfg, recipe)
    return h + ff, aux


def forward(params, tokens, cfg: Config, recipe: str):
    """Next-token LM loss over a [batch, seq] token batch."""

    def single(seq_tokens):
        x = params["embed"][seq_tokens]  # [seq, d]
        aux_total = 0.0
        for li in range(cfg.n_layers):
            x, aux = block(x, params["layers"][li], cfg, recipe)
            aux_total = aux_total + aux
        x = rms_norm(x, params["ln_f"])
        logits = x @ params["embed"].T  # tied head
        return logits, aux_total

    logits, aux = jax.vmap(single)(tokens)  # [b, seq, vocab]
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1).mean()
    return nll + 0.01 * aux.mean()


# ---------------------------------------------------------------------------
# parameters & optimizer (AdamW, f32 master weights)
# ---------------------------------------------------------------------------

def init_params(cfg: Config, key):
    """Initialize f32 master weights (shared across recipes so convergence
    runs start from identical states)."""
    keys = iter(jax.random.split(key, 64))
    d, h, e = cfg.d_model, cfg.d_ff, cfg.n_experts

    def dense(k, *shape, scale=None):
        scale = scale or (1.0 / jnp.sqrt(shape[0]))
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "ln1": jnp.ones(d), "ln2": jnp.ones(d),
            "wqkv": dense(next(keys), d, 3 * d),
            "wo": dense(next(keys), d, d),
            "router": dense(next(keys), d, e),
            "w1": jax.vmap(lambda k: dense(k, d, h))(jax.random.split(next(keys), e)),
            "w3": jax.vmap(lambda k: dense(k, d, h))(jax.random.split(next(keys), e)),
            "w2": jax.vmap(lambda k: dense(k, h, d))(jax.random.split(next(keys), e)),
        })
    return {
        "embed": dense(next(keys), cfg.vocab, d, scale=0.02),
        "ln_f": jnp.ones(d),
        "layers": layers,
    }


def adamw_update(p, g, m, v, step, cfg: Config):
    m2 = cfg.beta1 * m + (1 - cfg.beta1) * g
    v2 = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mhat = m2 / (1 - cfg.beta1 ** step)
    vhat = v2 / (1 - cfg.beta2 ** step)
    p2 = p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.wd * p)
    return p2, m2, v2


def train_step(params, opt_m, opt_v, step, tokens, cfg: Config, recipe: str):
    """One optimization step; returns (flat params', flat m', flat v',
    loss) — flat leaf lists in ``param_structure`` order."""
    loss, grads = jax.value_and_grad(forward)(params, tokens, cfg, recipe)
    stepf = step.astype(jnp.float32)
    p2, m2, v2 = [], [], []
    for p, g, m, v in zip(
        jax.tree.leaves(params), jax.tree.leaves(grads),
        jax.tree.leaves(opt_m), jax.tree.leaves(opt_v),
    ):
        np_, nm, nv = adamw_update(p, g, m, v, stepf, cfg)
        p2.append(np_)
        m2.append(nm)
        v2.append(nv)
    return p2, m2, v2, loss


# ---------------------------------------------------------------------------
# flat (HLO-boundary) wrappers — Rust drives these
# ---------------------------------------------------------------------------

def param_structure(cfg: Config):
    """The canonical flattening order of the parameter pytree."""
    shapes = init_params(cfg, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(shapes)
    return [l.shape for l in leaves], treedef


def flat_train_step(cfg: Config, recipe: str):
    """Returns f(flat_params…, flat_m…, flat_v…, step_i32, tokens_i32) →
    (flat_params'…, flat_m'…, flat_v'…, loss) for AOT lowering."""
    _, treedef = param_structure(cfg)

    def fn(*args):
        n = treedef.num_leaves
        params = jax.tree.unflatten(treedef, args[:n])
        m = jax.tree.unflatten(treedef, args[n:2 * n])
        v = jax.tree.unflatten(treedef, args[2 * n:3 * n])
        step, tokens = args[3 * n], args[3 * n + 1]
        p2, m2, v2, loss = train_step(params, m, v, step, tokens, cfg, recipe)
        return tuple(p2) + tuple(m2) + tuple(v2) + (loss,)

    return fn


def flat_init(cfg: Config):
    """f(seed_u32) → flat params + zeros m + zeros v, for AOT lowering."""

    def fn(seed):
        params = init_params(cfg, jax.random.PRNGKey(seed))
        leaves = jax.tree.leaves(params)
        zeros = [jnp.zeros_like(l) for l in leaves]
        return tuple(leaves) + tuple(zeros) + tuple(zeros)

    return fn


def flat_moe_fwd(cfg: Config, recipe: str):
    """Single-MoE-layer forward f(x [tokens, d], router, w1, w3, w2) → y —
    the runtime microbench / integration-test artifact."""

    def fn(x, wr, w1, w3, w2):
        params = {"router": wr, "w1": w1, "w3": w3, "w2": w2}
        y, _aux = moe_ffn(x, params, cfg, recipe)
        return (y,)

    return fn
