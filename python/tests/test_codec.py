"""Codec parity: bitop codec ≡ native f8e4m3fn dtype ≡ ml_dtypes semantics.

These are the numeric-format ground truth for the whole repo: the Rust
codec's unit tests pin the same values (`rust/src/fp8/e4m3.rs`), and
`test_rust_parity.py` checks Rust↔Python agreement through artifacts.
"""

import ml_dtypes
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8_codec as codec


def all_codes():
    return np.arange(256, dtype=np.uint8)


class TestDecode:
    def test_bitop_matches_mldtypes_all_codes(self):
        c = all_codes()
        ours = np.asarray(codec.decode_bitop(jnp.asarray(c)))
        ref = c.view(ml_dtypes.float8_e4m3fn).astype(np.float32)
        np.testing.assert_array_equal(np.isnan(ours), np.isnan(ref))
        m = ~np.isnan(ref)
        np.testing.assert_array_equal(ours[m], ref[m])

    def test_native_matches_bitop_all_codes(self):
        c = jnp.asarray(all_codes())
        a = np.asarray(codec.decode_native(c))
        b = np.asarray(codec.decode_bitop(c))
        m = ~np.isnan(a)
        np.testing.assert_array_equal(np.isnan(a), np.isnan(b))
        np.testing.assert_array_equal(a[m], b[m])


class TestEncode:
    @pytest.mark.parametrize(
        "x,code",
        [
            (448.0, 0x7E), (449.0, 0x7E), (464.0, 0x7E), (465.0, 0x7F),
            (np.inf, 0x7F), (-449.0, 0xFE), (-1000.0, 0xFF),
            (0.0, 0x00), (-0.0, 0x80), (2.0**-6, 0x08), (2.0**-9, 0x01),
            (2.0**-10, 0x00), (1.0, 0x38), (1.0625, 0x38), (1.1875, 0x3A),
            (216.0, 0x76), (0.0029296875, 0x02),
        ],
    )
    def test_known_values(self, x, code):
        assert int(codec.encode_bitop(jnp.float32(x))) == code
        assert int(codec.encode_native(jnp.float32(x))) == code

    def test_roundtrip_all_codes(self):
        c = all_codes()
        finite = c[(c & 0x7F) != 0x7F]
        vals = codec.decode_bitop(jnp.asarray(finite))
        back = np.asarray(codec.encode_bitop(vals))
        np.testing.assert_array_equal(back, finite)

    @settings(deadline=None, max_examples=300)
    @given(st.floats(-500, 500, allow_nan=False, width=32))
    def test_bitop_matches_mldtypes(self, x):
        ours = int(codec.encode_bitop(jnp.float32(x)))
        ref = int(np.float32(x).astype(ml_dtypes.float8_e4m3fn).view(np.uint8))
        assert ours == ref, f"x={x}: ours={ours:#04x} ref={ref:#04x}"

    @settings(deadline=None, max_examples=200)
    @given(st.floats(-0.0078125, 0.0078125, allow_nan=False, width=32))
    def test_bitop_matches_mldtypes_subnormal_region(self, x):
        ours = int(codec.encode_bitop(jnp.float32(x)))
        ref = int(np.float32(x).astype(ml_dtypes.float8_e4m3fn).view(np.uint8))
        assert ours == ref

    def test_batch_native_vs_bitop(self):
        rng = np.random.default_rng(0)
        x = (rng.standard_normal(4096) * np.exp2(rng.uniform(-12, 9, 4096))).astype(np.float32)
        a = np.asarray(codec.encode_native(jnp.asarray(x)))
        b = np.asarray(codec.encode_bitop(jnp.asarray(x)))
        np.testing.assert_array_equal(a, b)


class TestScaleDownCode:
    def test_exhaustive_vs_decode_multiply_encode(self):
        c = all_codes()
        for k in range(17):
            fast = np.asarray(codec.scale_down_code(jnp.asarray(c), jnp.int32(k)))
            vals = codec.decode_bitop(jnp.asarray(c)) * np.float32(2.0 ** -k)
            slow = np.asarray(codec.encode_bitop(vals))
            nan = (c & 0x7F) == 0x7F
            np.testing.assert_array_equal(fast[~nan], slow[~nan], err_msg=f"k={k}")
            assert ((fast[nan] & 0x7F) == 0x7F).all()

    def test_k_zero_identity(self):
        c = jnp.asarray(all_codes())
        np.testing.assert_array_equal(np.asarray(codec.scale_down_code(c, 0)), all_codes())


class TestCeilLog2:
    @pytest.mark.parametrize("e", range(-30, 30))
    def test_exact_powers(self, e):
        assert int(codec.ceil_log2(jnp.float32(2.0**e))) == e

    @pytest.mark.parametrize("s,e", [(1.5, 1), (3.0, 2), (0.75, 0), (0.51, 0), (0.5, -1)])
    def test_between_powers(self, s, e):
        assert int(codec.ceil_log2(jnp.float32(s))) == e

    @settings(deadline=None, max_examples=200)
    @given(st.integers(-99, 99), st.floats(1.0, 1.984375, allow_nan=False, width=32))
    def test_bound_property(self, e2, mant):
        s = float(np.float32(mant) * np.float32(2.0) ** e2)
        e = int(codec.ceil_log2(jnp.float32(s)))
        assert 2.0 ** e >= s * (1 - 1e-6)
        assert 2.0 ** (e - 1) < s * (1 + 1e-6)
