"""L2 model tests: recipe semantics, gradients, and the wgrad-operand
divergence that separates blockwise from fp8flow (the paper's §3.1 story
at the model level)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def tokens_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)


@pytest.fixture(scope="module")
def tiny_state():
    cfg = model.TINY
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestRecipes:
    def test_forward_losses_close_across_recipes(self, tiny_state):
        cfg, params = tiny_state
        toks = tokens_for(cfg)
        losses = {r: float(model.forward(params, toks, cfg, r)) for r in model.RECIPES}
        base = losses["bf16"]
        for r, l in losses.items():
            assert np.isfinite(l)
            assert abs(l - base) < 0.05 * base, f"{r}: {l} vs {base}"
        # quantized recipes must actually differ from bf16
        assert losses["fp8flow"] != base
        assert losses["blockwise"] != base

    def test_gradients_flow_to_all_params(self, tiny_state):
        cfg, params = tiny_state
        toks = tokens_for(cfg, 1)
        grads = jax.grad(model.forward)(params, toks, cfg, "fp8flow")
        for leaf in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()
        # expert weights receive nonzero gradient (dispatch + custom vjp work)
        g_w1 = np.asarray(grads["layers"][0]["w1"])
        assert np.abs(g_w1).max() > 0

    def test_fp8flow_grads_close_to_bf16(self, tiny_state):
        cfg, params = tiny_state
        toks = tokens_for(cfg, 2)
        g_bf = jax.grad(model.forward)(params, toks, cfg, "bf16")
        g_f8 = jax.grad(model.forward)(params, toks, cfg, "fp8flow")
        # MoE gradients are discontinuous in the router (a quantization
        # nudge can flip a token's top-1 expert, rerouting its whole
        # gradient), so a tight norm bound is ill-posed; the meaningful
        # parity statistic is directional agreement of the full gradient.
        a = np.concatenate([np.asarray(l).ravel() for l in jax.tree.leaves(g_bf)])
        b = np.concatenate([np.asarray(l).ravel() for l in jax.tree.leaves(g_f8)])
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))
        assert cos > 0.5, f"gradient direction diverged: cos={cos}"
        assert not np.array_equal(a, b)


class TestWgradOperand:
    def test_fp8flow_operand_is_lossless_blockwise_is_not(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(
            np.exp2(rng.uniform(-5, 5, (256, 256))).astype(np.float32)
            * rng.choice([-1, 1], (256, 256)).astype(np.float32)
        )
        # fp8flow: direct transpose of the po2 codes — equals D(Q(x))ᵀ
        flow = np.asarray(model._qdq_wgrad_operand(x, "fp8flow"))
        c, s, _ = ref.quantize_rowwise(x, "po2")
        one_rounding = np.asarray(ref.dequantize_rowwise(c, s)).T
        assert (np.abs(flow - one_rounding) <= 0.5 * 2.0**-9 * np.abs(one_rounding).max()).all()
        exact_frac = (flow == one_rounding).mean()
        assert exact_frac > 0.9
        # blockwise: second float-scale quantization — visible error
        block = np.asarray(model._qdq_wgrad_operand(x, "blockwise"))
        cf, sf, _ = ref.quantize_rowwise(x, "float")
        one_rounding_f = np.asarray(ref.dequantize_rowwise(cf, sf)).T
        rel = np.linalg.norm(block - one_rounding_f) / np.linalg.norm(one_rounding_f)
        assert rel > 1e-3, f"blockwise should show double-quant error, got {rel}"


class TestTrainStep:
    def test_loss_decreases_eager(self, tiny_state):
        cfg, params = tiny_state
        leaves = jax.tree.leaves(params)
        zeros = [jnp.zeros_like(l) for l in leaves]
        fn = jax.jit(model.flat_train_step(cfg, "fp8flow"))
        state = list(leaves) + list(zeros) + list(zeros)
        n = len(leaves)
        rng = np.random.default_rng(0)
        first = last = None
        for s in range(1, 9):
            toks = jnp.asarray(
                (np.arange(cfg.batch * cfg.seq).reshape(cfg.batch, cfg.seq) * 7 + rng.integers(0, 3)) % cfg.vocab,
                jnp.int32,
            )
            out = fn(*state, jnp.int32(s), toks)
            loss = float(out[-1])
            assert np.isfinite(loss)
            first = first if first is not None else loss
            last = loss
            state = list(out[:-1])
        assert last < first, f"{first} -> {last}"

    def test_param_structure_is_stable(self):
        shapes1, td1 = model.param_structure(model.TINY)
        shapes2, td2 = model.param_structure(model.TINY)
        assert shapes1 == shapes2
        assert td1 == td2

    def test_topk_by_argmax_matches_lax_topk(self):
        rng = np.random.default_rng(5)
        probs = jax.nn.softmax(jnp.asarray(rng.standard_normal((64, 8)), jnp.float32))
        v1, i1 = model._topk_by_argmax(probs, 2)
        v2, i2 = jax.lax.top_k(probs, 2)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
