"""Pallas kernels (interpret mode) vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes; assertions are bitwise for the quantized domain
(codes/scales/sexp) and allclose for f32 accumulation outputs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fp8_codec as codec,
    grouped_gemm as k_gemm,
    permute as k_permute,
    quantize as k_quantize,
    ref,
    swiglu as k_swiglu,
    transpose as k_transpose,
)

TILE = 128


def rand(shape, seed=0, spread=6.0):
    rng = np.random.default_rng(seed)
    mags = np.exp2(rng.uniform(-spread, spread, shape)).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], shape).astype(np.float32)
    return jnp.asarray(mags * signs)


shapes128 = st.tuples(
    st.integers(1, 3).map(lambda i: i * 128),
    st.integers(1, 3).map(lambda i: i * 128),
)


class TestQuantizeKernel:
    @settings(deadline=None, max_examples=12)
    @given(shape=shapes128, mode=st.sampled_from(["po2", "float"]), seed=st.integers(0, 99))
    def test_matches_ref(self, shape, mode, seed):
        x = rand(shape, seed)
        kc, ks, ke = k_quantize.quantize_rowwise(x, mode)
        rc, rs, re = ref.quantize_rowwise(x, mode)
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(re))
        if mode == "po2":
            # po2: scales exact powers of two — bitwise everywhere
            np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
            np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
        else:
            # float scales: XLA may rewrite x/448 as x·(1/448) in one of
            # the two paths — a 1-ulp wobble on the scale, which with the
            # exact (non-f16-double-rounded) encoder can flip a handful of
            # codes at exact rounding ties. Allow ≤0.1% single-step flips.
            np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=2e-7)
            kcn, rcn = np.asarray(kc).astype(np.int16), np.asarray(rc).astype(np.int16)
            diff = kcn != rcn
            assert diff.mean() < 1e-3, f"{diff.mean()} of codes differ"
            assert (np.abs(kcn[diff] - rcn[diff]) <= 1).all()

    def test_dequantize_roundtrip(self):
        x = rand((256, 256), 7)
        kc, ks, _ = k_quantize.quantize_rowwise(x, "po2")
        dq = k_quantize.dequantize_rowwise(kc, ks)
        rdq = ref.dequantize_rowwise(jnp.asarray(kc), jnp.asarray(ks))
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(rdq))
        # quantization error bounded: rel fro < 5%
        rel = np.linalg.norm(np.asarray(dq) - np.asarray(x)) / np.linalg.norm(np.asarray(x))
        assert rel < 0.05

    def test_zero_input(self):
        x = jnp.zeros((128, 128), jnp.float32)
        kc, ks, ke = k_quantize.quantize_rowwise(x, "po2")
        assert (np.asarray(kc) == 0).all()
        assert (np.asarray(ks) == 1.0).all()


class TestDirectTransposeKernel:
    @settings(deadline=None, max_examples=10)
    @given(shape=shapes128, seed=st.integers(0, 99))
    def test_matches_ref_bitwise(self, shape, seed):
        x = rand(shape, seed)
        c, s, e = ref.quantize_rowwise(x, "po2")
        kc, ks, ke = k_transpose.direct_transpose(c, e)
        rc, rs, re = ref.direct_transpose(c, e)
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(re))

    def test_losslessness_vs_one_rounding_reference(self):
        # D(direct_T(Q)) == D(Q)ᵀ except bounded subnormal underflow
        x = rand((256, 384), 11)
        c, s, e = ref.quantize_rowwise(x, "po2")
        dq = np.asarray(ref.dequantize_rowwise(c, s))
        tc, ts, te = k_transpose.direct_transpose(c, e)
        dt = np.asarray(ref.dequantize_rowwise(tc, ts))
        diff = np.abs(dt - dq.T)
        smax = np.repeat(np.asarray(ts), TILE, axis=1)[:, : dq.T.shape[1]]
        assert (diff <= 0.5 * 2.0**-9 * smax + 1e-30).all()
        # and the overwhelming majority is bit-exact
        assert (dt == dq.T).mean() > 0.9

    def test_naive_pallas_matches_ref(self):
        x = rand((256, 256), 13)
        c, s, e = ref.quantize_rowwise(x, "po2")
        kc, ks, ke = k_transpose.naive_transpose(c, s)
        rc, rs, re = ref.naive_transpose(c, s, "po2")
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))

    def test_double_quant_error_float_vs_po2(self):
        # float scales: naive transpose re-rounds (nonzero DQE);
        # po2 + direct: bit-exact relayout
        x = rand((256, 256), 17)
        cf, sf, _ = ref.quantize_rowwise(x, "float")
        dq_f = np.asarray(ref.dequantize_rowwise(cf, sf))
        nc, ns, _ = ref.naive_transpose(cf, sf, "float")
        naive = np.asarray(ref.dequantize_rowwise(nc, ns))
        err_naive = np.linalg.norm(naive - dq_f.T) / np.linalg.norm(dq_f)
        assert err_naive > 1e-3

        cp, sp, ep = ref.quantize_rowwise(x, "po2")
        dq_p = np.asarray(ref.dequantize_rowwise(cp, sp))
        tc, ts, _ = k_transpose.direct_transpose(cp, ep)
        direct = np.asarray(ref.dequantize_rowwise(tc, ts))
        err_direct = np.linalg.norm(direct - dq_p.T) / np.linalg.norm(dq_p)
        assert err_direct < err_naive / 50


class TestSwigluKernels:
    @settings(deadline=None, max_examples=8)
    @given(shape=shapes128, seed=st.integers(0, 99))
    def test_fused_equals_unfused_bitwise(self, shape, seed):
        g = rand(shape, seed, spread=3.0)
        u = rand(shape, seed + 1000, spread=3.0)
        fc, fs, fe = k_swiglu.swiglu_quant(g, u, "po2")
        rc, rs, re = ref.swiglu_quant(g, u, "po2")
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(fs), np.asarray(rs))

    def test_unfused_swiglu_matches_jax(self):
        g, u = rand((128, 256), 3), rand((128, 256), 4)
        a = np.asarray(k_swiglu.swiglu(g, u))
        b = np.asarray(ref.swiglu(g, u))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_bwd_quant_matches_ref(self):
        g, u, dy = rand((128, 128), 5, 2.0), rand((128, 128), 6, 2.0), rand((128, 128), 7, 2.0)
        (dgc, dgs, _), (duc, dus, _) = k_swiglu.swiglu_bwd_quant(g, u, dy)
        dg_ref, du_ref = ref.swiglu_bwd(g, u, dy)
        rdgc, rdgs, _ = ref.quantize_rowwise(dg_ref, "po2")
        rduc, rdus, _ = ref.quantize_rowwise(du_ref, "po2")
        np.testing.assert_array_equal(np.asarray(dgc), np.asarray(rdgc))
        np.testing.assert_array_equal(np.asarray(duc), np.asarray(rduc))

    def test_bwd_matches_jax_autodiff(self):
        g, u = rand((128, 128), 8, 2.0), rand((128, 128), 9, 2.0)
        dy = jnp.ones_like(g)
        dg, du = ref.swiglu_bwd(g, u, dy)
        jg, ju = jax.grad(lambda g, u: jnp.sum(ref.swiglu(g, u)), argnums=(0, 1))(g, u)
        np.testing.assert_allclose(np.asarray(dg), np.asarray(jg), rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(du), np.asarray(ju), rtol=2e-5, atol=1e-5)


class TestPermuteKernels:
    def _plan(self, tokens, experts, capacity, seed):
        rng = np.random.default_rng(seed)
        expert_of = jnp.asarray(rng.integers(0, experts, tokens), jnp.int32)
        return expert_of, ref.permute_pad_plan(expert_of, experts, capacity)

    @settings(deadline=None, max_examples=8)
    @given(seed=st.integers(0, 99), experts=st.sampled_from([2, 4, 8]))
    def test_fused_matches_ref(self, seed, experts):
        tokens, capacity = 256, 128
        _, plan = self._plan(tokens, experts, capacity, seed)
        x = rand((tokens, 64), seed)
        a = np.asarray(k_permute.permute_pad(x, plan))
        b = np.asarray(ref.permute_pad(x, plan))
        np.testing.assert_array_equal(a, b)

    def test_works_on_u8_codes(self):
        _, plan = self._plan(256, 4, 128, 0)
        c, _, _ = ref.quantize_rowwise(rand((256, 128), 1), "po2")
        a = np.asarray(k_permute.permute_pad(c, plan))
        b = np.asarray(ref.permute_pad(c, plan))
        np.testing.assert_array_equal(a, b)

    def test_unfused_baseline_equals_fused(self):
        _, plan = self._plan(256, 4, 128, 2)
        x = rand((256, 64), 3)
        compact, padexp = k_permute.split_plans(plan)
        two_pass = np.asarray(k_permute.permute_then_pad(x, compact, padexp))
        fused = np.asarray(k_permute.permute_pad(x, plan))
        np.testing.assert_array_equal(two_pass, fused)

    def test_unpermute_roundtrip(self):
        tokens, experts, capacity = 256, 4, 128
        expert_of, plan = self._plan(tokens, experts, capacity, 4)
        x = rand((tokens, 64), 5)
        y = k_permute.permute_pad(x, plan)
        back = np.asarray(k_permute.unpermute_unpad(y, plan, tokens))
        # capacity ≥ tokens/experts here, so no drops: exact roundtrip
        np.testing.assert_array_equal(back, np.asarray(x))

    def test_capacity_drop_semantics(self):
        # all tokens to expert 0, capacity 128 < 256 tokens → 128 kept
        expert_of = jnp.zeros(256, jnp.int32)
        plan = ref.permute_pad_plan(expert_of, 4, 128)
        x = rand((256, 32), 6)
        y = np.asarray(k_permute.permute_pad(x, plan))
        assert (np.asarray(plan)[:128] >= 0).all()
        assert (np.asarray(plan)[128:] == -1).all()
        assert (y[128:] == 0).all()


class TestGroupedGemm:
    @settings(deadline=None, max_examples=6)
    @given(seed=st.integers(0, 99), e=st.sampled_from([1, 2, 4]))
    def test_matches_ref(self, seed, e):
        c, k, n = 128, 256, 128
        rng = np.random.default_rng(seed)
        a = rand((e, c, k), seed, 2.0).reshape(e * c, k)
        b = rand((e, n, k), seed + 1, 2.0).reshape(e * n, k)
        ac, asc, _ = ref.quantize_rowwise(a, "po2")
        bc, bsc, _ = ref.quantize_rowwise(b, "po2")
        ac, asc = ac.reshape(e, c, k), asc.reshape(e, c, k // TILE)
        bc, bsc = bc.reshape(e, n, k), bsc.reshape(e, n, k // TILE)
        out = np.asarray(k_gemm.grouped_fp8_matmul(ac, asc, bc, bsc))
        expect = np.asarray(ref.grouped_fp8_matmul(ac, asc, bc, bsc))
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)

    def test_fp8_gemm_close_to_f32_gemm(self):
        a, b = rand((256, 256), 21, 2.0), rand((128, 256), 22, 2.0)
        ac, asc, _ = ref.quantize_rowwise(a, "po2")
        bc, bsc, _ = ref.quantize_rowwise(b, "po2")
        got = np.asarray(ref.fp8_matmul(ac, asc, bc, bsc))
        expect = np.asarray(a) @ np.asarray(b).T
        rel = np.linalg.norm(got - expect) / np.linalg.norm(expect)
        assert rel < 0.08, rel
