//! Walkthrough of the executed casting-free backward pass: run the
//! stashing forward and the full backward in all three recipes, verify
//! the Fp8Flow cast audit against the Fig. 2 graphs (the 12→2 table's
//! backward half: one entry cast, zero requantizations), check the FP8
//! gradients against the BF16 reference, and prove the EP-sharded
//! backward is bit-identical to the single-rank one.
//!
//! ```bash
//! cargo run --release --example bwd -- [--tokens N] [--ranks R]
//! ```

use fp8_flow_moe::cluster::ep_exec::{ep_backward, EpConfig};
use fp8_flow_moe::dataflow::{build, Variant};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward};
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::assert_mat_bits_eq;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    fp8_flow_moe::exec::set_threads(args.usize_or("threads", 0));
    let tokens = args.usize_or("tokens", 256);
    let d_model = args.usize_or("d-model", 128);
    let ffn = args.usize_or("ffn", 128);
    let experts = args.usize_or("experts", 4);
    let top_k = 2;
    let capacity = (tokens * top_k).div_ceil(experts);
    let ranks = args.usize_or("ranks", 2).min(experts).max(1);

    let mut rng = Rng::seed_from(11);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let dy = Mat::randn(tokens, d_model, 1.0, &mut rng);

    println!(
        "executed backward: {tokens} tokens, d={d_model}, {experts} experts, \
         top-{top_k}, capacity {capacity}\n"
    );

    // BF16 reference gradients
    let pw_ref = PreparedWeights::new(w.clone(), Recipe::Bf16);
    let ref_grads = {
        let stash = forward_stash(&x, &pw_ref, top_k, capacity);
        moe_backward(&stash, &pw_ref, &dy)
    };

    for (recipe, variant) in [
        (Recipe::Bf16, Variant::Bf16),
        (Recipe::Blockwise, Variant::TeBlockwise),
        (Recipe::Fp8Flow, Variant::Fp8Flow),
    ] {
        let g = build(variant);
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, capacity);
        let grads = moe_backward(&stash, &pw, &dy);
        println!("== {recipe:?} ==");
        println!(
            "  stages: combine-bwd {:.3} ms, expert-bwd {:.3} ms, dispatch-bwd {:.3} ms",
            grads.stages.combine_bwd_s * 1e3,
            grads.stages.expert_bwd_s * 1e3,
            grads.stages.dispatch_bwd_s * 1e3,
        );
        println!(
            "  casts executed fwd+bwd: {} + {} (graph: {}); requants: {} (graph naive-T nodes: {})",
            stash.cast_ops,
            grads.stats.casts,
            g.explicit_casts(),
            grads.stats.requants,
            g.requant_nodes_bwd(),
        );
        println!(
            "  dx rel err vs bf16: {:.4}; dw1[0] rel err: {:.4}",
            grads.dx.rel_err(&ref_grads.dx),
            grads.dw1[0].rel_err(&ref_grads.dw1[0]),
        );

        // the recipe's structural claims, executed. The graph counts one
        // cast per direction per layer pass; the executed forward pays its
        // entry cast once and the backward pays Q(dy) once per top-k slot
        // (with top_k = 1 the sum is exactly the paper's headline "2").
        if recipe == Recipe::Fp8Flow {
            assert_eq!(grads.stats.requants, 0, "Fp8Flow backward must be casting-free");
            assert_eq!(stash.cast_ops, g.explicit_casts_fwd());
            assert_eq!(grads.stats.casts, top_k * g.explicit_casts_bwd());
            assert!(g.casting_free_wgrad());
            println!("  casting-free wgrad: CONFIRMED (direct transpose, 0 requantizations)");
        }
        if recipe == Recipe::Blockwise {
            assert!(grads.stats.requants > 0);
            assert!(!g.casting_free_wgrad());
            println!("  double-quantization site executed: {} requants", grads.stats.requants);
        }

        // EP-sharded backward == single-rank, bit for bit
        let cfg = EpConfig { ranks, top_k, capacity, threads: 0 };
        let ep = ep_backward(&stash, &pw, &dy, &cfg);
        assert_mat_bits_eq(&ep.grads.dx, &grads.dx, &format!("{recipe:?} ep dx"));
        for e in 0..experts {
            assert_mat_bits_eq(&ep.grads.dw1[e], &grads.dw1[e], &format!("{recipe:?} dw1[{e}]"));
            assert_mat_bits_eq(&ep.grads.dw2[e], &grads.dw2[e], &format!("{recipe:?} dw2[{e}]"));
            assert_mat_bits_eq(&ep.grads.dw3[e], &grads.dw3[e], &format!("{recipe:?} dw3[{e}]"));
        }
        println!("  EP-sharded backward (R={ranks}) bit-identical: yes\n");
    }
    println!("bwd OK");
}
