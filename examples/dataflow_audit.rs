//! Fig. 2 audit: print all four MoE dataflow variants node-by-node with
//! the cast accounting (12 → 2) and the BF16-island check.
//!
//! ```bash
//! cargo run --release --example dataflow_audit
//! ```

use fp8_flow_moe::dataflow::{build, Variant};

fn main() {
    for v in Variant::all() {
        let g = build(v);
        print!("{}", g.render());
        let islands: Vec<String> = g
            .bf16_islands()
            .into_iter()
            .filter(|n| !n.backward)
            .map(|n| n.name.clone())
            .collect();
        println!("forward BF16 islands on the expert path: {islands:?}\n");
    }
    println!("== headline ==");
    println!(
        "explicit casts: deepseek-v3 {} -> fp8-flow-moe {}   (paper: 12 -> 2)",
        build(Variant::DeepSeekV3).explicit_casts(),
        build(Variant::Fp8Flow).explicit_casts()
    );
}
