//! Walkthrough of the executed expert-parallel sharding: run the same
//! MoE forward single-rank and sharded across 2 and 4 simulated ranks,
//! verify the outputs are bit-identical, and print the per-stage
//! measured-vs-modeled report plus the FP8-vs-BF16 wire accounting.
//!
//! ```bash
//! cargo run --release --example ep_shard -- [--tokens N] [--ranks R]
//! ```

use fp8_flow_moe::cluster::ep_exec::{ep_forward, EpConfig, EpShape};
use fp8_flow_moe::cluster::sim::ep_measured_vs_modeled;
use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::assert_mat_bits_eq;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    fp8_flow_moe::exec::set_threads(args.usize_or("threads", 0));
    let tokens = args.usize_or("tokens", 512);
    let d_model = args.usize_or("d-model", 256);
    let ffn = args.usize_or("ffn", 256);
    let experts = args.usize_or("experts", 8);
    let top_k = 2;
    let capacity = (tokens * top_k).div_ceil(experts);
    // rank counts: powers of two up to --ranks (clamped to the expert count)
    let ranks_cap = args.usize_or("ranks", 4).min(experts).max(1);
    let mut rank_counts = vec![1usize];
    while rank_counts.last().unwrap() * 2 <= ranks_cap {
        let next = rank_counts.last().unwrap() * 2;
        rank_counts.push(next);
    }
    let ranks_max = *rank_counts.last().unwrap();

    let mut rng = Rng::seed_from(5);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);

    println!(
        "executed EP sharding: {tokens} tokens, d={d_model}, {experts} experts, \
         top-{top_k}, capacity {capacity}\n"
    );

    let mut wire = Vec::new();
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        // reference: the classic single-rank forward
        let reference = moe_forward(&x, &pw, top_k, capacity);
        for &ranks in &rank_counts {
            let cfg = EpConfig { ranks, top_k, capacity, threads: 0 };
            let out = ep_forward(&x, &pw, &cfg);
            assert_mat_bits_eq(&out.y, &reference.y, &format!("{recipe:?} R={ranks}"));
            if ranks == ranks_max {
                let shape = EpShape::of(&x, &pw, &cfg);
                print!("{}", ep_measured_vs_modeled(recipe, ranks, &shape, &out));
                println!("    bit-identical to single-rank moe_forward: yes\n");
                wire.push((recipe, out.dispatch_payload_bytes + out.dispatch_sidecar_bytes));
            }
        }
    }

    let bf16_bytes = wire.iter().find(|(r, _)| *r == Recipe::Bf16).unwrap().1;
    println!("dispatch wire bytes at R={ranks_max} (lower is less all-to-all traffic):");
    for (recipe, bytes) in &wire {
        println!(
            "  {recipe:?}: {bytes} B  ({:.2}x of BF16)",
            *bytes as f64 / bf16_bytes as f64
        );
    }
    println!("\nep_shard OK");
}
