//! Serve a single MoE layer through the full AOT path: load the
//! `moe_fwd_<recipe>_<cfg>` executables, run batched requests, compare the
//! three recipes' outputs and latency — the runtime-side twin of the
//! native `moe::layer` (which the integration tests cross-check).
//!
//! ```bash
//! make artifacts && cargo run --release --example moe_forward -- --cfg tiny --batches 8
//! ```

use anyhow::Result;
use fp8_flow_moe::runtime::{literal, Runtime};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = args.get_or("cfg", "tiny");
    let batches = args.usize_or("batches", 8);

    let rt = Runtime::open(Runtime::default_dir())?;
    let mut rng = Rng::seed_from(5);

    // shared random weights/inputs across recipes (identical literals)
    let spec = rt
        .manifest
        .get(&format!("moe_fwd_bf16_{cfg}"))
        .expect("run `make artifacts` first")
        .clone();
    let inputs: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| {
            let n: usize = t.shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal() * 0.5).collect();
            literal::f32_literal(&t.shape, &data).unwrap()
        })
        .collect();

    let mut outputs: Vec<(String, Vec<f32>, f64)> = Vec::new();
    for recipe in ["bf16", "blockwise", "fp8flow"] {
        let exe = rt.load(&format!("moe_fwd_{recipe}_{cfg}"))?;
        // warmup
        let out = exe.run(&inputs)?;
        let t0 = std::time::Instant::now();
        for _ in 0..batches {
            let _ = exe.run(&inputs)?;
        }
        let per_batch = t0.elapsed().as_secs_f64() / batches as f64;
        let y = literal::to_f32_vec(&out[0])?;
        println!(
            "{recipe:<10} {} tokens/layer: {:.2} ms/batch  |y|={:.3}",
            spec.inputs[0].shape[0],
            per_batch * 1e3,
            y.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt()
        );
        outputs.push((recipe.to_string(), y, per_batch));
    }

    // recipe agreement report
    let base = &outputs[0].1;
    let den: f64 = base.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    println!("\nrelative distance to bf16 output:");
    for (name, y, _) in &outputs[1..] {
        let num: f64 = base
            .iter()
            .zip(y)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        println!("  {name:<10} rel = {:.4}", num / den.max(1e-12));
    }
    println!("\nmoe_forward OK");
    Ok(())
}
