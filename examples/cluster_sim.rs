//! Cluster-simulation walkthrough: regenerate Tables 1–3 and show the
//! per-stage time/memory decomposition that explains them.
//!
//! ```bash
//! cargo run --release --example cluster_sim
//! ```

use fp8_flow_moe::cluster::memory::AcMode;
use fp8_flow_moe::cluster::model_cfg::DEEPSEEK_V3;
use fp8_flow_moe::cluster::sim::simulate;
use fp8_flow_moe::coordinator::reports;
use fp8_flow_moe::moe::layer::Recipe;

fn main() {
    print!("{}", reports::table1());
    println!();
    print!("{}", reports::table2());
    println!();
    print!("{}", reports::table3());

    println!("\n== per-stage decomposition (AC=full; per microbatch per stage, ms) ==");
    println!(
        "{:<14} {:>4} {:>10} {:>10} {:>10} {:>10}",
        "method", "EP", "gemm", "a2a", "move", "casts"
    );
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        for ep in [8usize, 16, 32] {
            let r = simulate(&DEEPSEEK_V3, ep, 256 / ep, recipe, AcMode::Full);
            println!(
                "{:<14} {:>4} {:>10.2} {:>10.2} {:>10.2} {:>10.3}",
                format!("{recipe:?}"),
                ep,
                r.t_gemm * 1e3,
                r.t_comm * 1e3,
                r.t_move * 1e3,
                r.t_cast * 1e3,
            );
        }
    }
    println!("\ntakeaway: at EP32 the all-to-all dominates; FP8-Flow wins on");
    println!("comm bytes + fused movement + near-zero casts, exactly the");
    println!("mechanism §4.3 describes (\"scaling amplifies FP8-Flow's gains\").");
}
