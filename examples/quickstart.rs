//! Quickstart: the paper's core numeric ideas in 60 lines of API use.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. quantize a tensor into 1×128-tile FP8 (Eq. 2–3, po2 scales);
//! 2. convert row-wise → column-wise with the scaling-aware **direct
//!    transpose** (Alg. 1) — bitwise-lossless, no dequantize/requantize;
//! 3. show the **double quantization error** (Eq. 1) the naive path incurs
//!    under the incumbent float-scale recipe;
//! 4. run an FP8 GEMM on the transposed operand (the Wgrad layout).

use fp8_flow_moe::fp8::error::dqe_report;
use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::direct_transpose;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::gemm::fp8_matmul;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from(42);

    // A wide-dynamic-range activation tensor (the adversarial case for
    // per-tile quantization: every tile has its own binade).
    let x = Mat::rand_log_uniform(512, 512, -6.0, 6.0, &mut rng);

    // 1. row-wise per-tile quantization, power-of-two scales
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    let rel = q.dequantize().rel_err(&x);
    println!("quantized [512,512] f32 -> FP8: {} payload bytes + {} scale bytes", q.data.len(), q.n_scales());
    println!("  one-rounding relative error: {rel:.4}  (E4M3 half-ulp is 1/16 ≈ 0.0625/√3)");

    // 2. scaling-aware direct transpose: row-wise -> column-wise layout
    let t = direct_transpose(&q);
    let exact = q
        .dequantize()
        .transpose()
        .data
        .iter()
        .zip(&t.dequantize().data)
        .filter(|(a, b)| a.to_bits() == b.to_bits())
        .count();
    println!("\ndirect transpose (Alg. 1): {}/{} values bit-identical to D(Q_row(X))ᵀ", exact, t.data.len());
    println!("  (the rest differ only at the subnormal grid — bounded underflow)");

    // 3. double quantization error of the naive path (float scales)
    let rf = dqe_report(&x, Fp8Format::E4M3, ScaleMode::Float);
    let rp = dqe_report(&x, Fp8Format::E4M3, ScaleMode::Po2);
    println!("\ndouble quantization error E = Q_col(D(Q_row(X))) - Q_col(X)   (Eq. 1):");
    println!("  float scales, naive dequant->T->requant: rel={:.2e}, {:.0}% of elements perturbed",
        rf.naive_vs_ref.rel_fro, rf.naive_vs_ref.frac_nonzero * 100.0);
    println!("  po2 scales,   direct transpose (ours):   rel={:.2e}, {:.2}% perturbed",
        rp.direct_vs_ref.rel_fro, rp.direct_vs_ref.frac_nonzero * 100.0);

    // 4. FP8 GEMM in the Wgrad layout (transposed operand from step 2)
    let w = Mat::randn(256, 512, 0.1, &mut rng);
    let qw = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
    let y = fp8_matmul(&t, &qw); // Xᵀ @ Wᵀ : [512, 256]
    let y_ref = x.transpose().matmul(&w.transpose());
    println!("\nFP8 GEMM on the direct-transposed operand: rel err vs f32 GEMM = {:.4}", y.rel_err(&y_ref));
    println!("\nquickstart OK");
}
