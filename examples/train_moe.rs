//! **End-to-end driver (Fig. 6), executed natively**: train the MoE LM
//! under all three recipes from identical init/data on the in-repo
//! substrate — no AOT artifacts — log the loss curves, and report
//! convergence parity plus the per-step cast audit.
//!
//! ```bash
//! cargo run --release --example train_moe -- --cfg tiny --steps 200 --seed 42
//! ```
//!
//! Scaled per DESIGN.md §Hardware-Adaptation: the paper trains a 16 B
//! model for 200 B tokens on 256 H100s; this testbed trains the `tiny`
//! config for a few hundred steps on a synthetic Markov corpus. The claim
//! under test is the same: the FP8-Flow loss curve is indistinguishable
//! from BF16 while the per-step cast audit stays at the Fig. 2 headline
//! (and Blockwise pays its requantizations every step).
//!
//! The AOT form of this experiment lives behind `fp8-flow-moe train
//! --aot` once `make artifacts` + real xla bindings exist.

use anyhow::Result;
use fp8_flow_moe::coordinator::write_run_json;
use fp8_flow_moe::moe::layer::Recipe;
use fp8_flow_moe::train::{Corpus, NativeTrainer, TrainConfig, TrainOutcome};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::json::Json;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg_name = args.get_or("cfg", "tiny");
    let mut cfg = TrainConfig::named(&cfg_name)
        .unwrap_or_else(|| panic!("unknown --cfg {cfg_name:?} (want tiny|small)"));
    cfg.ranks = args.usize_or("ranks", 1);
    let steps = args.usize_or("steps", 200);
    anyhow::ensure!(steps >= 1, "--steps must be at least 1");
    let seed = args.u64_or("seed", 42);
    let noise = args.usize_or("noise", 10);

    let mut outcomes: Vec<(Recipe, TrainOutcome, Json)> = Vec::new();
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        println!("=== {recipe:?} / {cfg_name}: {steps} steps (seed {seed}) ===");
        // identical init seed + identical corpus stream per recipe
        let mut trainer = NativeTrainer::new(cfg, recipe, seed);
        let mut corpus = Corpus::new(cfg.vocab, seed, noise);
        let out = trainer.run(&mut corpus, steps, (steps / 10).max(1))?;
        let m = trainer.metrics.last().unwrap();
        println!(
            "{:?}: loss {:.4} -> tail-mean {:.4}  ({:.0} tokens/s; per step: casts {}+{}, \
             bwd requants {}, opt requants {})\n",
            recipe,
            out.losses[0],
            out.tail_mean(20),
            out.tokens_per_s,
            m.casts_fwd,
            m.casts_bwd,
            m.requants_bwd,
            m.opt_requants,
        );
        let report = trainer.report_json(&out);
        outcomes.push((recipe, out, report));
    }

    let bf16 = &outcomes[0].1;
    let flow = &outcomes[2].1;
    // convergence-parity statistics (what Fig. 6 shows visually)
    let tail_gap = (flow.tail_mean(20) - bf16.tail_mean(20)).abs();
    let max_gap = bf16
        .losses
        .iter()
        .zip(&flow.losses)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let learned = bf16.losses[0] - bf16.tail_mean(20) as f32;

    println!("== Fig. 6 reproduction summary (native) ==");
    println!("loss drop (bf16):        {learned:.4}");
    println!("tail-mean gap bf16↔fp8:  {tail_gap:.4}");
    println!("max pointwise gap:       {max_gap:.4}");
    // tail agreement is the substantive statistic; the pointwise gate gets
    // an absolute floor for short horizons where per-step loss noise
    // exceeds 25% of the learned drop
    let verdict = tail_gap < 0.10 && (max_gap as f64) < (0.25 * learned as f64).max(0.15);
    println!("convergence parity:      {}", if verdict { "PASS" } else { "CHECK" });

    // loss-curve table (plottable)
    println!("\nstep, bf16, blockwise, fp8flow");
    let stride = (steps / 30).max(1);
    for i in (0..steps).step_by(stride) {
        println!(
            "{}, {:.4}, {:.4}, {:.4}",
            i + 1,
            outcomes[0].1.losses[i],
            outcomes[1].1.losses[i],
            outcomes[2].1.losses[i]
        );
    }

    let mut doc = Json::obj()
        .set("cfg", cfg_name.as_str())
        .set("steps", steps)
        .set("seed", seed)
        .set("tail_gap", tail_gap)
        .set("max_gap", max_gap as f64)
        .set("parity_pass", verdict);
    for (recipe, _, report) in &outcomes {
        let key = match recipe {
            Recipe::Bf16 => "bf16",
            Recipe::Blockwise => "blockwise",
            Recipe::Fp8Flow => "fp8flow",
        };
        doc = doc.set(key, report.clone());
    }
    let path = write_run_json(&format!("fig6_{cfg_name}_s{seed}"), &doc)?;
    println!("\nwrote {path:?}");
    Ok(())
}
