//! **End-to-end driver (Fig. 6)**: train the MoE transformer LM under the
//! BF16 and FP8-Flow recipes from identical init/data, log both loss
//! curves, and report convergence parity — the full three-layer stack in
//! one run (Rust loop → PJRT executable → JAX graph → software-FP8
//! numerics).
//!
//! ```bash
//! make artifacts && cargo run --release --example train_moe -- \
//!     --cfg small --steps 300 --seed 42
//! ```
//!
//! Scaled per DESIGN.md §Hardware-Adaptation: the paper trains a 16 B model
//! for 200 B tokens on 256 H100s; this testbed trains the `small` config
//! (≈7 M params) for a few hundred steps on a synthetic Markov corpus. The
//! claim under test is the same: the FP8-Flow loss curve is
//! indistinguishable from BF16.

use anyhow::Result;
use fp8_flow_moe::coordinator::write_run_json;
use fp8_flow_moe::runtime::Runtime;
use fp8_flow_moe::train::{Corpus, Trainer};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::json::Json;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = args.get_or("cfg", "tiny");
    let steps = args.usize_or("steps", if cfg == "tiny" { 120 } else { 300 });
    let seed = args.u64_or("seed", 42);
    let noise = args.usize_or("noise", 10);
    let vocab = if cfg == "tiny" { 64 } else { 256 };

    let rt = Runtime::open(Runtime::default_dir())?;
    let mut outcomes = Vec::new();
    for recipe in ["bf16", "fp8flow"] {
        println!("=== {recipe} / {cfg}: {steps} steps (seed {seed}) ===");
        // identical init seed + identical corpus stream per recipe
        let mut trainer = Trainer::new(&rt, &cfg, recipe, seed as u32)?;
        let mut corpus = Corpus::new(vocab, seed, noise);
        let out = trainer.run(&mut corpus, steps, (steps / 10).max(1))?;
        println!(
            "{recipe}: loss {:.4} -> tail-mean {:.4}  ({:.0} tokens/s)\n",
            out.losses[0],
            out.tail_mean(20),
            out.tokens_per_s
        );
        outcomes.push(out);
    }

    let (bf16, flow) = (&outcomes[0], &outcomes[1]);
    // convergence-parity statistics (what Fig. 6 shows visually)
    let tail_gap = (flow.tail_mean(20) - bf16.tail_mean(20)).abs();
    let max_gap = bf16
        .losses
        .iter()
        .zip(&flow.losses)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let learned = bf16.losses[0] - bf16.tail_mean(20) as f32;

    println!("== Fig. 6 reproduction summary ==");
    println!("loss drop (bf16):        {learned:.4}");
    println!("tail-mean gap bf16↔fp8:  {tail_gap:.4}");
    println!("max pointwise gap:       {max_gap:.4}");
    // tail agreement is the substantive statistic; the pointwise gate gets
    // an absolute floor for short horizons where per-step loss noise
    // (~0.05 nats at this batch size) exceeds 25% of the learned drop
    let verdict = tail_gap < 0.05 && (max_gap as f64) < (0.25 * learned as f64).max(0.1);
    println!("convergence parity:      {}", if verdict { "PASS" } else { "CHECK" });

    // loss-curve table (plottable)
    println!("\nstep, bf16, fp8flow");
    let stride = (steps / 30).max(1);
    for i in (0..steps).step_by(stride) {
        println!("{}, {:.4}, {:.4}", i + 1, bf16.losses[i], flow.losses[i]);
    }

    let doc = Json::obj()
        .set("cfg", cfg.as_str())
        .set("steps", steps)
        .set("seed", seed)
        .set("bf16", bf16.to_json())
        .set("fp8flow", flow.to_json())
        .set("tail_gap", tail_gap as f64)
        .set("max_gap", max_gap as f64)
        .set("parity_pass", verdict);
    let path = write_run_json(&format!("fig6_{cfg}_s{seed}"), &doc)?;
    println!("\nwrote {path:?}");
    Ok(())
}
