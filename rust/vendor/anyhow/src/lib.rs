//! Vendored minimal substitute for the `anyhow` crate.
//!
//! This offline image cannot fetch crates.io, so the crate ships the small
//! slice of anyhow's API the repository actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait (on `Result` and `Option`),
//! and the [`bail!`] / [`ensure!`] / [`anyhow!`] macros. Errors are a
//! flattened message chain — no backtraces, no downcasting.

use std::fmt;

/// A flattened error: the innermost cause plus any context strings,
/// rendered as `context: cause`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (anyhow renders context outermost-first).
    fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both render the full chain here.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does NOT
// implement `std::error::Error`, which keeps this blanket impl coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value (`Result` or `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::Error::msg(format!($($arg)*))) };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7)
    }

    fn checked(x: u32) -> Result<u32> {
        ensure!(x < 10, "too big: {x}");
        Ok(x)
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
        assert_eq!(checked(3).unwrap(), 3);
        assert_eq!(checked(12).unwrap_err().to_string(), "too big: 12");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "), "{e}");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "shape")).unwrap_err();
        assert_eq!(e.to_string(), "missing shape");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
