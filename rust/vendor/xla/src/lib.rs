//! Vendored offline stub of the `xla` (PJRT) bindings.
//!
//! The real bindings link libxla and a PJRT plugin, neither of which is
//! present in this offline image. The repository's runtime layer
//! (`fp8_flow_moe::runtime`) only needs two things to stay honest:
//!
//! 1. **Host literals work for real** — [`Literal`] stores element type,
//!    shape and row-major bytes, and the typed constructors/extractors are
//!    fully functional (the `runtime::literal` unit tests run against
//!    them).
//! 2. **Device paths fail loudly, not silently** — [`PjRtClient::compile`]
//!    and friends return a clear "no XLA backend in this build" error, so
//!    the integration tests over AOT artifacts skip with an actionable
//!    message instead of linking garbage.
//!
//! Swapping in real bindings later is a Cargo.toml change; the API surface
//! mirrors the subset of `xla-rs` the runtime uses.

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` converts it into
/// the crate's `anyhow`-style error).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn backend() -> Error {
        Error(
            "XLA/PJRT backend is not vendored in this offline build; \
             host literals work but compilation/execution is unavailable"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the restricted artifact boundary set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    U32,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::U8 => 1,
            _ => 4,
        }
    }
}

/// Rust scalar types that can view a literal's payload.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le_bytes(b: &[u8]) -> u32 {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le_bytes(b: &[u8]) -> u8 {
        b[0]
    }
}

/// A host tensor: element type + shape + row-major little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw row-major bytes (validated length).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.size_bytes();
        if data.len() != want {
            return Err(Error(format!(
                "literal byte length {} does not match shape {dims:?} of {ty:?} ({want} bytes)",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Extract the payload as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let sz = self.ty.size_bytes();
        Ok(self.bytes.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    /// Decompose a tuple literal. Stub literals are never tuples (tuples
    /// only come back from executions, which need the real backend).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::backend())
    }
}

/// Parsed HLO module text (held verbatim; compilation needs the backend).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an `.hlo.txt` artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: HloModuleProto { text: proto.text.clone() } }
    }
}

/// Device-resident buffer handle (unreachable without the backend).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::backend())
    }
}

/// A loaded executable (never constructed by the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend())
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend())
    }
}

/// The PJRT client. Construction succeeds (host-side work is fine);
/// anything that would touch a device errors.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::backend())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::backend())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<u8> = [1.5f32, -2.0, 0.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.5, -2.0, 0.0]);
        assert_eq!(lit.dims(), &[3]);
    }

    #[test]
    fn literal_type_checked() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2], &[1, 2]).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), vec![1, 2]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn literal_length_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7]).is_err()
        );
    }

    #[test]
    fn device_paths_error_loudly() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("not vendored"), "{err}");
    }
}
