//! EP invariance: the R-rank executed sharded forward must be
//! **bit-identical** to the single-rank path for R ∈ {1, 2, 4}, ragged
//! per-expert token loads (including experts that receive zero tokens),
//! and all three recipes.
//!
//! This is the executed-dispatch analogue of `tests/prop_parallel.rs`'s
//! thread-invariance contract: sharding the experts across simulated
//! ranks — with the real pack → all-to-all → assemble wire in FP8 code
//! space — must not change a single output bit, because per-expert math
//! reads only its own `capacity` rows, the UE8M0 sidecar reproduces po2
//! scales exactly, and per-rank combine partials sum in plan order.

use fp8_flow_moe::cluster::ep_exec::{ep_forward, EpConfig};
use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::{assert_mat_bits_eq, props};
use fp8_flow_moe::util::rng::Rng;

const RANK_COUNTS: [usize; 3] = [1, 2, 4];

/// Random MoE problem with one *starved* expert: a constant input feature
/// plus a router bias column guarantees expert `E-1` never lands in the
/// top-k, so every sharding sees an expert with zero tokens (and the
/// rank owning it an all-padding batch).
fn starved_setup(
    g: &mut fp8_flow_moe::util::prop::Gen,
) -> (Mat, MoeWeights, usize, usize) {
    let t = g.usize_in(3, 72);
    let d = g.usize_in(8, 144);
    let h = g.usize_in(8, 96);
    let e = g.usize_in(4, 8); // ≥ 4 so R = 4 is a valid sharding
    let cap = g.usize_in(1, t); // ragged loads + capacity drops
    let top_k = g.usize_in(1, 2);
    let mut rng = Rng::seed_from(g.seed ^ 0xE9A2);
    let mut x = Mat::randn(t, d, 0.5, &mut rng);
    let mut w = MoeWeights::random(d, h, e, &mut rng);
    // constant feature drives a +10 router bias into every expert except
    // the last → its logit trails by ~100σ, never chosen
    for tt in 0..t {
        *x.at_mut(tt, d - 1) = 10.0;
    }
    for j in 0..e {
        *w.router.at_mut(d - 1, j) = if j == e - 1 { 0.0 } else { 10.0 };
    }
    (x, w, cap, top_k)
}

#[test]
fn prop_ep_sharded_forward_bit_identical() {
    props("ep sharded forward == single-rank", 10, |g| {
        let (x, w, cap, top_k) = starved_setup(g);
        let e = w.n_experts();
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let reference = moe_forward(&x, &pw, top_k, cap);
            for ranks in RANK_COUNTS {
                let cfg = EpConfig { ranks, top_k, capacity: cap, threads: 0 };
                let out = ep_forward(&x, &pw, &cfg);
                assert_mat_bits_eq(
                    &out.y,
                    &reference.y,
                    &format!("{recipe:?} R={ranks} E={e} cap={cap} top_k={top_k}"),
                );
                assert_eq!(
                    out.aux_loss.to_bits(),
                    reference.aux_loss.to_bits(),
                    "{recipe:?} R={ranks}: aux_loss"
                );
            }
        }
    });
}

#[test]
fn starved_expert_really_receives_zero_tokens() {
    // sanity for the generator: the bias construction must actually
    // produce a zero-load expert, or the property above tests less than
    // it claims.
    let mut g = fp8_flow_moe::util::prop::Gen { rng: Rng::seed_from(99), seed: 99 };
    let (x, w, cap, top_k) = starved_setup(&mut g);
    let e = w.n_experts();
    let routing =
        fp8_flow_moe::moe::router::route(&x, &w.router, top_k);
    let hits = routing
        .experts
        .iter()
        .flat_map(|slots| slots.iter())
        .filter(|&&ex| ex == e - 1)
        .count();
    assert_eq!(hits, 0, "expert {e}-1 should be starved");
    // and the sharded forward still runs through the empty shard
    let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
    let reference = moe_forward(&x, &pw, top_k, cap);
    let out = ep_forward(&x, &pw, &EpConfig { ranks: 4, top_k, capacity: cap, threads: 0 });
    assert_mat_bits_eq(&out.y, &reference.y, "starved shard");
}

#[test]
fn fixed_shape_exhaustive_thread_budgets() {
    // thread budget must not matter either: the rank runtime carves
    // disjoint worker shares, and every kernel underneath is
    // thread-invariant.
    let mut rng = Rng::seed_from(7);
    let (t, d, h, e, cap) = (48, 64, 48, 4, 16);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let reference = moe_forward(&x, &pw, 2, cap);
        for ranks in RANK_COUNTS {
            for threads in [1usize, 2, 8] {
                let cfg = EpConfig { ranks, top_k: 2, capacity: cap, threads };
                let out = ep_forward(&x, &pw, &cfg);
                assert_mat_bits_eq(&out.y, &reference.y, &format!("{recipe:?} R={ranks} t={threads}"));
            }
        }
    }
}
