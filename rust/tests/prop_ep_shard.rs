//! EP invariance: the R-rank executed sharded forward must be
//! **bit-identical** to the single-rank path for R ∈ {1, 2, 4}, ragged
//! per-expert token loads (including experts that receive zero tokens),
//! and all three recipes.
//!
//! This is the executed-dispatch analogue of `tests/prop_parallel.rs`'s
//! thread-invariance contract: sharding the experts across simulated
//! ranks — with the real pack → all-to-all → assemble wire in FP8 code
//! space — must not change a single output bit, because per-expert math
//! reads only its own `capacity` rows, the UE8M0 sidecar reproduces po2
//! scales exactly, and per-rank combine partials sum in plan order.
//!
//! PR 7 widens the matrix with the pipeline dimensions: chunk counts
//! C ∈ {1, 2, 4} and both schedules (bulk-synchronous chunked, and the
//! overlapped step graph with a comm lane per rank) must all stay
//! bitwise equal — overlapped == serialized == single-rank — because
//! chunk boundaries land on expert boundaries in plan order and the
//! combine reduce reads exactly one partial per served token.

use fp8_flow_moe::cluster::ep_exec::{ep_backward, ep_forward, EpConfig};
use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward, MoeGrads};
use fp8_flow_moe::moe::layer::{
    combine, dispatch, expert_ffn, moe_forward, DispatchSource, MoeWeights, PreparedWeights,
    Recipe,
};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::{assert_mat_bits_eq, props};
use fp8_flow_moe::util::rng::Rng;

const RANK_COUNTS: [usize; 3] = [1, 2, 4];
const CHUNK_COUNTS: [usize; 3] = [1, 2, 4];

/// The pipeline configurations every (R, C) point is checked under:
/// serialized chunked, and the overlapped step graph.
const SCHEDULES: [bool; 2] = [false, true];

/// Random MoE problem with one *starved* expert: a constant input feature
/// plus a router bias column guarantees expert `E-1` never lands in the
/// top-k, so every sharding sees an expert with zero tokens (and the
/// rank owning it an all-padding batch).
fn starved_setup(
    g: &mut fp8_flow_moe::util::prop::Gen,
) -> (Mat, MoeWeights, usize, usize) {
    let t = g.usize_in(3, 72);
    let d = g.usize_in(8, 144);
    let h = g.usize_in(8, 96);
    let e = g.usize_in(4, 8); // ≥ 4 so R = 4 is a valid sharding
    let cap = g.usize_in(1, t); // ragged loads + capacity drops
    let top_k = g.usize_in(1, 2);
    let mut rng = Rng::seed_from(g.seed ^ 0xE9A2);
    let mut x = Mat::randn(t, d, 0.5, &mut rng);
    let mut w = MoeWeights::random(d, h, e, &mut rng);
    // constant feature drives a +10 router bias into every expert except
    // the last → its logit trails by ~100σ, never chosen
    for tt in 0..t {
        *x.at_mut(tt, d - 1) = 10.0;
    }
    for j in 0..e {
        *w.router.at_mut(d - 1, j) = if j == e - 1 { 0.0 } else { 10.0 };
    }
    (x, w, cap, top_k)
}

#[test]
fn prop_ep_sharded_forward_bit_identical() {
    // R × C × schedule: overlapped == serialized == single-rank, with
    // ragged loads and a zero-token expert in every draw
    props("ep sharded forward == single-rank", 6, |g| {
        let (x, w, cap, top_k) = starved_setup(g);
        let e = w.n_experts();
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let reference = moe_forward(&x, &pw, top_k, cap);
            for ranks in RANK_COUNTS {
                for chunks in CHUNK_COUNTS {
                    for overlap in SCHEDULES {
                        let cfg = EpConfig::serial(ranks, top_k, cap, 0)
                            .with_pipeline(chunks, overlap);
                        let out = ep_forward(&x, &pw, &cfg);
                        assert_mat_bits_eq(
                            &out.y,
                            &reference.y,
                            &format!(
                                "{recipe:?} R={ranks} C={chunks} ov={overlap} E={e} \
                                 cap={cap} top_k={top_k}"
                            ),
                        );
                        assert_eq!(
                            out.aux_loss.to_bits(),
                            reference.aux_loss.to_bits(),
                            "{recipe:?} R={ranks} C={chunks} ov={overlap}: aux_loss"
                        );
                    }
                }
            }
        }
    });
}

fn assert_grads_bits_eq(a: &MoeGrads, b: &MoeGrads, what: &str) {
    assert_mat_bits_eq(&a.dx, &b.dx, &format!("{what}: dx"));
    assert_eq!(a.dw1.len(), b.dw1.len(), "{what}: expert count");
    for e in 0..a.dw1.len() {
        assert_mat_bits_eq(&a.dw1[e], &b.dw1[e], &format!("{what}: dw1[{e}]"));
        assert_mat_bits_eq(&a.dw3[e], &b.dw3[e], &format!("{what}: dw3[{e}]"));
        assert_mat_bits_eq(&a.dw2[e], &b.dw2[e], &format!("{what}: dw2[{e}]"));
    }
    assert_eq!(a.stats, b.stats, "{what}: cast audit");
}

#[test]
fn prop_ep_sharded_backward_bit_identical() {
    // the reverse-direction analogue of the forward property: the
    // EP-sharded backward (combine-bwd a2a in FP8 code space, per-rank
    // dgrad/wgrad, dispatch-bwd reduce) must match the single-rank
    // backward bit for bit — R ∈ {1,2,4}, C ∈ {1,2,4}, both schedules,
    // all recipes, ragged loads including a zero-token expert (whose
    // owning rank backprops through an all-padding slab). The stats
    // equality also pins chunk-invariance of the cast/requant audit.
    props("ep sharded backward == single-rank", 5, |g| {
        let (x, w, cap, top_k) = starved_setup(g);
        let e = w.n_experts();
        let mut rng = Rng::seed_from(g.seed ^ 0x8B3D);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let stash = forward_stash(&x, &pw, top_k, cap);
            let reference = moe_backward(&stash, &pw, &dy);
            for ranks in RANK_COUNTS {
                for chunks in CHUNK_COUNTS {
                    for overlap in SCHEDULES {
                        let cfg = EpConfig::serial(ranks, top_k, cap, 0)
                            .with_pipeline(chunks, overlap);
                        let out = ep_backward(&stash, &pw, &dy, &cfg);
                        assert_grads_bits_eq(
                            &out.grads,
                            &reference,
                            &format!(
                                "{recipe:?} R={ranks} C={chunks} ov={overlap} E={e} \
                                 cap={cap} top_k={top_k}"
                            ),
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn ep_backward_fixed_shape_exhaustive_thread_budgets() {
    let mut rng = Rng::seed_from(17);
    let (t, d, h, e, cap) = (48, 64, 48, 4, 16);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, 2, cap);
        let reference = moe_backward(&stash, &pw, &dy);
        for ranks in RANK_COUNTS {
            for threads in [1usize, 2, 8] {
                let cfg = EpConfig::serial(ranks, 2, cap, threads);
                let out = ep_backward(&stash, &pw, &dy, &cfg);
                assert_grads_bits_eq(
                    &out.grads,
                    &reference,
                    &format!("{recipe:?} R={ranks} t={threads}"),
                );
                // the overlapped pipeline must be thread-budget-invariant
                // too: a 1-worker rank degrades to a merged serial lane,
                // an 8-worker rank to comm(1) + compute(7) — same bits
                let out = ep_backward(&stash, &pw, &dy, &cfg.with_pipeline(2, true));
                assert_grads_bits_eq(
                    &out.grads,
                    &reference,
                    &format!("{recipe:?} R={ranks} t={threads} overlapped"),
                );
            }
        }
    }
}

#[test]
fn starved_expert_really_receives_zero_tokens() {
    // sanity for the generator: the bias construction must actually
    // produce a zero-load expert, or the property above tests less than
    // it claims.
    let mut g = fp8_flow_moe::util::prop::Gen { rng: Rng::seed_from(99), seed: 99 };
    let (x, w, cap, top_k) = starved_setup(&mut g);
    let e = w.n_experts();
    let routing =
        fp8_flow_moe::moe::router::route(&x, &w.router, top_k);
    let hits = routing
        .experts
        .iter()
        .flat_map(|slots| slots.iter())
        .filter(|&&ex| ex == e - 1)
        .count();
    assert_eq!(hits, 0, "expert {e}-1 should be starved");
    // and the sharded forward still runs through the empty shard — in
    // both schedules (the overlapped graph must handle the all-padding
    // unit without deadlock or bit drift)
    let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
    let reference = moe_forward(&x, &pw, top_k, cap);
    let out = ep_forward(&x, &pw, &EpConfig::serial(4, top_k, cap, 0));
    assert_mat_bits_eq(&out.y, &reference.y, "starved shard");
    let cfg = EpConfig::serial(4, top_k, cap, 0).with_pipeline(2, true);
    let out = ep_forward(&x, &pw, &cfg);
    assert_mat_bits_eq(&out.y, &reference.y, "starved shard overlapped");
}

#[test]
fn all_dropped_plan_is_defined_across_thread_budgets() {
    // a capacity-starved serving tick can drop EVERY (token, slot) pair:
    // the plan is all padding, dispatch carries zero real rows, and the
    // combine must come back as exact zeros — no panic, no stale data —
    // for every recipe, both wire types, and worker budgets {1, 2, 8}
    let (t, d, h, e, cap) = (12usize, 32usize, 24usize, 4usize, 3usize);
    let mut rng = Rng::seed_from(0xD0);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let plan = vec![-1i64; e * cap];
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let xq = (recipe == Recipe::Fp8Flow)
            .then(|| quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2));
        for threads in [1usize, 2, 8] {
            let src = match &xq {
                Some(q) => DispatchSource::Fp8(q),
                None => DispatchSource::Dense(&x),
            };
            let batch = dispatch(src, &plan, 0..e, cap, threads);
            let yk = expert_ffn(&batch, &pw, threads);
            assert_eq!(yk.rows, e * cap, "{recipe:?} t={threads}: padded slab shape");
            let back = combine(&yk, &plan, 0..e, cap, t, threads);
            assert_eq!((back.rows, back.cols), (t, d), "{recipe:?} t={threads}");
            assert!(
                back.data.iter().all(|&v| v.to_bits() == 0),
                "{recipe:?} t={threads}: all-dropped combine must be exact +0.0"
            );
        }
    }
}

#[test]
fn fixed_shape_exhaustive_thread_budgets() {
    // thread budget must not matter either: the rank runtime carves
    // disjoint worker shares, and every kernel underneath is
    // thread-invariant.
    let mut rng = Rng::seed_from(7);
    let (t, d, h, e, cap) = (48, 64, 48, 4, 16);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let reference = moe_forward(&x, &pw, 2, cap);
        for ranks in RANK_COUNTS {
            for threads in [1usize, 2, 8] {
                let cfg = EpConfig::serial(ranks, 2, cap, threads);
                let out = ep_forward(&x, &pw, &cfg);
                assert_mat_bits_eq(
                    &out.y,
                    &reference.y,
                    &format!("{recipe:?} R={ranks} t={threads}"),
                );
                let out = ep_forward(&x, &pw, &cfg.with_pipeline(2, true));
                assert_mat_bits_eq(
                    &out.y,
                    &reference.y,
                    &format!("{recipe:?} R={ranks} t={threads} overlapped"),
                );
            }
        }
    }
}
