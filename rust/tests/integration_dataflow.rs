//! Integration tests tying the dataflow graphs (Fig. 2) to the measured
//! behaviour of the native MoE layer and the cluster simulator — the
//! audited cast accounting must agree with what actually executes.

use fp8_flow_moe::cluster::memory::AcMode;
use fp8_flow_moe::cluster::model_cfg::DEEPSEEK_V3;
use fp8_flow_moe::cluster::sim::simulate;
use fp8_flow_moe::dataflow::{build, OpKind, Variant};
use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

#[test]
fn paper_headline_twelve_to_two() {
    assert_eq!(build(Variant::DeepSeekV3).explicit_casts(), 12);
    assert_eq!(build(Variant::Fp8Flow).explicit_casts(), 2);
}

#[test]
fn graph_forward_casts_match_executed_layer() {
    // the graph's FORWARD cast count must equal what the native layer
    // actually performs (layer.rs counts casts as it executes)
    let mut rng = Rng::seed_from(7);
    let x = Mat::randn(128, 128, 0.5, &mut rng);
    let w = MoeWeights::random(128, 128, 2, &mut rng);

    // fp8flow: graph says 1 fwd cast (entry quantize)
    let g = build(Variant::Fp8Flow);
    let fwd_casts = g.nodes.iter().filter(|n| !n.backward && n.op.is_explicit_cast()).count();
    let out = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 128);
    assert_eq!(out.cast_ops, fwd_casts, "fp8flow fwd casts");

    // blockwise: graph says 2 fwd casts per expert path; the native layer
    // executes per-expert (2·E with E=2 experts) — per-expert granularity
    // is an implementation detail, the per-layer kernel count is what the
    // graph models
    let gb = build(Variant::TeBlockwise);
    let fwd_casts_b = gb.nodes.iter().filter(|n| !n.backward && n.op.is_explicit_cast()).count();
    assert_eq!(fwd_casts_b, 2);
    let outb = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 128);
    assert_eq!(outb.cast_ops, fwd_casts_b * 2 /* experts */, "blockwise fwd casts");
}

#[test]
fn sim_cast_cost_proportional_to_graph_counts() {
    // more explicit casts in the graph ⇒ more cast wallclock in the sim
    let t = |r: Recipe| simulate(&DEEPSEEK_V3, 16, 16, r, AcMode::Full).t_cast;
    let (bf16, block, flow) = (t(Recipe::Bf16), t(Recipe::Blockwise), t(Recipe::Fp8Flow));
    assert_eq!(bf16, 0.0);
    assert!(flow > 0.0 && block > flow);
    // graph ratio 4:2 ⇒ sim ratio ≈ 2
    let ratio = block / flow;
    assert!((1.5..3.0).contains(&ratio), "ratio {ratio}");
}

#[test]
fn fp8flow_kernel_launch_reduction() {
    // fusion reduces launches vs deepseek-style by a meaningful margin
    let ds = build(Variant::DeepSeekV3).kernel_launches();
    let flow = build(Variant::Fp8Flow).kernel_launches();
    assert!(flow as f64 <= ds as f64 * 0.8, "{flow} vs {ds}");
}

#[test]
fn fp8_edges_dominate_fp8flow_expert_path() {
    let g = build(Variant::Fp8Flow);
    let expert_path: Vec<_> = g
        .nodes
        .iter()
        .filter(|n| {
            matches!(
                n.stage,
                fp8_flow_moe::dataflow::Stage::Permute
                    | fp8_flow_moe::dataflow::Stage::Fc1
                    | fp8_flow_moe::dataflow::Stage::Activation
                    | fp8_flow_moe::dataflow::Stage::Fc2
            )
        })
        .collect();
    let fp8 = expert_path
        .iter()
        .filter(|n| n.out_dtype == fp8_flow_moe::dataflow::Dtype::Fp8)
        .count();
    // FP8 persists across most of the expert path (§3.2)
    assert!(fp8 * 2 > expert_path.len(), "{fp8}/{}", expert_path.len());
}

#[test]
fn all_variants_render_and_validate() {
    for v in Variant::all() {
        let g = build(v);
        g.validate().unwrap();
        let r = g.render();
        assert!(r.contains("explicit casts"));
    }
}
