//! Integration tests over the cluster simulator: full Tables 1–3
//! regeneration plus cross-checks between the comm model, memory model and
//! the dataflow graphs.

use fp8_flow_moe::cluster::comm::{table1_row, TABLE1_CONFIGS};
use fp8_flow_moe::cluster::memory::AcMode;
use fp8_flow_moe::cluster::model_cfg::{DEEPSEEK_V2, DEEPSEEK_V2_LITE, DEEPSEEK_V3};
use fp8_flow_moe::cluster::sim::simulate;
use fp8_flow_moe::coordinator::reports;
use fp8_flow_moe::moe::layer::Recipe;

#[test]
fn table1_full_grid_shape_fidelity() {
    // paper shape: comm speedup in (1, 2); ALL speedup strictly below comm
    // speedup; erosion ≥ 25% of the comm gain somewhere (the paper's
    // "reduces the gain by roughly one third")
    let mut max_erosion_frac: f64 = 0.0;
    for &(m, n, ep) in &TABLE1_CONFIGS {
        let r = table1_row(m, n, ep);
        assert!(r.speedup_comm > 1.0 && r.speedup_comm < 2.0);
        assert!(r.speedup_all < r.speedup_comm);
        let erosion = (r.speedup_comm - r.speedup_all) / (r.speedup_comm - 1.0).max(1e-9);
        max_erosion_frac = max_erosion_frac.max(erosion);
    }
    assert!(max_erosion_frac > 0.25, "max erosion {max_erosion_frac}");
}

#[test]
fn table2_relative_gains_match_paper_direction() {
    // paper: fp8flow vs bf16 = +6% (EP8) +8% (EP16) +16% (EP32)
    let gain = |ep: usize| {
        let b = simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Bf16, AcMode::Full).tgs;
        let f = simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Fp8Flow, AcMode::Full).tgs;
        f / b - 1.0
    };
    let (g8, g16, g32) = (gain(8), gain(16), gain(32));
    assert!(g8 > 0.0 && g16 > g8 * 0.8 && g32 > g16, "{g8:.3} {g16:.3} {g32:.3}");
    assert!(g32 > 0.10, "EP32 gain should exceed 10%: {g32:.3}");
    assert!(g32 < 1.0, "gain should stay same order as paper's 16-21%: {g32:.3}");
}

#[test]
fn table3_reproduces_oom_cells_exactly() {
    let cases = [
        (Recipe::Bf16, 8, false),
        (Recipe::Bf16, 16, false),
        (Recipe::Bf16, 32, true),
        (Recipe::Blockwise, 8, false),
        (Recipe::Blockwise, 16, false),
        (Recipe::Blockwise, 32, true),
        (Recipe::Fp8Flow, 8, false),
        (Recipe::Fp8Flow, 16, false),
        (Recipe::Fp8Flow, 32, false),
    ];
    for (recipe, ep, want_oom) in cases {
        let r = simulate(&DEEPSEEK_V3, ep, 256 / ep, recipe, AcMode::SelMoeExpert);
        assert_eq!(r.oom, want_oom, "{recipe:?} EP{ep}: {:.1} GB", r.mem_gb);
    }
}

#[test]
fn memory_savings_match_paper_magnitudes() {
    // paper (Table 3, EP8): fp8flow ≈ 8 GB below BF16 and 16.5 GB below
    // blockwise — require same sign and 0.5–2× magnitude
    let bf16 = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Bf16, AcMode::SelMoeExpert).mem_gb;
    let block = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Blockwise, AcMode::SelMoeExpert).mem_gb;
    let flow = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Fp8Flow, AcMode::SelMoeExpert).mem_gb;
    let vs_bf16 = bf16 - flow;
    let vs_block = block - flow;
    assert!((4.0..16.0).contains(&vs_bf16), "vs bf16: {vs_bf16:.1} GB (paper 8)");
    assert!((8.0..33.0).contains(&vs_block), "vs blockwise: {vs_block:.1} GB (paper 16.5)");
    assert!(vs_block > vs_bf16);
}

#[test]
fn smaller_models_cost_less() {
    for recipe in [Recipe::Bf16, Recipe::Fp8Flow] {
        let lite = simulate(&DEEPSEEK_V2_LITE, 8, 4, recipe, AcMode::Full);
        let v2 = simulate(&DEEPSEEK_V2, 8, 8, recipe, AcMode::Full);
        assert!(lite.mem_gb < v2.mem_gb, "{recipe:?}");
        assert!(lite.tgs > v2.tgs, "{recipe:?}");
    }
}

#[test]
fn reports_cover_every_cell() {
    let t2 = reports::table2();
    for recipe in ["BF16", "Blockwise", "FP8-Flow-MoE"] {
        assert!(t2.contains(recipe));
    }
    let t3 = reports::table3();
    assert_eq!(t3.matches("OOM").count() >= 4, true); // 2 cells × (TGS+status)
}

#[test]
fn bubble_fraction_decreases_with_ep() {
    // EP up ⇒ PP down ⇒ smaller 1F1B bubble — structural sanity of the
    // schedule model (the compute per stage grows correspondingly)
    let b = |ep: usize| simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Bf16, AcMode::Full).bubble_frac;
    assert!(b(8) > b(16) && b(16) > b(32));
}
