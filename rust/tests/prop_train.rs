//! Property suite for the native training subsystem (ISSUE 4):
//! optimizer numerics against closed-form scalar references, the
//! requantize-then-prepare bit-identity (the optimizer's single-quantization
//! weight cast), the executed Fig. 6 convergence assertions (loss falls
//! for all three recipes; Fp8Flow tracks the Bf16 oracle; the per-step
//! cast audit holds the Fig. 2 headline with zero optimizer requants),
//! and the EP/thread bit-identity of the full training step.

use fp8_flow_moe::dataflow::{build_train_step, Variant};
use fp8_flow_moe::fp8::tensor::Fp8Tensor;
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::train::native::{NativeTrainer, OptAlgo, OptConfig, Optimizer, TrainConfig};
use fp8_flow_moe::train::Corpus;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

// ---------------------------------------------------------------------------
// Optimizer numerics vs closed-form scalar references
// ---------------------------------------------------------------------------

#[test]
fn adamw_matches_closed_form_two_param_reference() {
    let (lr, b1, b2, eps, wd) = (0.1f32, 0.9f32, 0.999f32, 1e-8f32, 0.01f32);
    let cfg = OptConfig {
        algo: OptAlgo::AdamW { beta1: b1, beta2: b2, eps },
        lr,
        weight_decay: wd,
        warmup: 0,
    };
    let mut opt = Optimizer::new(cfg);
    let mut pa = Mat::from_vec(1, 1, vec![1.5f32]);
    let mut pb = Mat::from_vec(1, 2, vec![-0.75f32, 0.3]);
    // closed-form scalar mirror (same f32 op order as the implementation)
    let mut refs = [(1.5f32, 0.0f32, 0.0f32), (-0.75, 0.0, 0.0), (0.3, 0.0, 0.0)];
    for t in 1i32..=4 {
        let gs = [0.3f32 * t as f32, -0.2 + 0.05 * t as f32, 0.7];
        let ga = Mat::from_vec(1, 1, vec![gs[0]]);
        let gb = Mat::from_vec(1, 2, vec![gs[1], gs[2]]);
        let used_lr = opt.step(&mut [&mut pa, &mut pb], &[&ga, &gb]);
        assert_eq!(used_lr, lr, "warmup 0 → constant lr");
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        for ((p, m, v), g) in refs.iter_mut().zip(gs) {
            *m = b1 * *m + (1.0 - b1) * g;
            *v = b2 * *v + (1.0 - b2) * g * g;
            let mh = *m / bc1;
            let vh = *v / bc2;
            *p -= lr * (mh / (vh.sqrt() + eps) + wd * *p);
        }
        assert_eq!(pa.data[0].to_bits(), refs[0].0.to_bits(), "step {t} param a");
        assert_eq!(pb.data[0].to_bits(), refs[1].0.to_bits(), "step {t} param b0");
        assert_eq!(pb.data[1].to_bits(), refs[2].0.to_bits(), "step {t} param b1");
    }
    // first-step sanity: v̂ = g² ⇒ update ≈ lr·sign(g) (+ decay), the
    // well-known AdamW step-1 magnitude
    let mut o2 = Optimizer::new(cfg);
    let mut p = Mat::from_vec(1, 1, vec![0.0f32]);
    let g = Mat::from_vec(1, 1, vec![0.42f32]);
    o2.step(&mut [&mut p], &[&g]);
    assert!((p.data[0] + lr).abs() < 1e-4, "step 1 ≈ -lr·sign(g): {}", p.data[0]);
}

#[test]
fn sgd_momentum_matches_closed_form_reference() {
    let (lr, mu, wd) = (0.05f32, 0.9f32, 0.1f32);
    let cfg = OptConfig {
        algo: OptAlgo::SgdMomentum { momentum: mu },
        lr,
        weight_decay: wd,
        warmup: 0,
    };
    let mut opt = Optimizer::new(cfg);
    let mut p = Mat::from_vec(1, 1, vec![2.0f32]);
    let (mut pr, mut buf) = (2.0f32, 0.0f32);
    for t in 1i32..=5 {
        let gv = 0.1 * t as f32;
        let g = Mat::from_vec(1, 1, vec![gv]);
        opt.step(&mut [&mut p], &[&g]);
        buf = mu * buf + gv;
        pr -= lr * (buf + wd * pr);
        assert_eq!(p.data[0].to_bits(), pr.to_bits(), "step {t}");
    }
}

#[test]
fn warmup_schedule_is_applied_to_the_step() {
    let cfg = OptConfig { warmup: 4, ..OptConfig::adamw(0.08) };
    let mut opt = Optimizer::new(cfg);
    let mut p = Mat::zeros(1, 1);
    let g = Mat::from_vec(1, 1, vec![1.0f32]);
    let lrs: Vec<f32> = (0..5).map(|_| opt.step(&mut [&mut p], &[&g])).collect();
    assert_eq!(lrs[0], 0.08 * 0.25);
    assert_eq!(lrs[1], 0.08 * 0.5);
    assert_eq!(lrs[2], 0.08 * 0.75);
    assert_eq!(lrs[3], 0.08);
    assert_eq!(lrs[4], 0.08);
}

// ---------------------------------------------------------------------------
// Requantize-then-prepare bit-identity (the single-quantization weight cast)
// ---------------------------------------------------------------------------

fn assert_fp8_eq(a: &Fp8Tensor, b: &Fp8Tensor, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_eq!(a.data, b.data, "{what}: codes");
    assert_eq!(a.sexp, b.sexp, "{what}: scale exponents");
    assert_eq!(a.scales.len(), b.scales.len(), "{what}: scale count");
    for (k, (x, y)) in a.scales.iter().zip(&b.scales).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: scale {k}");
    }
}

#[test]
fn requantize_from_masters_bit_matches_fresh_prepare() {
    let mut rng = Rng::seed_from(11);
    // d spans a full tile plus a ragged tail (160 = 128 + 32)
    let (d, h, e) = (160, 96, 3);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let mut pw = PreparedWeights::new(w.clone(), recipe);
        // simulate an optimizer update on the masters
        for ws in [&mut pw.raw.w1, &mut pw.raw.w3, &mut pw.raw.w2] {
            for m in ws.iter_mut() {
                for (k, v) in m.data.iter_mut().enumerate() {
                    *v += 0.01 * ((k % 7) as f32 - 3.0);
                }
            }
        }
        let stats = pw.requantize_from_masters();
        assert_eq!(stats.requants, 0, "{recipe:?}: layouts must come from the masters");
        let expected_quants = if recipe == Recipe::Bf16 { 0 } else { 6 * e };
        assert_eq!(stats.weight_quants, expected_quants, "{recipe:?}");
        let fresh = PreparedWeights::new(pw.raw.clone(), recipe);
        for (name, got, want) in [
            ("w1_t", &pw.w1_t, &fresh.w1_t),
            ("w3_t", &pw.w3_t, &fresh.w3_t),
            ("w2_t", &pw.w2_t, &fresh.w2_t),
            ("w1_d", &pw.w1_d, &fresh.w1_d),
            ("w3_d", &pw.w3_d, &fresh.w3_d),
            ("w2_d", &pw.w2_d, &fresh.w2_d),
        ] {
            assert_eq!(got.len(), want.len(), "{recipe:?} {name}");
            for (ex, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                assert_fp8_eq(a, b, &format!("{recipe:?} {name}[{ex}]"));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The executed Fig. 6 convergence assertions
// ---------------------------------------------------------------------------

/// Fixed-batch training run (full-batch descent on a deterministic
/// synthetic task — the monotonicity testbed).
fn fixed_batch_run(recipe: Recipe, steps: usize, seed: u64) -> (NativeTrainer, Vec<f32>) {
    let cfg = TrainConfig::tiny();
    let mut corpus = Corpus::new(cfg.vocab, seed, 10);
    let tokens = corpus.next_batch(cfg.batch, cfg.seq);
    let mut tr = NativeTrainer::new(cfg, recipe, seed);
    let losses: Vec<f32> = (0..steps).map(|_| tr.step_batch(&tokens).loss).collect();
    (tr, losses)
}

#[test]
fn loss_decreases_over_50_plus_steps_for_all_three_recipes() {
    let steps = 60;
    let mut finals = Vec::new();
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let (_, losses) = fixed_batch_run(recipe, steps, 7);
        assert!(losses.iter().all(|l| l.is_finite()), "{recipe:?}: non-finite loss");
        let tail: f32 = losses[steps - 10..].iter().sum::<f32>() / 10.0;
        assert!(
            losses[0] - tail > 1.5,
            "{recipe:?}: insufficient learning: {} -> {tail}",
            losses[0]
        );
        // windowed monotonicity: 10-step means must not rise beyond the
        // late-training wiggle (exact-stream calibration: worst observed
        // rise +0.038 across seeds — slack keeps ≥ 2× margin)
        let windows: Vec<f32> = losses
            .chunks(10)
            .map(|c| c.iter().sum::<f32>() / c.len() as f32)
            .collect();
        for k in 1..windows.len() {
            assert!(
                windows[k] <= windows[k - 1] + 0.08,
                "{recipe:?}: loss window rose: {:?}",
                windows
            );
        }
        finals.push((recipe, tail));
    }
    // Fp8Flow tracks the Bf16 oracle within tolerance (the Fig. 6 claim);
    // exact-stream calibration: gap ≈ 0.015 at this seed, ≤ 0.041 across
    // seeds — 0.10 nats on a ~3.4-nat drop keeps ≥ 2.5× margin
    let get = |r: Recipe| finals.iter().find(|(x, _)| *x == r).unwrap().1;
    let flow_gap = (get(Recipe::Fp8Flow) - get(Recipe::Bf16)).abs();
    assert!(flow_gap < 0.10, "fp8flow final-loss gap vs bf16: {flow_gap}");
}

#[test]
fn per_step_cast_audit_matches_the_train_step_graph() {
    // three steps so the audit covers steady-state requantization too
    let (tr, _) = fixed_batch_run(Recipe::Fp8Flow, 3, 3);
    let g = build_train_step(Variant::Fp8Flow);
    for m in &tr.metrics {
        // the Fig. 2 headline survives the whole training step (tiny is
        // top-1: one entry cast per direction)
        assert_eq!(m.casts_fwd, g.explicit_casts_fwd(), "step {}", m.step);
        assert_eq!(m.casts_bwd, g.explicit_casts_bwd(), "step {}", m.step);
        assert_eq!(m.casts_fwd + m.casts_bwd, 2, "step {}", m.step);
        assert_eq!(m.requants_bwd, 0, "step {}", m.step);
        // the optimizer's weight requantization adds ZERO requant events,
        // exactly as the graph's optimizer tail models
        assert_eq!(m.opt_requants, g.requant_nodes_opt());
        assert_eq!(m.opt_requants, 0, "step {}", m.step);
        assert!(m.opt_weight_quants > 0, "weights are re-cast every step");
    }
    // the Blockwise foil requantizes every step, in the backward
    let (trb, _) = fixed_batch_run(Recipe::Blockwise, 2, 3);
    for m in &trb.metrics {
        assert_eq!(m.requants_bwd, 5 * trb.cfg.n_experts * trb.cfg.top_k);
        assert_eq!(m.opt_requants, 0);
    }
}

// ---------------------------------------------------------------------------
// EP-sharded and thread-budget bit-identity of the full training step
// ---------------------------------------------------------------------------

fn run_steps(mut cfg: TrainConfig, ranks: usize, threads: usize, steps: usize, seed: u64)
    -> (Vec<u32>, NativeTrainer)
{
    cfg.ranks = ranks;
    cfg.threads = threads;
    let mut tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, seed);
    let mut corpus = Corpus::new(cfg.vocab, seed, 10);
    let losses = (0..steps)
        .map(|_| {
            let toks = corpus.next_batch(cfg.batch, cfg.seq);
            tr.step_batch(&toks).loss.to_bits()
        })
        .collect();
    (losses, tr)
}

fn assert_trainers_bitwise_eq(a: &NativeTrainer, b: &NativeTrainer, what: &str) {
    let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&a.embed), bits(&b.embed), "{what}: embed");
    assert_eq!(bits(&a.head), bits(&b.head), "{what}: head");
    assert_eq!(bits(&a.pw.raw.router), bits(&b.pw.raw.router), "{what}: router");
    for e in 0..a.pw.raw.n_experts() {
        assert_eq!(bits(&a.pw.raw.w1[e]), bits(&b.pw.raw.w1[e]), "{what}: w1[{e}]");
        assert_eq!(bits(&a.pw.raw.w3[e]), bits(&b.pw.raw.w3[e]), "{what}: w3[{e}]");
        assert_eq!(bits(&a.pw.raw.w2[e]), bits(&b.pw.raw.w2[e]), "{what}: w2[{e}]");
        assert_eq!(a.pw.w1_t[e].data, b.pw.w1_t[e].data, "{what}: w1_t[{e}] codes");
        assert_eq!(a.pw.w2_d[e].data, b.pw.w2_d[e].data, "{what}: w2_d[{e}] codes");
    }
}

#[test]
fn ep_sharded_training_step_is_bitwise_single_rank() {
    let cfg = TrainConfig::tiny();
    let (ref_losses, ref_tr) = run_steps(cfg, 1, 0, 3, 21);
    for ranks in [1usize, 2, 4] {
        let (losses, tr) = run_steps(cfg, ranks, 0, 3, 21);
        assert_eq!(losses, ref_losses, "R={ranks}: loss trajectory");
        assert_trainers_bitwise_eq(&tr, &ref_tr, &format!("R={ranks}"));
    }
}

#[test]
fn training_step_is_bitwise_invariant_across_thread_budgets() {
    let cfg = TrainConfig::tiny();
    let (ref_losses, ref_tr) = run_steps(cfg, 1, 1, 2, 22);
    for threads in [2usize, 8] {
        let (losses, tr) = run_steps(cfg, 1, threads, 2, 22);
        assert_eq!(losses, ref_losses, "threads={threads}");
        assert_trainers_bitwise_eq(&tr, &ref_tr, &format!("threads={threads}"));
    }
    // and the EP step under an explicit worker budget
    for threads in [2usize, 8] {
        let (losses, tr) = run_steps(cfg, 2, threads, 2, 22);
        assert_eq!(losses, ref_losses, "R=2 threads={threads}");
        assert_trainers_bitwise_eq(&tr, &ref_tr, &format!("R=2 threads={threads}"));
    }
}

// ---------------------------------------------------------------------------
// Convergence audit of the richer config (top-2: live gate gradient)
// ---------------------------------------------------------------------------

#[test]
fn top2_config_learns_and_audits() {
    let mut cfg = TrainConfig::small();
    // shrink for test budget; keep top-2 routing and the no-drop capacity
    cfg.vocab = 64;
    cfg.d_model = 32;
    cfg.ffn = 32;
    cfg.n_experts = 4;
    cfg.batch = 4;
    cfg.seq = 12;
    cfg.capacity = cfg.positions();
    let mut corpus = Corpus::new(cfg.vocab, 5, 10);
    let tokens = corpus.next_batch(cfg.batch, cfg.seq);
    let mut tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, 5);
    let first = tr.step_batch(&tokens).loss;
    let mut last = first;
    for _ in 0..29 {
        last = tr.step_batch(&tokens).loss;
    }
    assert!(last < first - 0.5, "top-2 run failed to learn: {first} -> {last}");
    let m = tr.metrics.last().unwrap();
    // executed audit generalizes: 1 entry cast fwd, one Q(dy) per slot bwd
    assert_eq!(m.casts_fwd, 1);
    assert_eq!(m.casts_bwd, cfg.top_k);
    assert_eq!(m.requants_bwd, 0);
    assert_eq!(m.opt_requants, 0);
}
