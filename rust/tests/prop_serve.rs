//! End-to-end serving properties: the heavy-traffic loop must be (a)
//! **deterministic** — the seeded trace, the SLO schedule, and the served
//! outputs are bitwise identical across worker budgets {1, 2, 8} — and
//! (b) **bit-faithful** — every fully served token equals one-shot
//! [`moe_forward`] over the whole trace bit for bit, across rank counts
//! {1, 2, 4}, both arrival modes, and the overlapped pipeline, with
//! capacity drops accounted **exactly** against the per-rank load report.
//!
//! Determinism holds because trace generation and batch composition are
//! pure functions of (seed, SLO) — no wall clock, no thread interaction —
//! and every kernel underneath is thread-invariant (`prop_parallel.rs`).
//! Bit-identity holds because every per-token path is batch-independent
//! and per-rank combine partials sum to the single-rank combine
//! (`moe::layer` pins that); serving only ever *removes* (token, slot)
//! pairs, and removal is exactly what the drop accounting counts.

use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::serve::{
    generate_requests, schedule, serve_trace, ArrivalMode, DropPolicy, GenConfig, ServeConfig,
    ServeEngine, SloPolicy, TokenEmbed,
};
use fp8_flow_moe::util::rng::Rng;

const THREAD_BUDGETS: [usize; 3] = [1, 2, 8];
const RANK_COUNTS: [usize; 3] = [1, 2, 4];

const D: usize = 32;
const FFN: usize = 24;
const EXPERTS: usize = 4;
const TOP_K: usize = 2;
const VOCAB: usize = 64;
const SEED: u64 = 42;

fn gen_cfg(mode: ArrivalMode) -> GenConfig {
    GenConfig { mode, vocab: VOCAB, seed: SEED, ..GenConfig::default() }
}

fn engine(
    recipe: Recipe,
    ranks: usize,
    threads: usize,
    cf: f64,
    policy: DropPolicy,
    chunks: usize,
    overlap: bool,
) -> ServeEngine {
    let mut rng = Rng::seed_from(SEED);
    let w = MoeWeights::random(D, FFN, EXPERTS, &mut rng);
    ServeEngine::new(
        PreparedWeights::new(w, recipe),
        TokenEmbed::new(VOCAB, D, SEED),
        ServeConfig {
            ranks,
            top_k: TOP_K,
            capacity_factor: cf,
            drop_policy: policy,
            threads,
            chunks,
            overlap,
        },
    )
}

#[test]
fn trace_schedule_and_outputs_deterministic_across_thread_budgets() {
    let slo = SloPolicy { max_wait_s: 0.005, max_tokens: 96 };
    for mode in [ArrivalMode::Poisson, ArrivalMode::Bursty] {
        let cfg = gen_cfg(mode);
        let reqs = generate_requests(&cfg, 96);
        // the trace and its schedule are pure functions of (seed, SLO)
        assert_eq!(reqs, generate_requests(&cfg, 96), "{mode:?}: trace must be seeded");
        assert_eq!(
            schedule(&reqs, &slo),
            schedule(&reqs, &slo),
            "{mode:?}: schedule must be deterministic"
        );
        // and the served outputs are bitwise invariant to the worker budget
        let eng = engine(Recipe::Fp8Flow, 2, 1, 0.5, DropPolicy::Capacity, 1, false);
        let reference = serve_trace(&eng, &reqs, &slo);
        for t in THREAD_BUDGETS {
            let eng = engine(Recipe::Fp8Flow, 2, t, 0.5, DropPolicy::Capacity, 1, false);
            let s = serve_trace(&eng, &reqs, &slo);
            assert_eq!(s.ticks, reference.ticks, "{mode:?} t={t}: tick count");
            assert_eq!(s.dropped_slots, reference.dropped_slots, "{mode:?} t={t}: drops");
            assert_eq!(s.fully_served, reference.fully_served, "{mode:?} t={t}: served flags");
            for (i, (a, b)) in s.y.data.iter().zip(&reference.y.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} t={t}: y[{i}]");
            }
        }
    }
}

#[test]
fn no_token_dropped_under_capacity_and_drops_reconcile_exactly() {
    let slo = SloPolicy { max_wait_s: 0.01, max_tokens: 64 };
    let reqs = generate_requests(&gen_cfg(ArrivalMode::Bursty), 64);
    let total: usize = reqs.iter().map(|r| r.len()).sum();
    for ranks in RANK_COUNTS {
        // DropPolicy::None raises capacity to the batch bound: zero drops
        let s = serve_trace(
            &engine(Recipe::Fp8Flow, ranks, 1, 0.25, DropPolicy::None, 1, false),
            &reqs,
            &slo,
        );
        assert_eq!(s.dropped_slots, 0, "R={ranks}: nodrop policy dropped");
        assert_eq!(s.served_tokens, s.total_tokens, "R={ranks}: nodrop degraded");
        assert_eq!(
            s.rank_rows.iter().sum::<usize>(),
            total * TOP_K,
            "R={ranks}: nodrop rank load must carry every (token, slot) pair"
        );
        // under a starving capacity factor the ledger still balances:
        // Σ_rank dispatched rows + dropped slots = tokens · top_k
        let s = serve_trace(
            &engine(Recipe::Fp8Flow, ranks, 1, 0.25, DropPolicy::Capacity, 1, false),
            &reqs,
            &slo,
        );
        assert_eq!(
            s.rank_rows.iter().sum::<usize>() + s.dropped_slots,
            total * TOP_K,
            "R={ranks}: drop ledger must reconcile with the per-rank load report"
        );
        assert!(s.dropped_slots > 0, "R={ranks}: cf=0.25 must drop by pigeonhole");
        assert_eq!(s.served_tokens + s.degraded_tokens, s.total_tokens, "R={ranks}");
    }
}

#[test]
fn served_rows_bitwise_equal_one_shot_moe_forward() {
    // the tentpole contract: micro-batched serving == one-shot forward on
    // every fully served token, modulo dropped tokens (accounted above) —
    // across rank counts, arrival modes, recipes, and both schedules
    // (serialized stage loop, and the PR 7 overlap pipeline)
    let slo = SloPolicy { max_wait_s: 0.004, max_tokens: 48 };
    for mode in [ArrivalMode::Poisson, ArrivalMode::Bursty] {
        let reqs = generate_requests(&gen_cfg(mode), 48);
        let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            // one-shot reference: capacity = token count → nothing drops
            let eng0 = engine(recipe, 1, 1, 1.0, DropPolicy::None, 1, false);
            let x_all = eng0.embed.embed(&ids);
            let one = moe_forward(&x_all, &eng0.weights, TOP_K, x_all.rows);
            for ranks in RANK_COUNTS {
                for (chunks, overlap) in [(1usize, false), (2, true)] {
                    let s = serve_trace(
                        &engine(recipe, ranks, 1, 0.5, DropPolicy::Capacity, chunks, overlap),
                        &reqs,
                        &slo,
                    );
                    let tag = format!("{recipe:?} {mode:?} R={ranks} C={chunks} ov={overlap}");
                    assert!(s.served_tokens > 0, "{tag}: nothing served");
                    for (tt, &ok) in s.fully_served.iter().enumerate() {
                        if !ok {
                            continue;
                        }
                        for j in 0..D {
                            assert_eq!(
                                s.y.data[tt * D + j].to_bits(),
                                one.y.data[tt * D + j].to_bits(),
                                "{tag}: token {tt} col {j}"
                            );
                        }
                    }
                }
            }
        }
    }
}
