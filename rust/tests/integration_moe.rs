//! Integration tests across the native MoE substrate: router → permute →
//! grouped FP8 GEMM → SwiGLU → combine, plus FP8/BF16 recipe coherence.

use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::direct_transpose;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::gemm::fp8_matmul;
use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::moe::permute::{permute_pad_plan, unpermute_unpad};
use fp8_flow_moe::moe::router::route;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

#[test]
fn full_layer_pipeline_is_finite_and_reasonable() {
    let mut rng = Rng::seed_from(100);
    let (t, d, h, e) = (256, 128, 256, 4);
    let x = Mat::randn(t, d, 0.7, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let out = moe_forward(&x, &pw, 2, 128);
        assert!(out.y.data.iter().all(|v| v.is_finite()), "{recipe:?}");
        assert!(out.y.frobenius() > 0.0);
        assert!(out.aux_loss >= 0.9, "{recipe:?} aux {}", out.aux_loss);
    }
}

#[test]
fn wgrad_via_direct_transpose_matches_explicit_colwise_gemm() {
    // The dataflow's key step: Wgrad consumes direct_T(Q_row(x)). Verify
    // the GEMM result equals using an explicitly column-quantized operand,
    // up to the bounded-underflow tolerance.
    let mut rng = Rng::seed_from(101);
    let x = Mat::rand_log_uniform(256, 256, -4.0, 4.0, &mut rng); // activations
    let dy = Mat::randn(256, 128, 1.0, &mut rng); // upstream grads
    let q_x = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    let xt = direct_transpose(&q_x); // [256(k), 256(m)] = Q(xᵀ)
    let q_dy_t = quantize_rowwise(&dy.transpose(), Fp8Format::E4M3, ScaleMode::Po2);
    // dw = xᵀ @ dy = fp8_matmul(xt, Q(dyᵀ))
    let dw = fp8_matmul(&xt, &q_dy_t);
    // reference: f32 GEMM on dequantized one-rounding values
    let expect = q_x.dequantize().transpose().matmul(&dy);
    let rel = dw.rel_err(&expect);
    assert!(rel < 0.08, "rel={rel}");
}

#[test]
fn expert_locality_of_permute() {
    // tokens routed to expert e land contiguously in e's capacity segment
    let mut rng = Rng::seed_from(102);
    let x = Mat::randn(128, 64, 1.0, &mut rng);
    let wr = Mat::randn(64, 4, 1.0, &mut rng);
    let r = route(&x, &wr, 1);
    let expert_of: Vec<usize> = r.experts.iter().map(|e| e[0]).collect();
    let plan = permute_pad_plan(&expert_of, 4, 64);
    for (d, &src) in plan.iter().enumerate() {
        if src >= 0 {
            assert_eq!(expert_of[src as usize], d / 64);
        }
    }
}

#[test]
fn combine_weights_by_gates() {
    // with top_k=1 and capacity ≥ tokens, unpermute(permute(x)) == x and
    // the layer output equals gate * expert_ffn(x) tokenwise
    let mut rng = Rng::seed_from(103);
    let (t, d) = (64, 128);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, 128, 2, &mut rng);
    let pw = PreparedWeights::new(w.clone(), Recipe::Bf16);
    let out = moe_forward(&x, &pw, 1, 64);
    let r = route(&x, &w.router, 1);
    // recompute token 0 by hand
    let e0 = r.experts[0][0];
    let x0 = Mat::from_vec(1, d, x.row(0).to_vec());
    let gate = x0.matmul(&w.w1[e0]);
    let up = x0.matmul(&w.w3[e0]);
    let act = fp8_flow_moe::moe::swiglu::swiglu(&gate, &up);
    let y0 = act.matmul(&w.w2[e0]);
    for j in 0..d {
        let want = r.gates[0][0] * y0.data[j];
        let got = out.y.at(0, j);
        assert!((want - got).abs() < 1e-4, "j={j}: {want} vs {got}");
    }
}

#[test]
fn scatter_add_semantics_for_topk() {
    // a token appearing in two plans receives the sum of both expert outs
    let y1 = Mat::from_fn(4, 2, |i, _| i as f32);
    let plan = vec![2i64, -1, 0, 1];
    let back = unpermute_unpad(&y1, &plan, 3);
    assert_eq!(back.at(2, 0), 0.0); // dest row 0 ← src plan[0]=2? no: plan[d]=src token
    assert_eq!(back.at(0, 0), 2.0); // token 0 came from row 2
    assert_eq!(back.at(1, 0), 3.0);
}

#[test]
fn fp8flow_more_accurate_than_blockwise_on_wide_dynamic_range() {
    // po2 + direct transpose should not be WORSE than float-scale
    // blockwise on wide-dynamic-range inputs (the adversarial case for
    // quantization); both stay within tolerance of bf16.
    let mut rng = Rng::seed_from(104);
    let (t, d, h, e) = (256, 128, 128, 2);
    let x = Mat::rand_log_uniform(t, d, -5.0, 3.0, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let bf16 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 256);
    let flow = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 256);
    let block = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 256);
    let rel_flow = flow.y.rel_err(&bf16.y);
    let rel_block = block.y.rel_err(&bf16.y);
    assert!(rel_flow < 0.25 && rel_block < 0.25);
    assert!(rel_flow < rel_block * 2.0, "flow {rel_flow} vs block {rel_block}");
}
