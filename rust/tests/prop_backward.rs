//! Gradient-checking suite for the executed backward pass (ISSUE 3's
//! first-class cargo): central-difference gradchecks of `swiglu_bwd`, the
//! FP8 GEMM backward, and the full layer backward, plus the cast-count
//! audit that ties the executed Fp8Flow backward to the Fig. 2 graphs —
//! zero re-quantizations of already-FP8 tensors, wgrad via the
//! scaling-aware transpose.
//!
//! Gradcheck conventions: the loss is `Σ y ⊙ dy` accumulated in f64.
//! The expert-path checks freeze the whole routing (the Fig. 2 surrogate,
//! `moe_backward`); the router-path checks freeze only the top-k
//! *selection* (`route_with_selection`) so the gates and the aux loss
//! stay live, and pair with `moe_backward_with_router`.

use fp8_flow_moe::dataflow::{build, Variant};
use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::direct_transpose;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::{
    forward_stash, forward_stash_with_routing, moe_backward, moe_backward_with_router,
};
use fp8_flow_moe::moe::gemm::fp8_matmul;
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::moe::router::{route, route_with_selection};
use fp8_flow_moe::moe::swiglu::{swiglu, swiglu_bwd};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::{gradcheck, probe_indices};
use fp8_flow_moe::util::rng::Rng;

// ---------------------------------------------------------------------------
// Kernel-level gradchecks
// ---------------------------------------------------------------------------

#[test]
fn swiglu_bwd_gradchecks_against_finite_differences() {
    let mut rng = Rng::seed_from(1);
    let (m, n) = (6, 24);
    let gate = Mat::randn(m, n, 1.0, &mut rng);
    let up = Mat::randn(m, n, 1.0, &mut rng);
    let dy = Mat::randn(m, n, 1.0, &mut rng);
    let (dg, du) = swiglu_bwd(&gate, &up, &dy);
    let probes = probe_indices(m * n, 12);
    gradcheck(
        "swiglu d_gate",
        |xs| swiglu(&Mat::from_vec(m, n, xs.to_vec()), &up).data,
        &gate.data,
        &dy.data,
        &dg.data,
        1e-3,
        2e-2,
        &probes,
    );
    gradcheck(
        "swiglu d_up",
        |xs| swiglu(&gate, &Mat::from_vec(m, n, xs.to_vec())).data,
        &up.data,
        &dy.data,
        &du.data,
        1e-3,
        2e-2,
        &probes,
    );
}

#[test]
fn fp8_matmul_bwd_tracks_f32_gradients_within_quant_tolerance() {
    // y = x · wᵀ. The f32 gradients (dx = dy·w, dw = dyᵀ·x) gradcheck
    // exactly (the map is linear); the FP8 backward — dgrad through the
    // dgrad-layout weights, wgrad through direct-transposed operands —
    // must track them within quantization noise.
    let mut rng = Rng::seed_from(2);
    let (m, k, n) = (16, 128, 12);
    let x = Mat::randn(m, k, 1.0, &mut rng);
    let w = Mat::randn(n, k, 1.0, &mut rng); // Wᵀ layout, like the fwd GEMM's B
    let dy = Mat::randn(m, n, 1.0, &mut rng);

    // f32 reference gradients
    let dx_ref = dy.matmul(&w); // [m, k]
    let dw_ref = dy.transpose().matmul(&x); // [n, k]
    gradcheck(
        "matmul dx (f32)",
        |xs| Mat::from_vec(m, k, xs.to_vec()).matmul(&w.transpose()).data,
        &x.data,
        &dy.data,
        &dx_ref.data,
        1e-2,
        2e-2,
        &probe_indices(m * k, 10),
    );
    gradcheck(
        "matmul dw (f32)",
        |ws| x.matmul(&Mat::from_vec(n, k, ws.to_vec()).transpose()).data,
        &w.data,
        &dy.data,
        &dw_ref.data,
        1e-2,
        2e-2,
        &probe_indices(n * k, 10),
    );

    // FP8 backward of the same map
    let qx = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    let qw = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
    let qdy = quantize_rowwise(&dy, Fp8Format::E4M3, ScaleMode::Po2);
    // dgrad: dx = dy · w = fp8_matmul(Q(dy), direct_T(Q(w)))
    let dx8 = fp8_matmul(&qdy, &direct_transpose(&qw));
    // wgrad: dw = dyᵀ · x = fp8_matmul(direct_T(Q(dy)), direct_T(Q(x)))
    let dw8 = fp8_matmul(&direct_transpose(&qdy), &direct_transpose(&qx));
    let rel_dx = dx8.rel_err(&dx_ref);
    let rel_dw = dw8.rel_err(&dw_ref);
    assert!(rel_dx > 0.0 && rel_dx < 0.1, "dgrad rel={rel_dx}");
    assert!(rel_dw > 0.0 && rel_dw < 0.1, "wgrad rel={rel_dw}");
}

// ---------------------------------------------------------------------------
// Layer-level gradchecks (frozen routing)
// ---------------------------------------------------------------------------

#[test]
fn layer_backward_gradchecks_bf16() {
    let mut rng = Rng::seed_from(3);
    let (t, d, h, e, cap, top_k) = (6, 12, 10, 2, 6, 2);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    let routing = route(&x, &w.router, top_k);
    let pw = PreparedWeights::new(w.clone(), Recipe::Bf16);
    let stash = forward_stash_with_routing(&x, &pw, &routing, cap);
    let grads = moe_backward(&stash, &pw, &dy);

    // dgrad: layer output as a function of x under the frozen routing
    gradcheck(
        "layer dx (bf16)",
        |xs| {
            let xm = Mat::from_vec(t, d, xs.to_vec());
            forward_stash_with_routing(&xm, &pw, &routing, cap).y.data
        },
        &x.data,
        &dy.data,
        &grads.dx.data,
        1e-2,
        3e-2,
        &probe_indices(t * d, 10),
    );

    // wgrad: every weight tensor of every expert, a few probes each
    for ex in 0..e {
        let cases: [(&str, &Mat, &Mat, fn(&mut MoeWeights, usize, Mat)); 3] = [
            ("dw1", &w.w1[ex], &grads.dw1[ex], |wm, ex, m| wm.w1[ex] = m),
            ("dw3", &w.w3[ex], &grads.dw3[ex], |wm, ex, m| wm.w3[ex] = m),
            ("dw2", &w.w2[ex], &grads.dw2[ex], |wm, ex, m| wm.w2[ex] = m),
        ];
        for (name, wt, analytic, set) in cases {
            let (rows, cols) = (wt.rows, wt.cols);
            gradcheck(
                &format!("layer {name}[{ex}] (bf16)"),
                |ws| {
                    let mut wc = w.clone();
                    set(&mut wc, ex, Mat::from_vec(rows, cols, ws.to_vec()));
                    let pwc = PreparedWeights::new(wc, Recipe::Bf16);
                    forward_stash_with_routing(&x, &pwc, &routing, cap).y.data
                },
                &wt.data,
                &dy.data,
                &analytic.data,
                1e-2,
                3e-2,
                &probe_indices(rows * cols, 6),
            );
        }
    }
}

#[test]
fn fp8_recipes_backward_tracks_bf16_reference() {
    let mut rng = Rng::seed_from(4);
    let (t, d, h, e, cap, top_k) = (64, 64, 48, 4, 32, 2);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);

    let run = |recipe: Recipe| {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, cap);
        moe_backward(&stash, &pw, &dy)
    };
    let reference = run(Recipe::Bf16);
    for recipe in [Recipe::Fp8Flow, Recipe::Blockwise] {
        let g = run(recipe);
        let rel_dx = g.dx.rel_err(&reference.dx);
        assert!(rel_dx > 0.0 && rel_dx < 0.35, "{recipe:?} dx rel={rel_dx}");
        for ex in 0..e {
            for (name, got, want) in [
                ("dw1", &g.dw1[ex], &reference.dw1[ex]),
                ("dw3", &g.dw3[ex], &reference.dw3[ex]),
                ("dw2", &g.dw2[ex], &reference.dw2[ex]),
            ] {
                let rel = got.rel_err(want);
                assert!(rel < 0.35, "{recipe:?} {name}[{ex}] rel={rel}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Router backward (live gates + aux under a frozen selection)
// ---------------------------------------------------------------------------

#[test]
fn layer_backward_with_router_gradchecks_bf16() {
    // the full-path surrogate: selection frozen, gates + aux live;
    // flat output = y ++ [aux], dy weights = dy ++ [λ]
    let mut rng = Rng::seed_from(8);
    let (t, d, h, e, cap, top_k) = (6, 12, 10, 3, 6, 2);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    let lam = 0.5f32;
    let routing = route(&x, &w.router, top_k);
    let sel = routing.experts.clone();
    let pw = PreparedWeights::new(w.clone(), Recipe::Bf16);
    let stash = forward_stash_with_routing(&x, &pw, &routing, cap);
    let grads = moe_backward_with_router(&stash, &pw, &dy, lam);
    let d_router = grads.d_router.as_ref().expect("router-aware path sets d_router");

    let mut dyv = dy.data.clone();
    dyv.push(lam);
    let surrogate = |xm: &Mat, wrm: &Mat| -> Vec<f32> {
        let r = route_with_selection(xm, wrm, &sel);
        let st = forward_stash_with_routing(xm, &pw, &r, cap);
        let mut out = st.y.data;
        out.push(st.aux_loss);
        out
    };
    gradcheck(
        "layer dx incl. router (bf16)",
        |xs| surrogate(&Mat::from_vec(t, d, xs.to_vec()), &w.router),
        &x.data,
        &dyv,
        &grads.dx.data,
        1e-2,
        3e-2,
        &probe_indices(t * d, 10),
    );
    gradcheck(
        "layer d_router (bf16)",
        |ws| surrogate(&x, &Mat::from_vec(d, e, ws.to_vec())),
        &w.router.data,
        &dyv,
        &d_router.data,
        1e-2,
        3e-2,
        &probe_indices(d * e, 12),
    );
}

#[test]
fn router_gradient_tracks_bf16_across_fp8_recipes() {
    // the gate gradients read the recipe's quantized expert outputs
    // (`back`), so FP8 d_router deviates only by quantization noise
    let mut rng = Rng::seed_from(9);
    let (t, d, h, e, cap, top_k) = (64, 64, 48, 4, 64, 2);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    let run = |recipe: Recipe| {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, cap);
        moe_backward_with_router(&stash, &pw, &dy, 0.01)
    };
    let reference = run(Recipe::Bf16);
    let ref_router = reference.d_router.as_ref().unwrap();
    assert!(ref_router.frobenius() > 0.0, "top-2 gate path must drive the router");
    for recipe in [Recipe::Fp8Flow, Recipe::Blockwise] {
        let g = run(recipe);
        let rel = g.d_router.as_ref().unwrap().rel_err(ref_router);
        assert!(rel > 0.0 && rel < 0.35, "{recipe:?} d_router rel={rel}");
        if recipe == Recipe::Fp8Flow {
            // the (dense f32) router path adds nothing to the cast audit
            assert_eq!(g.stats.casts, top_k, "unchanged from the frozen-path audit");
            assert_eq!(g.stats.requants, 0);
        }
    }
}

#[test]
fn router_aware_dx_is_frozen_dx_plus_router_contribution() {
    let mut rng = Rng::seed_from(10);
    let (t, d, h, e, cap, top_k) = (32, 32, 24, 4, 32, 2);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
    let stash = forward_stash(&x, &pw, top_k, cap);
    let frozen = moe_backward(&stash, &pw, &dy);
    let full = moe_backward_with_router(&stash, &pw, &dy, 0.01);
    assert!(frozen.d_router.is_none());
    // expert wgrads are untouched by the router path
    for ex in 0..e {
        for (a, b) in frozen.dw1[ex].data.iter().zip(&full.dw1[ex].data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    // dx differs exactly by the (nonzero) router contribution
    let delta: f32 = frozen
        .dx
        .data
        .iter()
        .zip(&full.dx.data)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(delta > 0.0, "router path must contribute to dx under top-2");
}

// ---------------------------------------------------------------------------
// Cast-count audit: executed backward vs the Fig. 2 bwd graphs
// ---------------------------------------------------------------------------

#[test]
fn fp8flow_backward_casting_free_audited_against_graph() {
    let mut rng = Rng::seed_from(5);
    let (t, d, h, e, cap) = (48, 64, 48, 3, 32);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);

    // Fp8Flow: the acceptance contract — ZERO requantizations of
    // already-FP8 tensors, and exactly the graph's explicit casts
    let g = build(Variant::Fp8Flow);
    assert!(g.casting_free_wgrad());
    let pw = PreparedWeights::new(w.clone(), Recipe::Fp8Flow);
    let stash = forward_stash(&x, &pw, 1, cap);
    let grads = moe_backward(&stash, &pw, &dy);
    assert_eq!(grads.stats.requants, 0, "Fp8Flow bwd must not requantize FP8 data");
    assert_eq!(grads.stats.casts, g.explicit_casts_bwd(), "Fp8Flow bwd cast parity");
    // fwd + bwd together reproduce the paper's headline "2"
    assert_eq!(stash.cast_ops + grads.stats.casts, g.explicit_casts());
    assert_eq!(g.explicit_casts(), 2);

    // Blockwise foil: requantization executes (per-expert granularity;
    // the graph models the per-layer kernel schema — 2 naive-T nodes)
    let gb = build(Variant::TeBlockwise);
    assert!(!gb.casting_free_wgrad());
    let pwb = PreparedWeights::new(w, Recipe::Blockwise);
    let stashb = forward_stash(&x, &pwb, 1, cap);
    let gradsb = moe_backward(&stashb, &pwb, &dy);
    assert!(gradsb.stats.requants > 0);
    assert_eq!(gradsb.stats.requants, 5 * e);
    assert_eq!(gradsb.stats.casts, 3 * e);
    // ordering: the casting-free recipe executes strictly fewer casts
    assert!(stash.cast_ops + grads.stats.casts < stashb.cast_ops + gradsb.stats.casts);
}

#[test]
fn fp8flow_bwd_cast_count_scales_only_with_slots() {
    // one Q(dy) per top-k slot, independent of expert count — the
    // dataflow stays casting-free as the layer widens
    let mut rng = Rng::seed_from(6);
    let (t, d, h) = (48, 32, 24);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    for e in [2usize, 4, 8] {
        let w = MoeWeights::random(d, h, e, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        for top_k in [1usize, 2] {
            let stash = forward_stash(&x, &pw, top_k, 16);
            let grads = moe_backward(&stash, &pw, &dy);
            assert_eq!(grads.stats.casts, top_k, "E={e} top_k={top_k}");
            assert_eq!(grads.stats.requants, 0, "E={e} top_k={top_k}");
        }
    }
}

// ---------------------------------------------------------------------------
// Degenerate routing
// ---------------------------------------------------------------------------

#[test]
fn starved_expert_gets_exactly_zero_gradients() {
    // expert E-1 receives no tokens (constant feature + router bias, the
    // prop_ep_shard construction): its weight gradients must be exactly
    // zero and the backward must run through the all-padding slab
    let mut rng = Rng::seed_from(7);
    let (t, d, h, e, cap) = (40, 32, 24, 4, 16);
    let mut x = Mat::randn(t, d, 0.5, &mut rng);
    let mut w = MoeWeights::random(d, h, e, &mut rng);
    for tt in 0..t {
        *x.at_mut(tt, d - 1) = 10.0;
    }
    for j in 0..e {
        *w.router.at_mut(d - 1, j) = if j == e - 1 { 0.0 } else { 10.0 };
    }
    let routing = route(&x, &w.router, 2);
    let hits = routing
        .experts
        .iter()
        .flat_map(|s| s.iter())
        .filter(|&&ex| ex == e - 1)
        .count();
    assert_eq!(hits, 0, "construction must starve expert {}", e - 1);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, 2, cap);
        let grads = moe_backward(&stash, &pw, &dy);
        for (name, m) in [
            ("dw1", &grads.dw1[e - 1]),
            ("dw3", &grads.dw3[e - 1]),
            ("dw2", &grads.dw2[e - 1]),
        ] {
            assert!(
                m.data.iter().all(|&v| v == 0.0),
                "{recipe:?}: starved expert {name} must be zero"
            );
        }
        // a served expert does get gradient
        assert!(grads.dw1[0].frobenius() > 0.0, "{recipe:?}");
        assert!(grads.dx.data.iter().all(|v| v.is_finite()), "{recipe:?}");
    }
}
