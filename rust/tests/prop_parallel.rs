//! Property tests for the tile-parallel execution layer: every parallel
//! kernel must be **bit-identical** to its serial form across worker
//! counts {1, 2, 8} and ragged shapes.
//!
//! This is the exec layer's central contract: the static partitioner keeps
//! each worker's iteration order identical to the serial loop's, and FP8
//! tile accumulation order is fixed per output element, so thread count
//! must never change a single bit of payload, scale, or accumulator.

use fp8_flow_moe::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use fp8_flow_moe::fp8::transpose::{
    direct_transpose_with_threads, grouped_direct_transpose,
};
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward_with_threads};
use fp8_flow_moe::moe::gemm::fp8_matmul_with_threads;
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::moe::permute::{
    permute_pad_fp8_with_threads, permute_pad_plan, permute_pad_with_threads,
    unpermute_unpad_with_threads,
};
use fp8_flow_moe::moe::swiglu::{
    swiglu_bwd_quant_with_threads, swiglu_bwd_with_threads, swiglu_quant_with_threads,
};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::{assert_bits_eq as assert_f32_bits_eq, assert_mat_bits_eq, props};
use fp8_flow_moe::util::rng::Rng;

const THREAD_COUNTS: [usize; 2] = [2, 8];

#[test]
fn prop_fp8_matmul_parallel_bit_exact() {
    props("fp8_matmul parallel == serial", 24, |g| {
        let m = g.usize_in(1, 220); // ragged row panels
        let k = g.usize_in(1, 300); // ragged contraction (tail tile)
        let n = g.usize_in(1, 48);
        let mut rng = Rng::seed_from(g.seed ^ 0x9E41);
        let x = Mat::rand_log_uniform(m, k, -4.0, 4.0, &mut rng);
        let w = Mat::randn(n, k, 1.0, &mut rng);
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = fp8_matmul_with_threads(&qa, &qb, 1);
        for t in THREAD_COUNTS {
            let par = fp8_matmul_with_threads(&qa, &qb, t);
            assert_f32_bits_eq(&par.data, &serial.data, &format!("matmul t={t} m={m} k={k} n={n}"));
        }
    });
}

#[test]
fn prop_direct_transpose_parallel_bit_exact() {
    props("direct_transpose parallel == serial", 24, |g| {
        let m = g.usize_in(1, 300);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0xD17E);
        let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = direct_transpose_with_threads(&q, 1);
        for t in THREAD_COUNTS {
            let par = direct_transpose_with_threads(&q, t);
            assert_eq!(par.data, serial.data, "payload t={t} {m}x{n}");
            assert_f32_bits_eq(&par.scales, &serial.scales, &format!("scales t={t} {m}x{n}"));
            assert_eq!(par.sexp, serial.sexp, "sexp t={t} {m}x{n}");
        }
    });
}

#[test]
fn prop_swiglu_quant_parallel_bit_exact() {
    props("swiglu_quant parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x5157);
        let gate = Mat::randn(m, n, 2.0, &mut rng);
        let up = Mat::randn(m, n, 2.0, &mut rng);
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let serial = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, mode, 1);
            for t in THREAD_COUNTS {
                let par = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, mode, t);
                assert_eq!(par.data, serial.data, "payload {mode:?} t={t} {m}x{n}");
                assert_f32_bits_eq(
                    &par.scales,
                    &serial.scales,
                    &format!("scales {mode:?} t={t} {m}x{n}"),
                );
                assert_eq!(par.sexp, serial.sexp, "sexp {mode:?} t={t}");
            }
        }
    });
}

#[test]
fn prop_quantize_rowwise_parallel_bit_exact() {
    props("quantize_rowwise parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x0A7B);
        let x = Mat::rand_log_uniform(m, n, -8.0, 8.0, &mut rng);
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let serial = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, 1);
            for t in THREAD_COUNTS {
                let par = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, t);
                assert_eq!(par.data, serial.data, "payload {mode:?} t={t} {m}x{n}");
                assert_f32_bits_eq(
                    &par.scales,
                    &serial.scales,
                    &format!("scales {mode:?} t={t} {m}x{n}"),
                );
                assert_eq!(par.sexp, serial.sexp, "sexp {mode:?} t={t}");
            }
        }
    });
}

#[test]
fn prop_swiglu_bwd_parallel_bit_exact() {
    props("swiglu_bwd parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x5B3D);
        let gate = Mat::randn(m, n, 2.0, &mut rng);
        let up = Mat::randn(m, n, 2.0, &mut rng);
        let dy = Mat::randn(m, n, 1.0, &mut rng);
        let (sg, su) = swiglu_bwd_with_threads(&gate, &up, &dy, 1);
        for t in THREAD_COUNTS {
            let (pg, pu) = swiglu_bwd_with_threads(&gate, &up, &dy, t);
            assert_f32_bits_eq(&pg.data, &sg.data, &format!("d_gate t={t} {m}x{n}"));
            assert_f32_bits_eq(&pu.data, &su.data, &format!("d_up t={t} {m}x{n}"));
        }
    });
}

#[test]
fn prop_swiglu_bwd_quant_parallel_bit_exact() {
    props("swiglu_bwd_quant parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0xF5BD);
        let gate = Mat::randn(m, n, 2.0, &mut rng);
        let up = Mat::randn(m, n, 2.0, &mut rng);
        let dy = Mat::randn(m, n, 1.0, &mut rng);
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let (sg, su) =
                swiglu_bwd_quant_with_threads(&gate, &up, &dy, Fp8Format::E4M3, mode, 1);
            for t in THREAD_COUNTS {
                let (pg, pu) =
                    swiglu_bwd_quant_with_threads(&gate, &up, &dy, Fp8Format::E4M3, mode, t);
                assert_eq!(pg.data, sg.data, "d_gate payload {mode:?} t={t}");
                assert_f32_bits_eq(&pg.scales, &sg.scales, &format!("d_gate scales {mode:?} t={t}"));
                assert_eq!(pg.sexp, sg.sexp, "d_gate sexp {mode:?} t={t}");
                assert_eq!(pu.data, su.data, "d_up payload {mode:?} t={t}");
                assert_f32_bits_eq(&pu.scales, &su.scales, &format!("d_up scales {mode:?} t={t}"));
                assert_eq!(pu.sexp, su.sexp, "d_up sexp {mode:?} t={t}");
            }
        }
    });
}

#[test]
fn prop_grouped_direct_transpose_parallel_bit_exact() {
    props("grouped_direct_transpose parallel == serial", 24, |g| {
        let groups = g.usize_in(1, 8);
        let cap = g.usize_in(1, 64);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x6D17);
        let x = Mat::rand_log_uniform(groups * cap, n, -5.0, 5.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = grouped_direct_transpose(&q, groups, 1);
        for t in THREAD_COUNTS {
            let par = grouped_direct_transpose(&q, groups, t);
            assert_eq!(par.len(), serial.len(), "t={t}");
            for (e, (a, b)) in par.iter().zip(&serial).enumerate() {
                assert_eq!(a.data, b.data, "payload g={e} t={t}");
                assert_f32_bits_eq(&a.scales, &b.scales, &format!("scales g={e} t={t}"));
                assert_eq!(a.sexp, b.sexp, "sexp g={e} t={t}");
            }
        }
    });
}

#[test]
fn prop_moe_backward_parallel_bit_exact() {
    // the full backward — combine-bwd, per-expert dgrad/wgrad (GEMMs +
    // scaling-aware transposes), dispatch-bwd scatter — is bit-identical
    // across worker counts for every recipe, ragged shapes included
    props("moe_backward parallel == serial", 6, |g| {
        let t = g.usize_in(3, 64);
        let d = g.usize_in(8, 96);
        let h = g.usize_in(8, 64);
        let e = g.usize_in(1, 6);
        let cap = g.usize_in(1, t);
        let top_k = g.usize_in(1, e.min(2));
        let mut rng = Rng::seed_from(g.seed ^ 0xBD2);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let dy = Mat::randn(t, d, 1.0, &mut rng);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let stash = forward_stash(&x, &pw, top_k, cap);
            let serial = moe_backward_with_threads(&stash, &pw, &dy, 1);
            for threads in THREAD_COUNTS {
                let par = moe_backward_with_threads(&stash, &pw, &dy, threads);
                let tag = format!("{recipe:?} t={threads} E={e} cap={cap}");
                assert_mat_bits_eq(&par.dx, &serial.dx, &format!("{tag} dx"));
                for ex in 0..e {
                    assert_mat_bits_eq(&par.dw1[ex], &serial.dw1[ex], &format!("{tag} dw1[{ex}]"));
                    assert_mat_bits_eq(&par.dw3[ex], &serial.dw3[ex], &format!("{tag} dw3[{ex}]"));
                    assert_mat_bits_eq(&par.dw2[ex], &serial.dw2[ex], &format!("{tag} dw2[{ex}]"));
                }
                assert_eq!(par.stats, serial.stats, "{tag} audit");
            }
        }
    });
}

#[test]
fn zero_row_edges_are_defined_across_thread_budgets() {
    // the serving loop can flush a micro-batch with zero tokens, so the
    // M = 0 edge of the quantizer and the GEMM must return empty results
    // — never panic, never a bogus shape — for every worker budget
    let (k, n) = (96usize, 24usize);
    let mut rng = Rng::seed_from(0xE0);
    let w = Mat::randn(n, k, 1.0, &mut rng);
    let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
    let x0 = Mat::zeros(0, k);
    for t in [1usize, 2, 8] {
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let qa = quantize_rowwise_with_threads(&x0, Fp8Format::E4M3, mode, t);
            assert_eq!((qa.rows, qa.cols), (0, k), "quantize {mode:?} t={t}");
            assert!(qa.data.is_empty(), "quantize payload {mode:?} t={t}");
            assert!(qa.scales.is_empty(), "quantize scales {mode:?} t={t}");
        }
        let qa = quantize_rowwise(&x0, Fp8Format::E4M3, ScaleMode::Po2);
        let y = fp8_matmul_with_threads(&qa, &qb, t);
        assert_eq!((y.rows, y.cols), (0, n), "matmul t={t}");
        assert!(y.data.is_empty(), "matmul payload t={t}");
    }
}

#[test]
fn prop_permute_family_parallel_bit_exact() {
    props("permute/unpermute parallel == serial", 24, |g| {
        let tokens = g.usize_in(1, 300);
        let h = g.usize_in(1, 160);
        let experts = g.usize_in(1, 8);
        let cap = g.usize_in(1, tokens.max(2));
        let mut rng = Rng::seed_from(g.seed ^ 0xFACE);
        let x = Mat::randn(tokens, h, 1.0, &mut rng);
        let expert_of: Vec<usize> = (0..tokens).map(|_| rng.below(experts)).collect();
        let plan = permute_pad_plan(&expert_of, experts, cap);

        let serial = permute_pad_with_threads(&x, &plan, 1);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let serial_q = permute_pad_fp8_with_threads(&q, &plan, 1);
        let back_serial = unpermute_unpad_with_threads(&serial, &plan, tokens, 1);
        for t in THREAD_COUNTS {
            let par = permute_pad_with_threads(&x, &plan, t);
            assert_f32_bits_eq(&par.data, &serial.data, &format!("permute_pad t={t}"));

            let par_q = permute_pad_fp8_with_threads(&q, &plan, t);
            assert_eq!(par_q.data, serial_q.data, "permute_pad_fp8 payload t={t}");
            assert_f32_bits_eq(
                &par_q.scales,
                &serial_q.scales,
                &format!("permute_pad_fp8 scales t={t}"),
            );
            assert_eq!(par_q.sexp, serial_q.sexp, "permute_pad_fp8 sexp t={t}");

            let back = unpermute_unpad_with_threads(&serial, &plan, tokens, t);
            assert_f32_bits_eq(&back.data, &back_serial.data, &format!("unpermute t={t}"));
        }
    });
}
