//! Property tests for the tile-parallel execution layer: every parallel
//! kernel must be **bit-identical** to its serial form across worker
//! counts {1, 2, 8} and ragged shapes.
//!
//! This is the exec layer's central contract: the static partitioner keeps
//! each worker's iteration order identical to the serial loop's, and FP8
//! tile accumulation order is fixed per output element, so thread count
//! must never change a single bit of payload, scale, or accumulator.

use fp8_flow_moe::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use fp8_flow_moe::fp8::transpose::direct_transpose_with_threads;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::gemm::fp8_matmul_with_threads;
use fp8_flow_moe::moe::permute::{
    permute_pad_fp8_with_threads, permute_pad_plan, permute_pad_with_threads,
    unpermute_unpad_with_threads,
};
use fp8_flow_moe::moe::swiglu::swiglu_quant_with_threads;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::{assert_bits_eq as assert_f32_bits_eq, props};
use fp8_flow_moe::util::rng::Rng;

const THREAD_COUNTS: [usize; 2] = [2, 8];

#[test]
fn prop_fp8_matmul_parallel_bit_exact() {
    props("fp8_matmul parallel == serial", 24, |g| {
        let m = g.usize_in(1, 220); // ragged row panels
        let k = g.usize_in(1, 300); // ragged contraction (tail tile)
        let n = g.usize_in(1, 48);
        let mut rng = Rng::seed_from(g.seed ^ 0x9E41);
        let x = Mat::rand_log_uniform(m, k, -4.0, 4.0, &mut rng);
        let w = Mat::randn(n, k, 1.0, &mut rng);
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = fp8_matmul_with_threads(&qa, &qb, 1);
        for t in THREAD_COUNTS {
            let par = fp8_matmul_with_threads(&qa, &qb, t);
            assert_f32_bits_eq(&par.data, &serial.data, &format!("matmul t={t} m={m} k={k} n={n}"));
        }
    });
}

#[test]
fn prop_direct_transpose_parallel_bit_exact() {
    props("direct_transpose parallel == serial", 24, |g| {
        let m = g.usize_in(1, 300);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0xD17E);
        let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = direct_transpose_with_threads(&q, 1);
        for t in THREAD_COUNTS {
            let par = direct_transpose_with_threads(&q, t);
            assert_eq!(par.data, serial.data, "payload t={t} {m}x{n}");
            assert_f32_bits_eq(&par.scales, &serial.scales, &format!("scales t={t} {m}x{n}"));
            assert_eq!(par.sexp, serial.sexp, "sexp t={t} {m}x{n}");
        }
    });
}

#[test]
fn prop_swiglu_quant_parallel_bit_exact() {
    props("swiglu_quant parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x5157);
        let gate = Mat::randn(m, n, 2.0, &mut rng);
        let up = Mat::randn(m, n, 2.0, &mut rng);
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let serial = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, mode, 1);
            for t in THREAD_COUNTS {
                let par = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, mode, t);
                assert_eq!(par.data, serial.data, "payload {mode:?} t={t} {m}x{n}");
                assert_f32_bits_eq(
                    &par.scales,
                    &serial.scales,
                    &format!("scales {mode:?} t={t} {m}x{n}"),
                );
                assert_eq!(par.sexp, serial.sexp, "sexp {mode:?} t={t}");
            }
        }
    });
}

#[test]
fn prop_quantize_rowwise_parallel_bit_exact() {
    props("quantize_rowwise parallel == serial", 24, |g| {
        let m = g.usize_in(1, 260);
        let n = g.usize_in(1, 300);
        let mut rng = Rng::seed_from(g.seed ^ 0x0A7B);
        let x = Mat::rand_log_uniform(m, n, -8.0, 8.0, &mut rng);
        for mode in [ScaleMode::Po2, ScaleMode::Float] {
            let serial = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, 1);
            for t in THREAD_COUNTS {
                let par = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, t);
                assert_eq!(par.data, serial.data, "payload {mode:?} t={t} {m}x{n}");
                assert_f32_bits_eq(
                    &par.scales,
                    &serial.scales,
                    &format!("scales {mode:?} t={t} {m}x{n}"),
                );
                assert_eq!(par.sexp, serial.sexp, "sexp {mode:?} t={t}");
            }
        }
    });
}

#[test]
fn prop_permute_family_parallel_bit_exact() {
    props("permute/unpermute parallel == serial", 24, |g| {
        let tokens = g.usize_in(1, 300);
        let h = g.usize_in(1, 160);
        let experts = g.usize_in(1, 8);
        let cap = g.usize_in(1, tokens.max(2));
        let mut rng = Rng::seed_from(g.seed ^ 0xFACE);
        let x = Mat::randn(tokens, h, 1.0, &mut rng);
        let expert_of: Vec<usize> = (0..tokens).map(|_| rng.below(experts)).collect();
        let plan = permute_pad_plan(&expert_of, experts, cap);

        let serial = permute_pad_with_threads(&x, &plan, 1);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let serial_q = permute_pad_fp8_with_threads(&q, &plan, 1);
        let back_serial = unpermute_unpad_with_threads(&serial, &plan, tokens, 1);
        for t in THREAD_COUNTS {
            let par = permute_pad_with_threads(&x, &plan, t);
            assert_f32_bits_eq(&par.data, &serial.data, &format!("permute_pad t={t}"));

            let par_q = permute_pad_fp8_with_threads(&q, &plan, t);
            assert_eq!(par_q.data, serial_q.data, "permute_pad_fp8 payload t={t}");
            assert_f32_bits_eq(
                &par_q.scales,
                &serial_q.scales,
                &format!("permute_pad_fp8 scales t={t}"),
            );
            assert_eq!(par_q.sexp, serial_q.sexp, "permute_pad_fp8 sexp t={t}");

            let back = unpermute_unpad_with_threads(&serial, &plan, tokens, t);
            assert_f32_bits_eq(&back.data, &back_serial.data, &format!("unpermute t={t}"));
        }
    });
}
