//! Property/mutation tests of the scale-lineage static analyzer.
//!
//! Three claims, each acceptance-gating:
//!
//! 1. **Clean graphs are clean.** The Fp8Flow layer and train graphs (and
//!    the BF16 oracle) produce zero diagnostics; the incumbent graphs
//!    reproduce exactly their known double-quantization findings.
//! 2. **Each defect class is caught by its designated rule.** We inject
//!    one defect at a time into an otherwise-clean Fp8Flow graph and
//!    assert the analyzer fires exactly the expected rule.
//! 3. **The static pass and the runtime agree.** Analyzer-predicted
//!    cast/requant counts match the executed `FwdStash`/`BwdStats`/
//!    `WeightPrepStats`/`TrainMetrics` audits for every recipe and
//!    several shapes.

use fp8_flow_moe::analysis::{
    cross_check, lint_graph, CastSummary, ExecPrediction, ExecutedAudit, RuleId, Severity,
};
use fp8_flow_moe::dataflow::graph::{DataflowGraph, Dtype, OpKind, ScaleAxis, Stage};
use fp8_flow_moe::dataflow::{build, build_train_step, Variant};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward};
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::train::{Corpus, NativeTrainer, TrainConfig};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn codes(g: &DataflowGraph) -> Vec<&'static str> {
    lint_graph(g).iter().map(|d| d.rule.code()).collect()
}

fn node_named(g: &DataflowGraph, name: &str) -> usize {
    g.nodes.iter().find(|n| n.name == name).unwrap_or_else(|| panic!("no node '{name}'")).id
}

// ---------------------------------------------------------------------------
// 1. clean vs known-dirty baselines
// ---------------------------------------------------------------------------

#[test]
fn clean_graphs_produce_zero_diagnostics() {
    for v in [Variant::Fp8Flow, Variant::Bf16] {
        for (phase, g) in [("layer", build(v)), ("train", build_train_step(v))] {
            let diags = lint_graph(&g);
            assert!(diags.is_empty(), "{} {phase}: {:?}", v.name(), codes(&g));
        }
    }
}

#[test]
fn blockwise_reproduces_known_requant_findings() {
    // layer: two naive wgrad transposes (SL001), the axis mismatch they
    // cause at each wgrad GEMM (SL002), and the two dense activation
    // islands (SL007) — warnings all, no structural errors
    let diags = lint_graph(&build(Variant::TeBlockwise));
    let count = |r: RuleId| diags.iter().filter(|d| d.rule == r).count();
    assert_eq!(count(RuleId::DoubleQuant), 2);
    assert_eq!(count(RuleId::AxisMismatchGemm), 2);
    assert_eq!(count(RuleId::Bf16Island), 2);
    assert_eq!(diags.len(), 6);
    assert!(diags.iter().all(|d| d.severity == Severity::Warning));
    // train: +1 DoubleQuant for the storage-derived weight layout
    assert_eq!(lint_graph(&build_train_step(Variant::TeBlockwise)).len(), 7);
}

#[test]
fn deepseek_flags_wire_and_wgrad_requants() {
    let diags = lint_graph(&build(Variant::DeepSeekV3));
    let dq: Vec<&str> = diags
        .iter()
        .filter(|d| d.rule == RuleId::DoubleQuant)
        .map(|d| d.node_name.as_str())
        .collect();
    // the two re-quantizations after the wire dequants plus the two naive
    // wgrad transposes
    assert_eq!(dq, vec!["Q(x) fc1-in", "Q(dy) fc2-grads", "act naive-T", "x naive-T"]);
    assert_eq!(diags.len(), 8);
}

#[test]
fn lineage_traces_tell_the_requant_story() {
    let diags = lint_graph(&build(Variant::DeepSeekV3));
    let d = diags.iter().find(|d| d.node_name == "Q(x) fc1-in").unwrap();
    // "quantized row-wise at n1 (Q(x) pre-dispatch), dequantized at n3
    //  (DQ post-dispatch), requantized row-wise at n6 (Q(x) fc1-in)"
    assert!(d.trace.contains("quantized row-wise"), "{}", d.trace);
    assert!(d.trace.contains("dequantized at"), "{}", d.trace);
    assert!(d.trace.contains("requantized"), "{}", d.trace);
    let nt = diags.iter().find(|d| d.node_name == "act naive-T").unwrap();
    assert!(nt.trace.contains("requantized col-wise"), "{}", nt.trace);
    assert!(nt.message.contains("cross-axis"), "{}", nt.message);
}

// ---------------------------------------------------------------------------
// 2. mutation suite — one injected defect, one designated rule
// ---------------------------------------------------------------------------

#[test]
fn mutation_direct_to_naive_transpose_fires_double_quant() {
    let mut g = build(Variant::Fp8Flow);
    let at = node_named(&g, "act direct-T");
    g.nodes[at].op = OpKind::NaiveTransposeRequant;
    assert_eq!(codes(&g), vec!["SL001"]);
    assert!(!g.casting_free_wgrad(), "the swap also kills the wgrad guarantee");
    assert_eq!(g.requant_nodes_bwd(), 1, "…and shows up in the lineage-derived counter");
}

#[test]
fn mutation_dropped_sidecar_fires_missing_sidecar() {
    let mut g = build(Variant::Fp8Flow);
    let disp = node_named(&g, "dispatch-a2a (fp8)");
    g.nodes[disp].sidecar = false;
    let diags = lint_graph(&g);
    assert_eq!(codes(&g), vec!["SL005"]);
    assert_eq!(diags[0].severity, Severity::Error, "undecodable wire payload is structural");
}

#[test]
fn mutation_flipped_wgrad_axis_fires_gemm_mismatch() {
    // declare the act transpose's output row-wise (as if its scales were
    // never transposed): fc2-wgrad now mixes col-wise dy with row-wise act
    let mut g = build(Variant::Fp8Flow);
    let at = node_named(&g, "act direct-T");
    g.nodes[at].axis = Some(ScaleAxis::RowWise);
    let diags = lint_graph(&g);
    assert_eq!(codes(&g), vec!["SL002"]);
    assert_eq!(diags[0].node_name, "fc2-wgrad");
    assert!(diags[0].message.contains("row-wise") && diags[0].message.contains("col-wise"));
}

#[test]
fn mutation_orphaned_node_fires_orphan_rule() {
    let mut g = build(Variant::Fp8Flow);
    let comb = node_named(&g, "combine-a2a");
    g.nodes[comb].inputs.clear();
    assert_eq!(codes(&g), vec!["SL008"]);
    assert!(g.validate().unwrap_err().contains("orphan"), "validate agrees");
}

#[test]
fn mutation_stray_qdq_pair_fires_redundant_qdq() {
    let mut g = build(Variant::Fp8Flow);
    let y = node_named(&g, "gate-scale-add");
    let q = g.add("stray Q", OpKind::Quantize, Stage::Combine, false, Dtype::Fp8, &[y]);
    g.add("stray DQ", OpKind::Dequantize, Stage::Combine, false, Dtype::Bf16, &[q]);
    assert_eq!(codes(&g), vec!["SL004"]);
}

#[test]
fn mutation_dequant_of_dense_fires_error() {
    let mut g = build(Variant::Fp8Flow);
    let y = node_named(&g, "gate-scale-add");
    g.add("bogus DQ", OpKind::Dequantize, Stage::Combine, false, Dtype::Bf16, &[y]);
    let diags = lint_graph(&g);
    assert_eq!(codes(&g), vec!["SL003"]);
    assert_eq!(diags[0].severity, Severity::Error);
}

#[test]
fn mutation_mixed_gemm_operands_fire_dtype_mismatch() {
    let mut g = build(Variant::Fp8Flow);
    let act = node_named(&g, "fused-swiglu-quant"); // FP8
    let fc1 = node_named(&g, "fc1-grouped-gemm"); // BF16
    g.add("mixed-gemm", OpKind::GroupedGemm, Stage::Fc2, false, Dtype::Bf16, &[act, fc1]);
    assert_eq!(codes(&g), vec!["SL006"]);
}

#[test]
fn mutation_dense_island_fires_bf16_island() {
    // a standalone dense activation inside the expert span of an FP8
    // graph (exactly what Fp8Flow's fused kernels exist to avoid)
    let mut g = build(Variant::Fp8Flow);
    let fc1 = node_named(&g, "fc1-grouped-gemm");
    g.add("dense-swiglu", OpKind::SwiGlu, Stage::Activation, false, Dtype::Bf16, &[fc1]);
    assert_eq!(codes(&g), vec!["SL007"]);
}

// ---------------------------------------------------------------------------
// 3. static ↔ executed agreement
// ---------------------------------------------------------------------------

fn run_executed(recipe: Recipe, experts: usize, top_k: usize) -> ExecutedAudit {
    let tokens = 48;
    let capacity = (tokens * top_k).div_ceil(experts);
    let mut rng = Rng::seed_from(9);
    let x = Mat::randn(tokens, 16, 0.5, &mut rng);
    let w = MoeWeights::random(16, 24, experts, &mut rng);
    let dy = Mat::randn(tokens, 16, 1.0, &mut rng);
    let mut pw = PreparedWeights::new(w, recipe);
    let stash = forward_stash(&x, &pw, top_k, capacity);
    let grads = moe_backward(&stash, &pw, &dy);
    let prep = pw.requantize_from_masters();
    ExecutedAudit {
        casts_fwd: stash.cast_ops,
        casts_bwd: grads.stats.casts,
        requants_bwd: grads.stats.requants,
        opt_weight_quants: prep.weight_quants,
        opt_requants: prep.requants,
    }
}

/// Predicted audit for an executed recipe: layer-path counts from the
/// recipe's own graph, optimizer tail from the master-sourced (casting-
/// free) tail that `requantize_from_masters` implements for every FP8
/// recipe.
fn predict(v: Variant, experts: usize, top_k: usize) -> ExecPrediction {
    let layer = ExecPrediction::of(&build(v), experts, top_k);
    let tail_variant = if v == Variant::Bf16 { v } else { Variant::Fp8Flow };
    let tail = ExecPrediction::of(&build_train_step(tail_variant), experts, top_k);
    ExecPrediction {
        opt_weight_quants: tail.opt_weight_quants,
        opt_requants: tail.opt_requants,
        ..layer
    }
}

#[test]
fn predictions_match_executed_audits_for_every_recipe() {
    for (v, recipe) in [
        (Variant::Bf16, Recipe::Bf16),
        (Variant::TeBlockwise, Recipe::Blockwise),
        (Variant::Fp8Flow, Recipe::Fp8Flow),
    ] {
        for (experts, top_k) in [(4, 1), (6, 2), (8, 3)] {
            let predicted = predict(v, experts, top_k);
            let executed = run_executed(recipe, experts, top_k);
            let div = cross_check(v.name(), &predicted, &executed);
            assert!(
                div.is_empty(),
                "{} E={experts} K={top_k}: {:?}",
                v.name(),
                div.iter().map(|d| d.message.clone()).collect::<Vec<_>>()
            );
        }
    }
}

#[test]
fn chunked_ep_backward_matches_chunk_invariant_prediction() {
    // the `--chunks C` pipeline regroups experts into per-rank units but
    // must not change a single cast/requant count: entry quant is once
    // per batch, Q(dy) once per slot, per-expert counters once per
    // expert. `ExecPrediction::of_chunked` pins that invariance on the
    // static side; this test pins it on the executed side by running the
    // EP-sharded chunked backward (both schedules) through the same
    // cross-check the `lint` gate uses.
    use fp8_flow_moe::cluster::ep_exec::{ep_backward, EpConfig};
    let (experts, top_k, tokens) = (6usize, 2usize, 48usize);
    let capacity = (tokens * top_k).div_ceil(experts);
    let mut rng = Rng::seed_from(31);
    let x = Mat::randn(tokens, 16, 0.5, &mut rng);
    let w = MoeWeights::random(16, 24, experts, &mut rng);
    let dy = Mat::randn(tokens, 16, 1.0, &mut rng);
    for (v, recipe) in [
        (Variant::Bf16, Recipe::Bf16),
        (Variant::TeBlockwise, Recipe::Blockwise),
        (Variant::Fp8Flow, Recipe::Fp8Flow),
    ] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, capacity);
        for (ranks, chunks, overlap) in
            [(1, 2, false), (2, 2, true), (2, 4, false), (3, 2, true)]
        {
            let cfg = EpConfig::serial(ranks, top_k, capacity, 0)
                .with_pipeline(chunks, overlap);
            let out = ep_backward(&stash, &pw, &dy, &cfg);
            let predicted =
                ExecPrediction::of_chunked(&build(v), experts, top_k, chunks);
            let executed = ExecutedAudit {
                casts_fwd: stash.cast_ops,
                casts_bwd: out.grads.stats.casts,
                requants_bwd: out.grads.stats.requants,
                ..Default::default()
            };
            // optimizer tail not exercised here: zero its prediction too
            let predicted = ExecPrediction {
                opt_weight_quants: 0,
                opt_requants: 0,
                ..predicted
            };
            let div = cross_check(v.name(), &predicted, &executed);
            assert!(
                div.is_empty(),
                "{} R={ranks} C={chunks} ov={overlap}: {:?}",
                v.name(),
                div.iter().map(|d| d.message.clone()).collect::<Vec<_>>()
            );
        }
    }
}

#[test]
fn cross_check_catches_a_seeded_divergence() {
    let mut predicted = predict(Variant::Fp8Flow, 4, 2);
    predicted.casts_bwd += 10; // sabotage
    let executed = run_executed(Recipe::Fp8Flow, 4, 2);
    let div = cross_check("fp8-flow-moe", &predicted, &executed);
    assert_eq!(div.len(), 1);
    assert_eq!(div[0].rule, RuleId::AuditDivergence);
    assert_eq!(div[0].severity, Severity::Error);
}

#[test]
fn predictions_match_one_executed_train_step() {
    // TrainMetrics is the full-loop audit: forward + backward + optimizer
    let cfg = TrainConfig::named("tiny").unwrap();
    for (v, recipe) in [
        (Variant::Bf16, Recipe::Bf16),
        (Variant::TeBlockwise, Recipe::Blockwise),
        (Variant::Fp8Flow, Recipe::Fp8Flow),
    ] {
        let mut trainer = NativeTrainer::new(cfg, recipe, 3);
        let mut corpus = Corpus::new(cfg.vocab, 3, 10);
        trainer.run(&mut corpus, 2, 0).unwrap();
        let m = trainer.metrics.last().unwrap();
        let p = predict(v, cfg.n_experts, cfg.top_k);
        assert_eq!(m.casts_fwd, p.casts_fwd, "{} casts_fwd", v.name());
        assert_eq!(m.casts_bwd, p.casts_bwd, "{} casts_bwd", v.name());
        assert_eq!(m.requants_bwd, p.requants_bwd, "{} requants_bwd", v.name());
        assert_eq!(m.opt_weight_quants, p.opt_weight_quants, "{} opt quants", v.name());
        assert_eq!(m.opt_requants, p.opt_requants, "{} opt requants", v.name());
    }
}

// ---------------------------------------------------------------------------
// counter parity: the lineage queries reproduce the legacy op-filters
// ---------------------------------------------------------------------------

#[test]
fn lineage_counters_equal_legacy_op_filters() {
    for v in Variant::all() {
        for g in [build(v), build_train_step(v)] {
            let s = CastSummary::of(&g);
            let casts =
                g.nodes.iter().filter(|n| n.op.is_explicit_cast()).count();
            let casts_fwd = g
                .nodes
                .iter()
                .filter(|n| !n.backward && n.stage != Stage::Optimizer && n.op.is_explicit_cast())
                .count();
            let casts_bwd = g.nodes.iter().filter(|n| n.backward && n.op.is_explicit_cast()).count();
            let casts_opt = g
                .nodes
                .iter()
                .filter(|n| n.stage == Stage::Optimizer && n.op.is_explicit_cast())
                .count();
            let requants_bwd = g
                .nodes
                .iter()
                .filter(|n| n.backward && n.op == OpKind::NaiveTransposeRequant)
                .count();
            let requants_opt = g
                .nodes
                .iter()
                .filter(|n| n.stage == Stage::Optimizer && n.op == OpKind::NaiveTransposeRequant)
                .count();
            assert_eq!(s.casts_total, casts, "{}", v.name());
            assert_eq!(s.casts_fwd, casts_fwd, "{}", v.name());
            assert_eq!(s.casts_bwd, casts_bwd, "{}", v.name());
            assert_eq!(s.casts_opt, casts_opt, "{}", v.name());
            assert_eq!(s.requants_bwd, requants_bwd, "{}", v.name());
            assert_eq!(s.requants_opt, requants_opt, "{}", v.name());
            assert_eq!(s.casts_total, g.explicit_casts(), "{} delegation", v.name());
        }
    }
}
