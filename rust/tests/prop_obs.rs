//! Observability non-perturbation & counter-exactness properties.
//!
//! The recorder's contract (see `src/obs/recorder.rs`) has two halves,
//! each pinned here:
//!
//! 1. **Non-perturbation**: recording on vs off is bitwise invisible to
//!    every executed artifact — forward outputs, backward gradients,
//!    train-step losses, served rows — across worker budgets {1, 2, 8}
//!    and rank counts {1, 2, 4}. Instrumentation sits *around* kernels,
//!    never inside their arithmetic, and this suite is what keeps that
//!    true as sites accrete.
//! 2. **Counter exactness**: recorded totals equal the analytic
//!    accounting — `ExecPrediction` for casts/requants, the EP run's own
//!    exact byte fields for the wire — and the byte/cast totals are
//!    invariant under pipeline chunking and schedule (only the
//!    buffer-count proxy is allowed to grow with chunks).
//!
//! Recording is scoped to the installing thread's tree, so these
//! exact-totals assertions stay deterministic even when the harness runs
//! other tests of this binary concurrently.

use fp8_flow_moe::analysis::ExecPrediction;
use fp8_flow_moe::cluster::ep_exec::{ep_backward, ep_forward, EpConfig};
use fp8_flow_moe::dataflow::{build, Variant};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward, MoeGrads};
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::obs::{self, Counter};
use fp8_flow_moe::serve::{
    generate_requests, serve_trace, ArrivalMode, DropPolicy, GenConfig, ServeConfig, ServeEngine,
    SloPolicy, TokenEmbed,
};
use fp8_flow_moe::train::{Corpus, NativeTrainer, TrainConfig};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::assert_mat_bits_eq;
use fp8_flow_moe::util::rng::Rng;

const RECIPES: [Recipe; 3] = [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow];
const THREADS: [usize; 3] = [1, 2, 8];
const RANKS: [usize; 3] = [1, 2, 4];

fn variant_of(recipe: Recipe) -> Variant {
    match recipe {
        Recipe::Bf16 => Variant::Bf16,
        Recipe::Blockwise => Variant::TeBlockwise,
        Recipe::Fp8Flow => Variant::Fp8Flow,
    }
}

fn assert_grads_bits_eq(a: &MoeGrads, b: &MoeGrads, what: &str) {
    assert_mat_bits_eq(&a.dx, &b.dx, &format!("{what}: dx"));
    for e in 0..a.dw1.len() {
        assert_mat_bits_eq(&a.dw1[e], &b.dw1[e], &format!("{what}: dw1[{e}]"));
        assert_mat_bits_eq(&a.dw3[e], &b.dw3[e], &format!("{what}: dw3[{e}]"));
        assert_mat_bits_eq(&a.dw2[e], &b.dw2[e], &format!("{what}: dw2[{e}]"));
    }
    assert_eq!(a.stats, b.stats, "{what}: cast audit");
}

#[test]
fn recorder_is_bitwise_invisible_to_forward_and_backward() {
    let (t, d, h, e, cap, top_k) = (40, 48, 32, 4, 12, 2);
    let mut rng = Rng::seed_from(0x0B5);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    for recipe in RECIPES {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, cap);
        for ranks in RANKS {
            for threads in THREADS {
                let cfg = EpConfig::serial(ranks, top_k, cap, threads).with_pipeline(2, true);
                // baseline with every hook on the no-op fast path
                assert!(!obs::enabled());
                let off_f = ep_forward(&x, &pw, &cfg);
                let off_b = ep_backward(&stash, &pw, &dy, &cfg);
                // identical run under a live recorder at max detail
                let rec = obs::Recorder::new(2);
                let (on_f, on_b) = {
                    let _g = obs::install(rec.clone());
                    (ep_forward(&x, &pw, &cfg), ep_backward(&stash, &pw, &dy, &cfg))
                };
                let what = format!("{recipe:?} R={ranks} t={threads}");
                assert_mat_bits_eq(&on_f.y, &off_f.y, &format!("{what}: y"));
                assert_eq!(on_f.aux_loss.to_bits(), off_f.aux_loss.to_bits(), "{what}: aux");
                assert_grads_bits_eq(&on_b.grads, &off_b.grads, &what);
                assert!(rec.n_spans() > 0, "{what}: recording session saw no spans");
            }
        }
    }
}

#[test]
fn recorder_is_bitwise_invisible_to_train_steps() {
    let mut cfg = TrainConfig::named("tiny").expect("tiny config");
    let steps = 3;
    for ranks in [1usize, 2] {
        cfg.ranks = ranks;
        for recipe in RECIPES {
            let run = |record: bool| {
                let mut trainer = NativeTrainer::new(cfg, recipe, 11);
                let mut corpus = Corpus::new(cfg.vocab, 11, 10);
                let rec = record.then(|| obs::Recorder::new(1));
                let _g = rec.clone().map(obs::install);
                let out = trainer.run(&mut corpus, steps, steps + 1).expect("train run");
                (out, trainer.metrics, rec)
            };
            let (off, off_m, _) = run(false);
            let (on, on_m, rec) = run(true);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(
                bits(&on.losses),
                bits(&off.losses),
                "{recipe:?} R={ranks}: loss trajectory must not feel the recorder"
            );
            for (a, b) in on_m.iter().zip(&off_m) {
                assert_eq!(a.casts_fwd, b.casts_fwd, "{recipe:?} R={ranks}: casts_fwd");
                assert_eq!(a.casts_bwd, b.casts_bwd, "{recipe:?} R={ranks}: casts_bwd");
                assert_eq!(a.requants_bwd, b.requants_bwd, "{recipe:?} R={ranks}: requants");
            }
            // and the recorded totals equal the per-step audit sums plus
            // the trainer construction's initial weight prep
            let rec = rec.expect("recorder");
            let totals = rec.totals();
            let sum = |f: fn(&fp8_flow_moe::train::TrainMetrics) -> usize| {
                on_m.iter().map(f).sum::<usize>() as u64
            };
            let prep = if recipe == Recipe::Bf16 { 0 } else { 6 * cfg.n_experts as u64 };
            assert_eq!(totals[Counter::CastsFwd as usize], sum(|m| m.casts_fwd));
            assert_eq!(totals[Counter::CastsBwd as usize], sum(|m| m.casts_bwd));
            assert_eq!(totals[Counter::RequantsBwd as usize], sum(|m| m.requants_bwd));
            assert_eq!(
                totals[Counter::OptWeightQuants as usize],
                sum(|m| m.opt_weight_quants) + prep,
                "{recipe:?} R={ranks}: optimizer-tail weight quants"
            );
            assert_eq!(totals[Counter::OptRequants as usize], 0);
        }
    }
}

#[test]
fn recorder_is_bitwise_invisible_to_serving() {
    let gen = GenConfig {
        seed: 5,
        mode: ArrivalMode::parse("bursty").expect("arrival mode"),
        rate: 400.0,
        burst: 3.0,
        burst_period_s: 0.02,
        zipf_s: 1.1,
        min_len: 2,
        max_len: 24,
        vocab: 32,
        noise_pct: 10,
    };
    let requests = generate_requests(&gen, 24);
    let slo = SloPolicy { max_wait_s: 0.002, max_tokens: 48 };
    let (d, h, e, top_k) = (32, 24, 4, 2);
    let mut rng = Rng::seed_from(0x5E);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for ranks in [1usize, 2] {
        for threads in THREADS {
            let engine = ServeEngine::new(
                PreparedWeights::new(w.clone(), Recipe::Fp8Flow),
                TokenEmbed::new(gen.vocab, d, 5),
                ServeConfig {
                    ranks,
                    top_k,
                    capacity_factor: 0.75, // force real capacity drops
                    drop_policy: DropPolicy::parse("capacity").expect("drop policy"),
                    threads,
                    chunks: 1,
                    overlap: false,
                },
            );
            assert!(!obs::enabled());
            let off = serve_trace(&engine, &requests, &slo);
            let rec = obs::Recorder::new(1);
            let on = {
                let _g = obs::install(rec.clone());
                serve_trace(&engine, &requests, &slo)
            };
            let what = format!("R={ranks} t={threads}");
            assert_mat_bits_eq(&on.y, &off.y, &format!("{what}: served rows"));
            assert_eq!(on.fully_served, off.fully_served, "{what}: served flags");
            assert_eq!(on.dropped_slots, off.dropped_slots, "{what}: drop accounting");
            // the drop/served counters are exact, not sampled
            let totals = rec.totals();
            assert_eq!(totals[Counter::ServedTokens as usize], on.served_tokens as u64, "{what}");
            assert_eq!(
                totals[Counter::DegradedTokens as usize],
                on.degraded_tokens as u64,
                "{what}"
            );
            assert_eq!(totals[Counter::DroppedSlots as usize], on.dropped_slots as u64, "{what}");
            assert_eq!(
                on.served_tokens + on.degraded_tokens,
                on.total_tokens,
                "{what}: every token is either fully served or degraded"
            );
        }
    }
}

#[test]
fn counter_totals_match_prediction_and_ignore_chunking() {
    let (t, d, h, e, cap, top_k) = (48, 64, 48, 4, 16, 2);
    let mut rng = Rng::seed_from(0xC4A);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    // the casts/requants the lint graphs predict for one fwd + one bwd
    for recipe in RECIPES {
        let pred = ExecPrediction::of(&build(variant_of(recipe)), e, top_k);
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, cap);
        let mut invariant: Option<[u64; 6]> = None;
        for (chunks, overlap) in [(1, false), (2, false), (2, true), (4, true)] {
            let cfg = EpConfig::serial(2, top_k, cap, 0).with_pipeline(chunks, overlap);
            let rec = obs::Recorder::new(1);
            let (fwd, bwd) = {
                let _g = obs::install(rec.clone());
                (ep_forward(&x, &pw, &cfg), ep_backward(&stash, &pw, &dy, &cfg))
            };
            let totals = rec.totals();
            let what = format!("{recipe:?} C={chunks} ov={overlap}");
            assert_eq!(totals[Counter::CastsFwd as usize], pred.casts_fwd as u64, "{what}");
            assert_eq!(totals[Counter::CastsBwd as usize], pred.casts_bwd as u64, "{what}");
            assert_eq!(totals[Counter::RequantsBwd as usize], pred.requants_bwd as u64, "{what}");
            // wire bytes: recorded at the pack sites, checked against the
            // runs' own independent byte accounting
            assert_eq!(
                totals[Counter::WirePayloadBytes as usize],
                (fwd.dispatch_payload_bytes + bwd.dy_payload_bytes) as u64,
                "{what}: payload"
            );
            assert_eq!(
                totals[Counter::WireSidecarBytes as usize],
                (fwd.dispatch_sidecar_bytes + bwd.dy_sidecar_bytes) as u64,
                "{what}: sidecar"
            );
            assert_eq!(
                totals[Counter::WireBuffers as usize],
                (fwd.dispatch_buffers + bwd.dy_buffers) as u64,
                "{what}: buffers"
            );
            assert_eq!(
                totals[Counter::CombineBytes as usize],
                (fwd.combine_bytes + bwd.dx_bytes) as u64,
                "{what}: combine"
            );
            // the byte/cast totals must be schedule-invariant (buffers —
            // the sync-count proxy — legitimately grow with chunking)
            let key = [
                totals[Counter::CastsFwd as usize],
                totals[Counter::CastsBwd as usize],
                totals[Counter::RequantsBwd as usize],
                totals[Counter::WirePayloadBytes as usize],
                totals[Counter::WireSidecarBytes as usize],
                totals[Counter::CombineBytes as usize],
            ];
            match &invariant {
                None => invariant = Some(key),
                Some(k) => assert_eq!(*k, key, "{what}: chunking changed a byte/cast total"),
            }
        }
    }
}

#[test]
fn uninstalled_hooks_record_nothing_anywhere() {
    let (t, d, h, e, cap, top_k) = (24, 32, 24, 4, 8, 2);
    let mut rng = Rng::seed_from(0xD15);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    // run the full instrumented surface with no recorder installed…
    assert!(!obs::enabled());
    for recipe in RECIPES {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, top_k, cap);
        let _ = ep_forward(&x, &pw, &EpConfig::serial(2, top_k, cap, 0));
        let _ = moe_backward(&stash, &pw, &dy);
    }
    // …then install a fresh recorder and confirm nothing leaked into it
    let rec = obs::Recorder::new(1);
    let _g = obs::install(rec.clone());
    assert_eq!(rec.totals(), [0u64; 12], "counts leaked across install");
    assert_eq!(rec.n_spans(), 0);
}
