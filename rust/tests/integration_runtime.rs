//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Require `make artifacts` to have run (skipped with a clear message
//! otherwise). These are the cross-layer proofs: the L2 JAX graphs (with
//! L1 kernels inside) load, compile and execute via the Rust runtime, and
//! their numerics match the native Rust substrate.

use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::direct_transpose;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::runtime::{literal, Runtime};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e:#}) — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "init_tiny",
        "train_step_bf16_tiny",
        "train_step_fp8flow_tiny",
        "train_step_blockwise_tiny",
        "moe_fwd_bf16_tiny",
        "moe_fwd_fp8flow_tiny",
    ] {
        assert!(rt.manifest.get(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn init_then_train_step_tiny_decreases_loss() {
    let Some(rt) = runtime_or_skip() else { return };
    let init = rt.load("init_tiny").unwrap();
    let step = rt.load("train_step_fp8flow_tiny").unwrap();

    let state = init.run(&[literal::u32_scalar(42).unwrap()]).unwrap();
    // init returns params + m + v (3P leaves)
    assert_eq!(state.len() % 3, 0);
    let p = state.len() / 3;
    assert_eq!(step.spec.inputs.len(), 3 * p + 2);

    // synthetic token stream (structured: repeating n-grams => learnable)
    let (batch, seq) = (
        step.spec.inputs[3 * p + 1].shape[0],
        step.spec.inputs[3 * p + 1].shape[1],
    );
    let mut rng = Rng::seed_from(7);
    let vocab = 64i32;
    let mut losses = Vec::new();
    let mut state = state;
    for s in 1..=8 {
        let tokens: Vec<i32> = (0..batch * seq)
            .map(|i| ((i % 13) as i32 * 5 + (rng.below(3) as i32)) % vocab)
            .collect();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * p + 2);
        for lit in state.iter().take(3 * p) {
            inputs.push(lit.clone());
        }
        inputs.push(literal::i32_scalar(s).unwrap());
        inputs.push(literal::i32_literal(&[batch, seq], &tokens).unwrap());
        let out = step.run(&inputs).unwrap();
        assert_eq!(out.len(), 3 * p + 1);
        let loss = literal::to_f32_scalar(&out[3 * p]).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {s}");
        losses.push(loss);
        state = out[..3 * p].to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should decrease on structured data: {losses:?}"
    );
}

#[test]
fn moe_fwd_recipes_agree_within_quantization_tolerance() {
    let Some(rt) = runtime_or_skip() else { return };
    let bf16 = rt.load("moe_fwd_bf16_tiny").unwrap();
    let fp8 = rt.load("moe_fwd_fp8flow_tiny").unwrap();

    let spec = &bf16.spec.inputs;
    let mut rng = Rng::seed_from(3);
    let mut mk = |shape: &[usize], rng: &mut Rng, scale: f32| {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
        literal::f32_literal(shape, &data).unwrap()
    };
    let inputs: Vec<xla::Literal> = spec
        .iter()
        .map(|t| mk(&t.shape, &mut rng, 0.5))
        .collect();

    let y_bf16 = bf16.run(&inputs).unwrap();
    let y_fp8 = fp8.run(&inputs).unwrap();
    let a = literal::to_f32_vec(&y_bf16[0]).unwrap();
    let b = literal::to_f32_vec(&y_fp8[0]).unwrap();
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(&b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
    let den: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-9);
    let rel = num / den;
    assert!(rel < 0.15, "recipes diverged: rel={rel}");
    assert!(rel > 0.0, "fp8 recipe should differ from bf16 at all");
}

#[test]
fn hlo_direct_transpose_matches_rust_native_bitwise() {
    let Some(rt) = runtime_or_skip() else { return };
    if rt.manifest.get("k_direct_transpose_1024x2048").is_none() {
        eprintln!("SKIP: kernel artifacts not built");
        return;
    }
    let exe = rt.load("k_direct_transpose_1024x2048").unwrap();
    let (m, n) = (1024usize, 2048usize);

    let mut rng = Rng::seed_from(11);
    let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);

    let inputs = vec![
        literal::u8_literal(&[m, n], &q.data).unwrap(),
        literal::i32_literal(&[m, n / 128], &q.sexp).unwrap(),
    ];
    let out = exe.run(&inputs).unwrap();
    let hlo_codes = literal::to_u8_vec(&out[0]).unwrap();
    let hlo_sexp = literal::to_i32_vec(&out[2]).unwrap();

    let t = direct_transpose(&q);
    assert_eq!(hlo_codes, t.data, "HLO and Rust direct transpose payload differ");
    assert_eq!(hlo_sexp, t.sexp, "HLO and Rust direct transpose scales differ");
}

#[test]
fn hlo_quantize_matches_rust_native_bitwise() {
    let Some(rt) = runtime_or_skip() else { return };
    if rt.manifest.get("k_quantize_1024x2048").is_none() {
        eprintln!("SKIP: kernel artifacts not built");
        return;
    }
    let exe = rt.load("k_quantize_1024x2048").unwrap();
    let (m, n) = (1024usize, 2048usize);
    let mut rng = Rng::seed_from(13);
    let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);
    let out = exe
        .run(&[literal::f32_literal(&[m, n], &x.data).unwrap()])
        .unwrap();
    let hlo_codes = literal::to_u8_vec(&out[0]).unwrap();
    let hlo_sexp = literal::to_i32_vec(&out[2]).unwrap();
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    assert_eq!(hlo_codes, q.data, "HLO and Rust quantizer payload differ");
    assert_eq!(hlo_sexp, q.sexp, "HLO and Rust quantizer scales differ");
}
