//! Property suite for the fault-injected EP runtime (ISSUE 10): CRC32
//! wire integrity (exhaustive single-bit detection over *both* wire
//! buffers), the silent-sidecar-flip hazard the split seal exists for,
//! EP forward/backward bit-identity under fault plans across the
//! rank × thread × overlap matrix with schedule-independent recovery
//! counters, the degraded-serving extended drop ledger, and bitwise
//! checkpoint resume across ranks and thread budgets.

use fp8_flow_moe::cluster::ep_exec::{
    ep_backward, ep_backward_with_faults, ep_forward, ep_forward_with_faults, EpConfig,
};
use fp8_flow_moe::cluster::fault::{wire_tick, Fault, FaultKind, FaultPlan, WireSums, ANY_DST};
use fp8_flow_moe::cluster::rank::WireBuf;
use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::{ue8m0, Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::forward_stash;
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::serve::{
    generate_requests, serve_trace, ArrivalMode, DropPolicy, FailoverPolicy, GenConfig,
    ServeConfig, ServeEngine, SloPolicy, TokenEmbed,
};
use fp8_flow_moe::train::native::{restore_trainer, save_checkpoint, NativeTrainer, TrainConfig};
use fp8_flow_moe::train::Corpus;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// Wire integrity: CRC32 detects 100% of single-bit flips, per buffer
// ---------------------------------------------------------------------------

#[test]
fn wire_checksum_detects_every_single_bit_flip_in_both_buffers() {
    // a real FP8 wire image: quantized codes + UE8M0 sidecar, with a
    // ragged tile tail (160 = 128 + 32) so the sidecar has >1 byte/row
    let mut rng = Rng::seed_from(3);
    let x = Mat::randn(4, 160, 0.7, &mut rng);
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    let sidecar: Vec<u8> = q.sexp.iter().map(|&e| ue8m0::from_exponent(e)).collect();
    assert!(!sidecar.is_empty() && !q.data.is_empty());
    let buf = WireBuf::Fp8 { codes: q.data.clone(), sidecar: sidecar.clone() };
    let seal = WireSums::seal(&buf);
    assert!(seal.verify(&buf), "the pristine image must verify");

    // exhaustive: every (byte offset, bit) in the code buffer
    for off in 0..q.data.len() {
        for bit in 0..8u8 {
            let mut codes = q.data.clone();
            codes[off] ^= 1 << bit;
            let bad = WireBuf::Fp8 { codes, sidecar: sidecar.clone() };
            assert!(!seal.verify(&bad), "undetected code flip at byte {off} bit {bit}");
        }
    }
    // exhaustive: every (byte offset, bit) in the UE8M0 sidecar
    for off in 0..sidecar.len() {
        for bit in 0..8u8 {
            let mut sc = sidecar.clone();
            sc[off] ^= 1 << bit;
            let bad = WireBuf::Fp8 { codes: q.data.clone(), sidecar: sc };
            assert!(!seal.verify(&bad), "undetected sidecar flip at byte {off} bit {bit}");
        }
    }
}

#[test]
fn dense_wire_checksum_detects_every_single_bit_flip() {
    let vals: Vec<f32> = (0..16).map(|k| (k as f32) * 0.37 - 2.0).collect();
    let seal = WireSums::seal(&WireBuf::Dense(vals.clone()));
    assert_eq!(seal.sidecar, 0, "dense wires carry no sidecar");
    for k in 0..vals.len() {
        for bit in 0..32 {
            let mut v = vals.clone();
            v[k] = f32::from_bits(v[k].to_bits() ^ (1u32 << bit));
            assert!(
                !seal.verify(&WireBuf::Dense(v)),
                "undetected dense flip at element {k} bit {bit}"
            );
        }
    }
}

#[test]
fn an_undetected_sidecar_flip_would_rescale_decoded_values() {
    // why the sidecar seal is load-bearing: every single-bit corruption
    // of every UE8M0 code decodes to a *different* scale — a silent
    // 2^±2^k rescale of a whole tile had the CRC not caught it
    for b in 0u16..=255 {
        let b = b as u8;
        let base = ue8m0::decode(b);
        for bit in 0..8u8 {
            let flipped = b ^ (1 << bit);
            let other = ue8m0::decode(flipped);
            assert_ne!(
                base.to_bits(),
                other.to_bits(),
                "decode({b}) == decode({flipped}): flip of bit {bit} would be value-silent"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// EP forward/backward: recovered runs are bitwise clean, counters are
// schedule-independent across threads × overlap
// ---------------------------------------------------------------------------

/// Chunk-0 fault plan for one wire direction: every schedule (serial or
/// overlapped, any chunk count ≥ 1) executes chunk 0 of every slot, so
/// recovery totals are identical across the whole schedule matrix.
fn chunk0_plan(ranks: usize, top_k: usize, backward: bool) -> FaultPlan {
    FaultPlan::new(vec![
        Fault {
            tick: wire_tick(0, 0, backward),
            src: 0,
            dst: ANY_DST,
            kind: FaultKind::FlipPayloadBit { offset: 11, bit: 3 },
            attempts: 1,
        },
        Fault {
            tick: wire_tick(top_k - 1, 0, backward),
            src: ranks - 1,
            dst: ANY_DST,
            kind: FaultKind::FlipSidecarBit { offset: 2, bit: 6 },
            attempts: 2,
        },
        Fault {
            tick: wire_tick(0, 0, backward),
            src: ranks - 1,
            dst: 0,
            kind: FaultKind::DropMessage,
            attempts: 1,
        },
    ])
}

#[test]
fn ep_forward_and_backward_are_bitwise_clean_under_injected_faults() {
    let (t, d, h, e, k) = (96usize, 64usize, 64usize, 8usize, 2usize);
    let cap = (t * k).div_ceil(e);
    let mut rng = Rng::seed_from(17);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let dy = Mat::randn(t, d, 1.0, &mut rng);
    for recipe in [Recipe::Fp8Flow, Recipe::Bf16] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let stash = forward_stash(&x, &pw, k, cap);
        for ranks in [1usize, 2, 4] {
            for overlap in [false, true] {
                // recovery totals must not depend on the worker budget
                // (all FaultPlan state is atomic and commutative); the
                // chunked schedule may split a message into a different
                // buffer set, so totals are compared per schedule
                let mut ref_stats = None;
                for threads in [1usize, 2, 8] {
                    let cfg = EpConfig::serial(ranks, k, cap, threads)
                        .with_pipeline(if overlap { 2 } else { 1 }, overlap);
                    let tag = format!("{recipe:?} R={ranks} T={threads} overlap={overlap}");

                    let clean_f = ep_forward(&x, &pw, &cfg);
                    let plan_f = chunk0_plan(ranks, k, false);
                    let fwd = ep_forward_with_faults(&x, &pw, &cfg, &plan_f);
                    assert_eq!(bits(&fwd.y.data), bits(&clean_f.y.data), "{tag}: fwd y");

                    let clean_b = ep_backward(&stash, &pw, &dy, &cfg);
                    let plan_b = chunk0_plan(ranks, k, true);
                    let bwd = ep_backward_with_faults(&stash, &pw, &dy, &cfg, &plan_b);
                    assert_eq!(bits(&bwd.grads.dx.data), bits(&clean_b.grads.dx.data), "{tag}: dx");
                    for ex in 0..e {
                        assert_eq!(
                            bits(&bwd.grads.dw1[ex].data),
                            bits(&clean_b.grads.dw1[ex].data),
                            "{tag}: dw1[{ex}]"
                        );
                    }

                    let st = (plan_f.stats(), plan_b.stats());
                    assert_eq!(st.0.failovers, 0, "{tag}: transient faults must not escalate");
                    if recipe == Recipe::Fp8Flow && ranks > 1 && !overlap {
                        assert!(st.0.checksum_fails >= 1, "{tag}: fwd flip went unexercised");
                        assert!(st.0.retries >= 1, "{tag}: fwd recovery issued no retries");
                    }
                    match &ref_stats {
                        None => ref_stats = Some(st),
                        Some(r) => assert_eq!(*r, st, "{tag}: thread-dependent recovery"),
                    }
                }
            }
        }
    }
}

#[test]
fn seeded_fault_matrices_replay_to_identical_recovery_counters() {
    let (t, d, h, e, k) = (64usize, 32usize, 32usize, 8usize, 2usize);
    let cap = (t * k).div_ceil(e);
    let mut rng = Rng::seed_from(23);
    let x = Mat::randn(t, d, 0.5, &mut rng);
    let w = MoeWeights::random(d, h, e, &mut rng);
    let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
    let cfg = EpConfig::serial(4, k, cap, 2);
    let clean = ep_forward(&x, &pw, &cfg);
    let mut first = None;
    for run in 0..2 {
        let plan = FaultPlan::seeded(77, 4, 4, 16);
        let out = ep_forward_with_faults(&x, &pw, &cfg, &plan);
        assert_eq!(bits(&out.y.data), bits(&clean.y.data), "run {run}: y must stay clean");
        match &first {
            None => first = Some(plan.stats()),
            Some(st) => assert_eq!(*st, plan.stats(), "seeded chaos must replay exactly"),
        }
    }
}

// ---------------------------------------------------------------------------
// Degraded serving: the extended drop ledger balances, thread-invariantly
// ---------------------------------------------------------------------------

#[test]
fn degraded_serving_ledger_balances_across_ranks_threads_and_policies() {
    let gen = GenConfig {
        seed: 9,
        mode: ArrivalMode::parse("bursty").unwrap(),
        rate: 300.0,
        burst: 3.0,
        burst_period_s: 0.03,
        zipf_s: 1.1,
        min_len: 4,
        max_len: 24,
        vocab: 64,
        noise_pct: 10,
    };
    let requests = generate_requests(&gen, 24);
    let total: usize = requests.iter().map(|r| r.len()).sum();
    let slo = SloPolicy { max_wait_s: 0.004, max_tokens: 48 };
    let (d, h, e, k) = (32usize, 32usize, 8usize, 2usize);
    let mut rng = Rng::seed_from(5);
    let w = MoeWeights::random(d, h, e, &mut rng);
    for ranks in [1usize, 2, 4] {
        for policy in [FailoverPolicy::Reroute, FailoverPolicy::Drop] {
            // batch composition and the ledger are thread-invariant
            let mut reference: Option<(Vec<usize>, usize, usize, usize)> = None;
            for threads in [1usize, 2, 8] {
                let plan = FaultPlan::new(vec![Fault {
                    tick: 1,
                    src: ranks - 1,
                    dst: ANY_DST,
                    kind: FaultKind::CrashRank,
                    attempts: 1,
                }]);
                let engine = ServeEngine::new(
                    PreparedWeights::new(w.clone(), Recipe::Fp8Flow),
                    TokenEmbed::new(gen.vocab, d, 9),
                    ServeConfig {
                        ranks,
                        top_k: k,
                        capacity_factor: 1.0,
                        drop_policy: DropPolicy::parse("capacity").unwrap(),
                        threads,
                        chunks: 1,
                        overlap: false,
                    },
                )
                .with_faults(plan, policy);
                let s = serve_trace(&engine, &requests, &slo);
                let tag = format!("R={ranks} T={threads} {policy:?}");
                let slots = s.rank_rows.iter().sum::<usize>()
                    + s.dropped_slots
                    + s.failed_rank_drops;
                assert_eq!(slots, total * k, "{tag}: extended ledger does not balance");
                assert!(s.degraded_ticks >= 1, "{tag}: the crash never degraded a tick");
                assert!(engine.fault_stats().failovers >= 1, "{tag}: crash not recorded");
                let key =
                    (s.rank_rows.clone(), s.dropped_slots, s.failed_rank_drops, s.served_tokens);
                match &reference {
                    None => reference = Some(key),
                    Some(r) => assert_eq!(*r, key, "{tag}: thread-dependent ledger"),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint resume: bitwise across the rank × thread matrix
// ---------------------------------------------------------------------------

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fp8_flow_prop_fault_{}_{tag}.json", std::process::id()))
}

#[test]
fn checkpoint_resume_is_bitwise_across_ranks_and_thread_budgets() {
    let seed = 31u64;
    for ranks in [1usize, 2, 4] {
        for threads in [1usize, 2, 8] {
            let mut cfg = TrainConfig::tiny();
            cfg.ranks = ranks;
            cfg.threads = threads;
            let tag = format!("R={ranks} T={threads}");

            let mut gold = NativeTrainer::new(cfg, Recipe::Fp8Flow, seed);
            let mut gold_c = Corpus::new(cfg.vocab, seed, 10);
            let gold_out = gold.run(&mut gold_c, 4, 0).unwrap();

            let mut pre = NativeTrainer::new(cfg, Recipe::Fp8Flow, seed);
            let mut pre_c = Corpus::new(cfg.vocab, seed, 10);
            let pre_out = pre.run(&mut pre_c, 2, 0).unwrap();
            let path = ckpt_path(&format!("r{ranks}_t{threads}"));
            save_checkpoint(&pre, &pre_c, &path).unwrap();
            drop(pre); // the simulated crash

            // different init seed: restore must overwrite every stream
            let mut post = NativeTrainer::new(cfg, Recipe::Fp8Flow, seed ^ 0xDEAD);
            let mut post_c = Corpus::new(cfg.vocab, seed ^ 0xDEAD, 10);
            let at = restore_trainer(&mut post, &mut post_c, &path).unwrap();
            assert_eq!(at, 2, "{tag}: resumed at the wrong step");
            let post_out = post.run(&mut post_c, 2, 0).unwrap();
            let _ = std::fs::remove_file(&path);

            let replay: Vec<u32> =
                pre_out.losses.iter().chain(&post_out.losses).map(|l| l.to_bits()).collect();
            let gold_bits: Vec<u32> = gold_out.losses.iter().map(|l| l.to_bits()).collect();
            assert_eq!(replay, gold_bits, "{tag}: loss trajectory diverged across the crash");
            assert_eq!(bits(&gold.embed.data), bits(&post.embed.data), "{tag}: embed");
            assert_eq!(bits(&gold.head.data), bits(&post.head.data), "{tag}: head");
            for ex in 0..cfg.n_experts {
                assert_eq!(
                    gold.pw.w1_t[ex].data, post.pw.w1_t[ex].data,
                    "{tag}: w1_t[{ex}] codes"
                );
                assert_eq!(
                    gold.pw.w1_t[ex].sexp, post.pw.w1_t[ex].sexp,
                    "{tag}: w1_t[{ex}] scale exponents"
                );
            }
        }
    }
}
