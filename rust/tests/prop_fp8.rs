//! Property-test suite over the FP8 numeric substrate — the crate-level
//! invariants of DESIGN.md §6, run through the seeded property harness
//! (`PROP_CASES` env scales case counts; failures print a replay seed).

use fp8_flow_moe::fp8::tile::{quantize_rowwise, quantize_vec};
use fp8_flow_moe::fp8::transpose::{direct_transpose, naive_transpose, unaware_transpose};
use fp8_flow_moe::fp8::{e4m3, e5m2, Fp8Format, ScaleMode, TILE};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::prop::props;
use fp8_flow_moe::util::rng::Rng;

#[test]
fn prop_encode_decode_galois() {
    // decode∘encode is idempotent: encode(decode(encode(x))) == encode(x)
    props("e4m3 galois", 512, |g| {
        let x = g.f32_wide();
        let c = e4m3::encode(x);
        let c2 = e4m3::encode(e4m3::decode(c));
        if e4m3::is_nan(c) {
            // NaN sign is not preserved through f32 (canonical NaN)
            assert!(e4m3::is_nan(c2), "x={x} c={c:#04x}");
        } else {
            assert_eq!(c2, c, "x={x} c={c:#04x}");
        }
    });
}

#[test]
fn prop_encode_monotone() {
    props("e4m3 monotone", 512, |g| {
        let a = g.f32_wide();
        let b = g.f32_wide();
        if !a.is_finite() || !b.is_finite() {
            return;
        }
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (cl, ch) = (e4m3::decode(e4m3::encode(lo)), e4m3::decode(e4m3::encode(hi)));
        if cl.is_nan() || ch.is_nan() {
            return; // overflow → NaN (|x| > 464)
        }
        assert!(cl <= ch, "monotonicity: {lo} -> {cl}, {hi} -> {ch}");
    });
}

#[test]
fn prop_decode_within_half_ulp() {
    // |x − D(E(x))| ≤ max(|x|/16, half subnormal) for in-range x
    props("e4m3 half-ulp", 512, |g| {
        let x = g.rng.range_f32(-400.0, 400.0);
        let d = e4m3::decode(e4m3::encode(x));
        let tol = (x.abs() / 16.0).max(0.5 * e4m3::MIN_SUBNORMAL);
        assert!((x - d).abs() <= tol * (1.0 + 1e-6), "x={x} d={d}");
    });
}

#[test]
fn prop_e5m2_wider_coarser() {
    props("e5m2 vs e4m3 tradeoff", 256, |g| {
        let x = g.rng.range_f32(1.0, 400.0);
        let d4 = (e4m3::decode(e4m3::encode(x)) - x).abs();
        let d5 = (e5m2::decode(e5m2::encode(x)) - x).abs();
        // same magnitude range: e4m3 is at least as precise
        assert!(d4 <= d5 + 1e-6, "x={x}: e4m3 err {d4} vs e5m2 err {d5}");
    });
}

#[test]
fn prop_scale_down_conserves_value() {
    // scale_down_code(c, k) represents decode(c)·2^-k exactly or to the
    // nearest subnormal grid point
    props("scale_down semantics", 512, |g| {
        let c = (g.rng.next_u64() & 0xFF) as u8;
        let k = (g.rng.next_u64() % 16) as u32;
        if e4m3::is_nan(c) {
            assert!(e4m3::is_nan(e4m3::scale_down_code(c, k)));
            return;
        }
        let want = e4m3::decode(c) * (-(k as f32)).exp2();
        let got = e4m3::decode(e4m3::scale_down_code(c, k));
        let tol = 0.5 * e4m3::MIN_SUBNORMAL;
        assert!((want - got).abs() <= tol, "c={c:#04x} k={k}: want {want} got {got}");
    });
}

#[test]
fn prop_quantize_never_overflows() {
    // the quantizer's scale choice keeps every payload finite, both modes
    props("no payload overflow", 128, |g| {
        let n = TILE * g.usize_in(1, 4);
        let xs: Vec<f32> = g
            .vec_of(n, |g| g.f32_wide())
            .iter()
            .map(|&v| if v.is_finite() { v } else { 0.0 })
            .collect();
        for mode in [ScaleMode::Float, ScaleMode::Po2] {
            let q = quantize_vec(&xs, Fp8Format::E4M3, mode);
            assert!(q.data.iter().all(|&c| !e4m3::is_nan(c)), "{mode:?}");
        }
    });
}

#[test]
fn prop_direct_transpose_value_preserving() {
    // the paper's core claim, property-tested over random shapes/data:
    // D(direct_T(Q)) == D(Q)ᵀ up to bounded subnormal underflow
    props("direct transpose lossless", 24, |g| {
        let m = g.usize_in(1, 3) * 64;
        let n = g.usize_in(1, 3) * 64;
        let mut rng = Rng::seed_from(g.seed ^ 0xD17EC7);
        let spread = g.usize_in(2, 8) as f32;
        let x = Mat::rand_log_uniform(m, n, -spread, spread, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let t = direct_transpose(&q);
        let dq = q.dequantize();
        let dt = t.dequantize();
        for i in 0..m {
            for j in 0..n {
                let tol = 0.5 * e4m3::MIN_SUBNORMAL * t.scale_at(j, i);
                assert!(
                    (dq.at(i, j) - dt.at(j, i)).abs() <= tol,
                    "({i},{j}) {} vs {}",
                    dq.at(i, j),
                    dt.at(j, i)
                );
            }
        }
    });
}

#[test]
fn prop_double_transpose_identity_in_value_space() {
    props("transpose involution", 16, |g| {
        let m = g.usize_in(1, 2) * 128;
        let n = g.usize_in(1, 2) * 128;
        let mut rng = Rng::seed_from(g.seed ^ 0xB0B);
        let x = Mat::rand_log_uniform(m, n, -4.0, 4.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let tt = direct_transpose(&direct_transpose(&q));
        let rel = tt.dequantize().rel_err(&q.dequantize());
        assert!(rel < 1e-3, "rel={rel}");
    });
}

#[test]
fn prop_wgrad_operand_double_quantization_ordering() {
    // The backward's wgrad operands are transposed FP8 tensors; this locks
    // in the error ordering of the three preparation strategies (the
    // paper's Table 1 / Eq. 1 story, at the operand level):
    //
    //   direct (po2)        — bitwise scale-consistent: every element
    //                         survives exactly, up to ≤ half a subnormal
    //                         grid unit at the aligned scale;
    //   naive (float)       — dequantize→transpose→requantize re-rounds
    //                         onto an incommensurate grid (nonzero error);
    //   unaware (po2)       — scale-ignoring byte transpose: strictly the
    //                         largest max-ulp error.
    props("wgrad operand DQE ordering", 12, |g| {
        let m = g.usize_in(16, 200); // ≥ several rows per scale block so
        let n = g.usize_in(64, 300); // intra-block scale variance exists
        let mut rng = Rng::seed_from(g.seed ^ 0xD0E);
        let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);

        // --- direct path: bitwise scale-consistent ---
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let dq = q.dequantize();
        let dt = direct_transpose(&q);
        let dtd = dt.dequantize();
        let ut = unaware_transpose(&q);
        let utd = ut.dequantize();
        let mut direct_max_ulp = 0.0f64;
        let mut unaware_max_ulp = 0.0f64;
        let mut exact = 0usize;
        for i in 0..m {
            for j in 0..n {
                let v = dq.at(i, j) as f64;
                let unit_d = (e4m3::MIN_SUBNORMAL * dt.scale_at(j, i)) as f64;
                direct_max_ulp = direct_max_ulp.max((v - dtd.at(j, i) as f64).abs() / unit_d);
                if dq.at(i, j).to_bits() == dtd.at(j, i).to_bits() {
                    exact += 1;
                }
                let unit_u = (e4m3::MIN_SUBNORMAL * ut.scale_at(j, i)) as f64;
                unaware_max_ulp = unaware_max_ulp.max((v - utd.at(j, i) as f64).abs() / unit_u);
            }
        }
        // direct: bounded subnormal underflow only, almost all bit-exact
        assert!(direct_max_ulp <= 0.5 + 1e-9, "direct max ulp {direct_max_ulp}");
        assert!(exact * 10 >= m * n * 9, "direct exact {exact}/{}", m * n);
        // unaware: strictly larger max-ulp error (the Table 1 ordering)
        assert!(
            unaware_max_ulp > direct_max_ulp,
            "unaware {unaware_max_ulp} must exceed direct {direct_max_ulp}"
        );

        // --- relative-Frobenius chain across the three strategies ---
        let qf = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        let ref_t = qf.dequantize().transpose();
        let rel_naive_float = naive_transpose(&qf).dequantize().rel_err(&ref_t);
        let rel_direct = dtd.rel_err(&dq.transpose());
        let rel_unaware = utd.rel_err(&dq.transpose());
        assert!(rel_naive_float > 1e-4, "float naive must show DQE: {rel_naive_float}");
        assert!(rel_direct < rel_naive_float, "direct {rel_direct} vs naive {rel_naive_float}");
        assert!(
            rel_unaware > rel_naive_float,
            "unaware {rel_unaware} must exceed float-naive {rel_naive_float}"
        );
    });
}

#[test]
fn prop_naive_transpose_error_bounded_by_one_rounding() {
    // even the WORST recipe's double-quant error is bounded by two
    // independent roundings: rel ≤ 2·(1/16) per element ⇒ rel_fro ≤ 0.13
    props("naive transpose bounded", 24, |g| {
        let mut rng = Rng::seed_from(g.seed ^ 0xAA);
        let x = Mat::rand_log_uniform(128, 128, -5.0, 5.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        let nt = naive_transpose(&q);
        let rel = nt.dequantize().rel_err(&q.dequantize().transpose());
        assert!(rel < 0.13, "rel={rel}");
    });
}
