//! **Serving-loop throughput** — wall-clock of the heavy-traffic path
//! (seeded trace → SLO micro-batching → EP-sharded forward per tick) per
//! recipe, across arrival modes and the capacity-factor axis, plus a
//! serialized-vs-overlapped pair at the largest rank count.
//!
//! ```bash
//! cargo bench --bench serve [-- --requests N --ranks R --quick]
//! ```
//!
//! The `ROW serve/...` lines feed `rust/EXPERIMENTS.md` §Serving; the
//! bit-identity and drop-ledger contracts these runs ride on are pinned
//! by `tests/prop_serve.rs`, so this harness only measures.

use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::serve::{
    generate_requests, serve_trace, ArrivalMode, DropPolicy, GenConfig, ServeConfig, ServeEngine,
    SloPolicy, TokenEmbed,
};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::rng::Rng;

fn main() {
    // default --threads 0 (auto): the tick forward shares the rank budget
    let (b, args) = bencher_from_cli(0);
    let n_requests = args.usize_or("requests", if args.flag("quick") { 32 } else { 128 });
    let d_model = args.usize_or("d-model", 128);
    let ffn = args.usize_or("ffn", 128);
    let experts = args.usize_or("experts", 8);
    let top_k = args.usize_or("top-k", 2);
    let ranks = args.usize_or("ranks", 2).min(experts);
    let chunks = args.usize_or("chunks", 2);
    let seed = args.u64_or("seed", 42);

    let mut rng = Rng::seed_from(seed);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let slo = SloPolicy { max_wait_s: 0.005, max_tokens: 128 };
    let mk_engine = |recipe, ranks, cf, chunks, overlap| {
        ServeEngine::new(
            PreparedWeights::new(w.clone(), recipe),
            TokenEmbed::new(64, d_model, seed),
            ServeConfig {
                ranks,
                top_k,
                capacity_factor: cf,
                drop_policy: DropPolicy::Capacity,
                threads: 0,
                chunks,
                overlap,
            },
        )
    };

    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        // arrival-mode axis at cf = 1.0
        let mut rows = Vec::new();
        for mode in [ArrivalMode::Poisson, ArrivalMode::Bursty] {
            let reqs = generate_requests(&GenConfig { mode, seed, ..GenConfig::default() }, n_requests);
            let tokens: usize = reqs.iter().map(|r| r.len()).sum();
            let eng = mk_engine(recipe, ranks, 1.0, 1, false);
            rows.push(b.run_bytes(
                &format!("serve/{recipe:?}/R={ranks}/{}", mode.name()),
                (tokens * 4 * d_model) as u64,
                || {
                    std::hint::black_box(serve_trace(
                        std::hint::black_box(&eng),
                        std::hint::black_box(&reqs),
                        &slo,
                    ));
                },
            ));
        }
        print_table(
            &format!("serve {recipe:?} (requests={n_requests} R={ranks} E={experts})"),
            &rows,
        );

        // capacity-factor axis: the throughput/drop trade under burst load
        let reqs = generate_requests(
            &GenConfig { mode: ArrivalMode::Bursty, seed, ..GenConfig::default() },
            n_requests,
        );
        let tokens: usize = reqs.iter().map(|r| r.len()).sum();
        let mut cf_rows = Vec::new();
        for cf in [0.5, 1.0, 1.5] {
            let eng = mk_engine(recipe, ranks, cf, 1, false);
            cf_rows.push(b.run_bytes(
                &format!("serve/{recipe:?}/cf={cf}"),
                (tokens * 4 * d_model) as u64,
                || {
                    std::hint::black_box(serve_trace(
                        std::hint::black_box(&eng),
                        std::hint::black_box(&reqs),
                        &slo,
                    ));
                },
            ));
        }
        print_table(&format!("serve {recipe:?} capacity-factor sweep"), &cf_rows);

        // serialized vs the PR 7 overlap pipeline on the same trace
        let mut pair = Vec::new();
        for (label, c, ov) in [("serialized", 1usize, false), ("overlapped", chunks, true)] {
            let eng = mk_engine(recipe, ranks, 1.0, c, ov);
            pair.push(b.run_bytes(
                &format!("serve/{recipe:?}/R={ranks}/{label}"),
                (tokens * 4 * d_model) as u64,
                || {
                    std::hint::black_box(serve_trace(
                        std::hint::black_box(&eng),
                        std::hint::black_box(&reqs),
                        &slo,
                    ));
                },
            ));
        }
        print_table(&format!("serve {recipe:?} overlap (R={ranks} C={chunks})"), &pair);
        print_speedup(&format!("{recipe:?} serialized -> overlapped"), &pair[0], &pair[1]);
        println!();
    }
}
