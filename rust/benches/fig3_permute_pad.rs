//! **Fig. 3** — fused permute+padding vs the two-pass baseline (forward
//! dispatch direction). Paper: up to 1.7× from fusing the two
//! element-wise row moves into one streamed pass.

use fp8_flow_moe::moe::permute::{permute_pad, permute_pad_plan, permute_then_pad};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    // default to serial kernels: the unfused baselines are serial, so the
    // figure's SPEEDUP must isolate fusion (override with --threads N)
    let (b, _args) = bencher_from_cli(1);
    // (tokens, hidden, experts) — MoE dispatch shapes
    let configs = [(4096usize, 1024usize, 8usize), (8192, 1024, 16), (8192, 2048, 32)];
    let mut rows = Vec::new();
    println!("Fig. 3 — fused vs unfused permute+pad (paper: up to 1.7x fwd)");
    for (t, h, e) in configs {
        let mut rng = Rng::seed_from(3);
        let x = Mat::randn(t, h, 1.0, &mut rng);
        let expert_of: Vec<usize> = (0..t).map(|_| rng.below(e)).collect();
        let cap = (t / e) * 2;
        let plan = permute_pad_plan(&expert_of, e, cap);
        let bytes = (t * h * 4) as u64;
        let unfused = b.run_bytes(&format!("unfused {t}x{h} E{e}"), bytes, || {
            black_box(permute_then_pad(black_box(&x), black_box(&plan)));
        });
        let fused = b.run_bytes(&format!("fused {t}x{h} E{e}"), bytes, || {
            black_box(permute_pad(black_box(&x), black_box(&plan)));
        });
        print_speedup(&format!("{t}x{h} E{e}"), &unfused, &fused);
        rows.push(unfused);
        rows.push(fused);
    }
    print_table("fig3_permute_pad", &rows);
}
