//! **Native training step** — wall-clock of one full optimization step
//! per recipe with the per-stage split (fwd / bwd / opt), and the
//! step/fwd ratio that extends PR 3's bwd/fwd `RATIO` calibration lines
//! to the whole training loop (the optimizer adds the master update +
//! the masters→FP8 weight requantization on top of fwd+bwd).
//!
//! ```bash
//! cargo bench --bench train_step [-- --cfg tiny|small --threads T --quick]
//! ```

use fp8_flow_moe::moe::layer::Recipe;
use fp8_flow_moe::train::{Corpus, NativeTrainer, TrainConfig};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_table};

fn main() {
    let (b, args) = bencher_from_cli(0);
    let cfg_name = args.get_or("cfg", if args.flag("quick") { "tiny" } else { "small" });
    let cfg = TrainConfig::named(&cfg_name)
        .unwrap_or_else(|| panic!("unknown --cfg {cfg_name:?} (want tiny|small)"));
    let seed = args.u64_or("seed", 42);

    println!(
        "train_step/{cfg_name}: [{}, {}] tokens, top-{} over {} experts",
        cfg.batch, cfg.seq, cfg.top_k, cfg.n_experts
    );

    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let mut trainer = NativeTrainer::new(cfg, recipe, seed);
        let mut corpus = Corpus::new(cfg.vocab, seed, 10);
        let tokens = corpus.next_batch(cfg.batch, cfg.seq);
        // warm the optimizer state so steady-state steps are measured
        trainer.step_batch(&tokens);
        let step = b.run(&format!("train_step/{recipe:?}"), || {
            std::hint::black_box(trainer.step_batch(std::hint::black_box(&tokens)));
        });
        print_table(&format!("train step {recipe:?} ({cfg_name})"), &[step.clone()]);

        // per-stage means over the measured steps (TrainMetrics timers)
        let ms = &trainer.metrics[1..]; // skip the warmup step
        let n = ms.len().max(1) as f64;
        let (fwd, bwd, opt) = ms.iter().fold((0.0, 0.0, 0.0), |(f, w, o), m| {
            (f + m.fwd_s, w + m.bwd_s, o + m.opt_s)
        });
        let (fwd, bwd, opt) = (fwd / n * 1e3, bwd / n * 1e3, opt / n * 1e3);
        println!(
            "ROW {recipe:?} fwd {fwd:>9.4} ms | bwd {bwd:>9.4} ms | opt {opt:>9.4} ms"
        );
        println!(
            "RATIO {recipe:?} step/fwd: {:.2}x  (bwd/fwd {:.2}x, opt/fwd {:.2}x)",
            (fwd + bwd + opt) / fwd,
            bwd / fwd,
            opt / fwd,
        );
        println!();
    }
}
