//! **Fig. 4** — fused unpermute+unpadding vs the two-pass baseline
//! (backward/combine direction). Paper: up to 6.6× on large configs (the
//! baseline materializes a compact intermediate before scattering).

use fp8_flow_moe::moe::permute::{
    permute_pad, permute_pad_plan, unpad_then_unpermute, unpermute_unpad,
};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    // default to serial kernels: the unfused baselines are serial, so the
    // figure's SPEEDUP must isolate fusion (override with --threads N)
    let (b, _args) = bencher_from_cli(1);
    let configs = [(4096usize, 1024usize, 8usize), (8192, 1024, 16), (8192, 2048, 32)];
    let mut rows = Vec::new();
    println!("Fig. 4 — fused vs unfused unpermute+unpad (paper: up to 6.6x bwd)");
    for (t, h, e) in configs {
        let mut rng = Rng::seed_from(4);
        let x = Mat::randn(t, h, 1.0, &mut rng);
        let expert_of: Vec<usize> = (0..t).map(|_| rng.below(e)).collect();
        let cap = (t / e) * 2;
        let plan = permute_pad_plan(&expert_of, e, cap);
        let y = permute_pad(&x, &plan); // expert-side buffer to scatter back
        let bytes = (t * h * 4) as u64;
        let unfused = b.run_bytes(&format!("unfused {t}x{h} E{e}"), bytes, || {
            black_box(unpad_then_unpermute(black_box(&y), black_box(&plan), t));
        });
        let fused = b.run_bytes(&format!("fused {t}x{h} E{e}"), bytes, || {
            black_box(unpermute_unpad(black_box(&y), black_box(&plan), t));
        });
        print_speedup(&format!("{t}x{h} E{e}"), &unfused, &fused);
        rows.push(unfused);
        rows.push(fused);
    }
    print_table("fig4_unpermute", &rows);
}
