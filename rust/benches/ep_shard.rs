//! **Executed EP sharding** — wall-clock scaling of the rank-group
//! runtime across simulated rank counts, per recipe, with the per-stage
//! measured-vs-modeled report the simulator can be calibrated against.
//!
//! ```bash
//! cargo bench --bench ep_shard [-- --tokens N --ranks-max R --chunks C --quick]
//! ```
//!
//! Besides rank scaling, the max-rank point is re-run with the
//! double-buffered slot pipeline (`--chunks`, default 2) and reported as
//! a serialized-vs-overlapped pair plus the measured-vs-modeled overlap
//! efficiency block.

use fp8_flow_moe::cluster::ep_exec::{ep_forward, EpConfig, EpShape};
use fp8_flow_moe::cluster::sim::{ep_measured_vs_modeled, ep_overlap_report};
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    // default --threads 0 (auto): rank scaling needs the full budget
    let (b, args) = bencher_from_cli(0);
    let tokens = args.usize_or("tokens", if args.flag("quick") { 256 } else { 1024 });
    let d_model = args.usize_or("d-model", 256);
    let ffn = args.usize_or("ffn", 256);
    let experts = args.usize_or("experts", 8);
    let top_k = args.usize_or("top-k", 2);
    let capacity = args.usize_or("capacity", (tokens * top_k).div_ceil(experts));
    let ranks_max = args.usize_or("ranks-max", 4).min(experts);
    let chunks = args.usize_or("chunks", 2);

    let mut rng = Rng::seed_from(42);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);

    let mut rank_counts = vec![1usize];
    while *rank_counts.last().unwrap() * 2 <= ranks_max {
        let next = rank_counts.last().unwrap() * 2;
        rank_counts.push(next);
    }

    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let mut rows = Vec::new();
        for &ranks in &rank_counts {
            let cfg = EpConfig::serial(ranks, top_k, capacity, 0);
            let bytes = (tokens * top_k * d_model * 2) as u64; // combine-wire bytes/iter
            rows.push(b.run_bytes(
                &format!("ep_forward/{recipe:?}/R={ranks}"),
                bytes,
                || {
                    std::hint::black_box(ep_forward(
                        std::hint::black_box(&x),
                        std::hint::black_box(&pw),
                        &cfg,
                    ));
                },
            ));
        }
        print_table(
            &format!("ep_shard {recipe:?} (tokens={tokens} E={experts} cap={capacity})"),
            &rows,
        );
        if rows.len() > 1 {
            print_speedup(&format!("{recipe:?} R=1 -> R={}", rank_counts[rows.len() - 1]),
                &rows[0], &rows[rows.len() - 1]);
        }
        // one representative per-stage measured-vs-modeled report
        let ranks = *rank_counts.last().unwrap();
        let cfg = EpConfig::serial(ranks, top_k, capacity, 0);
        let shape = EpShape::of(&x, &pw, &cfg);
        let out = ep_forward(&x, &pw, &cfg);
        print!("{}", ep_measured_vs_modeled(recipe, ranks, &shape, &out));
        println!();

        // serialized vs double-buffered (C=2) at max ranks: measured
        // overlap efficiency beside the modeled pipelined wall, plus a
        // throughput row pair so the speedup is visible in bench units
        let over_cfg = cfg.with_pipeline(chunks, true);
        let mut pair = Vec::new();
        for (label, c) in [("serialized", &cfg), ("overlapped", &over_cfg)] {
            pair.push(b.run_bytes(
                &format!("ep_forward/{recipe:?}/R={ranks}/{label}"),
                (tokens * top_k * d_model * 2) as u64,
                || {
                    std::hint::black_box(ep_forward(
                        std::hint::black_box(&x),
                        std::hint::black_box(&pw),
                        c,
                    ));
                },
            ));
        }
        print_table(
            &format!("ep_shard {recipe:?} overlap (R={ranks} C={chunks})"),
            &pair,
        );
        print_speedup(&format!("{recipe:?} serialized -> overlapped"), &pair[0], &pair[1]);
        let over = ep_forward(&x, &pw, &over_cfg);
        print!("{}", ep_overlap_report(recipe, ranks, &shape, &out, &over));
        println!();
    }
}
