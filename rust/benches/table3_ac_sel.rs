//! **Table 3** — end-to-end 671B throughput/memory under AC=sel(+MoE
//! expert): the memory-efficiency headline (−8 GB vs BF16, −16.5 GB vs
//! Blockwise at EP8; baselines OOM at EP32, FP8-Flow survives).

use fp8_flow_moe::cluster::memory::AcMode;
use fp8_flow_moe::cluster::model_cfg::DEEPSEEK_V3;
use fp8_flow_moe::cluster::sim::simulate;
use fp8_flow_moe::coordinator::reports;
use fp8_flow_moe::moe::layer::Recipe;
use fp8_flow_moe::util::cli::Args;

fn main() {
    // analytic report: accepts --threads for CLI uniformity (no kernels run)
    fp8_flow_moe::exec::set_threads(Args::from_env().usize_or("threads", 0));
    print!("{}", reports::table3());
    println!();
    let bf16 = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Bf16, AcMode::SelMoeExpert).mem_gb;
    let block = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Blockwise, AcMode::SelMoeExpert).mem_gb;
    let flow = simulate(&DEEPSEEK_V3, 8, 32, Recipe::Fp8Flow, AcMode::SelMoeExpert).mem_gb;
    println!("memory savings at EP8 (paper: 8 GB vs BF16, 16.5 GB vs Blockwise):");
    println!("  vs BF16:      {:.1} GB", bf16 - flow);
    println!("  vs Blockwise: {:.1} GB", block - flow);
    println!();
    println!("OOM pattern at EP32 (paper: BF16 OOM, Blockwise OOM, FP8-Flow survives):");
    for r in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let s = simulate(&DEEPSEEK_V3, 32, 8, r, AcMode::SelMoeExpert);
        println!("  {:<12} {:>6.1} GB  {}", format!("{r:?}"), s.mem_gb, if s.oom { "OOM" } else { "ok" });
    }
}
