//! **Table 1** — communication performance with Q/DQ accounting on the
//! DeepEP-style all-to-all cost model, side-by-side with the paper's
//! measured numbers (shape fidelity: speedup bands and the erosion
//! pattern, not absolute ms).

use fp8_flow_moe::coordinator::reports;
use fp8_flow_moe::util::cli::Args;

fn main() {
    // analytic report: accepts --threads for CLI uniformity (no kernels run)
    fp8_flow_moe::exec::set_threads(Args::from_env().usize_or("threads", 0));
    print!("{}", reports::table1());
    println!();
    println!("shape checks (paper's findings):");
    use fp8_flow_moe::cluster::comm::{table1_row, TABLE1_CONFIGS};
    let mut comm_ok = 0;
    let mut erosion_ok = 0;
    for &(m, n, ep) in &TABLE1_CONFIGS {
        let r = table1_row(m, n, ep);
        if r.speedup_comm > 1.0 && r.speedup_comm < 2.0 {
            comm_ok += 1;
        }
        if r.speedup_all < r.speedup_comm {
            erosion_ok += 1;
        }
    }
    println!("  FP8 comm speedup in (1.0, 2.0): {comm_ok}/9 rows");
    println!("  Q/DQ erodes the gain:           {erosion_ok}/9 rows");
}
