//! **Executed backward pass** — wall-clock of the stashing forward vs the
//! full backward per recipe, the grouped scaling-aware transpose stage in
//! isolation, and the measured bwd/fwd ratio that calibrates the cluster
//! simulator (`cluster/sim.rs` charges `gemm_bwd = 2 × gemm_fwd` for
//! dgrad+wgrad — the printed `RATIO` lines are the executed check on that
//! assumption; movement-heavy shapes land above 2× because the backward
//! also pays the wgrad-operand transposes).
//!
//! ```bash
//! cargo bench --bench bwd [-- --tokens N --threads T --quick]
//! ```

use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::grouped_direct_transpose;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward};
use fp8_flow_moe::moe::layer::{MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    let (b, args) = bencher_from_cli(0);
    let tokens = args.usize_or("tokens", if args.flag("quick") { 128 } else { 512 });
    let d_model = args.usize_or("d-model", 256);
    let ffn = args.usize_or("ffn", 256);
    let experts = args.usize_or("experts", 8);
    let top_k = args.usize_or("top-k", 2);
    let capacity = args.usize_or("capacity", (tokens * top_k).div_ceil(experts));

    let mut rng = Rng::seed_from(42);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let dy = Mat::randn(tokens, d_model, 1.0, &mut rng);

    for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
        let pw = PreparedWeights::new(w.clone(), recipe);
        let fwd = b.run(&format!("forward_stash/{recipe:?}"), || {
            std::hint::black_box(forward_stash(
                std::hint::black_box(&x),
                std::hint::black_box(&pw),
                top_k,
                capacity,
            ));
        });
        let stash = forward_stash(&x, &pw, top_k, capacity);
        let bwd = b.run(&format!("moe_backward/{recipe:?}"), || {
            std::hint::black_box(moe_backward(
                std::hint::black_box(&stash),
                std::hint::black_box(&pw),
                std::hint::black_box(&dy),
            ));
        });
        print_table(
            &format!("bwd {recipe:?} (tokens={tokens} E={experts} cap={capacity})"),
            &[fwd.clone(), bwd.clone()],
        );
        println!(
            "RATIO {recipe:?} bwd/fwd: {:.2}x  (sim charges dgrad+wgrad as 2.0x the fwd GEMM)",
            bwd.median.as_secs_f64() / fwd.median.as_secs_f64()
        );
        println!();
    }

    // the wgrad-operand prep stage in isolation: batched scaling-aware
    // transpose over the expert slabs of a dispatched [E·cap, h] buffer
    let act = Mat::rand_log_uniform(experts * capacity, ffn, -4.0, 4.0, &mut rng);
    let aq = quantize_rowwise(&act, Fp8Format::E4M3, ScaleMode::Po2);
    let rows: Vec<_> = [1usize, fp8_flow_moe::exec::threads()]
        .iter()
        .map(|&t| {
            b.run_bytes(
                &format!("grouped_direct_transpose/E={experts}/t={t}"),
                aq.data.len() as u64,
                || {
                    std::hint::black_box(grouped_direct_transpose(
                        std::hint::black_box(&aq),
                        experts,
                        t,
                    ));
                },
            )
        })
        .collect();
    print_table("grouped wgrad-operand transpose", &rows);
}
