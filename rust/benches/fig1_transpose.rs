//! **Fig. 1** — latency of the two row→column FP8 conversion strategies:
//! naive dequantize→transpose→requantize vs the scaling-aware direct
//! transpose. Paper: direct is 2–3× faster across all tensor shapes.
//!
//! Shapes are the paper's aspect ratios scaled to the CPU testbed
//! (DESIGN.md §Hardware-Adaptation); the claim under test is the *factor*.

use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::{direct_transpose, naive_transpose};
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    // default to serial kernels: the unfused baselines are serial, so the
    // figure's SPEEDUP must isolate fusion (override with --threads N)
    let (b, _args) = bencher_from_cli(1);
    let shapes = [(1024usize, 2048usize), (2048, 2048), (2048, 5120), (4096, 2048)];
    let mut rows = Vec::new();
    println!("Fig. 1 — direct vs naive FP8 transpose (paper: 2-3x)");
    for (m, n) in shapes {
        let mut rng = Rng::seed_from(1);
        let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let bytes = (m * n) as u64;
        let naive = b.run_bytes(&format!("naive {m}x{n}"), bytes, || {
            black_box(naive_transpose(black_box(&q)));
        });
        let direct = b.run_bytes(&format!("direct {m}x{n}"), bytes, || {
            black_box(direct_transpose(black_box(&q)));
        });
        print_speedup(&format!("{m}x{n}"), &naive, &direct);
        rows.push(naive);
        rows.push(direct);
    }
    print_table("fig1_transpose", &rows);
}
