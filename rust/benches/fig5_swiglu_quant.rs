//! **Fig. 5** — fused SwiGLU+quantization vs standalone SwiGLU (and vs the
//! unfused SwiGLU→quantize pair). Paper: the fused kernel's latency is
//! nearly identical to the standalone SwiGLU while already emitting FP8
//! payload+scales — i.e. the quantization becomes free.

use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::swiglu::{swiglu, swiglu_quant, swiglu_then_quant};
use fp8_flow_moe::util::bench::{bencher_from_cli, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    // default to serial kernels: the unfused baselines are serial, so the
    // figure's SPEEDUP must isolate fusion (override with --threads N)
    let (b, _args) = bencher_from_cli(1);
    let shapes = [(2048usize, 1408usize), (4096, 2048), (8192, 2048)];
    let mut rows = Vec::new();
    println!("Fig. 5 — fused swiglu+quant vs standalone swiglu (paper: ~equal)");
    for (m, n) in shapes {
        let mut rng = Rng::seed_from(5);
        let gate = Mat::randn(m, n, 1.0, &mut rng);
        let up = Mat::randn(m, n, 1.0, &mut rng);
        let bytes = (m * n * 8) as u64;
        let alone = b.run_bytes(&format!("swiglu-only {m}x{n}"), bytes, || {
            black_box(swiglu(black_box(&gate), black_box(&up)));
        });
        let fused = b.run_bytes(&format!("fused swiglu+quant {m}x{n}"), bytes, || {
            black_box(swiglu_quant(black_box(&gate), black_box(&up), Fp8Format::E4M3, ScaleMode::Po2));
        });
        let unfused = b.run_bytes(&format!("swiglu->quant 2pass {m}x{n}"), bytes, || {
            black_box(swiglu_then_quant(black_box(&gate), black_box(&up), Fp8Format::E4M3, ScaleMode::Po2));
        });
        let overhead = fused.median.as_secs_f64() / alone.median.as_secs_f64();
        let vs_unfused = unfused.median.as_secs_f64() / fused.median.as_secs_f64();
        println!(
            "SPEEDUP {m}x{n}: fused/standalone = {overhead:.2}x (paper ~1.0x), unfused/fused = {vs_unfused:.2}x"
        );
        rows.push(alone);
        rows.push(fused);
        rows.push(unfused);
    }
    print_table("fig5_swiglu_quant", &rows);
}
