//! Hot-path kernel microbenchmarks (§Perf): throughput of the native
//! quantizer, codec, direct transpose and FP8 GEMM, with a `memcpy`
//! roofline reference for the movement kernels. This is the bench the
//! EXPERIMENTS.md §Perf iteration log quotes.

use fp8_flow_moe::fp8::tile::quantize_rowwise;
use fp8_flow_moe::fp8::transpose::direct_transpose;
use fp8_flow_moe::fp8::{e4m3, Fp8Format, ScaleMode};
use fp8_flow_moe::moe::gemm::fp8_matmul;
use fp8_flow_moe::util::bench::{print_table, Bencher};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let b = Bencher::default();
    let mut rows = Vec::new();
    let (m, n) = (2048usize, 2048usize);
    let mut rng = Rng::seed_from(9);
    let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);

    // memcpy roofline reference (same bytes as the u8 transpose)
    let src = vec![7u8; m * n];
    let mut dst = vec![0u8; m * n];
    rows.push(b.run_bytes("memcpy u8 (roofline ref)", (m * n) as u64, || {
        dst.copy_from_slice(black_box(&src));
        black_box(&dst);
    }));

    // codec throughput
    let codes: Vec<u8> = (0..m * n).map(|i| (i % 255) as u8).collect();
    rows.push(b.run_bytes("decode LUT", (m * n) as u64, || {
        let s: f32 = codes.iter().map(|&c| e4m3::DECODE_LUT[c as usize]).sum();
        black_box(s);
    }));
    rows.push(b.run_bytes("encode RNE", (m * n * 4) as u64, || {
        let mut acc = 0u32;
        for &v in &x.data {
            acc = acc.wrapping_add(e4m3::encode(v) as u32);
        }
        black_box(acc);
    }));

    // quantizer (read f32, write u8+scales)
    rows.push(b.run_bytes("quantize_rowwise po2", (m * n * 5) as u64, || {
        black_box(quantize_rowwise(black_box(&x), Fp8Format::E4M3, ScaleMode::Po2));
    }));

    // direct transpose (u8 in, u8 out)
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    rows.push(b.run_bytes("direct_transpose", (2 * m * n) as u64, || {
        black_box(direct_transpose(black_box(&q)));
    }));

    // fp8 GEMM (compute-bound)
    let w = quantize_rowwise(&Mat::randn(256, n, 1.0, &mut rng), Fp8Format::E4M3, ScaleMode::Po2);
    let gemm = b.run(&format!("fp8_matmul {m}x{n}x256"), || {
        black_box(fp8_matmul(black_box(&q), black_box(&w)));
    });
    let flops = 2.0 * (m * n * 256) as f64;
    println!(
        "fp8_matmul: {:.2} GFLOP/s",
        flops / gemm.median.as_secs_f64() / 1e9
    );
    rows.push(gemm);

    print_table("perf_kernels", &rows);
}
