//! Hot-path kernel microbenchmarks (§Perf): throughput of the native
//! quantizer, codec, direct transpose and FP8 GEMM, with a `memcpy`
//! roofline reference for the movement kernels — plus the tile-parallel
//! scaling section: each hot kernel and the fused expert pipeline
//! (grouped GEMM → swiglu_quant → grouped GEMM) at 1 vs 8 workers.
//! This is the bench the EXPERIMENTS.md §Perf iteration log quotes.
//!
//! `--threads N` sets the worker count for the serial section's kernels;
//! the scaling section always compares explicit worker counts.

use fp8_flow_moe::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use fp8_flow_moe::fp8::transpose::{direct_transpose, direct_transpose_with_threads};
use fp8_flow_moe::fp8::{e4m3, Fp8Format, ScaleMode};
use fp8_flow_moe::moe::gemm::{fp8_matmul, fp8_matmul_with_threads};
use fp8_flow_moe::moe::layer::{fused_expert_ffn, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::moe::swiglu::swiglu_quant_with_threads;
use fp8_flow_moe::util::bench::{bencher_from_cli, print_speedup, print_table};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let (b, args) = bencher_from_cli(0);
    let mut rows = Vec::new();
    let (m, n) = (2048usize, 2048usize);
    let mut rng = Rng::seed_from(9);
    let x = Mat::rand_log_uniform(m, n, -6.0, 6.0, &mut rng);

    // memcpy roofline reference (same bytes as the u8 transpose)
    let src = vec![7u8; m * n];
    let mut dst = vec![0u8; m * n];
    rows.push(b.run_bytes("memcpy u8 (roofline ref)", (m * n) as u64, || {
        dst.copy_from_slice(black_box(&src));
        black_box(&dst);
    }));

    // codec throughput
    let codes: Vec<u8> = (0..m * n).map(|i| (i % 255) as u8).collect();
    rows.push(b.run_bytes("decode LUT", (m * n) as u64, || {
        let s: f32 = codes.iter().map(|&c| e4m3::DECODE_LUT[c as usize]).sum();
        black_box(s);
    }));
    rows.push(b.run_bytes("encode RNE", (m * n * 4) as u64, || {
        let mut acc = 0u32;
        for &v in &x.data {
            acc = acc.wrapping_add(e4m3::encode(v) as u32);
        }
        black_box(acc);
    }));

    // quantizer (read f32, write u8+scales)
    rows.push(b.run_bytes("quantize_rowwise po2", (m * n * 5) as u64, || {
        black_box(quantize_rowwise(black_box(&x), Fp8Format::E4M3, ScaleMode::Po2));
    }));

    // direct transpose (u8 in, u8 out)
    let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
    rows.push(b.run_bytes("direct_transpose", (2 * m * n) as u64, || {
        black_box(direct_transpose(black_box(&q)));
    }));

    // fp8 GEMM (compute-bound)
    let w = quantize_rowwise(&Mat::randn(256, n, 1.0, &mut rng), Fp8Format::E4M3, ScaleMode::Po2);
    let gemm = b.run(&format!("fp8_matmul {m}x{n}x256"), || {
        black_box(fp8_matmul(black_box(&q), black_box(&w)));
    });
    let flops = 2.0 * (m * n * 256) as f64;
    println!(
        "fp8_matmul: {:.2} GFLOP/s",
        flops / gemm.median.as_secs_f64() / 1e9
    );
    rows.push(gemm);

    print_table("perf_kernels", &rows);

    // ---- tile-parallel scaling: serial vs N workers per kernel ----
    let hi = args.usize_or("scale-threads", 8);
    println!("\n== parallel scaling (1 vs {hi} workers; bit-identical outputs) ==");
    let mut srows = Vec::new();

    let q1 = b.run_bytes("quantize_rowwise t=1", (m * n * 5) as u64, || {
        black_box(quantize_rowwise_with_threads(black_box(&x), Fp8Format::E4M3, ScaleMode::Po2, 1));
    });
    let qn = b.run_bytes(&format!("quantize_rowwise t={hi}"), (m * n * 5) as u64, || {
        black_box(quantize_rowwise_with_threads(black_box(&x), Fp8Format::E4M3, ScaleMode::Po2, hi));
    });
    print_speedup("quantize_rowwise", &q1, &qn);

    let t1 = b.run_bytes("direct_transpose t=1", (2 * m * n) as u64, || {
        black_box(direct_transpose_with_threads(black_box(&q), 1));
    });
    let tn = b.run_bytes(&format!("direct_transpose t={hi}"), (2 * m * n) as u64, || {
        black_box(direct_transpose_with_threads(black_box(&q), hi));
    });
    print_speedup("direct_transpose", &t1, &tn);

    let g1 = b.run("fp8_matmul t=1", || {
        black_box(fp8_matmul_with_threads(black_box(&q), black_box(&w), 1));
    });
    let gn = b.run(&format!("fp8_matmul t={hi}"), || {
        black_box(fp8_matmul_with_threads(black_box(&q), black_box(&w), hi));
    });
    print_speedup("fp8_matmul", &g1, &gn);

    let gate = Mat::randn(4096, 2048, 1.0, &mut rng);
    let up = Mat::randn(4096, 2048, 1.0, &mut rng);
    let s1 = b.run("swiglu_quant t=1", || {
        black_box(swiglu_quant_with_threads(
            black_box(&gate), black_box(&up), Fp8Format::E4M3, ScaleMode::Po2, 1,
        ));
    });
    let sn = b.run(&format!("swiglu_quant t={hi}"), || {
        black_box(swiglu_quant_with_threads(
            black_box(&gate), black_box(&up), Fp8Format::E4M3, ScaleMode::Po2, hi,
        ));
    });
    print_speedup("swiglu_quant", &s1, &sn);

    // the expert FFN streaming pipeline: grouped GEMM → fused swiglu_quant
    // → grouped GEMM, E experts in parallel (the acceptance-criteria path)
    let (e, cap, d, h) = (8usize, 512usize, 512usize, 512usize);
    let mw = MoeWeights::random(d, h, e, &mut rng);
    let pw = PreparedWeights::new(mw, Recipe::Fp8Flow);
    let xg = quantize_rowwise(
        &Mat::randn(e * cap, d, 0.5, &mut rng),
        Fp8Format::E4M3,
        ScaleMode::Po2,
    );
    let p1 = b.run("expert_ffn pipeline t=1", || {
        black_box(fused_expert_ffn(black_box(&xg), &pw.w1_t, &pw.w3_t, &pw.w2_t, cap, 1));
    });
    let pn = b.run(&format!("expert_ffn pipeline t={hi}"), || {
        black_box(fused_expert_ffn(black_box(&xg), &pw.w1_t, &pw.w3_t, &pw.w2_t, cap, hi));
    });
    print_speedup("grouped GEMM + fused swiglu_quant pipeline", &p1, &pn);

    srows.extend([q1, qn, t1, tn, g1, gn, s1, sn, p1, pn]);
    print_table("perf_kernels_scaling", &srows);
}
