//! **Ablation** — double-quantization-error decomposition (Eq. 1) across
//! the recipe design axes DESIGN.md calls out:
//!
//! * scale mode: float (incumbent) vs po2 (paper);
//! * transpose strategy: naive dequant→T→requant vs direct;
//! * data dynamic range (binades per tile): where the error grows.
//!
//! Not a paper figure — it quantifies *why* the paper's two design choices
//! (po2 + direct) are each necessary.

use fp8_flow_moe::fp8::error::dqe_report;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

fn main() {
    // analytic report: accepts --threads for CLI uniformity (no kernels run)
    fp8_flow_moe::exec::set_threads(Args::from_env().usize_or("threads", 0));
    println!("ablation: double quantization error (rel Frobenius vs one-rounding ref)");
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>14}",
        "spread", "float/naive", "float/direct", "po2/naive", "po2/direct"
    );
    for spread in [1.0f32, 2.0, 4.0, 6.0, 8.0] {
        let mut rng = Rng::seed_from(11);
        let x = Mat::rand_log_uniform(512, 512, -spread, spread, &mut rng);
        let rf = dqe_report(&x, Fp8Format::E4M3, ScaleMode::Float);
        let rp = dqe_report(&x, Fp8Format::E4M3, ScaleMode::Po2);
        println!(
            "ROW ±2^{spread:<5} {:>12.3e} {:>14.3e} {:>14.3e} {:>14.3e}",
            rf.naive_vs_ref.rel_fro,
            rf.direct_vs_ref.rel_fro,
            rp.naive_vs_ref.rel_fro,
            rp.direct_vs_ref.rel_fro
        );
    }
    println!();
    println!("reading: the po2 constraint zeroes the error (grids nest); the direct");
    println!("transpose additionally removes the dequant/requant COMPUTE (Fig. 1).");
    println!("float scales keep a ~1e-2 rel error whichever transpose is used.");
}
