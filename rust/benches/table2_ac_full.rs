//! **Table 2** — end-to-end 671B throughput/memory under AC=full for
//! EP ∈ {8,16,32} across the three recipes (simulated cluster;
//! paper values printed alongside).

use fp8_flow_moe::cluster::memory::AcMode;
use fp8_flow_moe::cluster::model_cfg::DEEPSEEK_V3;
use fp8_flow_moe::cluster::sim::simulate;
use fp8_flow_moe::coordinator::reports;
use fp8_flow_moe::moe::layer::Recipe;
use fp8_flow_moe::util::cli::Args;

fn main() {
    // analytic report: accepts --threads for CLI uniformity (no kernels run)
    fp8_flow_moe::exec::set_threads(Args::from_env().usize_or("threads", 0));
    print!("{}", reports::table2());
    println!();
    println!("relative gains (FP8-Flow vs baselines; paper: +6/8/16% vs BF16, +3/8/21% vs Blockwise):");
    for ep in [8usize, 16, 32] {
        let b = simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Bf16, AcMode::Full).tgs;
        let w = simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Blockwise, AcMode::Full).tgs;
        let f = simulate(&DEEPSEEK_V3, ep, 256 / ep, Recipe::Fp8Flow, AcMode::Full).tgs;
        println!(
            "  EP{ep:<3} vs BF16: {:+.1}%   vs Blockwise: {:+.1}%",
            (f / b - 1.0) * 100.0,
            (f / w - 1.0) * 100.0
        );
    }
}
