//! Typed op-graph substrate for the Fig. 2 dataflow variants.

use std::collections::BTreeMap;

/// Tensor element type on a dataflow edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// FP8 payload (+ scale sidecar).
    Fp8,
    /// BF16 working precision.
    Bf16,
    /// FP32 (master weights / accumulators).
    F32,
}

/// Pipeline stage of the MoE layer (§3.2 decomposition), plus the
/// per-step optimizer tail of the training loop (master update + weight
/// requantization — `dataflow::variants::build_train_step`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    Router,
    Dispatch,
    Permute,
    Fc1,
    Activation,
    Fc2,
    Unperm,
    Combine,
    Optimizer,
}

/// Operator kinds. `Quantize`/`Dequantize`/`Cast` are the *explicit* cast
/// kernels the paper counts; fused ops carry their quantization inside a
/// compute kernel (not an explicit cast launch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Quantize,
    Dequantize,
    /// bf16↔f32 boundary cast.
    Cast,
    AllToAll,
    Permute,
    Pad,
    FusedPermutePad,
    Unpermute,
    Unpad,
    FusedUnpermuteUnpad,
    GroupedGemm,
    SwiGlu,
    FusedSwiGluQuant,
    SwiGluBwd,
    FusedSwiGluBwdQuant,
    /// dequantize→transpose→requantize (the naive Wgrad operand prep).
    NaiveTransposeRequant,
    /// the paper's scaling-aware direct transpose (code-space, no Q/DQ).
    DirectTranspose,
    Scale,
    Add,
    /// f32 optimizer math over the master weights (AdamW / SGD-momentum) —
    /// stays in master precision, never a cast.
    MasterUpdate,
}

impl OpKind {
    /// Is this an explicit cast kernel (the paper's counted ops)?
    pub fn is_explicit_cast(self) -> bool {
        matches!(self, OpKind::Quantize | OpKind::Dequantize | OpKind::Cast)
    }

    /// Q/DQ launches hidden inside this op (the naive transpose performs
    /// one dequantize and one requantize internally).
    pub fn internal_qdq(self) -> usize {
        match self {
            OpKind::NaiveTransposeRequant => 2,
            _ => 0,
        }
    }
}

/// One node of the dataflow graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub op: OpKind,
    pub stage: Stage,
    pub backward: bool,
    pub out_dtype: Dtype,
    pub inputs: Vec<usize>,
}

/// A dataflow graph for one MoE layer fwd+bwd.
#[derive(Clone, Debug, Default)]
pub struct DataflowGraph {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl DataflowGraph {
    pub fn new(name: &str) -> Self {
        DataflowGraph { name: name.to_string(), nodes: Vec::new() }
    }

    /// Add a node; returns its id.
    pub fn add(
        &mut self,
        name: &str,
        op: OpKind,
        stage: Stage,
        backward: bool,
        out_dtype: Dtype,
        inputs: &[usize],
    ) -> usize {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "forward reference in dataflow graph");
        }
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            op,
            stage,
            backward,
            out_dtype,
            inputs: inputs.to_vec(),
        });
        id
    }

    /// Count of *explicit* cast kernel launches (the Fig. 2 number).
    pub fn explicit_casts(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_explicit_cast()).count()
    }

    /// Explicit casts on the forward layer path only (the optimizer tail
    /// is accounted separately — [`Self::explicit_casts_opt`]).
    pub fn explicit_casts_fwd(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.backward && n.stage != Stage::Optimizer && n.op.is_explicit_cast())
            .count()
    }

    /// Explicit casts on the backward path only — what the executed
    /// backward's cast audit (`moe::backward::BwdStats::casts`) is checked
    /// against.
    pub fn explicit_casts_bwd(&self) -> usize {
        self.nodes.iter().filter(|n| n.backward && n.op.is_explicit_cast()).count()
    }

    /// Explicit casts in the optimizer tail: the per-step weight
    /// quantizations from the f32 masters (weight prep, counted apart
    /// from the Fig. 2 activation-path numbers).
    pub fn explicit_casts_opt(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.stage == Stage::Optimizer && n.op.is_explicit_cast())
            .count()
    }

    /// Optimizer-tail nodes that requantize already-FP8 data (deriving a
    /// second weight layout from the first instead of from the master) —
    /// zero for the Fp8Flow train step by construction, the audit behind
    /// `PreparedWeights::requantize_from_masters`.
    pub fn requant_nodes_opt(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.stage == Stage::Optimizer && n.op == OpKind::NaiveTransposeRequant)
            .count()
    }

    /// Backward nodes that requantize already-FP8 data (the naive wgrad
    /// transposes — the double-quantization site).
    pub fn requant_nodes_bwd(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.backward && n.op == OpKind::NaiveTransposeRequant)
            .count()
    }

    /// Is the wgrad operand prep casting-free? True iff every backward
    /// transpose is the scaling-aware direct transpose (no
    /// dequantize→transpose→requantize anywhere on the gradient path) —
    /// the structural precondition for `moe::backward`'s zero-requant
    /// Fp8Flow execution.
    pub fn casting_free_wgrad(&self) -> bool {
        self.requant_nodes_bwd() == 0
            && self.nodes.iter().any(|n| n.backward && n.op == OpKind::DirectTranspose)
    }

    /// Total quantization events including those hidden inside naive
    /// transposes (what the double-quantization analysis counts).
    pub fn total_qdq_events(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                n.op.internal_qdq()
                    + usize::from(matches!(n.op, OpKind::Quantize | OpKind::Dequantize))
            })
            .sum()
    }

    /// Number of kernel launches (every node is one kernel; fusion is the
    /// whole point — fused variants have fewer nodes for the same math).
    pub fn kernel_launches(&self) -> usize {
        self.nodes.len()
    }

    /// Ids of nodes whose output is BF16/F32 on the expert path
    /// (Fc1→Activation→Fc2), i.e. the "BF16 islands" of §3.2.
    pub fn bf16_islands(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(n.stage, Stage::Fc1 | Stage::Activation | Stage::Fc2)
                    && n.out_dtype != Dtype::Fp8
                    && !n.op.is_explicit_cast()
            })
            .collect()
    }

    /// Is the expert FFN span (Fc1 → Activation → Fc2) free of explicit
    /// cast kernels? This is the structural precondition for executing the
    /// span as one streaming pipeline (`moe::layer::fused_expert_ffn`):
    /// quantization may only happen *inside* compute kernels (fused ops),
    /// never as a standalone launch between the stages.
    pub fn casting_free_expert_ffn(&self) -> bool {
        !self.nodes.iter().any(|n| {
            matches!(n.stage, Stage::Fc1 | Stage::Activation | Stage::Fc2)
                && n.op.is_explicit_cast()
        })
    }

    /// Per-stage node histogram (used by reports and the cluster sim).
    pub fn stage_histogram(&self) -> BTreeMap<Stage, usize> {
        let mut h = BTreeMap::new();
        for n in &self.nodes {
            *h.entry(n.stage).or_insert(0) += 1;
        }
        h
    }

    /// Structural validation: edges resolve, at least one node per
    /// mandatory stage, single terminal output per direction.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty graph".into());
        }
        for s in [Stage::Dispatch, Stage::Fc1, Stage::Activation, Stage::Fc2, Stage::Combine] {
            if !self.nodes.iter().any(|n| n.stage == s) {
                return Err(format!("missing stage {s:?}"));
            }
        }
        // every non-root node consumes something
        for n in &self.nodes {
            if n.id > 0 && n.inputs.is_empty() && !n.name.contains("input") {
                return Err(format!("orphan node {}", n.name));
            }
        }
        Ok(())
    }

    /// Render as a readable audit listing (used by `examples/dataflow_audit`).
    pub fn render(&self) -> String {
        let mut s = format!("== dataflow: {} ==\n", self.name);
        for n in &self.nodes {
            s.push_str(&format!(
                "{:>3} {:<5} {:<10} {:<26} -> {:<5} {}\n",
                n.id,
                if n.backward { "bwd" } else { "fwd" },
                format!("{:?}", n.stage),
                n.name,
                format!("{:?}", n.out_dtype),
                if n.op.is_explicit_cast() { "  [CAST]" } else { "" },
            ));
        }
        s.push_str(&format!(
            "explicit casts: {}   total q/dq events: {}   kernel launches: {}\n",
            self.explicit_casts(),
            self.total_qdq_events(),
            self.kernel_launches()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_count() {
        let mut g = DataflowGraph::new("test");
        let x = g.add("input", OpKind::Add, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("quant", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let d = g.add("dequant", OpKind::Dequantize, Stage::Dispatch, false, Dtype::Bf16, &[q]);
        let n = g.add("naive-T", OpKind::NaiveTransposeRequant, Stage::Fc1, true, Dtype::Fp8, &[d]);
        let _ = n;
        assert_eq!(g.explicit_casts(), 2);
        assert_eq!(g.total_qdq_events(), 4); // 2 explicit + 2 inside naive-T
    }

    #[test]
    #[should_panic(expected = "forward reference")]
    fn rejects_forward_edges() {
        let mut g = DataflowGraph::new("bad");
        g.add("n", OpKind::Add, Stage::Router, false, Dtype::F32, &[3]);
    }

    #[test]
    fn validate_flags_missing_stages() {
        let mut g = DataflowGraph::new("incomplete");
        g.add("input", OpKind::Add, Stage::Router, false, Dtype::Bf16, &[]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn optimizer_stage_accounted_separately() {
        let mut g = DataflowGraph::new("opt");
        let x = g.add("input", OpKind::Add, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("Q(x)", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let u = g.add("update", OpKind::MasterUpdate, Stage::Optimizer, false, Dtype::F32, &[q]);
        g.add("Q(w)", OpKind::Quantize, Stage::Optimizer, false, Dtype::Fp8, &[u]);
        g.add("w naive-T", OpKind::NaiveTransposeRequant, Stage::Optimizer, false, Dtype::Fp8, &[u]);
        // the layer-path fwd count must not absorb the optimizer tail
        assert_eq!(g.explicit_casts_fwd(), 1);
        assert_eq!(g.explicit_casts_opt(), 1);
        assert_eq!(g.requant_nodes_opt(), 1);
        assert_eq!(g.requant_nodes_bwd(), 0);
    }
}
