//! Typed op-graph substrate for the Fig. 2 dataflow variants.
//!
//! Every [`Node`] carries scale-lineage metadata — declared scale axis,
//! wire sidecar, and an execution-multiplicity model (`units` ×
//! [`Mult`]) — consumed by the static analyzer in [`crate::analysis`].
//! The cast/requant counters on [`DataflowGraph`] are thin wrappers over
//! the analyzer's lineage queries ([`crate::analysis::CastSummary`]), so
//! the schematic counts and the lint verdicts can never drift apart.

use std::collections::BTreeMap;

/// Tensor element type on a dataflow edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// FP8 payload (+ scale sidecar).
    Fp8,
    /// BF16 working precision.
    Bf16,
    /// FP32 (master weights / accumulators).
    F32,
}

/// Scale-tile orientation of an FP8 value, mirroring the executed
/// [`crate::fp8::tensor::TileLayout`]: `RowWise` scales tile along the
/// rows (one scale per 1×128 row segment, the `quantize_rowwise` layout),
/// `ColWise` along the columns (the orientation a transpose produces).
/// `fp8_matmul` needs both operands tiled along the contraction axis —
/// the invariant the analyzer's GEMM axis rule checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAxis {
    /// Scale tiles run along rows (untransposed quantizer output).
    RowWise,
    /// Scale tiles run along columns (the transposed orientation).
    ColWise,
}

impl ScaleAxis {
    /// Orientation after a transpose (either kind — naive or direct).
    pub fn flipped(self) -> ScaleAxis {
        match self {
            ScaleAxis::RowWise => ScaleAxis::ColWise,
            ScaleAxis::ColWise => ScaleAxis::RowWise,
        }
    }

    /// Human-readable form used in lineage traces ("row-wise"/"col-wise").
    pub fn word(self) -> &'static str {
        match self {
            ScaleAxis::RowWise => "row-wise",
            ScaleAxis::ColWise => "col-wise",
        }
    }
}

/// How many kernel instances one schematic node stands for when the
/// graph executes with `E` experts and `K` routed slots (top-k). The
/// Fig. 2 graphs draw one node per *logical* operation; the executed
/// layer launches it once per slot and/or per expert — this is the
/// bridge the analyzer uses to predict the executed
/// `BwdStats`/`TrainMetrics` audits from the schematic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mult {
    /// Fires once per layer pass (entry casts, wire ops).
    Once,
    /// Fires once per routed slot (`×K`) — per-slot backward entries.
    PerSlot,
    /// Fires once per expert per slot (`×E·K`) — expert-span kernels.
    PerExpertSlot,
    /// Fires once per expert (`×E`) — optimizer-tail weight casts.
    PerExpert,
}

impl Mult {
    /// Instance count for `experts` experts and `top_k` routed slots.
    pub fn count(self, experts: usize, top_k: usize) -> usize {
        match self {
            Mult::Once => 1,
            Mult::PerSlot => top_k,
            Mult::PerExpertSlot => experts * top_k,
            Mult::PerExpert => experts,
        }
    }
}

/// Operator kinds. `Quantize`/`Dequantize`/`Cast` are the *explicit* cast
/// kernels the paper counts; fused ops carry their quantization inside a
/// compute kernel (not an explicit cast launch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Graph source: an external value entering the graph (layer input,
    /// upstream gradient, master-weight gradient). The explicit source
    /// marker [`DataflowGraph::validate`] keys on — never a kernel.
    Input,
    /// Standalone quantize launch (dense → FP8 codes + scales).
    Quantize,
    /// Standalone dequantize launch (FP8 codes + scales → dense).
    Dequantize,
    /// bf16↔f32 boundary cast.
    Cast,
    /// All-to-all wire exchange across EP ranks.
    AllToAll,
    /// Token→expert-order gather.
    Permute,
    /// Pad expert groups to capacity.
    Pad,
    /// Fused permute+pad (single pass over the payload).
    FusedPermutePad,
    /// Expert-order→token scatter.
    Unpermute,
    /// Drop capacity padding.
    Unpad,
    /// Fused unpermute+unpad (single pass).
    FusedUnpermuteUnpad,
    /// Grouped (per-expert) GEMM.
    GroupedGemm,
    /// Standalone SwiGLU activation.
    SwiGlu,
    /// SwiGLU with the output quantization fused into the kernel.
    FusedSwiGluQuant,
    /// Standalone SwiGLU backward.
    SwiGluBwd,
    /// SwiGLU backward with the gradient quantization fused in.
    FusedSwiGluBwdQuant,
    /// dequantize→transpose→requantize (the naive Wgrad operand prep).
    NaiveTransposeRequant,
    /// the paper's scaling-aware direct transpose (code-space, no Q/DQ).
    DirectTranspose,
    /// Gate scaling at the combine.
    Scale,
    /// Elementwise accumulate.
    Add,
    /// f32 optimizer math over the master weights (AdamW / SGD-momentum) —
    /// stays in master precision, never a cast.
    MasterUpdate,
}

impl OpKind {
    /// Is this an explicit cast kernel (the paper's counted ops)?
    pub fn is_explicit_cast(self) -> bool {
        matches!(self, OpKind::Quantize | OpKind::Dequantize | OpKind::Cast)
    }

    /// Q/DQ launches hidden inside this op (the naive transpose performs
    /// one dequantize and one requantize internally).
    pub fn internal_qdq(self) -> usize {
        match self {
            OpKind::NaiveTransposeRequant => 2,
            _ => 0,
        }
    }

    /// Does this op produce a (re)quantized value — explicitly, or fused
    /// inside a compute/transpose kernel?
    pub fn quantizes(self) -> bool {
        matches!(
            self,
            OpKind::Quantize
                | OpKind::NaiveTransposeRequant
                | OpKind::FusedSwiGluQuant
                | OpKind::FusedSwiGluBwdQuant
        )
    }
}

/// One node of the dataflow graph, with the scale-lineage metadata the
/// analyzer interprets. [`DataflowGraph::add`] derives sensible defaults
/// for the metadata from `(op, stage, backward, out_dtype)`; builders
/// override only where the schematic diverges from the default (e.g.
/// `units` for nodes standing for several kernel instances), and the
/// mutation tests override `axis`/`sidecar` to inject defects.
#[derive(Clone, Debug)]
pub struct Node {
    /// Topological id (== index in [`DataflowGraph::nodes`]).
    pub id: usize,
    /// Display name (audit listings, lineage traces).
    pub name: String,
    /// Operator kind.
    pub op: OpKind,
    /// Pipeline stage the node belongs to.
    pub stage: Stage,
    /// True on the backward path.
    pub backward: bool,
    /// Element type of the node's output edge.
    pub out_dtype: Dtype,
    /// Producer node ids (empty only for [`OpKind::Input`] sources).
    pub inputs: Vec<usize>,
    /// Declared scale axis of the output, when the op quantizes along a
    /// known orientation. `None` lets the analyzer derive it (transposes
    /// flip their input's axis; quantizers default row-wise).
    pub axis: Option<ScaleAxis>,
    /// For FP8 [`OpKind::AllToAll`] nodes: does the wire carry the scale
    /// sidecar next to the payload? (FP8 codes without their scales are
    /// undecodable — the analyzer's missing-sidecar rule.)
    pub sidecar: bool,
    /// Kernel instances this schematic node stands for *per firing* (e.g.
    /// one `Q(dact)` node covers the d_gate and d_up quantizations: 2).
    pub units: usize,
    /// Firing multiplicity class under execution (`×1/×K/×E·K/×E`).
    pub mult: Mult,
}

/// Pipeline stage of the MoE layer (§3.2 decomposition), plus the
/// per-step optimizer tail of the training loop (master update + weight
/// requantization — `dataflow::variants::build_train_step`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Dense f32 gating (runs outside the quantized expert path).
    Router,
    /// Token dispatch across EP ranks.
    Dispatch,
    /// Expert-order permute/pad data movement.
    Permute,
    /// First grouped GEMM (gate+up projections).
    Fc1,
    /// SwiGLU activation between the GEMMs.
    Activation,
    /// Second grouped GEMM (down projection).
    Fc2,
    /// Unpermute/unpad back to token order.
    Unperm,
    /// Combine across EP ranks + gate scaling.
    Combine,
    /// Per-step optimizer tail (master update + weight casts).
    Optimizer,
}

/// A dataflow graph for one MoE layer fwd+bwd.
#[derive(Clone, Debug, Default)]
pub struct DataflowGraph {
    /// Variant name (display only).
    pub name: String,
    /// Nodes in topological order (ids == indices).
    pub nodes: Vec<Node>,
}

impl DataflowGraph {
    /// Create an empty graph named `name`.
    pub fn new(name: &str) -> Self {
        DataflowGraph { name: name.to_string(), nodes: Vec::new() }
    }

    /// Add a node; returns its id. Scale-lineage metadata defaults are
    /// derived here (one site, so every builder gets them consistently):
    ///
    /// * `axis` — quantizers emit row-wise scales (the only executed
    ///   quantizer orientation); transposes derive by flipping their
    ///   input's axis at analysis time (`None` here);
    /// * `sidecar` — FP8 all-to-alls ship the scale sidecar by default;
    /// * `mult` — expert-span stages fire per expert per slot, the
    ///   optimizer tail per expert, other backward nodes per slot, and
    ///   everything else once per layer pass.
    pub fn add(
        &mut self,
        name: &str,
        op: OpKind,
        stage: Stage,
        backward: bool,
        out_dtype: Dtype,
        inputs: &[usize],
    ) -> usize {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "forward reference in dataflow graph");
        }
        let axis = match op {
            OpKind::Quantize | OpKind::FusedSwiGluQuant | OpKind::FusedSwiGluBwdQuant => {
                Some(ScaleAxis::RowWise)
            }
            _ => None,
        };
        let sidecar = op == OpKind::AllToAll && out_dtype == Dtype::Fp8;
        let mult = match stage {
            Stage::Fc1 | Stage::Activation | Stage::Fc2 => Mult::PerExpertSlot,
            Stage::Optimizer => Mult::PerExpert,
            _ if backward => Mult::PerSlot,
            _ => Mult::Once,
        };
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            op,
            stage,
            backward,
            out_dtype,
            inputs: inputs.to_vec(),
            axis,
            sidecar,
            units: 1,
            mult,
        });
        id
    }

    /// Declare that node `id` stands for `units` kernel instances per
    /// firing (builder override; see [`Node::units`]).
    pub fn set_units(&mut self, id: usize, units: usize) {
        self.nodes[id].units = units;
    }

    /// Count of *explicit* cast kernel launches (the Fig. 2 number).
    /// Lineage-derived: [`crate::analysis::CastSummary`].
    pub fn explicit_casts(&self) -> usize {
        crate::analysis::CastSummary::of(self).casts_total
    }

    /// Explicit casts on the forward layer path only (the optimizer tail
    /// is accounted separately — [`Self::explicit_casts_opt`]).
    pub fn explicit_casts_fwd(&self) -> usize {
        crate::analysis::CastSummary::of(self).casts_fwd
    }

    /// Explicit casts on the backward path only — what the executed
    /// backward's cast audit (`moe::backward::BwdStats::casts`) is checked
    /// against.
    pub fn explicit_casts_bwd(&self) -> usize {
        crate::analysis::CastSummary::of(self).casts_bwd
    }

    /// Explicit casts in the optimizer tail: the per-step weight
    /// quantizations from the f32 masters (weight prep, counted apart
    /// from the Fig. 2 activation-path numbers).
    pub fn explicit_casts_opt(&self) -> usize {
        crate::analysis::CastSummary::of(self).casts_opt
    }

    /// Optimizer-tail nodes that requantize already-FP8 data (deriving a
    /// second weight layout from the first instead of from the master) —
    /// zero for the Fp8Flow train step by construction, the audit behind
    /// `PreparedWeights::requantize_from_masters`.
    pub fn requant_nodes_opt(&self) -> usize {
        crate::analysis::CastSummary::of(self).requants_opt
    }

    /// Backward nodes that requantize already-FP8 data (the naive wgrad
    /// transposes — the double-quantization site).
    pub fn requant_nodes_bwd(&self) -> usize {
        crate::analysis::CastSummary::of(self).requants_bwd
    }

    /// Is the wgrad operand prep casting-free? True iff every backward
    /// transpose is the scaling-aware direct transpose (no
    /// dequantize→transpose→requantize anywhere on the gradient path) —
    /// the structural precondition for `moe::backward`'s zero-requant
    /// Fp8Flow execution.
    pub fn casting_free_wgrad(&self) -> bool {
        self.requant_nodes_bwd() == 0
            && self.nodes.iter().any(|n| n.backward && n.op == OpKind::DirectTranspose)
    }

    /// Total quantization events including those hidden inside naive
    /// transposes (what the double-quantization analysis counts).
    pub fn total_qdq_events(&self) -> usize {
        crate::analysis::CastSummary::of(self).qdq_events
    }

    /// Number of kernel launches (every node is one kernel; fusion is the
    /// whole point — fused variants have fewer nodes for the same math).
    /// Source nodes are values, not launches, and are excluded.
    pub fn kernel_launches(&self) -> usize {
        self.nodes.iter().filter(|n| n.op != OpKind::Input).count()
    }

    /// Ids of nodes whose output is BF16/F32 on the expert path
    /// (Fc1→Activation→Fc2), i.e. the "BF16 islands" of §3.2.
    pub fn bf16_islands(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(n.stage, Stage::Fc1 | Stage::Activation | Stage::Fc2)
                    && n.out_dtype != Dtype::Fp8
                    && !n.op.is_explicit_cast()
            })
            .collect()
    }

    /// Is the expert FFN span (Fc1 → Activation → Fc2) free of explicit
    /// cast kernels? This is the structural precondition for executing the
    /// span as one streaming pipeline (`moe::layer::fused_expert_ffn`):
    /// quantization may only happen *inside* compute kernels (fused ops),
    /// never as a standalone launch between the stages.
    pub fn casting_free_expert_ffn(&self) -> bool {
        !self.nodes.iter().any(|n| {
            matches!(n.stage, Stage::Fc1 | Stage::Activation | Stage::Fc2)
                && n.op.is_explicit_cast()
        })
    }

    /// Per-stage node histogram (used by reports and the cluster sim).
    pub fn stage_histogram(&self) -> BTreeMap<Stage, usize> {
        let mut h = BTreeMap::new();
        for n in &self.nodes {
            *h.entry(n.stage).or_insert(0) += 1;
        }
        h
    }

    /// Structural validation: edges resolve, at least one node per
    /// mandatory stage, every non-source node consumes something. Sources
    /// are recognized by the explicit [`OpKind::Input`] marker, not by
    /// name, so renaming an input cannot silently disable the orphan
    /// check.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty graph".into());
        }
        for s in [Stage::Dispatch, Stage::Fc1, Stage::Activation, Stage::Fc2, Stage::Combine] {
            if !self.nodes.iter().any(|n| n.stage == s) {
                return Err(format!("missing stage {s:?}"));
            }
        }
        for n in &self.nodes {
            if n.op == OpKind::Input && !n.inputs.is_empty() {
                return Err(format!("source node {} has inputs", n.name));
            }
            if n.op != OpKind::Input && n.inputs.is_empty() {
                return Err(format!("orphan node {}", n.name));
            }
        }
        Ok(())
    }

    /// Render as a readable audit listing (used by `examples/dataflow_audit`).
    pub fn render(&self) -> String {
        let mut s = format!("== dataflow: {} ==\n", self.name);
        for n in &self.nodes {
            s.push_str(&format!(
                "{:>3} {:<5} {:<10} {:<26} -> {:<5} {}\n",
                n.id,
                if n.backward { "bwd" } else { "fwd" },
                format!("{:?}", n.stage),
                n.name,
                format!("{:?}", n.out_dtype),
                if n.op.is_explicit_cast() { "  [CAST]" } else { "" },
            ));
        }
        s.push_str(&format!(
            "explicit casts: {}   total q/dq events: {}   kernel launches: {}\n",
            self.explicit_casts(),
            self.total_qdq_events(),
            self.kernel_launches()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_count() {
        let mut g = DataflowGraph::new("test");
        let x = g.add("input", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("quant", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let d = g.add("dequant", OpKind::Dequantize, Stage::Dispatch, false, Dtype::Bf16, &[q]);
        let n = g.add("naive-T", OpKind::NaiveTransposeRequant, Stage::Fc1, true, Dtype::Fp8, &[d]);
        let _ = n;
        assert_eq!(g.explicit_casts(), 2);
        assert_eq!(g.total_qdq_events(), 4); // 2 explicit + 2 inside naive-T
    }

    #[test]
    #[should_panic(expected = "forward reference")]
    fn rejects_forward_edges() {
        let mut g = DataflowGraph::new("bad");
        g.add("n", OpKind::Add, Stage::Router, false, Dtype::F32, &[3]);
    }

    #[test]
    fn validate_flags_missing_stages() {
        let mut g = DataflowGraph::new("incomplete");
        g.add("input", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn optimizer_stage_accounted_separately() {
        let mut g = DataflowGraph::new("opt");
        let x = g.add("input", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("Q(x)", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let u = g.add("update", OpKind::MasterUpdate, Stage::Optimizer, false, Dtype::F32, &[q]);
        g.add("Q(w)", OpKind::Quantize, Stage::Optimizer, false, Dtype::Fp8, &[u]);
        g.add("w naive-T", OpKind::NaiveTransposeRequant, Stage::Optimizer, false, Dtype::Fp8, &[u]);
        // the layer-path fwd count must not absorb the optimizer tail
        assert_eq!(g.explicit_casts_fwd(), 1);
        assert_eq!(g.explicit_casts_opt(), 1);
        assert_eq!(g.requant_nodes_opt(), 1);
        assert_eq!(g.requant_nodes_bwd(), 0);
    }

    #[test]
    fn metadata_defaults_derived_in_add() {
        let mut g = DataflowGraph::new("meta");
        let x = g.add("x", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("q", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let a = g.add("a2a", OpKind::AllToAll, Stage::Dispatch, false, Dtype::Fp8, &[q]);
        let t = g.add("t", OpKind::DirectTranspose, Stage::Fc1, true, Dtype::Fp8, &[a]);
        let o = g.add("qw", OpKind::Quantize, Stage::Optimizer, false, Dtype::Fp8, &[x]);
        assert_eq!(g.nodes[q].axis, Some(ScaleAxis::RowWise));
        assert_eq!(g.nodes[q].mult, Mult::Once);
        assert!(g.nodes[a].sidecar, "FP8 wire ships its sidecar by default");
        assert_eq!(g.nodes[t].axis, None, "transposes derive their axis");
        assert_eq!(g.nodes[t].mult, Mult::PerExpertSlot);
        assert_eq!(g.nodes[o].mult, Mult::PerExpert);
        assert_eq!(Mult::PerExpertSlot.count(8, 2), 16);
        assert_eq!(ScaleAxis::RowWise.flipped(), ScaleAxis::ColWise);
    }

    #[test]
    fn validate_uses_source_marker_not_name() {
        // a renamed source still validates (the old name heuristic broke
        // on this); a non-source without inputs is an orphan even at id 0
        let mut g = DataflowGraph::new("marker");
        let x = g.add("tokens", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        g.add("d", OpKind::AllToAll, Stage::Dispatch, false, Dtype::Bf16, &[x]);
        g.add("f1", OpKind::GroupedGemm, Stage::Fc1, false, Dtype::Bf16, &[x]);
        g.add("ac", OpKind::SwiGlu, Stage::Activation, false, Dtype::Bf16, &[x]);
        g.add("f2", OpKind::GroupedGemm, Stage::Fc2, false, Dtype::Bf16, &[x]);
        g.add("cm", OpKind::AllToAll, Stage::Combine, false, Dtype::Bf16, &[x]);
        assert!(g.validate().is_ok());
        // a node named "input" no longer gets a free pass
        let mut bad = g.clone();
        bad.add("input-like", OpKind::Scale, Stage::Combine, false, Dtype::Bf16, &[]);
        assert!(bad.validate().unwrap_err().contains("orphan"));
    }
}
