//! Dataflow graphs of one MoE layer (forward + backward) for the four
//! variants of Fig. 2, with measured cast accounting.
//!
//! This module makes the paper's "12 casts → 2 casts" claim *checkable*:
//! each variant is built as an explicit typed op graph; tests count the
//! quantize/dequantize/cast nodes and verify the dtype discipline (e.g.
//! the fp8-flow variant has FP8 on every expert-path edge except the two
//! BF16 islands of §3.2). The cluster simulator reuses these graphs to
//! cost kernel launches and memory traffic per recipe.

pub mod graph;
pub mod variants;

pub use graph::{DataflowGraph, Dtype, OpKind, Stage};
pub use variants::{build, build_train_step, Variant};
