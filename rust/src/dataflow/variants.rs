//! The four Fig. 2 dataflow variants, reconstructed node-by-node from the
//! paper (§3.2 and Fig. 2a–d). The cast counts the tests pin down:
//!
//! | variant          | explicit casts (fwd+bwd) | wgrad operand prep    |
//! |------------------|--------------------------|-----------------------|
//! | `Bf16`           | 0                        | plain transpose       |
//! | `TeBlockwise`    | 4 (+4 hidden in naive-T) | dequant→T→requant     |
//! | `DeepSeekV3`     | 12 (+4 hidden)           | dequant→T→requant     |
//! | `Fp8Flow` (ours) | 2                        | **direct transpose**  |
//!
//! DeepSeek-V3's twelve explicit casts: per direction, a Q/DQ pair around
//! each all-to-all (dispatch and combine) plus one producer-side quantize
//! per grouped GEMM input — §3.3.2's "around three such pairs" per pass.

use crate::dataflow::graph::{DataflowGraph, Dtype, OpKind, Stage};

/// Which Fig. 2 variant to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// The BF16 oracle: no quantization anywhere.
    Bf16,
    /// TransformerEngine-style blockwise FP8: FP8 strictly inside GEMMs.
    TeBlockwise,
    /// DeepSeek-V3 style: FP8 on the wire with Q/DQ around each all-to-all.
    DeepSeekV3,
    /// The paper's casting-free recipe.
    Fp8Flow,
}

impl Variant {
    /// Every variant, in Fig. 2 presentation order.
    pub fn all() -> [Variant; 4] {
        [Variant::Bf16, Variant::TeBlockwise, Variant::DeepSeekV3, Variant::Fp8Flow]
    }

    /// Canonical display name.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Bf16 => "bf16",
            Variant::TeBlockwise => "te-blockwise",
            Variant::DeepSeekV3 => "deepseek-v3",
            Variant::Fp8Flow => "fp8-flow-moe",
        }
    }

    /// Parse a variant name (the `lint` CLI's `--recipe` values). Accepts
    /// the canonical [`Variant::name`] forms plus the executed-recipe
    /// spellings (`blockwise`, `fp8flow`, …).
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "bf16" => Some(Variant::Bf16),
            "te-blockwise" | "blockwise" => Some(Variant::TeBlockwise),
            "deepseek-v3" | "deepseek" | "deepseekv3" => Some(Variant::DeepSeekV3),
            "fp8-flow-moe" | "fp8flow" | "fp8-flow" | "fp8_flow" => Some(Variant::Fp8Flow),
            _ => None,
        }
    }
}

/// Build the fwd+bwd dataflow graph of one MoE layer for `v`.
///
/// These graphs model the **expert path** (what Fig. 2 draws); the router
/// runs dense f32 in every variant, so the executed router backward
/// (`moe::router::route_backward`) adds no nodes here. The training
/// step's optimizer tail is appended by [`build_train_step`].
pub fn build(v: Variant) -> DataflowGraph {
    match v {
        Variant::Bf16 => build_bf16(),
        Variant::TeBlockwise => build_blockwise(),
        Variant::DeepSeekV3 => build_deepseek(),
        Variant::Fp8Flow => build_fp8flow(),
    }
}

/// The full training-step graph: the layer fwd+bwd of [`build`] plus the
/// per-step optimizer tail — f32 master update, then the weight cast
/// back to FP8 layouts:
///
/// * **Fp8Flow** (and the executed substrate for every recipe,
///   `PreparedWeights::requantize_from_masters`): each GEMM layout is ONE
///   quantization straight from the updated f32 master — fprop/dgrad
///   layouts are siblings of the same F32 node, so the step adds **zero**
///   requant nodes;
/// * **TeBlockwise / DeepSeekV3** (the incumbent foil): FP8 weights are
///   stored once and the second layout is derived by
///   dequantize→transpose→requantize — a per-step double-quantization
///   site on the *weights*, mirroring the wgrad-operand naive transposes
///   of the backward;
/// * **Bf16**: the master update only (weights never leave f32).
pub fn build_train_step(v: Variant) -> DataflowGraph {
    use Dtype::*;
    use OpKind::*;
    use Stage::Optimizer;
    let mut g = build(v);
    let din = g.add("dw-master-input", Input, Optimizer, false, F32, &[]);
    let upd = g.add("master-update", MasterUpdate, Optimizer, false, F32, &[din]);
    // each Q(w)/naive-T node covers the three expert weight tensors
    // (w1, w3, w2) — units 3, firing per expert
    match v {
        Variant::Bf16 => {}
        Variant::Fp8Flow => {
            let qf = g.add("Q(w) fprop-layout", Quantize, Optimizer, false, Fp8, &[upd]);
            let qd = g.add("Q(w) dgrad-layout", Quantize, Optimizer, false, Fp8, &[upd]);
            g.set_units(qf, 3);
            g.set_units(qd, 3);
        }
        Variant::TeBlockwise | Variant::DeepSeekV3 => {
            let q = g.add("Q(w) fprop-layout", Quantize, Optimizer, false, Fp8, &[upd]);
            let nt =
                g.add("w naive-T dgrad-layout", NaiveTransposeRequant, Optimizer, false, Fp8, &[q]);
            g.set_units(q, 3);
            g.set_units(nt, 3);
        }
    }
    g
}

fn build_bf16() -> DataflowGraph {
    use Dtype::*;
    use OpKind::*;
    use Stage::*;
    let mut g = DataflowGraph::new("bf16");
    // forward
    let x = g.add("input", Input, Router, false, Bf16, &[]);
    let disp = g.add("dispatch-a2a", AllToAll, Dispatch, false, Bf16, &[x]);
    let perm = g.add("permute", OpKind::Permute, Stage::Permute, false, Bf16, &[disp]);
    let pad = g.add("pad", Pad, Stage::Permute, false, Bf16, &[perm]);
    let fc1 = g.add("fc1-grouped-gemm", GroupedGemm, Fc1, false, Bf16, &[pad]);
    let act = g.add("swiglu", SwiGlu, Activation, false, Bf16, &[fc1]);
    let fc2 = g.add("fc2-grouped-gemm", GroupedGemm, Fc2, false, Bf16, &[act]);
    let unperm = g.add("unpermute", Unpermute, Unperm, false, Bf16, &[fc2]);
    let unpad = g.add("unpad", Unpad, Unperm, false, Bf16, &[unperm]);
    let comb = g.add("combine-a2a", AllToAll, Combine, false, Bf16, &[unpad]);
    let _y = g.add("gate-scale-add", Scale, Combine, false, Bf16, &[comb]);
    // backward
    let dy = g.add("dy-input", Input, Combine, true, Bf16, &[]);
    let cb = g.add("combine-bwd-a2a", AllToAll, Combine, true, Bf16, &[dy]);
    let rp = g.add("re-pad", Pad, Stage::Permute, true, Bf16, &[cb]);
    let dg2 = g.add("fc2-dgrad", GroupedGemm, Fc2, true, Bf16, &[rp]);
    let at = g.add("act-T", DirectTranspose, Fc2, true, Bf16, &[dg2]);
    let _wg2 = g.add("fc2-wgrad", GroupedGemm, Fc2, true, F32, &[rp, at]);
    let sb = g.add("swiglu-bwd", SwiGluBwd, Activation, true, Bf16, &[dg2]);
    let dg1 = g.add("fc1-dgrad", GroupedGemm, Fc1, true, Bf16, &[sb]);
    let xt = g.add("x-T", DirectTranspose, Fc1, true, Bf16, &[dg1]);
    let _wg1 = g.add("fc1-wgrad", GroupedGemm, Fc1, true, F32, &[sb, xt]);
    let up = g.add("unpermute-bwd", Unpermute, Stage::Permute, true, Bf16, &[dg1]);
    let _dx = g.add("dispatch-bwd-a2a", AllToAll, Dispatch, true, Bf16, &[up]);
    g
}

fn build_blockwise() -> DataflowGraph {
    use Dtype::*;
    use OpKind::*;
    use Stage::*;
    let mut g = DataflowGraph::new("te-blockwise");
    // forward — comm & data movement all BF16; FP8 strictly inside GEMMs
    let x = g.add("input", Input, Router, false, Bf16, &[]);
    let disp = g.add("dispatch-a2a", AllToAll, Dispatch, false, Bf16, &[x]);
    let perm = g.add("permute", OpKind::Permute, Stage::Permute, false, Bf16, &[disp]);
    let pad = g.add("pad", Pad, Stage::Permute, false, Bf16, &[perm]);
    let q1 = g.add("Q(x) fc1-in", Quantize, Fc1, false, Fp8, &[pad]);
    let fc1 = g.add("fc1-grouped-gemm", GroupedGemm, Fc1, false, Bf16, &[q1]);
    let act = g.add("swiglu", SwiGlu, Activation, false, Bf16, &[fc1]);
    let q2 = g.add("Q(act) fc2-in", Quantize, Fc2, false, Fp8, &[act]);
    let fc2 = g.add("fc2-grouped-gemm", GroupedGemm, Fc2, false, Bf16, &[q2]);
    let unperm = g.add("unpermute", Unpermute, Unperm, false, Bf16, &[fc2]);
    let unpad = g.add("unpad", Unpad, Unperm, false, Bf16, &[unperm]);
    let comb = g.add("combine-a2a", AllToAll, Combine, false, Bf16, &[unpad]);
    let _y = g.add("gate-scale-add", Scale, Combine, false, Bf16, &[comb]);
    // backward
    let dy = g.add("dy-input", Input, Combine, true, Bf16, &[]);
    let cb = g.add("combine-bwd-a2a", AllToAll, Combine, true, Bf16, &[dy]);
    let rp = g.add("re-pad", Pad, Stage::Permute, true, Bf16, &[cb]);
    let q3 = g.add("Q(dy) fc2-grads", Quantize, Fc2, true, Fp8, &[rp]);
    let dg2 = g.add("fc2-dgrad", GroupedGemm, Fc2, true, Bf16, &[q3]);
    let at = g.add("act naive-T", NaiveTransposeRequant, Fc2, true, Fp8, &[q2]);
    let _wg2 = g.add("fc2-wgrad", GroupedGemm, Fc2, true, F32, &[q3, at]);
    let sb = g.add("swiglu-bwd", SwiGluBwd, Activation, true, Bf16, &[dg2]);
    let q4 = g.add("Q(dact) fc1-grads", Quantize, Fc1, true, Fp8, &[sb]);
    let dg1 = g.add("fc1-dgrad", GroupedGemm, Fc1, true, Bf16, &[q4]);
    let xt = g.add("x naive-T", NaiveTransposeRequant, Fc1, true, Fp8, &[q1]);
    let _wg1 = g.add("fc1-wgrad", GroupedGemm, Fc1, true, F32, &[q4, xt]);
    let up = g.add("unpermute-bwd", Unpermute, Stage::Permute, true, Bf16, &[dg1]);
    let _dx = g.add("dispatch-bwd-a2a", AllToAll, Dispatch, true, Bf16, &[up]);
    // executed-instance multiplicities (the schematic draws one node per
    // logical op): Q(dact) covers Q(d_gate)+Q(d_up); the act transpose
    // covers {act, dy}ᵀ and the x transpose {x, d_gate, d_up}ᵀ — matching
    // the 3 casts + 5 requants per expert of `blockwise_expert_bwd`
    g.set_units(q4, 2);
    g.set_units(at, 2);
    g.set_units(xt, 3);
    g
}

fn build_deepseek() -> DataflowGraph {
    use Dtype::*;
    use OpKind::*;
    use Stage::*;
    let mut g = DataflowGraph::new("deepseek-v3");
    // forward — FP8 comm via DeepEP: Q before / DQ after each all-to-all
    let x = g.add("input", Input, Router, false, Bf16, &[]);
    let q1 = g.add("Q(x) pre-dispatch", Quantize, Dispatch, false, Fp8, &[x]);
    let disp = g.add("dispatch-a2a (fp8)", AllToAll, Dispatch, false, Fp8, &[q1]);
    let d1 = g.add("DQ post-dispatch", Dequantize, Dispatch, false, Bf16, &[disp]);
    let perm = g.add("permute", OpKind::Permute, Stage::Permute, false, Bf16, &[d1]);
    let pad = g.add("pad", Pad, Stage::Permute, false, Bf16, &[perm]);
    let q2 = g.add("Q(x) fc1-in", Quantize, Fc1, false, Fp8, &[pad]);
    let fc1 = g.add("fc1-grouped-gemm", GroupedGemm, Fc1, false, Bf16, &[q2]);
    let act = g.add("swiglu", SwiGlu, Activation, false, Bf16, &[fc1]);
    let q3 = g.add("Q(act) fc2-in", Quantize, Fc2, false, Fp8, &[act]);
    let fc2 = g.add("fc2-grouped-gemm", GroupedGemm, Fc2, false, Bf16, &[q3]);
    let unperm = g.add("unpermute", Unpermute, Unperm, false, Bf16, &[fc2]);
    let unpad = g.add("unpad", Unpad, Unperm, false, Bf16, &[unperm]);
    let q4 = g.add("Q(y) pre-combine", Quantize, Combine, false, Fp8, &[unpad]);
    let comb = g.add("combine-a2a (fp8)", AllToAll, Combine, false, Fp8, &[q4]);
    let d2 = g.add("DQ post-combine", Dequantize, Combine, false, Bf16, &[comb]);
    let _y = g.add("gate-scale-add", Scale, Combine, false, Bf16, &[d2]);
    // backward — mirrored Q/DQ around both all-to-alls
    let dy = g.add("dy-input", Input, Combine, true, Bf16, &[]);
    let q5 = g.add("Q(dy) pre-combine-bwd", Quantize, Combine, true, Fp8, &[dy]);
    let cb = g.add("combine-bwd-a2a (fp8)", AllToAll, Combine, true, Fp8, &[q5]);
    let d3 = g.add("DQ post-combine-bwd", Dequantize, Combine, true, Bf16, &[cb]);
    let rp = g.add("re-pad", Pad, Stage::Permute, true, Bf16, &[d3]);
    let q6 = g.add("Q(dy) fc2-grads", Quantize, Fc2, true, Fp8, &[rp]);
    let dg2 = g.add("fc2-dgrad", GroupedGemm, Fc2, true, Bf16, &[q6]);
    let at = g.add("act naive-T", NaiveTransposeRequant, Fc2, true, Fp8, &[q3]);
    let _wg2 = g.add("fc2-wgrad", GroupedGemm, Fc2, true, F32, &[q6, at]);
    let sb = g.add("swiglu-bwd", SwiGluBwd, Activation, true, Bf16, &[dg2]);
    let q7 = g.add("Q(dact) fc1-grads", Quantize, Fc1, true, Fp8, &[sb]);
    let dg1 = g.add("fc1-dgrad", GroupedGemm, Fc1, true, Bf16, &[q7]);
    let xt = g.add("x naive-T", NaiveTransposeRequant, Fc1, true, Fp8, &[q2]);
    let _wg1 = g.add("fc1-wgrad", GroupedGemm, Fc1, true, F32, &[q7, xt]);
    let up = g.add("unpermute-bwd", Unpermute, Stage::Permute, true, Bf16, &[dg1]);
    let q8 = g.add("Q(dx) pre-dispatch-bwd", Quantize, Dispatch, true, Fp8, &[up]);
    let db = g.add("dispatch-bwd-a2a (fp8)", AllToAll, Dispatch, true, Fp8, &[q8]);
    let _d4 = g.add("DQ post-dispatch-bwd", Dequantize, Dispatch, true, Bf16, &[db]);
    // same schematic-to-instance multiplicities as the blockwise backward
    // (the wgrad operand prep is identical)
    g.set_units(q7, 2);
    g.set_units(at, 2);
    g.set_units(xt, 3);
    g
}

fn build_fp8flow() -> DataflowGraph {
    use Dtype::*;
    use OpKind::*;
    use Stage::*;
    let mut g = DataflowGraph::new("fp8-flow-moe");
    // forward — ONE explicit cast at the MoE entry; FP8 persists
    let x = g.add("input", Input, Router, false, Bf16, &[]);
    let q1 = g.add("Q(x) entry", Quantize, Dispatch, false, Fp8, &[x]);
    let disp = g.add("dispatch-a2a (fp8)", AllToAll, Dispatch, false, Fp8, &[q1]);
    let perm = g.add("fused-permute-pad (fp8)", FusedPermutePad, Stage::Permute, false, Fp8, &[disp]);
    // fc1 consumes FP8 directly; output is the first BF16 island (§3.2:
    // reductions after the GEMM are overflow-prone in FP8)
    let fc1 = g.add("fc1-grouped-gemm", GroupedGemm, Fc1, false, Bf16, &[perm]);
    // fused SwiGLU+quant: BF16 island ends inside the compute kernel
    let act = g.add("fused-swiglu-quant", FusedSwiGluQuant, Activation, false, Fp8, &[fc1]);
    let fc2 = g.add("fc2-grouped-gemm", GroupedGemm, Fc2, false, Bf16, &[act]);
    let unperm = g.add("fused-unpermute-unpad", FusedUnpermuteUnpad, Unperm, false, Bf16, &[fc2]);
    let comb = g.add("combine-a2a", AllToAll, Combine, false, Bf16, &[unperm]);
    let _y = g.add("gate-scale-add", Scale, Combine, false, Bf16, &[comb]);
    // backward — ONE explicit cast at the backward entry (island #2 is
    // between fc2-dgrad and combine-bwd)
    let dy = g.add("dy-input", Input, Combine, true, Bf16, &[]);
    let q2 = g.add("Q(dy) bwd-entry", Quantize, Combine, true, Fp8, &[dy]);
    let cb = g.add("combine-bwd-a2a (fp8)", AllToAll, Combine, true, Fp8, &[q2]);
    let rp = g.add("fused-re-pad (fp8)", FusedPermutePad, Stage::Permute, true, Fp8, &[cb]);
    let dg2 = g.add("fc2-dgrad", GroupedGemm, Fc2, true, Bf16, &[rp]);
    // wgrad operands via the scaling-aware DIRECT transpose — zero Q/DQ
    let at = g.add("act direct-T", DirectTranspose, Fc2, true, Fp8, &[act]);
    let dyt = g.add("dy direct-T", DirectTranspose, Fc2, true, Fp8, &[rp]);
    let _wg2 = g.add("fc2-wgrad", GroupedGemm, Fc2, true, F32, &[dyt, at]);
    // fused SwiGLU-bwd+quant: consumes BF16 dgrad, emits FP8 grads
    let sb = g.add("fused-swiglu-bwd-quant", FusedSwiGluBwdQuant, Activation, true, Fp8, &[dg2]);
    let dg1 = g.add("fc1-dgrad", GroupedGemm, Fc1, true, Fp8, &[sb]);
    let xt = g.add("x direct-T", DirectTranspose, Fc1, true, Fp8, &[perm]);
    let sbt = g.add("dact direct-T", DirectTranspose, Fc1, true, Fp8, &[sb]);
    let _wg1 = g.add("fc1-wgrad", GroupedGemm, Fc1, true, F32, &[sbt, xt]);
    let up = g.add("fused-unpermute-bwd (fp8)", FusedUnpermuteUnpad, Stage::Permute, true, Fp8, &[dg1]);
    let _dx = g.add("dispatch-bwd-a2a (fp8)", AllToAll, Dispatch, true, Fp8, &[up]);
    // the dact transpose covers {d_gate, d_up}ᵀ — with the three unit
    // transposes above, the five direct transposes of `flow_expert_bwd`
    // (all code-space: zero casts, zero requants in the prediction)
    g.set_units(sbt, 2);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_variants_validate() {
        for v in Variant::all() {
            build(v).validate().unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        }
    }

    #[test]
    fn cast_counts_match_paper() {
        // The paper's headline accounting: 12 explicit casts (DeepSeek-V3
        // style) reduced to 2 (FP8-Flow).
        assert_eq!(build(Variant::Bf16).explicit_casts(), 0);
        assert_eq!(build(Variant::TeBlockwise).explicit_casts(), 4);
        assert_eq!(build(Variant::DeepSeekV3).explicit_casts(), 12);
        assert_eq!(build(Variant::Fp8Flow).explicit_casts(), 2);
    }

    #[test]
    fn qdq_events_include_naive_transposes() {
        // blockwise/deepseek hide 2 q/dq in each of the two naive wgrad
        // transposes (the double-quantization site)
        assert_eq!(build(Variant::TeBlockwise).total_qdq_events(), 4 + 4);
        assert_eq!(build(Variant::DeepSeekV3).total_qdq_events(), 12 + 4);
        assert_eq!(build(Variant::Fp8Flow).total_qdq_events(), 2);
    }

    #[test]
    fn per_direction_cast_split_matches_headline() {
        // fwd/bwd split of the Fig. 2 accounting (the executed backward's
        // audit anchors: tests/prop_backward.rs)
        for (v, fwd, bwd) in [
            (Variant::Bf16, 0usize, 0usize),
            (Variant::TeBlockwise, 2, 2),
            (Variant::DeepSeekV3, 6, 6),
            (Variant::Fp8Flow, 1, 1),
        ] {
            let g = build(v);
            assert_eq!(g.explicit_casts_fwd(), fwd, "{} fwd", v.name());
            assert_eq!(g.explicit_casts_bwd(), bwd, "{} bwd", v.name());
        }
    }

    #[test]
    fn wgrad_casting_freedom_per_variant() {
        // only the recipes whose backward transposes are scaling-aware can
        // run the executed zero-requant backward (moe::backward)
        assert!(build(Variant::Bf16).casting_free_wgrad());
        assert!(!build(Variant::TeBlockwise).casting_free_wgrad());
        assert!(!build(Variant::DeepSeekV3).casting_free_wgrad());
        assert!(build(Variant::Fp8Flow).casting_free_wgrad());
        assert_eq!(build(Variant::TeBlockwise).requant_nodes_bwd(), 2);
        assert_eq!(build(Variant::Fp8Flow).requant_nodes_bwd(), 0);
    }

    #[test]
    fn fp8flow_has_exactly_two_bf16_islands_forward() {
        let g = build(Variant::Fp8Flow);
        let islands: Vec<_> = g
            .bf16_islands()
            .into_iter()
            .filter(|n| !n.backward)
            .map(|n| n.name.clone())
            .collect();
        // fwd islands: fc1 output (pre-activation) and fc2 output
        // (pre-combine reduction) — §3.2's two exceptions
        assert_eq!(islands, vec!["fc1-grouped-gemm", "fc2-grouped-gemm"]);
    }

    #[test]
    fn fp8flow_uses_direct_transpose_everywhere() {
        let g = build(Variant::Fp8Flow);
        let naive = g.nodes.iter().filter(|n| n.op == OpKind::NaiveTransposeRequant).count();
        let direct = g.nodes.iter().filter(|n| n.op == OpKind::DirectTranspose).count();
        assert_eq!(naive, 0);
        assert!(direct >= 3, "wgrad operands + dy all via direct transpose");
    }

    #[test]
    fn fp8flow_fuses_data_movement() {
        let g = build(Variant::Fp8Flow);
        let fused = g
            .nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.op,
                    OpKind::FusedPermutePad
                        | OpKind::FusedUnpermuteUnpad
                        | OpKind::FusedSwiGluQuant
                        | OpKind::FusedSwiGluBwdQuant
                )
            })
            .count();
        assert!(fused >= 5);
        // and fewer kernel launches than deepseek for the same math
        assert!(g.kernel_launches() < build(Variant::DeepSeekV3).kernel_launches());
    }

    #[test]
    fn expert_ffn_casting_freedom_per_variant() {
        // Only the recipes without standalone casts inside Fc1/Act/Fc2 can
        // run the expert FFN as one fused streaming pipeline.
        assert!(build(Variant::Bf16).casting_free_expert_ffn());
        assert!(!build(Variant::TeBlockwise).casting_free_expert_ffn());
        assert!(!build(Variant::DeepSeekV3).casting_free_expert_ffn());
        assert!(build(Variant::Fp8Flow).casting_free_expert_ffn());
    }

    #[test]
    fn train_step_optimizer_tail_audit() {
        // The Fig. 2 headline is untouched by the optimizer tail, and the
        // weight requantization adds zero requant nodes for Fp8Flow while
        // the incumbent layout derivation pays one per step.
        for v in Variant::all() {
            let layer = build(v);
            let step = build_train_step(v);
            assert_eq!(step.explicit_casts_fwd(), layer.explicit_casts_fwd(), "{}", v.name());
            assert_eq!(step.explicit_casts_bwd(), layer.explicit_casts_bwd(), "{}", v.name());
            step.validate().unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        }
        let flow = build_train_step(Variant::Fp8Flow);
        assert_eq!(flow.explicit_casts_fwd() + flow.explicit_casts_bwd(), 2);
        assert_eq!(flow.requant_nodes_opt(), 0);
        assert_eq!(flow.explicit_casts_opt(), 2); // one Q per layout, both master-sourced
        assert_eq!(build_train_step(Variant::Bf16).explicit_casts_opt(), 0);
        for v in [Variant::TeBlockwise, Variant::DeepSeekV3] {
            assert_eq!(build_train_step(v).requant_nodes_opt(), 1, "{}", v.name());
        }
    }

    #[test]
    fn fp8_dispatch_volume() {
        // dispatch a2a runs in FP8 for deepseek & fp8flow, BF16 otherwise
        for (v, fp8) in [
            (Variant::Bf16, false),
            (Variant::TeBlockwise, false),
            (Variant::DeepSeekV3, true),
            (Variant::Fp8Flow, true),
        ] {
            let g = build(v);
            let disp = g
                .nodes
                .iter()
                .find(|n| n.op == OpKind::AllToAll && n.stage == Stage::Dispatch && !n.backward)
                .unwrap();
            assert_eq!(disp.out_dtype == Dtype::Fp8, fp8, "{}", v.name());
        }
    }
}
