//! Worker groups — [`Partition`]-backed sub-pools of the process worker
//! budget.
//!
//! A [`WorkerGroup`] carves the total worker budget into `n_groups`
//! disjoint shares (every group gets at least one worker) and runs one
//! closure per group concurrently. Each closure receives its group index
//! and its worker budget; kernels called inside a group body must use the
//! `*_with_threads` forms with that budget, so the sum of live workers
//! across all groups never exceeds the process budget — the same
//! no-nested-oversubscription rule the expert loops follow, lifted one
//! level up. The executed EP runtime ([`crate::cluster::rank`]) uses one
//! group per simulated rank.

use crate::exec::partition::Partition;

/// Disjoint worker budgets for `n_groups` concurrent sub-pools.
#[derive(Clone, Debug)]
pub struct WorkerGroup {
    budgets: Vec<usize>,
}

impl WorkerGroup {
    /// Split `total_workers` into `n_groups` near-equal budgets. When the
    /// budget is smaller than the group count, every group still gets one
    /// worker (the groups then oversubscribe by `n_groups - total`, the
    /// minimum possible).
    pub fn new(n_groups: usize, total_workers: usize) -> WorkerGroup {
        assert!(n_groups > 0, "WorkerGroup needs at least one group");
        let p = Partition::even(total_workers.max(n_groups), n_groups);
        WorkerGroup { budgets: p.ranges().map(|r| r.len()).collect() }
    }

    /// Number of groups.
    pub fn len(&self) -> usize {
        self.budgets.len()
    }

    /// True when there are no groups.
    pub fn is_empty(&self) -> bool {
        self.budgets.is_empty()
    }

    /// Worker budget of group `g`.
    pub fn budget(&self, g: usize) -> usize {
        self.budgets[g]
    }

    /// Sum of all budgets (= `max(total_workers, n_groups)`).
    pub fn total(&self) -> usize {
        self.budgets.iter().sum()
    }

    /// Run `f(group_index, budget)` once per group, concurrently: group 0
    /// on the calling thread, the rest on scoped threads. Results come
    /// back in group order; a panicking group propagates.
    pub fn run<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Sync,
    {
        let n = self.budgets.len();
        if n == 1 {
            return vec![f(0, self.budgets[0])];
        }
        let tok = crate::obs::session_token();
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = (1..n)
                .map(|g| {
                    let b = self.budgets[g];
                    s.spawn(move || {
                        tok.adopt();
                        f(g, b)
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(n);
            out.push(f(0, self.budgets[0]));
            for h in handles {
                out.push(h.join().expect("worker-group member panicked"));
            }
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn budgets_partition_the_total() {
        let g = WorkerGroup::new(3, 8);
        assert_eq!(g.len(), 3);
        assert_eq!(g.total(), 8);
        assert_eq!((g.budget(0), g.budget(1), g.budget(2)), (3, 3, 2));
    }

    #[test]
    fn every_group_gets_a_worker() {
        let g = WorkerGroup::new(4, 2); // budget smaller than group count
        assert_eq!(g.len(), 4);
        for i in 0..4 {
            assert_eq!(g.budget(i), 1);
        }
        assert_eq!(g.total(), 4);
    }

    #[test]
    fn run_covers_all_groups_in_order() {
        let g = WorkerGroup::new(5, 16);
        let out = g.run(|idx, budget| (idx, budget));
        assert_eq!(out.len(), 5);
        for (i, &(idx, budget)) in out.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(budget, g.budget(i));
        }
        let seen: BTreeSet<usize> = out.iter().map(|&(i, _)| i).collect();
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn single_group_gets_everything() {
        let g = WorkerGroup::new(1, 8);
        assert_eq!(g.run(|_, b| b), vec![8]);
    }
}
