//! Tile-parallel execution layer for the native (L3) hot-path kernels.
//!
//! The paper's kernels are data-parallel by construction: the 1×128-tile
//! quantizer (Eq. 2–3) is independent per row, the scaling-aware direct
//! transpose (Alg. 1) is independent per 128×128 block, the per-tile
//! scaled GEMM is independent per output row, and the grouped expert FFN
//! is independent per expert. This module exploits exactly that structure
//! and nothing more:
//!
//! * [`Partition`] — a **static** row/expert/block partitioner: contiguous
//!   near-equal ranges, optionally aligned to a block size. Static
//!   partitioning keeps every worker's iteration order identical to the
//!   serial kernel's, which is what makes the parallel kernels
//!   **bit-identical** to their serial forms (FP8 tile accumulation order
//!   is fixed per output element — see `tests/prop_parallel.rs`).
//! * [`pool`] — a scoped-thread worker pool (`std::thread::scope`, no
//!   external deps): part 0 runs on the calling thread, the rest on
//!   scoped workers; disjoint `&mut` output sub-slices are carved with
//!   `split_at_mut`, so the whole layer is safe Rust.
//! * [`group`] — worker groups: the budget itself partitioned into
//!   disjoint sub-pools, one per concurrently running coarse unit (e.g.
//!   one per simulated expert-parallel rank), so nested kernel calls
//!   never oversubscribe the machine.
//! * [`steps`] — a small async step-graph runtime: a DAG of one-shot
//!   steps over fixed lanes with per-step timers, used by the
//!   double-buffered EP pipeline to overlap comm and compute
//!   ([`crate::cluster::ep_exec`]). Lane budgets are carved from the
//!   same process budget, so overlap never oversubscribes either.
//!
//! Thread-count resolution (highest wins): [`set_threads`] (CLI
//! `--threads`), the `FP8_THREADS` environment variable, then
//! `std::thread::available_parallelism()`. Kernels running *inside* an
//! already-parallel region (e.g. per-expert work in
//! [`crate::moe::layer::fused_expert_ffn`]) call the `*_with_threads`
//! variants with `1` to avoid nested oversubscription.

pub mod group;
pub mod partition;
pub mod pool;
pub mod steps;

pub use group::WorkerGroup;
pub use partition::Partition;
pub use pool::{map_parts, run_tasks, split_parts};
pub use steps::{Handoff, StepGraph, StepId, StepTime};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread override; 0 = resolve automatically.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the worker count for subsequent kernel calls (0 = auto).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Resolved worker count: explicit [`set_threads`] value, else
/// `FP8_THREADS`, else the machine's available parallelism.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t > 0 {
        return t;
    }
    if let Some(n) = env_threads() {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn env_threads() -> Option<usize> {
    std::env::var("FP8_THREADS").ok()?.parse::<usize>().ok().filter(|&n| n > 0)
}

/// Clamp a requested worker count to the number of parallel items
/// (never zero, never more workers than items).
pub fn workers_for(threads: usize, n_items: usize) -> usize {
    threads.max(1).min(n_items.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_clamped_to_items() {
        assert_eq!(workers_for(8, 3), 3);
        assert_eq!(workers_for(2, 100), 2);
        assert_eq!(workers_for(0, 10), 1);
        assert_eq!(workers_for(4, 0), 1);
    }

    #[test]
    fn threads_resolves_to_something_positive() {
        assert!(threads() >= 1);
    }
}
