//! Scoped-thread worker pool: one worker per partition part, part 0 on the
//! calling thread, disjoint output sub-slices via `split_at_mut`.
//!
//! At [`crate::obs`] detail ≥ 2 (`--trace-detail 2`), [`map_parts`]
//! records one span per partition part so kernel-level load imbalance is
//! visible in the trace; at the default detail these sites cost one
//! relaxed atomic load each.

use crate::exec::partition::Partition;
use crate::obs;

/// Split `data` into per-part mutable sub-slices at the partition's item
/// boundaries, where each item owns `stride` consecutive elements.
///
/// `data.len()` must equal `partition.n_items() * stride`; the returned
/// slices are disjoint, in part order, and cover all of `data`.
pub fn split_parts<'a, T>(p: &Partition, stride: usize, data: &'a mut [T]) -> Vec<&'a mut [T]> {
    assert_eq!(
        data.len(),
        p.n_items() * stride,
        "split_parts: slice length does not match partition × stride"
    );
    let mut out = Vec::with_capacity(p.len());
    let mut rest = data;
    for r in p.ranges() {
        let (head, tail) = rest.split_at_mut(r.len() * stride);
        out.push(head);
        rest = tail;
    }
    out
}

/// Run one task per element of `tasks` on the scoped pool. The first task
/// runs on the calling thread; the rest on scoped workers. Returns when
/// every task has finished (a panicking worker propagates on scope exit).
pub fn run_tasks<T: Send, F: Fn(T) + Sync>(tasks: Vec<T>, f: F) {
    let mut it = tasks.into_iter();
    let Some(first) = it.next() else { return };
    let rest: Vec<T> = it.collect();
    if rest.is_empty() {
        f(first);
        return;
    }
    let tok = obs::session_token();
    std::thread::scope(|s| {
        let f = &f;
        for t in rest {
            s.spawn(move || {
                tok.adopt();
                f(t)
            });
        }
        f(first);
    });
}

/// Map `f` over `0..p.n_items()` with one worker per part, preserving item
/// order in the returned vector. Used where each item produces an owned
/// result (e.g. one output matrix per expert in the grouped GEMM).
pub fn map_parts<R, F>(p: &Partition, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let run_part = |w: usize| -> Vec<R> {
        let _s = (obs::detail() >= 2)
            .then(|| obs::span(format!("part {w}"), obs::SpanMeta::stage("part").lane(w)));
        p.range(w).map(&f).collect()
    };
    if p.len() <= 1 {
        return run_part(0);
    }
    let tok = obs::session_token();
    std::thread::scope(|s| {
        let run_part = &run_part;
        let handles: Vec<_> = (1..p.len())
            .map(|w| {
                s.spawn(move || {
                    tok.adopt();
                    run_part(w)
                })
            })
            .collect();
        let mut out: Vec<R> = run_part(0);
        for h in handles {
            out.extend(h.join().expect("worker panicked"));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_parts_disjoint_cover() {
        let p = Partition::even(10, 3);
        let mut data = vec![0u32; 10 * 4];
        let parts = split_parts(&p, 4, &mut data);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].len(), 16);
        assert_eq!(parts[1].len(), 12);
        assert_eq!(parts[2].len(), 12);
    }

    #[test]
    fn run_tasks_executes_all() {
        let hits = AtomicUsize::new(0);
        let tasks: Vec<usize> = (0..17).collect();
        run_tasks(tasks, |i| {
            hits.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), (1..=17).sum::<usize>());
    }

    #[test]
    fn run_tasks_writes_through_mut_slices() {
        let p = Partition::even(100, 8);
        let mut data = vec![0usize; 100 * 2];
        let tasks: Vec<_> = split_parts(&p, 2, &mut data)
            .into_iter()
            .zip(p.ranges())
            .collect();
        run_tasks(tasks, |(slice, r)| {
            for (k, v) in slice.iter_mut().enumerate() {
                *v = r.start * 2 + k;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn map_parts_preserves_order() {
        for workers in [1usize, 2, 5, 16] {
            let p = Partition::even(37, workers);
            let out = map_parts(&p, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_inputs_are_fine() {
        run_tasks(Vec::<usize>::new(), |_| {});
        let p = Partition::even(0, 4);
        assert_eq!(map_parts(&p, |i| i).len(), 0);
        let mut data: Vec<u8> = Vec::new();
        assert_eq!(split_parts(&p, 3, &mut data).len(), 1);
    }
}
