//! Static partitioner: contiguous near-equal item ranges, optionally
//! aligned to a block size (128 for the transpose's scale blocks).

use std::ops::Range;

/// A static partition of `0..n_items` into contiguous ranges.
///
/// `starts` has `n_parts + 1` entries; part `w` covers
/// `starts[w]..starts[w+1]`. Ranges are non-overlapping, cover the whole
/// item space, and are in ascending order — each worker processes exactly
/// the items the serial loop would have processed, in the same order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    starts: Vec<usize>,
}

impl Partition {
    /// Split `n_items` into `n_parts` near-equal contiguous ranges (the
    /// first `n_items % n_parts` parts get one extra item).
    pub fn even(n_items: usize, n_parts: usize) -> Partition {
        let n_parts = n_parts.max(1).min(n_items.max(1));
        let base = n_items / n_parts;
        let rem = n_items % n_parts;
        let mut starts = Vec::with_capacity(n_parts + 1);
        let mut at = 0usize;
        starts.push(at);
        for w in 0..n_parts {
            at += base + usize::from(w < rem);
            starts.push(at);
        }
        debug_assert_eq!(at, n_items);
        Partition { starts }
    }

    /// Split `n_items` into ranges whose boundaries fall on multiples of
    /// `block` (except the final boundary, which is `n_items`). Used by
    /// kernels whose unit of independence is a block of items — e.g. the
    /// direct transpose's 128-row scale blocks.
    pub fn blocks(n_items: usize, block: usize, n_parts: usize) -> Partition {
        assert!(block > 0);
        let n_blocks = n_items.div_ceil(block);
        let bp = Partition::even(n_blocks, n_parts);
        let starts = bp
            .starts
            .iter()
            .map(|&b| (b * block).min(n_items))
            .collect();
        Partition { starts }
    }

    /// Number of parts.
    pub fn len(&self) -> usize {
        self.starts.len() - 1
    }

    /// True when there are no parts.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Item range of part `w`.
    pub fn range(&self, w: usize) -> Range<usize> {
        self.starts[w]..self.starts[w + 1]
    }

    /// Iterate over all part ranges in order.
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.len()).map(|w| self.range(w))
    }

    /// Total number of items partitioned.
    pub fn n_items(&self) -> usize {
        *self.starts.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_covers_everything_in_order() {
        for n in [0usize, 1, 7, 8, 9, 100, 1000] {
            for p in [1usize, 2, 3, 8, 64] {
                let part = Partition::even(n, p);
                let mut at = 0;
                for r in part.ranges() {
                    assert_eq!(r.start, at, "n={n} p={p}");
                    at = r.end;
                }
                assert_eq!(at, n);
                assert!(part.len() <= p.max(1));
            }
        }
    }

    #[test]
    fn even_is_balanced() {
        let part = Partition::even(10, 3);
        let lens: Vec<usize> = part.ranges().map(|r| r.len()).collect();
        assert_eq!(lens, vec![4, 3, 3]);
    }

    #[test]
    fn never_more_parts_than_items() {
        assert_eq!(Partition::even(2, 8).len(), 2);
        assert_eq!(Partition::even(0, 8).len(), 1);
        assert_eq!(Partition::even(0, 8).range(0), 0..0);
    }

    #[test]
    fn blocks_align_to_block_size() {
        let part = Partition::blocks(300, 128, 2); // 3 blocks of 128 (last ragged)
        assert_eq!(part.len(), 2);
        assert_eq!(part.range(0), 0..256);
        assert_eq!(part.range(1), 256..300);
        for r in part.ranges() {
            assert_eq!(r.start % 128, 0);
        }
    }

    #[test]
    fn blocks_with_more_parts_than_blocks() {
        let part = Partition::blocks(130, 128, 8); // 2 blocks
        assert_eq!(part.len(), 2);
        assert_eq!(part.range(0), 0..128);
        assert_eq!(part.range(1), 128..130);
    }
}
