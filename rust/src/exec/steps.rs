//! Async step-graph runtime — software-pipelined execution over disjoint
//! worker lanes, for comm/compute overlap.
//!
//! [`StepGraph`] schedules a small DAG of one-shot steps onto a fixed set
//! of **lanes** (one OS thread each, scoped — no detached threads). The
//! expert-parallel pipeline ([`crate::cluster::ep_exec`]) uses one comm
//! lane plus one compute lane per simulated rank, so packing/all-to-all
//! of chunk k+1 runs while the expert FFN of chunk k is still in flight.
//! Lane worker budgets are carved from the same process budget as
//! [`crate::exec::WorkerGroup`] sub-pools, so nothing oversubscribes.
//!
//! **Deadlock freedom.** [`StepGraph::add`] asserts every dependency id
//! is smaller than the new step's id, and each lane executes its steps in
//! insertion order (= ascending id). Consider the lowest-id step not yet
//! complete: all its dependencies have smaller ids and are therefore
//! complete, and every earlier step on its own lane is complete too, so
//! its lane is either running it or about to — it cannot be blocked. By
//! induction every step completes, for **any** assignment of steps to
//! lanes (including fully merged single-lane graphs, which degrade to
//! plain serial execution — the property the bit-identity tests lean on).
//!
//! Steps communicate values over [`Handoff`] cells. A handoff carries no
//! synchronization of its own: the graph dependency from producer to
//! consumer *is* the synchronization, the cell just moves the value. A
//! `take` on an empty cell is a wiring bug and panics loudly.

use crate::obs;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// Opaque handle to a scheduled step; pass it to later
/// [`StepGraph::add`] calls as a dependency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepId(usize);

impl StepId {
    /// The step's global insertion index (unique, ascending).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Wall-clock record of one executed step (offsets in seconds from the
/// [`StepGraph::run`] start).
#[derive(Clone, Debug)]
pub struct StepTime {
    /// Insertion index of the step (= [`StepId::index`]).
    pub id: usize,
    /// Lane the step ran on.
    pub lane: usize,
    /// Display label given at [`StepGraph::add`].
    pub label: String,
    /// Start offset, seconds.
    pub start_s: f64,
    /// End offset, seconds.
    pub end_s: f64,
}

impl StepTime {
    /// Busy seconds of this step.
    pub fn dur_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

struct Step<'env> {
    id: usize,
    deps: Vec<usize>,
    label: String,
    meta: Option<obs::SpanMeta>,
    body: Box<dyn FnOnce() + Send + 'env>,
}

/// A DAG of one-shot steps scheduled onto fixed lanes.
///
/// Build with [`StepGraph::add`], execute with [`StepGraph::run`]; see
/// the module docs for the ordering/deadlock contract.
pub struct StepGraph<'env> {
    lanes: Vec<Vec<Step<'env>>>,
    next_id: usize,
}

impl<'env> StepGraph<'env> {
    /// A graph with `n_lanes` execution lanes (≥ 1).
    pub fn new(n_lanes: usize) -> StepGraph<'env> {
        assert!(n_lanes >= 1, "need at least one lane");
        StepGraph { lanes: (0..n_lanes).map(|_| Vec::new()).collect(), next_id: 0 }
    }

    /// Number of steps added so far.
    pub fn n_steps(&self) -> usize {
        self.next_id
    }

    /// Schedule `body` on `lane`, after all of `deps`. Returns the new
    /// step's id (strictly greater than every id issued before, which is
    /// what the deadlock-freedom argument needs).
    pub fn add<F>(
        &mut self,
        lane: usize,
        deps: &[StepId],
        label: impl Into<String>,
        body: F,
    ) -> StepId
    where
        F: FnOnce() + Send + 'env,
    {
        self.push_step(lane, deps, label.into(), None, Box::new(body))
    }

    /// Like [`StepGraph::add`], but also attaches [`obs`] span coordinates:
    /// when a recorder is installed, the step body is wrapped in a span
    /// named after the label, with the meta's lane overwritten by the
    /// executing lane. Steps added without meta record no span, so purely
    /// internal orchestration stays out of the trace.
    pub fn add_with_meta<F>(
        &mut self,
        lane: usize,
        deps: &[StepId],
        label: impl Into<String>,
        meta: obs::SpanMeta,
        body: F,
    ) -> StepId
    where
        F: FnOnce() + Send + 'env,
    {
        self.push_step(lane, deps, label.into(), Some(meta), Box::new(body))
    }

    fn push_step(
        &mut self,
        lane: usize,
        deps: &[StepId],
        label: String,
        meta: Option<obs::SpanMeta>,
        body: Box<dyn FnOnce() + Send + 'env>,
    ) -> StepId {
        assert!(lane < self.lanes.len(), "lane {lane} out of range");
        let id = self.next_id;
        for d in deps {
            assert!(
                d.0 < id,
                "step dependency must precede the step (dep {} >= id {id})",
                d.0
            );
        }
        self.lanes[lane].push(Step {
            id,
            deps: deps.iter().map(|d| d.0).collect(),
            label,
            meta,
            body,
        });
        self.next_id += 1;
        StepId(id)
    }

    /// Execute the whole graph: one scoped thread per non-empty lane
    /// (the first non-empty lane runs on the calling thread), each lane
    /// running its steps in insertion order and blocking on unfinished
    /// dependencies. Returns per-step wall-clock records sorted by id.
    pub fn run(self) -> Vec<StepTime> {
        let n = self.next_id;
        if n == 0 {
            return Vec::new();
        }
        let done = Mutex::new(vec![false; n]);
        let cv = Condvar::new();
        let t0 = Instant::now();
        let run_lane = |lane: usize, steps: Vec<Step<'env>>| -> Vec<StepTime> {
            let mut times = Vec::with_capacity(steps.len());
            for step in steps {
                wait_for(&done, &cv, &step.deps);
                let start_s = t0.elapsed().as_secs_f64();
                // The guard marks the step done (and wakes waiters) even
                // if the body panics, so sibling lanes unblock and the
                // panic can propagate through the scope join instead of
                // deadlocking the whole graph.
                let guard = MarkDone { done: &done, cv: &cv, id: step.id };
                // enabled() gate first so the label clone is never paid
                // on the no-op path (non-perturbation contract).
                let span = match step.meta {
                    Some(m) if obs::enabled() => {
                        Some(obs::span(step.label.clone(), m.lane(lane)))
                    }
                    _ => None,
                };
                (step.body)();
                drop(span);
                drop(guard);
                times.push(StepTime {
                    id: step.id,
                    lane,
                    label: step.label,
                    start_s,
                    end_s: t0.elapsed().as_secs_f64(),
                });
            }
            times
        };
        let mut lanes: Vec<(usize, Vec<Step<'env>>)> = self
            .lanes
            .into_iter()
            .enumerate()
            .filter(|(_, steps)| !steps.is_empty())
            .collect();
        let first = lanes.remove(0);
        let tok = crate::obs::session_token();
        let mut all = std::thread::scope(|s| {
            let run_lane = &run_lane;
            let handles: Vec<_> = lanes
                .into_iter()
                .map(|(lane, steps)| {
                    s.spawn(move || {
                        tok.adopt();
                        run_lane(lane, steps)
                    })
                })
                .collect();
            let mut all = run_lane(first.0, first.1);
            for h in handles {
                all.extend(h.join().expect("step-graph lane panicked"));
            }
            all
        });
        all.sort_by_key(|st| st.id);
        all
    }
}

fn lock<'a>(m: &'a Mutex<Vec<bool>>) -> MutexGuard<'a, Vec<bool>> {
    // A poisoned lock means another lane panicked mid-step; the flag
    // vector is still valid (bools only ever flip false→true), so keep
    // going and let the panic surface at the scope join.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait_for(done: &Mutex<Vec<bool>>, cv: &Condvar, deps: &[usize]) {
    if deps.is_empty() {
        return;
    }
    let mut g = lock(done);
    while !deps.iter().all(|&d| g[d]) {
        g = cv.wait(g).unwrap_or_else(|e| e.into_inner());
    }
}

struct MarkDone<'a> {
    done: &'a Mutex<Vec<bool>>,
    cv: &'a Condvar,
    id: usize,
}

impl Drop for MarkDone<'_> {
    fn drop(&mut self) {
        lock(self.done)[self.id] = true;
        self.cv.notify_all();
    }
}

/// Single-use rendezvous cell moving one value from a producer step to a
/// consumer step.
///
/// Deliberately unsynchronized beyond a mutex: the [`StepGraph`]
/// dependency from producer to consumer already orders `put` before
/// `take`; the cell only has to move the value across threads. Taking
/// from an empty cell (missing dependency edge) or double-putting
/// (duplicate producer) is a pipeline wiring bug and panics.
pub struct Handoff<T> {
    cell: Mutex<Option<T>>,
}

impl<T> Handoff<T> {
    /// An empty cell.
    pub fn new() -> Handoff<T> {
        Handoff { cell: Mutex::new(None) }
    }

    /// Deposit the value. Panics if the cell is already occupied.
    pub fn put(&self, v: T) {
        let mut g = self.cell.lock().unwrap_or_else(|e| e.into_inner());
        assert!(g.is_none(), "handoff already holds a value");
        *g = Some(v);
    }

    /// Move the value out. Panics if the producer step has not run —
    /// which the graph dependency must guarantee.
    pub fn take(&self) -> T {
        self.cell
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("handoff is empty — producer step did not run before take")
    }
}

impl<T> Default for Handoff<T> {
    fn default() -> Handoff<T> {
        Handoff::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[test]
    fn steps_run_in_dependency_order_across_lanes() {
        let order = StdMutex::new(Vec::new());
        let mut g = StepGraph::new(3);
        let a = g.add(0, &[], "a", || order.lock().unwrap().push('a'));
        let b = g.add(1, &[a], "b", || order.lock().unwrap().push('b'));
        let c = g.add(2, &[a], "c", || order.lock().unwrap().push('c'));
        let d = g.add(0, &[b, c], "d", || order.lock().unwrap().push('d'));
        assert_eq!(d.index(), 3);
        let times = g.run();
        assert_eq!(times.len(), 4);
        for (i, st) in times.iter().enumerate() {
            assert_eq!(st.id, i);
            assert!(st.end_s >= st.start_s);
        }
        let ord = order.into_inner().unwrap();
        let pos = |ch: char| ord.iter().position(|&x| x == ch).unwrap();
        assert!(pos('a') < pos('b'));
        assert!(pos('a') < pos('c'));
        assert!(pos('b') < pos('d'));
        assert!(pos('c') < pos('d'));
    }

    #[test]
    fn single_lane_serializes_in_insertion_order_without_deps() {
        let order = StdMutex::new(Vec::new());
        let mut g = StepGraph::new(1);
        for i in 0..5 {
            g.add(0, &[], format!("s{i}"), || order.lock().unwrap().push(i));
        }
        g.run();
        assert_eq!(order.into_inner().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn handoff_pipelines_values_between_lanes() {
        let n = 4;
        let cells: Vec<Handoff<usize>> = (0..n).map(|_| Handoff::new()).collect();
        let out = StdMutex::new(vec![0usize; n]);
        let mut g = StepGraph::new(2);
        let produced: Vec<StepId> = (0..n)
            .map(|c| {
                let cells = &cells;
                g.add(0, &[], format!("put{c}"), move || cells[c].put(c * 10))
            })
            .collect();
        for c in 0..n {
            let (cells, out) = (&cells, &out);
            g.add(1, &[produced[c]], format!("take{c}"), move || {
                out.lock().unwrap()[c] = cells[c].take() + 1;
            });
        }
        g.run();
        assert_eq!(out.into_inner().unwrap(), vec![1, 11, 21, 31]);
    }

    #[test]
    fn merged_lane_assignment_also_completes() {
        // Same shape as the pipelined test but everything on one lane —
        // the w_r = 1 degenerate case of the EP overlap schedule.
        let cells: Vec<Handoff<usize>> = (0..3).map(|_| Handoff::new()).collect();
        let sum = StdMutex::new(0usize);
        let mut g = StepGraph::new(1);
        for c in 0..3 {
            let cells = &cells;
            let p = g.add(0, &[], format!("put{c}"), move || cells[c].put(c + 1));
            let sum = &sum;
            g.add(0, &[p], format!("take{c}"), move || {
                *sum.lock().unwrap() += cells[c].take();
            });
        }
        g.run();
        assert_eq!(sum.into_inner().unwrap(), 6);
    }

    #[test]
    fn steps_with_meta_record_spans_on_their_executing_lane() {
        let rec = obs::Recorder::new(1);
        {
            let _g = obs::install(rec.clone());
            let mut g = StepGraph::new(2);
            let a = g.add_with_meta(
                0,
                &[],
                "pack c0",
                obs::SpanMeta::stage("pack").rank(3).chunk(0),
                || {},
            );
            g.add_with_meta(
                1,
                &[a],
                "ffn c0",
                obs::SpanMeta::stage("ffn").rank(3).chunk(0),
                || {},
            );
            g.add(0, &[], "internal", || {}); // no meta ⇒ no span
            g.run();
        }
        let spans = rec.spans();
        assert_eq!(spans.len(), 2, "meta-less steps stay out of the trace");
        let pack = spans.iter().find(|s| s.name == "pack c0").unwrap();
        let ffn = spans.iter().find(|s| s.name == "ffn c0").unwrap();
        assert_eq!((pack.meta.stage, pack.meta.rank, pack.meta.lane), ("pack", 3, 0));
        assert_eq!((ffn.meta.stage, ffn.meta.rank, ffn.meta.lane), ("ffn", 3, 1));
        assert!(ffn.t0_s >= pack.t0_s, "dependency order carries into span starts");
    }

    #[test]
    fn empty_graph_runs_to_nothing() {
        let g = StepGraph::new(2);
        assert_eq!(g.n_steps(), 0);
        assert!(g.run().is_empty());
    }

    #[test]
    #[should_panic(expected = "must precede")]
    fn forward_dependency_rejected() {
        let mut g1 = StepGraph::new(1);
        let a = g1.add(0, &[], "a", || {});
        // `a` has id 0; a fresh graph's first id is also 0, so using it
        // as a dependency there violates dep < id.
        let mut g2 = StepGraph::new(1);
        g2.add(0, &[a], "b", || {});
    }

    #[test]
    #[should_panic(expected = "already holds")]
    fn double_put_rejected() {
        let h = Handoff::new();
        h.put(1);
        h.put(2);
    }

    #[test]
    #[should_panic(expected = "producer step did not run")]
    fn take_from_empty_rejected() {
        let h: Handoff<usize> = Handoff::new();
        h.take();
    }
}
