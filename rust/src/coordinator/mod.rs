//! Coordinator — the L3 leader: experiment drivers behind the CLI, and
//! run-metrics plumbing.
//!
//! The paper's contribution lives at L1/L2 (numeric format + dataflow), so
//! per the architecture spec L3 is a *driver*: process lifecycle, the
//! experiment loop, metrics and reporting. The heavier L3 subsystems live
//! in their own modules ([`crate::cluster`], [`crate::train`],
//! [`crate::moe`]); this module wires them to the binary.

pub mod reports;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::json::Json;

/// Write a JSON document under `runs/` (created on demand), returning the
/// path. All experiment outputs funnel through here so EXPERIMENTS.md can
/// cite stable file names.
pub fn write_run_json(name: &str, doc: &Json) -> Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("runs");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, doc.render())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_readback() {
        let doc = Json::obj().set("hello", 1.0f64);
        let p = write_run_json("test_write_run", &doc).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, r#"{"hello":1}"#);
        std::fs::remove_file(p).unwrap();
    }
}
