//! Table renderers for the simulated experiments (Tables 1–3) — shared by
//! the CLI, the examples and the bench binaries so every surface prints
//! identical rows.

use crate::cluster::comm::{table1_row, TABLE1_CONFIGS, TABLE1_PAPER};
use crate::cluster::memory::AcMode;
use crate::cluster::model_cfg::DEEPSEEK_V3;
use crate::cluster::sim::{simulate, SimResult};
use crate::moe::layer::Recipe;

/// Render Table 1 (communication performance with speedup), ours next to
/// the paper's measurements.
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("== Table 1: FP8 all-to-all with Q/DQ accounting (sim vs paper) ==\n");
    s.push_str(&format!(
        "{:<20} {:>9} {:>11} {:>9} {:>9} {:>7} {:>7} | {:>7} {:>7}\n",
        "(M,N,EP)", "BF16 ms", "Q/D ms", "COMM ms", "ALL ms", "S.comm", "S.all", "paperSc", "paperSa"
    ));
    for (i, &(m, n, ep)) in TABLE1_CONFIGS.iter().enumerate() {
        let r = table1_row(m, n, ep);
        let p = TABLE1_PAPER[i];
        s.push_str(&format!(
            "ROW ({m},{n},{ep}){:>pad$} {:>9.3} {:>5.3}/{:<5.3} {:>9.3} {:>9.3} {:>6.2}x {:>6.2}x | {:>6.2}x {:>6.2}x\n",
            "",
            r.bf16_ms,
            r.quant_ms,
            r.dequant_ms,
            r.fp8_comm_ms,
            r.fp8_all_ms,
            r.speedup_comm,
            r.speedup_all,
            p.5,
            p.6,
            pad = 20usize.saturating_sub(format!("({m},{n},{ep})").len() + 4),
        ));
    }
    s
}

fn recipe_name(r: Recipe) -> &'static str {
    match r {
        Recipe::Bf16 => "BF16",
        Recipe::Blockwise => "Blockwise",
        Recipe::Fp8Flow => "FP8-Flow-MoE",
    }
}

fn table23(ac: AcMode, title: &str, paper: &[(&str, usize, Option<(f64, f64)>)]) -> String {
    let mut s = String::new();
    s.push_str(&format!("== {title} (sim vs paper) ==\n"));
    s.push_str(&format!(
        "{:<14} {:>4} {:>9} {:>8} {:>10} {:>9} {:>10} {:>8}\n",
        "method", "EP", "TGS", "Mem GB", "bubble", "paperTGS", "paperMem", "status"
    ));
    for (ri, recipe) in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow].iter().enumerate() {
        for (ei, ep) in [8usize, 16, 32].iter().enumerate() {
            let r: SimResult = simulate(&DEEPSEEK_V3, *ep, 256 / ep, *recipe, ac);
            let p = paper[ri * 3 + ei].2;
            let (ptgs, pmem) = match p {
                Some((t, m)) => (format!("{t:.0}"), format!("{m:.0}")),
                None => ("OOM".into(), "OOM".into()),
            };
            s.push_str(&format!(
                "ROW {:<10} {:>4} {:>9} {:>8.1} {:>9.1}% {:>9} {:>10} {:>8}\n",
                recipe_name(*recipe),
                ep,
                if r.oom { "OOM".to_string() } else { format!("{:.0}", r.tgs) },
                r.mem_gb,
                r.bubble_frac * 100.0,
                ptgs,
                pmem,
                if r.oom { "OOM" } else { "ok" },
            ));
        }
    }
    s
}

/// Render Table 2 (AC=full).
pub fn table2() -> String {
    let paper: Vec<(&str, usize, Option<(f64, f64)>)> = crate::cluster::sim::TABLE2_PAPER
        .iter()
        .map(|&(r, ep, tgs, mem)| (r, ep, Some((tgs, mem))))
        .collect();
    table23(AcMode::Full, "Table 2: throughput/memory, AC=full", &paper)
}

/// Render Table 3 (AC=sel (+MoE expert)).
pub fn table3() -> String {
    table23(
        AcMode::SelMoeExpert,
        "Table 3: throughput/memory, AC=sel (+MoE expert)",
        &crate::cluster::sim::TABLE3_PAPER,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        let t1 = table1();
        assert_eq!(t1.matches("ROW").count(), 9);
        let t2 = table2();
        assert_eq!(t2.matches("ROW").count(), 9);
        assert!(!t2.contains(" OOM")); // AC=full: no OOM cell
        let t3 = table3();
        assert_eq!(t3.matches("ROW").count(), 9);
        assert!(t3.contains("OOM")); // AC=sel: baselines OOM at EP32
    }
}
