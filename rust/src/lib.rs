//! # fp8-flow-moe
//!
//! Reproduction of **FP8-Flow-MoE: A Casting-Free FP8 Recipe without Double
//! Quantization Error** (Wang, Su, Hu, Wang, Sun — Zhejiang Lab, 2025).
//!
//! Three-layer architecture:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`, build-time only)
//! * **L2** — JAX MoE model + train step (`python/compile/model.py`),
//!   AOT-lowered to HLO text in `artifacts/`
//! * **L3** — this crate: the FP8 numeric substrate, the MoE dataflow
//!   recipes with cast accounting, the expert-parallel cluster simulator,
//!   native (hot-path) kernels, and the PJRT runtime that loads and
//!   executes the AOT artifacts.
//!
//! The paper's two central ideas are both implemented natively and in the
//! JAX graph:
//!
//! 1. [`fp8::transpose`] — the *scaling-aware direct transpose* (Alg. 1):
//!    converting a row-wise-quantized FP8 tensor into a column-wise one by
//!    exponent manipulation alone, eliminating the **double quantization
//!    error** `E = Q_col(D(Q_row(X))) − Q_col(X)` (Eq. 1).
//! 2. [`dataflow`] — the casting-free FP8 dataflow: the MoE expert path
//!    keeps FP8 end-to-end except two BF16 islands, reducing explicit cast
//!    ops from 12 to 2 (Fig. 2).

pub mod cluster;
pub mod coordinator;
pub mod dataflow;
pub mod exec;
pub mod fp8;
pub mod moe;
pub mod runtime;
pub mod train;
pub mod util;
