//! # fp8-flow-moe
//!
//! Reproduction of **FP8-Flow-MoE: A Casting-Free FP8 Recipe without Double
//! Quantization Error** (Wang, Su, Hu, Wang, Sun — Zhejiang Lab, 2025).
//!
//! Three-layer architecture:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`, build-time only)
//! * **L2** — JAX MoE model + train step (`python/compile/model.py`),
//!   AOT-lowered to HLO text in `artifacts/`
//! * **L3** — this crate: the FP8 numeric substrate, the MoE dataflow
//!   recipes with cast accounting, the expert-parallel cluster simulator,
//!   native (hot-path) kernels, and the PJRT runtime that loads and
//!   executes the AOT artifacts.
//!
//! The paper's two central ideas are both implemented natively and in the
//! JAX graph:
//!
//! 1. [`fp8::transpose`] — the *scaling-aware direct transpose* (Alg. 1):
//!    converting a row-wise-quantized FP8 tensor into a column-wise one by
//!    exponent manipulation alone, eliminating the **double quantization
//!    error** `E = Q_col(D(Q_row(X))) − Q_col(X)` (Eq. 1).
//! 2. [`dataflow`] — the casting-free FP8 dataflow: the MoE expert path
//!    keeps FP8 end-to-end except two BF16 islands, reducing explicit cast
//!    ops from 12 to 2 (Fig. 2).
//!
//! Both invariants are enforced *statically* by [`analysis`], the
//! scale-lineage linter (`lint` subcommand), before anything executes.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

/// Scale-lineage static analyzer: lineage propagation, lint rules, and
/// the static↔runtime cross-check over the [`dataflow`] graphs.
pub mod analysis;
/// Expert-parallel cluster: rank groups, wire format, the EP-sharded
/// executed layer, and the measured-vs-modeled simulator.
pub mod cluster;
/// Run-artifact coordination: `runs/` JSON writers and the Table 1–3
/// report generators.
pub mod coordinator;
/// The Fig. 2 dataflow graphs: typed op-graph substrate and the four
/// recipe variants with cast accounting.
pub mod dataflow;
/// Execution substrate: the worker pool behind every native kernel.
pub mod exec;
/// FP8 numerics: formats, tile-scaled tensors, the scaling-aware direct
/// transpose (Alg. 1), and the double-quantization error analysis.
pub mod fp8;
/// The MoE layer: routing, dispatch/combine, expert FFN recipes, and the
/// executed backward with its cast audit.
pub mod moe;
/// Observability: span/counter recorder, Chrome-trace export, live
/// counter cross-checks, and the calibrated sim cost-table feed.
pub mod obs;
/// PJRT-style runtime for the AOT-lowered HLO artifacts.
pub mod runtime;
/// Heavy-traffic serving: seeded request generation, SLO micro-batching,
/// and the EP-sharded serving loop with exact drop accounting.
pub mod serve;
/// Training loops: the native Fig. 6 trainer and the AOT-artifact driver.
pub mod train;
/// Shared utilities: matrices, RNG, CLI/JSON helpers, benchmarking.
pub mod util;
