//! Permute+padding kernels (§3.3.1) — fused and unfused variants.
//!
//! The *plan* abstraction matches `kernels/permute.py`: `plan[d]` is the
//! source token of destination row `d` in the `[E·capacity, H]` buffer, or
//! `-1` for a padding row. The fused kernel streams every token exactly
//! once; the unfused baseline (Fig. 3/4) materializes the compact
//! permutation first and re-reads it to insert padding.

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::util::mat::Mat;

/// Build the permute+pad row plan for slot assignments `expert_of`
/// (`-1` entries = padding). Tokens beyond `capacity` are dropped
/// (standard MoE capacity semantics); order within an expert is stable.
pub fn permute_pad_plan(expert_of: &[usize], n_experts: usize, capacity: usize) -> Vec<i64> {
    let mut plan = vec![-1i64; n_experts * capacity];
    let mut fill = vec![0usize; n_experts];
    for (tok, &e) in expert_of.iter().enumerate() {
        debug_assert!(e < n_experts);
        if fill[e] < capacity {
            plan[e * capacity + fill[e]] = tok as i64;
            fill[e] += 1;
        }
    }
    plan
}

/// Fused permute+pad over f32 rows: `out[d] = x[plan[d]]` or zeros.
/// Destination rows are independent — parallel over token chunks.
pub fn permute_pad(x: &Mat, plan: &[i64]) -> Mat {
    permute_pad_with_threads(x, plan, exec::threads())
}

/// [`permute_pad`] with an explicit worker count (pure row gather ⇒
/// bit-identical across worker counts).
pub fn permute_pad_with_threads(x: &Mat, plan: &[i64], threads: usize) -> Mat {
    let h = x.cols;
    let mut out = Mat::zeros(plan.len(), h);
    let p = Partition::even(plan.len(), exec::workers_for(threads, plan.len()));
    let tasks: Vec<_> = exec::split_parts(&p, h, &mut out.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(chunk, dr)| {
        for d in dr.clone() {
            let src = plan[d];
            if src >= 0 {
                let r = d - dr.start;
                chunk[r * h..(r + 1) * h].copy_from_slice(x.row(src as usize));
            }
        }
    });
    out
}

/// Fused permute+pad over FP8 rows (codes + row-wise scales move together;
/// padding rows are zero codes with scale 1 — exactly representable).
/// Parallel over destination-row chunks like [`permute_pad`].
pub fn permute_pad_fp8(x: &Fp8Tensor, plan: &[i64]) -> Fp8Tensor {
    permute_pad_fp8_with_threads(x, plan, exec::threads())
}

/// [`permute_pad_fp8`] with an explicit worker count.
pub fn permute_pad_fp8_with_threads(x: &Fp8Tensor, plan: &[i64], threads: usize) -> Fp8Tensor {
    assert_eq!(x.layout, TileLayout::RowWise);
    let h = x.cols;
    let tpr = n_tiles(h);
    let mut data = vec![0u8; plan.len() * h];
    let mut scales = vec![1.0f32; plan.len() * tpr];
    let mut sexp = vec![0i32; plan.len() * tpr];
    let p = Partition::even(plan.len(), exec::workers_for(threads, plan.len()));
    {
        let d_parts = exec::split_parts(&p, h, &mut data);
        let s_parts = exec::split_parts(&p, tpr, &mut scales);
        let e_parts = exec::split_parts(&p, tpr, &mut sexp);
        let tasks: Vec<_> = d_parts
            .into_iter()
            .zip(s_parts)
            .zip(e_parts)
            .zip(p.ranges())
            .map(|(((d, s), e), r)| (d, s, e, r))
            .collect();
        exec::run_tasks(tasks, |(dchunk, schunk, echunk, dr)| {
            for d in dr.clone() {
                let src = plan[d];
                if src >= 0 {
                    let s = src as usize;
                    let r = d - dr.start;
                    dchunk[r * h..(r + 1) * h].copy_from_slice(&x.data[s * h..(s + 1) * h]);
                    schunk[r * tpr..(r + 1) * tpr]
                        .copy_from_slice(&x.scales[s * tpr..(s + 1) * tpr]);
                    if !x.sexp.is_empty() {
                        echunk[r * tpr..(r + 1) * tpr]
                            .copy_from_slice(&x.sexp[s * tpr..(s + 1) * tpr]);
                    }
                }
            }
        });
    }
    Fp8Tensor {
        rows: plan.len(),
        cols: h,
        fmt: x.fmt,
        mode: x.mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp: if x.sexp.is_empty() { Vec::new() } else { sexp },
    }
}

/// Unfused baseline pass 1: compact permutation (no padding rows).
pub fn permute_compact(x: &Mat, plan: &[i64]) -> (Mat, Vec<i64>) {
    let h = x.cols;
    let compact_srcs: Vec<i64> = plan.iter().copied().filter(|&s| s >= 0).collect();
    let mut out = Mat::zeros(compact_srcs.len(), h);
    for (d, &src) in compact_srcs.iter().enumerate() {
        out.data[d * h..(d + 1) * h].copy_from_slice(x.row(src as usize));
    }
    // pass-2 plan: destination row -> compact row (or -1 padding)
    let mut pad_plan = vec![-1i64; plan.len()];
    let mut c = 0i64;
    for (d, &src) in plan.iter().enumerate() {
        if src >= 0 {
            pad_plan[d] = c;
            c += 1;
        }
    }
    (out, pad_plan)
}

/// Unfused baseline pass 2: insert padding rows (a second full pass).
pub fn pad_expand(compact: &Mat, pad_plan: &[i64]) -> Mat {
    permute_pad(compact, pad_plan)
}

/// Unfused permute→pad (the Fig. 3 baseline): two full HBM passes.
pub fn permute_then_pad(x: &Mat, plan: &[i64]) -> Mat {
    let (compact, pad_plan) = permute_compact(x, plan);
    pad_expand(&compact, &pad_plan)
}

/// Fused unpermute+unpad (backward of `permute_pad`): scatter-add rows
/// back to token order (a token routed to k experts receives the sum).
/// Parallel over *destination* token chunks: each worker scans the whole
/// plan and accumulates only rows landing in its token range, preserving
/// the serial kernel's ascending-`d` addition order per token (the
/// float-sum order is part of the bit-exactness contract).
pub fn unpermute_unpad(y: &Mat, plan: &[i64], n_tokens: usize) -> Mat {
    unpermute_unpad_with_threads(y, plan, n_tokens, exec::threads())
}

/// [`unpermute_unpad`] with an explicit worker count (1 = serial).
pub fn unpermute_unpad_with_threads(
    y: &Mat,
    plan: &[i64],
    n_tokens: usize,
    threads: usize,
) -> Mat {
    let h = y.cols;
    let mut out = Mat::zeros(n_tokens, h);
    let p = Partition::even(n_tokens, exec::workers_for(threads, n_tokens));
    let tasks: Vec<_> = exec::split_parts(&p, h, &mut out.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(chunk, tr)| {
        for (d, &src) in plan.iter().enumerate() {
            if src >= 0 {
                let dst = src as usize;
                if tr.contains(&dst) {
                    let yrow = &y.data[d * h..(d + 1) * h];
                    let r = dst - tr.start;
                    let orow = &mut chunk[r * h..(r + 1) * h];
                    for j in 0..h {
                        orow[j] += yrow[j];
                    }
                }
            }
        }
    });
    out
}

/// Unfused unpermute baseline (Fig. 4): pass 1 strips padding rows into a
/// compact buffer, pass 2 scatter-adds to token order.
pub fn unpad_then_unpermute(y: &Mat, plan: &[i64], n_tokens: usize) -> Mat {
    let h = y.cols;
    // pass 1: drop padding rows
    let kept: Vec<usize> = plan
        .iter()
        .enumerate()
        .filter(|(_, &s)| s >= 0)
        .map(|(d, _)| d)
        .collect();
    let mut compact = Mat::zeros(kept.len(), h);
    for (c, &d) in kept.iter().enumerate() {
        compact.data[c * h..(c + 1) * h].copy_from_slice(&y.data[d * h..(d + 1) * h]);
    }
    // pass 2: scatter to token order
    let mut out = Mat::zeros(n_tokens, h);
    for (c, &d) in kept.iter().enumerate() {
        let dst = plan[d] as usize;
        for j in 0..h {
            out.data[dst * h + j] += compact.data[c * h + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::quantize_rowwise;
    use crate::fp8::{Fp8Format, ScaleMode};
    use crate::util::rng::Rng;

    fn setup(tokens: usize, experts: usize, cap: usize, seed: u64) -> (Mat, Vec<usize>, Vec<i64>) {
        let mut rng = Rng::seed_from(seed);
        let x = Mat::randn(tokens, 32, 1.0, &mut rng);
        let expert_of: Vec<usize> = (0..tokens).map(|_| rng.below(experts)).collect();
        let plan = permute_pad_plan(&expert_of, experts, cap);
        (x, expert_of, plan)
    }

    #[test]
    fn plan_groups_by_expert() {
        let (_, expert_of, plan) = setup(100, 4, 64, 1);
        for (d, &src) in plan.iter().enumerate() {
            if src >= 0 {
                assert_eq!(expert_of[src as usize], d / 64, "row {d}");
            }
        }
    }

    #[test]
    fn plan_is_stable_within_expert() {
        let (_, _, plan) = setup(100, 4, 64, 2);
        for e in 0..4 {
            let seg: Vec<i64> = plan[e * 64..(e + 1) * 64].iter().copied().filter(|&s| s >= 0).collect();
            let mut sorted = seg.clone();
            sorted.sort();
            assert_eq!(seg, sorted, "expert {e} segment not in stable token order");
        }
    }

    #[test]
    fn fused_equals_unfused() {
        let (x, _, plan) = setup(256, 8, 64, 3);
        assert_eq!(permute_pad(&x, &plan), permute_then_pad(&x, &plan));
    }

    #[test]
    fn unpermute_roundtrip_no_drops() {
        let (x, _, plan) = setup(128, 4, 128, 4); // capacity ≥ tokens → no drop
        let y = permute_pad(&x, &plan);
        let back = unpermute_unpad(&y, &plan, 128);
        assert_eq!(back, x);
    }

    #[test]
    fn unfused_unpermute_matches_fused() {
        let (x, _, plan) = setup(256, 8, 32, 5); // with drops
        let y = permute_pad(&x, &plan);
        let a = unpermute_unpad(&y, &plan, 256);
        let b = unpad_then_unpermute(&y, &plan, 256);
        assert_eq!(a, b);
    }

    #[test]
    fn capacity_drops_excess_tokens() {
        let expert_of = vec![0usize; 10];
        let plan = permute_pad_plan(&expert_of, 2, 4);
        assert_eq!(plan.iter().filter(|&&s| s >= 0).count(), 4);
        assert_eq!(&plan[0..4], &[0, 1, 2, 3]);
        assert!(plan[4..].iter().all(|&s| s == -1));
    }

    #[test]
    fn fp8_permute_matches_f32_semantics() {
        let (x, _, plan) = setup(256, 4, 128, 6);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qp = permute_pad_fp8(&q, &plan);
        // dequantizing the permuted codes == permuting the dequantized mat
        let a = qp.dequantize();
        let b = permute_pad(&q.dequantize(), &plan);
        assert_eq!(a, b);
    }
}
