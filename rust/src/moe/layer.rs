//! The full MoE layer forward in the three recipes (§3.2, Fig. 2) on the
//! native substrate — route → dispatch (permute+pad) → grouped fc1 →
//! SwiGLU → grouped fc2 → unpermute → combine.
//!
//! Numerics mirror `python/compile/model.py::moe_ffn` (the integration
//! tests cross-check against the AOT `moe_fwd_*` artifacts):
//!
//! * `Bf16` — no quantization;
//! * `Blockwise` — float scales, quantize/dequantize around each GEMM,
//!   dispatch in BF16 (TE-style);
//! * `Fp8Flow` — po2 scales, quantize once at entry, dispatch/permute in
//!   FP8 code space, then [`fused_expert_ffn`]: the expert FFN as ONE
//!   streaming pipeline (grouped GEMM → fused SwiGLU+quant → grouped
//!   GEMM) that keeps activations in FP8 codes between the GEMMs — no
//!   intermediate dequantize, the two BF16 islands exactly where §3.2
//!   puts them (the GEMM accumulators).
//!
//! All three expert loops run expert-parallel on the [`crate::exec`] pool;
//! per-expert work calls the serial (`threads = 1`) kernel forms so the
//! grouped dimension is the only parallel axis (no nested oversubscription).

use crate::exec::{self, Partition};
use crate::fp8::tensor::Fp8Tensor;
use crate::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use crate::fp8::{Fp8Format, ScaleMode};
use crate::moe::gemm::fp8_matmul_with_threads;
use crate::moe::permute::{permute_pad, permute_pad_fp8, permute_pad_plan, unpermute_unpad};
use crate::moe::router::route;
use crate::moe::swiglu::{swiglu_quant_with_threads, swiglu_with_threads};
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Precision recipe (Fig. 2 variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recipe {
    Bf16,
    Blockwise,
    Fp8Flow,
}

impl Recipe {
    pub fn parse(s: &str) -> Option<Recipe> {
        match s {
            "bf16" => Some(Recipe::Bf16),
            "blockwise" => Some(Recipe::Blockwise),
            "fp8flow" | "fp8-flow" | "fp8_flow" => Some(Recipe::Fp8Flow),
            _ => None,
        }
    }
}

/// MoE layer weights (f32 masters; quantized per-recipe on construction).
#[derive(Clone, Debug)]
pub struct MoeWeights {
    pub router: Mat,      // [d, E]
    pub w1: Vec<Mat>,     // E × [d, h] (gate proj)
    pub w3: Vec<Mat>,     // E × [d, h] (up proj)
    pub w2: Vec<Mat>,     // E × [h, d] (down proj)
}

impl MoeWeights {
    pub fn random(d: usize, h: usize, e: usize, rng: &mut Rng) -> MoeWeights {
        let s1 = 1.0 / (d as f32).sqrt();
        let s2 = 1.0 / (h as f32).sqrt();
        MoeWeights {
            router: Mat::randn(d, e, s1, rng),
            w1: (0..e).map(|_| Mat::randn(d, h, s1, rng)).collect(),
            w3: (0..e).map(|_| Mat::randn(d, h, s1, rng)).collect(),
            w2: (0..e).map(|_| Mat::randn(h, d, s2, rng)).collect(),
        }
    }

    pub fn n_experts(&self) -> usize {
        self.w1.len()
    }
}

/// Per-recipe prepared weights: FP8 recipes store transposed-quantized
/// expert weights (row-wise over the contraction dim — the GEMM layout).
pub struct PreparedWeights {
    pub recipe: Recipe,
    pub raw: MoeWeights,
    pub w1_t: Vec<Fp8Tensor>, // E × [h, d] codes (w1ᵀ)
    pub w3_t: Vec<Fp8Tensor>,
    pub w2_t: Vec<Fp8Tensor>, // E × [d, h] codes (w2ᵀ)
}

impl PreparedWeights {
    pub fn new(raw: MoeWeights, recipe: Recipe) -> PreparedWeights {
        let mode = match recipe {
            Recipe::Blockwise => ScaleMode::Float,
            _ => ScaleMode::Po2,
        };
        let quant_t = |ws: &[Mat]| -> Vec<Fp8Tensor> {
            ws.iter()
                .map(|w| quantize_rowwise(&w.transpose(), Fp8Format::E4M3, mode))
                .collect()
        };
        let (w1_t, w3_t, w2_t) = if recipe == Recipe::Bf16 {
            (Vec::new(), Vec::new(), Vec::new())
        } else {
            (quant_t(&raw.w1), quant_t(&raw.w3), quant_t(&raw.w2))
        };
        PreparedWeights { recipe, raw, w1_t, w3_t, w2_t }
    }
}

/// Forward output plus dataflow accounting.
pub struct MoeOutput {
    pub y: Mat,
    pub aux_loss: f32,
    /// Bytes moved through the dispatch (permute) stage — FP8 dispatch
    /// halves this vs BF16 (plus scale sidecar), the Table 1 effect.
    pub dispatch_bytes: usize,
    /// Number of explicit quantize/dequantize ops executed (the Fig. 2
    /// cast accounting, measured rather than claimed).
    pub cast_ops: usize,
}

/// The casting-free expert FFN as one streaming pipeline: for each expert,
/// grouped GEMM (fc1 gate+up) → fused SwiGLU+quant → grouped GEMM (fc2),
/// with the activation staying in FP8 code space between the GEMMs.
///
/// `xg` is the dispatched FP8 buffer `[E·capacity, d]` (output of
/// [`permute_pad_fp8`]); `w*_t` are the transposed-quantized expert
/// weights. Returns the expert outputs `[E·capacity, d]`.
///
/// Experts are the parallel axis: each worker owns a contiguous expert
/// slab of the output and streams its experts end-to-end (the FP8
/// activation never leaves the worker between stages). Per-expert math is
/// the serial kernel chain, so the result is bit-identical for any worker
/// count.
pub fn fused_expert_ffn(
    xg: &Fp8Tensor,
    w1_t: &[Fp8Tensor],
    w3_t: &[Fp8Tensor],
    w2_t: &[Fp8Tensor],
    capacity: usize,
    threads: usize,
) -> Mat {
    let e = w1_t.len();
    assert_eq!(e, w3_t.len());
    assert_eq!(e, w2_t.len());
    assert!(e > 0, "fused_expert_ffn needs at least one expert");
    assert_eq!(xg.rows, e * capacity, "dispatched buffer must hold E×capacity rows");
    let d_out = w2_t[0].rows; // model dim (w2ᵀ is [d, h])
    let mut yk = Mat::zeros(e * capacity, d_out);
    let p = Partition::even(e, exec::workers_for(threads, e));
    let tasks: Vec<_> = exec::split_parts(&p, capacity * d_out, &mut yk.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(slab, er)| {
        for ex in er.clone() {
            let xe = slice_fp8(xg, ex * capacity, capacity);
            // fc1: FP8 in, f32 accumulator out — BF16 island #1 (§3.2)
            let gate = fp8_matmul_with_threads(&xe, &w1_t[ex], 1);
            let up = fp8_matmul_with_threads(&xe, &w3_t[ex], 1);
            // fused SwiGLU+quant: the island ends inside the compute
            // kernel — no standalone cast, activation re-enters FP8
            let aq = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2, 1);
            // fc2 consumes the FP8 codes directly (no dequantize between
            // the stages) — island #2 is this GEMM's accumulator
            let ye = fp8_matmul_with_threads(&aq, &w2_t[ex], 1);
            let r = ex - er.start;
            slab[r * capacity * d_out..(r + 1) * capacity * d_out].copy_from_slice(&ye.data);
        }
    });
    yk
}

/// Run the MoE layer forward.
pub fn moe_forward(x: &Mat, w: &PreparedWeights, top_k: usize, capacity: usize) -> MoeOutput {
    let t = x.rows;
    let e = w.raw.n_experts();
    let threads = exec::threads();
    let routing = route(x, &w.raw.router, top_k);
    let mut y = Mat::zeros(t, x.cols);
    let mut dispatch_bytes = 0usize;
    let mut cast_ops = 0usize;

    // fp8flow: ONE entry quantization (the recipe's single entry cast)
    let x_q = if w.recipe == Recipe::Fp8Flow {
        cast_ops += 1;
        Some(quantize_rowwise(x, Fp8Format::E4M3, ScaleMode::Po2))
    } else {
        None
    };

    for kk in 0..top_k {
        let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
        let plan = permute_pad_plan(&expert_of, e, capacity);

        let yk = match w.recipe {
            Recipe::Bf16 => {
                let xg = permute_pad(x, &plan);
                dispatch_bytes += xg.data.len() * 2; // bf16 on the wire
                let mut yk = Mat::zeros(e * capacity, x.cols);
                let p = Partition::even(e, exec::workers_for(threads, e));
                let tasks: Vec<_> = exec::split_parts(&p, capacity * x.cols, &mut yk.data)
                    .into_iter()
                    .zip(p.ranges())
                    .collect();
                exec::run_tasks(tasks, |(slab, er)| {
                    for ex in er.clone() {
                        let xe = Mat::from_vec(
                            capacity,
                            x.cols,
                            xg.data[ex * capacity * x.cols..(ex + 1) * capacity * x.cols].to_vec(),
                        );
                        let gate = xe.matmul(&w.raw.w1[ex]);
                        let up = xe.matmul(&w.raw.w3[ex]);
                        let act = swiglu_with_threads(&gate, &up, 1);
                        let ye = act.matmul(&w.raw.w2[ex]);
                        let r = ex - er.start;
                        slab[r * capacity * x.cols..(r + 1) * capacity * x.cols]
                            .copy_from_slice(&ye.data);
                    }
                });
                yk
            }
            Recipe::Blockwise => {
                // TE-style: dispatch BF16; quantize at each GEMM boundary.
                let xg = permute_pad(x, &plan);
                dispatch_bytes += xg.data.len() * 2;
                // 2 explicit casts per expert: Q(x) for fc1, Q(act) for
                // fc2 (each expert quantizes its slice unconditionally)
                cast_ops += 2 * e;
                let mut yk = Mat::zeros(e * capacity, x.cols);
                let p = Partition::even(e, exec::workers_for(threads, e));
                let tasks: Vec<_> = exec::split_parts(&p, capacity * x.cols, &mut yk.data)
                    .into_iter()
                    .zip(p.ranges())
                    .collect();
                exec::run_tasks(tasks, |(slab, er)| {
                    for ex in er.clone() {
                        let xe = Mat::from_vec(
                            capacity,
                            x.cols,
                            xg.data[ex * capacity * x.cols..(ex + 1) * capacity * x.cols].to_vec(),
                        );
                        // Q(x) for fc1 (one cast), DQ after GEMM is
                        // implicit in f32 accumulation; fc1 runs twice
                        // (gate+up) on the same quantized activation.
                        let xq =
                            quantize_rowwise_with_threads(&xe, Fp8Format::E4M3, ScaleMode::Float, 1);
                        let gate = fp8_matmul_with_threads(&xq, &w.w1_t[ex], 1);
                        let up = fp8_matmul_with_threads(&xq, &w.w3_t[ex], 1);
                        let act = swiglu_with_threads(&gate, &up, 1);
                        // Q(act) for fc2 — the second per-expert cast
                        let aq =
                            quantize_rowwise_with_threads(&act, Fp8Format::E4M3, ScaleMode::Float, 1);
                        let ye = fp8_matmul_with_threads(&aq, &w.w2_t[ex], 1);
                        let r = ex - er.start;
                        slab[r * capacity * x.cols..(r + 1) * capacity * x.cols]
                            .copy_from_slice(&ye.data);
                    }
                });
                yk
            }
            Recipe::Fp8Flow => {
                // dispatch moves FP8 codes + scales (half the bytes)
                let xq = x_q.as_ref().unwrap();
                let xg = permute_pad_fp8(xq, &plan);
                dispatch_bytes += xg.nbytes();
                // the casting-free streaming pipeline: no explicit cast
                // between entry quantize and combine
                fused_expert_ffn(&xg, &w.w1_t, &w.w3_t, &w.w2_t, capacity, threads)
            }
        };
        let back = unpermute_unpad(&yk, &plan, t);
        for tt in 0..t {
            let g = routing.gates[tt][kk];
            for j in 0..x.cols {
                y.data[tt * x.cols + j] += g * back.data[tt * x.cols + j];
            }
        }
    }
    MoeOutput { y, aux_loss: routing.aux_loss, dispatch_bytes, cast_ops }
}

/// View `rows` rows of an FP8 tensor starting at `start` (copy).
fn slice_fp8(t: &Fp8Tensor, start: usize, rows: usize) -> Fp8Tensor {
    let tpr = t.scales.len() / t.rows;
    Fp8Tensor {
        rows,
        cols: t.cols,
        fmt: t.fmt,
        mode: t.mode,
        layout: t.layout,
        data: t.data[start * t.cols..(start + rows) * t.cols].to_vec(),
        scales: t.scales[start * tpr..(start + rows) * tpr].to_vec(),
        sexp: if t.sexp.is_empty() {
            Vec::new()
        } else {
            t.sexp[start * tpr..(start + rows) * tpr].to_vec()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::quantize_rowwise;
    use crate::moe::swiglu::swiglu_quant;

    fn setup(seed: u64) -> (Mat, MoeWeights) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e) = (128, 128, 128, 2);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        (x, w)
    }

    #[test]
    fn recipes_agree_within_quantization_tolerance() {
        let (x, w) = setup(1);
        let bf16 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let flow = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 128);
        let block = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 128);
        let rel_flow = flow.y.rel_err(&bf16.y);
        let rel_block = block.y.rel_err(&bf16.y);
        assert!(rel_flow > 0.0 && rel_flow < 0.12, "fp8flow rel={rel_flow}");
        assert!(rel_block > 0.0 && rel_block < 0.12, "blockwise rel={rel_block}");
    }

    #[test]
    fn fp8_dispatch_halves_bytes() {
        let (x, w) = setup(2);
        let bf16 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let flow = moe_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), 1, 128);
        // FP8 payload = half of BF16 bytes, plus the scale sidecar (po2 → 1B/tile)
        assert!(flow.dispatch_bytes < bf16.dispatch_bytes * 6 / 10,
            "fp8 {} vs bf16 {}", flow.dispatch_bytes, bf16.dispatch_bytes);
    }

    #[test]
    fn cast_accounting_fwd() {
        let (x, w) = setup(3);
        let e = 2;
        let flow = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 128);
        let block = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 128);
        // fp8flow fwd: exactly ONE explicit cast (entry); the SwiGLU+quant
        // is fused into the compute kernel.
        assert_eq!(flow.cast_ops, 1);
        // blockwise: 2 casts per expert per slot
        assert_eq!(block.cast_ops, 2 * e);
    }

    #[test]
    fn top2_combines_both_experts() {
        let (x, w) = setup(4);
        let out1 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let out2 = moe_forward(&x, &PreparedWeights::new(w, Recipe::Bf16), 2, 128);
        // top-2 output differs from top-1 (second expert contributes)
        assert!(out2.y.rel_err(&out1.y) > 0.01);
    }

    #[test]
    fn capacity_overflow_drops_gracefully() {
        let (x, w) = setup(5);
        let out = moe_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), 2, 32);
        assert!(out.y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_pipeline_matches_sequential_reference_bitwise() {
        // The fused streaming pipeline must be the same math as the
        // unfused sequential chain: per-expert GEMM → swiglu_quant → GEMM.
        let mut rng = Rng::seed_from(6);
        let (e, cap, d, h) = (3usize, 32usize, 128usize, 96usize);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let x = Mat::randn(e * cap, d, 0.5, &mut rng);
        let xq = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        for threads in [1usize, 2, 8] {
            let yk = fused_expert_ffn(&xq, &pw.w1_t, &pw.w3_t, &pw.w2_t, cap, threads);
            for ex in 0..e {
                let xe = slice_fp8(&xq, ex * cap, cap);
                let gate = fp8_matmul_with_threads(&xe, &pw.w1_t[ex], 1);
                let up = fp8_matmul_with_threads(&xe, &pw.w3_t[ex], 1);
                let aq = swiglu_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
                let ye = fp8_matmul_with_threads(&aq, &pw.w2_t[ex], 1);
                let got = &yk.data[ex * cap * d..(ex + 1) * cap * d];
                for (a, b) in got.iter().zip(&ye.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "expert {ex} threads {threads}");
                }
            }
        }
    }

    #[test]
    fn fused_pipeline_keeps_activation_in_fp8() {
        // Structural claim of the recipe: between fc1 and fc2 the
        // activation is an Fp8Tensor (codes + po2 scales), not a Mat —
        // checked here by reproducing the stage boundary types.
        let mut rng = Rng::seed_from(7);
        let (d, h) = (128usize, 64usize);
        let w = MoeWeights::random(d, h, 1, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let x = Mat::randn(16, d, 0.5, &mut rng);
        let xq = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let gate = fp8_matmul_with_threads(&xq, &pw.w1_t[0], 1);
        let up = fp8_matmul_with_threads(&xq, &pw.w3_t[0], 1);
        let aq = swiglu_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(aq.mode, ScaleMode::Po2);
        assert_eq!(aq.fmt, Fp8Format::E4M3);
        assert_eq!(aq.rows, 16);
        assert_eq!(aq.cols, h);
    }
}
