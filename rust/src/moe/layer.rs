//! The full MoE layer forward in the three recipes (§3.2, Fig. 2) on the
//! native substrate — route → dispatch (permute+pad) → grouped fc1 →
//! SwiGLU → grouped fc2 → unpermute → combine.
//!
//! Numerics mirror `python/compile/model.py::moe_ffn` (the integration
//! tests cross-check against the AOT `moe_fwd_*` artifacts):
//!
//! * `Bf16` — no quantization;
//! * `Blockwise` — float scales, quantize/dequantize around each GEMM,
//!   dispatch in BF16 (TE-style);
//! * `Fp8Flow` — po2 scales, quantize once at entry, dispatch/permute in
//!   FP8 code space, then [`fused_expert_ffn`]: the expert FFN as ONE
//!   streaming pipeline (grouped GEMM → fused SwiGLU+quant → grouped
//!   GEMM) that keeps activations in FP8 codes between the GEMMs — no
//!   intermediate dequantize, the two BF16 islands exactly where §3.2
//!   puts them (the GEMM accumulators).
//!
//! The forward is decomposed into three **stage APIs** over a
//! [`RankLocalBatch`] — [`dispatch`], [`expert_ffn`], [`combine`] — each
//! scoped to an arbitrary contiguous *expert range*. [`moe_forward`] runs
//! them over the full range `0..E` (the single-rank path); the executed
//! expert-parallel runtime ([`crate::cluster::ep_exec`]) runs one range
//! per simulated rank with a real wire in between, and is bit-identical
//! to the single-rank path by construction. The three recipes differ
//! only in the dispatch **wire type** ([`WirePayload`]): Fp8Flow ships
//! FP8 codes + scales, the other two ship dense (BF16-accounted) rows.
//!
//! All three expert loops run expert-parallel on the [`crate::exec`] pool;
//! per-expert work calls the serial (`threads = 1`) kernel forms so the
//! grouped dimension is the only parallel axis (no nested oversubscription).

use std::ops::Range;

use crate::exec::{self, Partition};
use crate::fp8::tensor::Fp8Tensor;
use crate::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use crate::fp8::{Fp8Format, ScaleMode};
use crate::moe::gemm::fp8_matmul_with_threads;
use crate::moe::permute::{
    permute_pad_fp8_with_threads, permute_pad_plan, permute_pad_with_threads,
    unpermute_unpad_with_threads,
};
use crate::moe::router::route;
use crate::moe::swiglu::{swiglu_quant_with_threads, swiglu_with_threads};
use crate::obs::{self, Counter};
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Precision recipe (Fig. 2 variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recipe {
    /// No quantization anywhere (the convergence oracle).
    Bf16,
    /// TE-style blockwise FP8: float scales, naive transposes.
    Blockwise,
    /// The paper's casting-free FP8 recipe: Po2 scales, direct transposes.
    Fp8Flow,
}

impl Recipe {
    /// Parse a recipe name as the CLI spells it.
    pub fn parse(s: &str) -> Option<Recipe> {
        match s {
            "bf16" => Some(Recipe::Bf16),
            "blockwise" => Some(Recipe::Blockwise),
            "fp8flow" | "fp8-flow" | "fp8_flow" => Some(Recipe::Fp8Flow),
            _ => None,
        }
    }
}

/// MoE layer weights (f32 masters; quantized per-recipe on construction).
#[derive(Clone, Debug)]
pub struct MoeWeights {
    /// Router projection `[d, E]` (dense f32 path).
    pub router: Mat,      // [d, E]
    /// Gate projections, `E x [d, h]`.
    pub w1: Vec<Mat>,     // E × [d, h] (gate proj)
    /// Up projections, `E x [d, h]`.
    pub w3: Vec<Mat>,     // E × [d, h] (up proj)
    /// Down projections, `E x [h, d]`.
    pub w2: Vec<Mat>,     // E × [h, d] (down proj)
}

impl MoeWeights {
    /// Random init (masters in f32).
    pub fn random(d: usize, h: usize, e: usize, rng: &mut Rng) -> MoeWeights {
        let s1 = 1.0 / (d as f32).sqrt();
        let s2 = 1.0 / (h as f32).sqrt();
        MoeWeights {
            router: Mat::randn(d, e, s1, rng),
            w1: (0..e).map(|_| Mat::randn(d, h, s1, rng)).collect(),
            w3: (0..e).map(|_| Mat::randn(d, h, s1, rng)).collect(),
            w2: (0..e).map(|_| Mat::randn(h, d, s2, rng)).collect(),
        }
    }

    /// Expert count.
    pub fn n_experts(&self) -> usize {
        self.w1.len()
    }
}

/// Per-recipe prepared weights. FP8 recipes store both GEMM layouts of
/// each expert weight, quantized once from the f32 masters at
/// construction time (weight prep, not a runtime cast):
///
/// * `w*_t` — **fprop/dgrad-operand** layout: the transposed weight,
///   row-wise over the forward contraction dim (`fp8_matmul`'s B side);
/// * `w*_d` — **dgrad-weight** layout: the untransposed weight, row-wise
///   over the backward contraction dim (dgrad is `dY · Wᵀ`, so W itself
///   is already the `[N, K]` operand `fp8_matmul` wants).
///
/// Both layouts are prepared eagerly: weight prep is a one-time cost off
/// every timed path, and real training touches both directions each step.
/// Forward-only callers pay ~2× the (small) prep quantization for it.
pub struct PreparedWeights {
    /// Recipe these layouts serve.
    pub recipe: Recipe,
    /// The f32 masters.
    pub raw: MoeWeights,
    /// fprop layout: per-expert w1-transpose codes.
    pub w1_t: Vec<Fp8Tensor>, // E × [h, d] codes (w1ᵀ)
    /// fprop layout: per-expert w3-transpose codes.
    pub w3_t: Vec<Fp8Tensor>,
    /// fprop layout: per-expert w2-transpose codes.
    pub w2_t: Vec<Fp8Tensor>, // E × [d, h] codes (w2ᵀ)
    /// dgrad layout: per-expert w1 codes.
    pub w1_d: Vec<Fp8Tensor>, // E × [d, h] codes (w1, dgrad layout)
    /// dgrad layout: per-expert w3 codes.
    pub w3_d: Vec<Fp8Tensor>,
    /// dgrad layout: per-expert w2 codes.
    pub w2_d: Vec<Fp8Tensor>, // E × [h, d] codes (w2, dgrad layout)
}

/// Audit of one weight-requantization pass (same counting convention as
/// `moe::backward::BwdStats`: launches tallied at the call site).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WeightPrepStats {
    /// Quantize launches whose input is f32 master data (one per layout
    /// per expert weight — the legitimate per-step weight cast).
    pub weight_quants: usize,
    /// Requantizations of already-FP8 tensors. Zero by construction:
    /// every layout is sourced from the masters, never derived from
    /// another FP8 layout (the audit the graph's optimizer tail pins,
    /// `dataflow::variants::build_train_step`).
    pub requants: usize,
}

impl PreparedWeights {
    /// Prepare both GEMM layouts from `raw` for `recipe`.
    pub fn new(raw: MoeWeights, recipe: Recipe) -> PreparedWeights {
        let mut pw = PreparedWeights {
            recipe,
            raw,
            w1_t: Vec::new(),
            w3_t: Vec::new(),
            w2_t: Vec::new(),
            w1_d: Vec::new(),
            w3_d: Vec::new(),
            w2_d: Vec::new(),
        };
        pw.requantize_from_masters();
        pw
    }

    /// Regenerate every FP8 weight layout from the f32 masters (`raw`) —
    /// the optimizer's post-update weight cast (the Fig. 2 weight-prep
    /// discipline, executed once per training step).
    ///
    /// Each layout is ONE quantization of master data — `w*_t` quantizes
    /// the transposed master, `w*_d` the untransposed master — so no
    /// already-FP8 tensor is ever requantized: the step contributes **zero**
    /// requant events to the audit (the graph's optimizer tail,
    /// `dataflow::variants::build_train_step`, models the same discipline;
    /// the incumbent foil there derives the second layout by
    /// requantizing the first). Bit-identical to a fresh
    /// [`PreparedWeights::new`] over the same masters
    /// (`tests/prop_train.rs`).
    pub fn requantize_from_masters(&mut self) -> WeightPrepStats {
        if self.recipe == Recipe::Bf16 {
            return WeightPrepStats::default();
        }
        let mode = match self.recipe {
            Recipe::Blockwise => ScaleMode::Float,
            _ => ScaleMode::Po2,
        };
        let quant_t = |ws: &[Mat]| -> Vec<Fp8Tensor> {
            ws.iter()
                .map(|w| quantize_rowwise(&w.transpose(), Fp8Format::E4M3, mode))
                .collect()
        };
        let quant_d = |ws: &[Mat]| -> Vec<Fp8Tensor> {
            ws.iter().map(|w| quantize_rowwise(w, Fp8Format::E4M3, mode)).collect()
        };
        self.w1_t = quant_t(&self.raw.w1);
        self.w3_t = quant_t(&self.raw.w3);
        self.w2_t = quant_t(&self.raw.w2);
        self.w1_d = quant_d(&self.raw.w1);
        self.w3_d = quant_d(&self.raw.w3);
        self.w2_d = quant_d(&self.raw.w2);
        obs::count(Counter::OptWeightQuants, (6 * self.raw.n_experts()) as u64);
        WeightPrepStats { weight_quants: 6 * self.raw.n_experts(), requants: 0 }
    }
}

/// Forward output plus dataflow accounting.
pub struct MoeOutput {
    /// Layer output `[t, d]`.
    pub y: Mat,
    /// Load-balancing aux loss.
    pub aux_loss: f32,
    /// Bytes moved through the dispatch (permute) stage — FP8 dispatch
    /// halves this vs BF16 (plus scale sidecar), the Table 1 effect.
    pub dispatch_bytes: usize,
    /// Number of explicit quantize/dequantize ops executed (the Fig. 2
    /// cast accounting, measured rather than claimed).
    pub cast_ops: usize,
}

// ---------------------------------------------------------------------------
// Stage APIs: dispatch → expert_ffn → combine over a RankLocalBatch.
// ---------------------------------------------------------------------------

/// What crosses the dispatch wire: the recipe's wire type.
#[derive(Clone, Debug)]
pub enum WirePayload {
    /// Dense rows (f32 in memory, accounted as BF16 on the wire) — the
    /// Bf16 and Blockwise (TE-style) dispatch.
    Dense(Mat),
    /// FP8 codes + per-tile scales — the Fp8Flow dispatch.
    Fp8(Fp8Tensor),
}

/// The dispatched, expert-grouped activations local to one rank: rows
/// `[|experts| · capacity, d]` for a contiguous range of global experts.
#[derive(Clone, Debug)]
pub struct RankLocalBatch {
    /// Global expert ids this batch covers (row block `i` holds expert
    /// `experts.start + i`).
    pub experts: Range<usize>,
    /// Per-expert row budget.
    pub capacity: usize,
    /// The wire payload, in the recipe's wire type.
    pub payload: WirePayload,
}

impl RankLocalBatch {
    /// Number of experts this batch covers.
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Total row count (`experts x capacity`).
    pub fn rows(&self) -> usize {
        self.experts.len() * self.capacity
    }

    /// Bytes this batch puts on the dispatch wire (BF16-accounted dense
    /// rows, or FP8 payload + scale sidecar).
    pub fn wire_bytes(&self) -> usize {
        match &self.payload {
            WirePayload::Dense(m) => m.data.len() * 2,
            WirePayload::Fp8(t) => t.nbytes(),
        }
    }
}

/// What the dispatch stage reads: raw activations (BF16 wire) or the
/// entry-quantized codes (FP8 wire). The choice IS the recipe's wire
/// type — Blockwise and Fp8Flow differ here and nowhere else in the
/// dispatch path.
#[derive(Clone, Copy, Debug)]
pub enum DispatchSource<'a> {
    /// Dense rows (BF16-accounted wire).
    Dense(&'a Mat),
    /// FP8 codes plus scale sidecar.
    Fp8(&'a Fp8Tensor),
}

/// Dispatch stage: gather the rows destined for `experts` (a contiguous
/// sub-range of the global plan) into an expert-grouped rank-local batch.
/// With `experts == 0..E` this is exactly the classic single-rank fused
/// permute+pad.
pub fn dispatch(
    src: DispatchSource,
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    threads: usize,
) -> RankLocalBatch {
    let sub = &plan[experts.start * capacity..experts.end * capacity];
    let payload = match src {
        DispatchSource::Dense(x) => WirePayload::Dense(permute_pad_with_threads(x, sub, threads)),
        DispatchSource::Fp8(xq) => {
            WirePayload::Fp8(permute_pad_fp8_with_threads(xq, sub, threads))
        }
    };
    RankLocalBatch { experts, capacity, payload }
}

/// Expert-FFN stage: run this rank's experts over its dispatched batch,
/// per-recipe. Returns `[|experts| · capacity, d]` outputs.
///
/// Experts are the parallel axis (one contiguous expert slab per worker,
/// serial kernels inside), so the result is bit-identical for any
/// `threads` — and, because per-expert math reads only that expert's
/// `capacity` rows, bit-identical under any sharding of the expert range.
pub fn expert_ffn(batch: &RankLocalBatch, w: &PreparedWeights, threads: usize) -> Mat {
    let er = batch.experts.clone();
    let cap = batch.capacity;
    match (&batch.payload, w.recipe) {
        (WirePayload::Fp8(xg), Recipe::Fp8Flow) => {
            fused_expert_ffn(xg, &w.w1_t[er.clone()], &w.w3_t[er.clone()], &w.w2_t[er], cap, threads)
        }
        (WirePayload::Dense(xg), Recipe::Bf16) => {
            dense_expert_loop(xg, er, cap, threads, |ge, xe| {
                let gate = xe.matmul(&w.raw.w1[ge]);
                let up = xe.matmul(&w.raw.w3[ge]);
                let act = swiglu_with_threads(&gate, &up, 1);
                act.matmul(&w.raw.w2[ge])
            })
        }
        (WirePayload::Dense(xg), Recipe::Blockwise) => {
            // TE-style: dispatched BF16; quantize at each GEMM boundary
            // (2 explicit casts per expert: Q(x) for fc1, Q(act) for fc2).
            dense_expert_loop(xg, er, cap, threads, |ge, xe| {
                obs::count(Counter::CastsFwd, 2);
                // Q(x) for fc1 (one cast), DQ after GEMM is implicit in
                // f32 accumulation; fc1 runs twice (gate+up) on the same
                // quantized activation.
                let xq = quantize_rowwise_with_threads(&xe, Fp8Format::E4M3, ScaleMode::Float, 1);
                let gate = fp8_matmul_with_threads(&xq, &w.w1_t[ge], 1);
                let up = fp8_matmul_with_threads(&xq, &w.w3_t[ge], 1);
                let act = swiglu_with_threads(&gate, &up, 1);
                // Q(act) for fc2 — the second per-expert cast
                let aq = quantize_rowwise_with_threads(&act, Fp8Format::E4M3, ScaleMode::Float, 1);
                fp8_matmul_with_threads(&aq, &w.w2_t[ge], 1)
            })
        }
        _ => panic!("recipe/wire mismatch: {:?} batch for {:?}", batch.payload, w.recipe),
    }
}

/// Shared scaffolding of the dense (BF16-wire) expert loops: experts are
/// the parallel axis, each worker owns a contiguous expert slab of the
/// output, `per_expert(global_expert, xe)` supplies the recipe's math on
/// one expert's `[capacity, d]` slice (serial kernels inside — the
/// grouped dimension is the only parallel axis).
fn dense_expert_loop(
    xg: &Mat,
    experts: Range<usize>,
    cap: usize,
    threads: usize,
    per_expert: impl Fn(usize, Mat) -> Mat + Sync,
) -> Mat {
    let el = experts.len();
    let cols = xg.cols;
    let mut yk = Mat::zeros(el * cap, cols);
    let p = Partition::even(el, exec::workers_for(threads, el));
    let tasks: Vec<_> = exec::split_parts(&p, cap * cols, &mut yk.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(slab, lr)| {
        for lx in lr.clone() {
            let xe = Mat::from_vec(
                cap,
                cols,
                xg.data[lx * cap * cols..(lx + 1) * cap * cols].to_vec(),
            );
            let ye = per_expert(experts.start + lx, xe);
            let r = lx - lr.start;
            slab[r * cap * cols..(r + 1) * cap * cols].copy_from_slice(&ye.data);
        }
    });
    yk
}

/// Combine stage: scatter this rank's expert outputs back to token order
/// through its slice of the global plan. Tokens served by other ranks'
/// experts stay exactly zero, so summing the per-rank results (in rank
/// order) reproduces the single-rank `unpermute_unpad` bit-for-bit —
/// each token appears at most once per top-k slot.
pub fn combine(
    yk: &Mat,
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    n_tokens: usize,
    threads: usize,
) -> Mat {
    let sub = &plan[experts.start * capacity..experts.end * capacity];
    unpermute_unpad_with_threads(yk, sub, n_tokens, threads)
}

/// The casting-free expert FFN as one streaming pipeline: for each expert,
/// grouped GEMM (fc1 gate+up) → fused SwiGLU+quant → grouped GEMM (fc2),
/// with the activation staying in FP8 code space between the GEMMs.
///
/// `xg` is the dispatched FP8 buffer `[E·capacity, d]` (output of
/// [`crate::moe::permute::permute_pad_fp8`]); `w*_t` are the
/// transposed-quantized expert weights. Returns the expert outputs
/// `[E·capacity, d]`.
///
/// Experts are the parallel axis: each worker owns a contiguous expert
/// slab of the output and streams its experts end-to-end (the FP8
/// activation never leaves the worker between stages). Per-expert math is
/// the serial kernel chain, so the result is bit-identical for any worker
/// count.
pub fn fused_expert_ffn(
    xg: &Fp8Tensor,
    w1_t: &[Fp8Tensor],
    w3_t: &[Fp8Tensor],
    w2_t: &[Fp8Tensor],
    capacity: usize,
    threads: usize,
) -> Mat {
    let e = w1_t.len();
    assert_eq!(e, w3_t.len());
    assert_eq!(e, w2_t.len());
    assert!(e > 0, "fused_expert_ffn needs at least one expert");
    assert_eq!(xg.rows, e * capacity, "dispatched buffer must hold E×capacity rows");
    let d_out = w2_t[0].rows; // model dim (w2ᵀ is [d, h])
    let mut yk = Mat::zeros(e * capacity, d_out);
    let p = Partition::even(e, exec::workers_for(threads, e));
    let tasks: Vec<_> = exec::split_parts(&p, capacity * d_out, &mut yk.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(slab, er)| {
        for ex in er.clone() {
            let xe = slice_fp8(xg, ex * capacity, capacity);
            // fc1: FP8 in, f32 accumulator out — BF16 island #1 (§3.2)
            let gate = fp8_matmul_with_threads(&xe, &w1_t[ex], 1);
            let up = fp8_matmul_with_threads(&xe, &w3_t[ex], 1);
            // fused SwiGLU+quant: the island ends inside the compute
            // kernel — no standalone cast, activation re-enters FP8
            let aq = swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2, 1);
            // fc2 consumes the FP8 codes directly (no dequantize between
            // the stages) — island #2 is this GEMM's accumulator
            let ye = fp8_matmul_with_threads(&aq, &w2_t[ex], 1);
            let r = ex - er.start;
            slab[r * capacity * d_out..(r + 1) * capacity * d_out].copy_from_slice(&ye.data);
        }
    });
    yk
}

/// Run the MoE layer forward — the single-rank composition of the stage
/// APIs over the full expert range `0..E`.
pub fn moe_forward(x: &Mat, w: &PreparedWeights, top_k: usize, capacity: usize) -> MoeOutput {
    let t = x.rows;
    let e = w.raw.n_experts();
    let threads = exec::threads();
    let routing = route(x, &w.raw.router, top_k);
    let mut y = Mat::zeros(t, x.cols);
    let mut dispatch_bytes = 0usize;
    let mut cast_ops = 0usize;

    // fp8flow: ONE entry quantization (the recipe's single entry cast)
    let x_q = if w.recipe == Recipe::Fp8Flow {
        cast_ops += 1;
        obs::count(Counter::CastsFwd, 1);
        Some(quantize_rowwise(x, Fp8Format::E4M3, ScaleMode::Po2))
    } else {
        None
    };

    for kk in 0..top_k {
        let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
        let plan = permute_pad_plan(&expert_of, e, capacity);

        let src = match &x_q {
            Some(xq) => DispatchSource::Fp8(xq),
            None => DispatchSource::Dense(x),
        };
        let batch = dispatch(src, &plan, 0..e, capacity, threads);
        dispatch_bytes += batch.wire_bytes();
        if w.recipe == Recipe::Blockwise {
            // 2 explicit casts per expert: Q(x) for fc1, Q(act) for fc2
            // (each expert quantizes its slice unconditionally)
            cast_ops += 2 * e;
        }

        let yk = expert_ffn(&batch, w, threads);
        let back = combine(&yk, &plan, 0..e, capacity, t, threads);
        for tt in 0..t {
            let g = routing.gates[tt][kk];
            for j in 0..x.cols {
                y.data[tt * x.cols + j] += g * back.data[tt * x.cols + j];
            }
        }
    }
    MoeOutput { y, aux_loss: routing.aux_loss, dispatch_bytes, cast_ops }
}

/// View `rows` rows of an FP8 tensor starting at `start` (copy).
fn slice_fp8(t: &Fp8Tensor, start: usize, rows: usize) -> Fp8Tensor {
    t.slice_rows(start, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::quantize_rowwise;
    use crate::moe::swiglu::swiglu_quant;

    fn setup(seed: u64) -> (Mat, MoeWeights) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e) = (128, 128, 128, 2);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        (x, w)
    }

    #[test]
    fn recipes_agree_within_quantization_tolerance() {
        let (x, w) = setup(1);
        let bf16 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let flow = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 128);
        let block = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 128);
        let rel_flow = flow.y.rel_err(&bf16.y);
        let rel_block = block.y.rel_err(&bf16.y);
        assert!(rel_flow > 0.0 && rel_flow < 0.12, "fp8flow rel={rel_flow}");
        assert!(rel_block > 0.0 && rel_block < 0.12, "blockwise rel={rel_block}");
    }

    #[test]
    fn fp8_dispatch_halves_bytes() {
        let (x, w) = setup(2);
        let bf16 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let flow = moe_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), 1, 128);
        // FP8 payload = half of BF16 bytes, plus the scale sidecar (po2 → 1B/tile)
        assert!(flow.dispatch_bytes < bf16.dispatch_bytes * 6 / 10,
            "fp8 {} vs bf16 {}", flow.dispatch_bytes, bf16.dispatch_bytes);
    }

    #[test]
    fn cast_accounting_fwd() {
        let (x, w) = setup(3);
        let e = 2;
        let flow = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), 1, 128);
        let block = moe_forward(&x, &PreparedWeights::new(w, Recipe::Blockwise), 1, 128);
        // fp8flow fwd: exactly ONE explicit cast (entry); the SwiGLU+quant
        // is fused into the compute kernel.
        assert_eq!(flow.cast_ops, 1);
        // blockwise: 2 casts per expert per slot
        assert_eq!(block.cast_ops, 2 * e);
    }

    #[test]
    fn top2_combines_both_experts() {
        let (x, w) = setup(4);
        let out1 = moe_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Bf16), 1, 128);
        let out2 = moe_forward(&x, &PreparedWeights::new(w, Recipe::Bf16), 2, 128);
        // top-2 output differs from top-1 (second expert contributes)
        assert!(out2.y.rel_err(&out1.y) > 0.01);
    }

    #[test]
    fn capacity_overflow_drops_gracefully() {
        let (x, w) = setup(5);
        let out = moe_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), 2, 32);
        assert!(out.y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_pipeline_matches_sequential_reference_bitwise() {
        // The fused streaming pipeline must be the same math as the
        // unfused sequential chain: per-expert GEMM → swiglu_quant → GEMM.
        let mut rng = Rng::seed_from(6);
        let (e, cap, d, h) = (3usize, 32usize, 128usize, 96usize);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let x = Mat::randn(e * cap, d, 0.5, &mut rng);
        let xq = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        for threads in [1usize, 2, 8] {
            let yk = fused_expert_ffn(&xq, &pw.w1_t, &pw.w3_t, &pw.w2_t, cap, threads);
            for ex in 0..e {
                let xe = slice_fp8(&xq, ex * cap, cap);
                let gate = fp8_matmul_with_threads(&xe, &pw.w1_t[ex], 1);
                let up = fp8_matmul_with_threads(&xe, &pw.w3_t[ex], 1);
                let aq = swiglu_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
                let ye = fp8_matmul_with_threads(&aq, &pw.w2_t[ex], 1);
                let got = &yk.data[ex * cap * d..(ex + 1) * cap * d];
                for (a, b) in got.iter().zip(&ye.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "expert {ex} threads {threads}");
                }
            }
        }
    }

    #[test]
    fn fused_pipeline_keeps_activation_in_fp8() {
        // Structural claim of the recipe: between fc1 and fc2 the
        // activation is an Fp8Tensor (codes + po2 scales), not a Mat —
        // checked here by reproducing the stage boundary types.
        let mut rng = Rng::seed_from(7);
        let (d, h) = (128usize, 64usize);
        let w = MoeWeights::random(d, h, 1, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let x = Mat::randn(16, d, 0.5, &mut rng);
        let xq = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let gate = fp8_matmul_with_threads(&xq, &pw.w1_t[0], 1);
        let up = fp8_matmul_with_threads(&xq, &pw.w3_t[0], 1);
        let aq = swiglu_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(aq.mode, ScaleMode::Po2);
        assert_eq!(aq.fmt, Fp8Format::E4M3);
        assert_eq!(aq.rows, 16);
        assert_eq!(aq.cols, h);
    }

    // --- stage-API contracts -------------------------------------------

    /// Routing plan with ragged per-expert loads for the stage tests.
    fn staged_setup(
        seed: u64,
        recipe: Recipe,
    ) -> (Mat, PreparedWeights, Vec<i64>, usize, usize) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e, cap) = (96, 64, 48, 4, 32);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let expert_of: Vec<usize> = (0..t).map(|_| rng.below(e)).collect();
        let plan = permute_pad_plan(&expert_of, e, cap);
        (x, PreparedWeights::new(w, recipe), plan, e, cap)
    }

    #[test]
    fn sharded_stages_cover_the_full_range_bitwise() {
        // dispatch/expert_ffn over expert sub-ranges must tile the full
        // single-range result exactly, for every recipe.
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let (x, pw, plan, e, cap) = staged_setup(8, recipe);
            let xq = (recipe == Recipe::Fp8Flow)
                .then(|| quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2));
            let src = || match &xq {
                Some(q) => DispatchSource::Fp8(q),
                None => DispatchSource::Dense(&x),
            };
            let full = expert_ffn(&dispatch(src(), &plan, 0..e, cap, 1), &pw, 1);
            for n_shards in [2usize, 4] {
                let p = Partition::even(e, n_shards);
                for er in p.ranges() {
                    let yk = expert_ffn(&dispatch(src(), &plan, er.clone(), cap, 1), &pw, 2);
                    let lo = er.start * cap * x.cols;
                    let hi = er.end * cap * x.cols;
                    for (a, b) in yk.data.iter().zip(&full.data[lo..hi]) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{recipe:?} shard {er:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_combine_sums_to_single_rank_bitwise() {
        let (x, pw, plan, e, cap) = staged_setup(9, Recipe::Bf16);
        let t = x.rows;
        let batch = dispatch(DispatchSource::Dense(&x), &plan, 0..e, cap, 1);
        let yk = expert_ffn(&batch, &pw, 1);
        let full = combine(&yk, &plan, 0..e, cap, t, 1);
        let p = Partition::even(e, 2);
        let mut summed = Mat::zeros(t, x.cols);
        for er in p.ranges() {
            let lo = er.start * cap * x.cols;
            let hi = er.end * cap * x.cols;
            let yk_local =
                Mat::from_vec(er.len() * cap, x.cols, yk.data[lo..hi].to_vec());
            let part = combine(&yk_local, &plan, er, cap, t, 1);
            for (acc, v) in summed.data.iter_mut().zip(&part.data) {
                *acc += v;
            }
        }
        for (a, b) in summed.data.iter().zip(&full.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wire_bytes_follow_the_wire_type() {
        let (x, _, plan, e, cap) = staged_setup(10, Recipe::Fp8Flow);
        let xq = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let dense = dispatch(DispatchSource::Dense(&x), &plan, 0..e, cap, 1);
        let fp8 = dispatch(DispatchSource::Fp8(&xq), &plan, 0..e, cap, 1);
        assert_eq!(dense.rows(), fp8.rows());
        // FP8 wire ≈ half the dense bytes (+1B/128 sidecar)
        assert!(fp8.wire_bytes() * 2 <= dense.wire_bytes() + fp8.rows() * 2);
    }
}
