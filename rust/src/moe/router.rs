//! Top-k softmax router (§3.2 "routing" stage) — forward and backward.
//!
//! The backward ([`route_backward`]) is the piece the Fig. 2 graphs leave
//! out (they model the expert path only): the gradient of the gated
//! combine `y = Σ_k g_k · back_k` plus the Switch-style auxiliary
//! load-balancing loss, w.r.t. the router weights and the layer input.
//! Conventions:
//!
//! * the discrete top-k **selection** is a constant of the backward (an
//!   argmax has no gradient); the **gates** are live through the softmax
//!   and the top-k renormalization — [`route_with_selection`] is the
//!   matching frozen-selection forward the gradchecks differentiate;
//! * the aux loss `E · Σ_e f_e · m_e` follows the Switch convention: the
//!   dispatch fraction `f` is a constant, gradient flows through the mean
//!   probabilities `m` only.
//!
//! The router runs in f32 on every recipe (the paper keeps routing in
//! high precision), so the backward adds **zero** casts and zero
//! requantizations to the per-step audit.

use crate::util::mat::Mat;

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// `[tokens, k]` expert index per token per slot.
    pub experts: Vec<Vec<usize>>,
    /// `[tokens, k]` normalized gate weights.
    pub gates: Vec<Vec<f32>>,
    /// Switch-style load-balancing auxiliary loss.
    pub aux_loss: f32,
}

/// Row-wise softmax with max-subtraction. Shared by the forward route and
/// the backward's recomputation so both see bit-identical probabilities
/// (same per-element op order).
fn softmax_rows(logits: &Mat) -> Mat {
    let mut probs = Mat::zeros(logits.rows, logits.cols);
    for t in 0..logits.rows {
        let row = logits.row(t);
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let out = &mut probs.data[t * logits.cols..(t + 1) * logits.cols];
        let mut z = 0.0f32;
        for (o, &v) in out.iter_mut().zip(row) {
            *o = (v - mx).exp();
            z += *o;
        }
        for o in out.iter_mut() {
            *o /= z;
        }
    }
    probs
}

/// `aux = E · Σ_e f_e · m_e` from the per-expert top-1 counts and
/// probability sums (both over `n` tokens).
fn aux_from(first_counts: &[usize], prob_sums: &[f64], n: f64) -> f32 {
    (first_counts.len() as f64
        * first_counts
            .iter()
            .zip(prob_sums)
            .map(|(&f, &p)| (f as f64 / n) * (p / n))
            .sum::<f64>()) as f32
}

/// Route `x [tokens, d]` through router weights `wr [d, E]`, top-k.
///
/// A zero-row `x` (an empty serving flush tick) routes to an empty
/// decision with `aux_loss = 0.0` — without the early return,
/// [`aux_from`] would divide by `n = 0` and poison the aux loss with NaN.
pub fn route(x: &Mat, wr: &Mat, top_k: usize) -> Routing {
    assert_eq!(x.cols, wr.rows);
    let e = wr.cols;
    assert!(top_k <= e);
    if x.rows == 0 {
        return Routing { experts: Vec::new(), gates: Vec::new(), aux_loss: 0.0 };
    }
    let probs = softmax_rows(&x.matmul(wr));
    let mut experts = Vec::with_capacity(x.rows);
    let mut gates = Vec::with_capacity(x.rows);
    let mut first_counts = vec![0usize; e];
    let mut prob_sums = vec![0f64; e];
    for t in 0..x.rows {
        let prow = probs.row(t);
        for (i, &p) in prow.iter().enumerate() {
            prob_sums[i] += p as f64;
        }
        // iterative top-k (ties broken by lower index — matches argmax)
        let mut chosen = Vec::with_capacity(top_k);
        let mut g = Vec::with_capacity(top_k);
        let mut masked = prow.to_vec();
        for _ in 0..top_k {
            let (bi, bv) = masked
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
                    if v > bv {
                        (i, v)
                    } else {
                        (bi, bv)
                    }
                });
            chosen.push(bi);
            g.push(bv);
            masked[bi] = f32::NEG_INFINITY;
        }
        first_counts[chosen[0]] += 1;
        let gz: f32 = g.iter().sum();
        let g: Vec<f32> = g.iter().map(|&v| v / gz).collect();
        experts.push(chosen);
        gates.push(g);
    }
    let aux_loss = aux_from(&first_counts, &prob_sums, x.rows as f64);
    Routing { experts, gates, aux_loss }
}

/// [`route`] under a **frozen selection**: the top-k indices are given,
/// the gates (and the aux loss) are recomputed live from `x` and `wr`.
///
/// With `selection == route(..).experts` this reproduces [`route`] bit
/// for bit; with the selection held fixed while `x`/`wr` are perturbed it
/// is the smooth surrogate that [`route_backward`] differentiates — the
/// gradcheck entry point for the router path (`tests/prop_backward.rs`).
pub fn route_with_selection(x: &Mat, wr: &Mat, selection: &[Vec<usize>]) -> Routing {
    assert_eq!(x.cols, wr.rows);
    assert_eq!(selection.len(), x.rows, "selection/token count mismatch");
    let e = wr.cols;
    let probs = softmax_rows(&x.matmul(wr));
    let mut gates = Vec::with_capacity(x.rows);
    let mut first_counts = vec![0usize; e];
    let mut prob_sums = vec![0f64; e];
    for t in 0..x.rows {
        let prow = probs.row(t);
        for (i, &p) in prow.iter().enumerate() {
            prob_sums[i] += p as f64;
        }
        let chosen = &selection[t];
        assert!(!chosen.is_empty() && chosen.iter().all(|&c| c < e), "bad selection");
        let g: Vec<f32> = chosen.iter().map(|&c| prow[c]).collect();
        first_counts[chosen[0]] += 1;
        let gz: f32 = g.iter().sum();
        gates.push(g.iter().map(|&v| v / gz).collect());
    }
    let aux_loss = aux_from(&first_counts, &prob_sums, x.rows as f64);
    Routing { experts: selection.to_vec(), gates, aux_loss }
}

/// Gradients of the routing path.
pub struct RouterBwd {
    /// `[d, E]` router weight gradient.
    pub d_router: Mat,
    /// `[tokens, d]` contribution to the layer input gradient.
    pub dx: Mat,
}

/// Backward of the routing path: given `d_gates[t][k] = ∂L/∂g_{t,k}` (the
/// upstream gradient of each normalized gate, i.e. `⟨dy_t, back_k[t]⟩`)
/// and the aux-loss coefficient, produce the router weight gradient and
/// the input-gradient contribution.
///
/// Chain, per token (selection `c` frozen, probabilities `p` recomputed
/// bit-identically to the forward):
///
/// ```text
/// g_j = p_{c_j} / Σ_i p_{c_i}          (top-k renormalization)
/// ∂L/∂p_{c_j} = (d_gates_j − Σ_i d_gates_i·g_i) / Σ_i p_{c_i}
/// ∂L/∂p_e    += λ · E · f_e / T        (aux: f frozen, m live)
/// dlogits     = p ⊙ (dp − ⟨dp, p⟩)     (softmax backward)
/// d_router    = Xᵀ · dlogits;   dx = dlogits · Wrᵀ
/// ```
///
/// For top-1 the renormalized gate is identically 1, so the gate path
/// vanishes exactly (zero gradient) and only the aux term drives the
/// router — the formulas handle it without special-casing.
pub fn route_backward(
    x: &Mat,
    wr: &Mat,
    routing: &Routing,
    d_gates: &[Vec<f32>],
    aux_coef: f32,
) -> RouterBwd {
    let t_n = x.rows;
    let e = wr.cols;
    assert_eq!(routing.experts.len(), t_n, "routing/token count mismatch");
    assert_eq!(d_gates.len(), t_n, "d_gates/token count mismatch");
    let probs = softmax_rows(&x.matmul(wr));

    // dispatch fraction f (frozen, Switch convention)
    let mut first_counts = vec![0usize; e];
    for ex in &routing.experts {
        first_counts[ex[0]] += 1;
    }
    let aux_term: Vec<f32> = first_counts
        .iter()
        .map(|&f| aux_coef * (e as f32) * (f as f32 / t_n as f32) / t_n as f32)
        .collect();

    let mut dlogits = Mat::zeros(t_n, e);
    let mut dp = vec![0f32; e];
    for t in 0..t_n {
        let prow = probs.row(t);
        let chosen = &routing.experts[t];
        let g = &routing.gates[t];
        assert_eq!(d_gates[t].len(), chosen.len(), "d_gates/top-k mismatch");
        dp.copy_from_slice(&aux_term);
        let gz: f32 = chosen.iter().map(|&c| prow[c]).sum();
        let inner: f32 = d_gates[t].iter().zip(g).map(|(&a, &b)| a * b).sum();
        for (j, &c) in chosen.iter().enumerate() {
            dp[c] += (d_gates[t][j] - inner) / gz;
        }
        let s: f32 = dp.iter().zip(prow).map(|(&a, &b)| a * b).sum();
        let out = &mut dlogits.data[t * e..(t + 1) * e];
        for ((o, &dpe), &pe) in out.iter_mut().zip(&dp).zip(prow) {
            *o = pe * (dpe - s);
        }
    }
    RouterBwd { d_router: x.transpose().matmul(&dlogits), dx: dlogits.matmul(&wr.transpose()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gradcheck, probe_indices};
    use crate::util::rng::Rng;

    #[test]
    fn routes_all_tokens() {
        let mut rng = Rng::seed_from(1);
        let x = Mat::randn(32, 16, 1.0, &mut rng);
        let wr = Mat::randn(16, 4, 1.0, &mut rng);
        let r = route(&x, &wr, 2);
        assert_eq!(r.experts.len(), 32);
        for t in 0..32 {
            assert_eq!(r.experts[t].len(), 2);
            assert_ne!(r.experts[t][0], r.experts[t][1]);
            let gsum: f32 = r.gates[t].iter().sum();
            assert!((gsum - 1.0).abs() < 1e-5);
            assert!(r.gates[t][0] >= r.gates[t][1]); // top-1 has larger gate
        }
    }

    #[test]
    fn empty_batch_routes_to_empty_not_nan() {
        let mut rng = Rng::seed_from(9);
        let wr = Mat::randn(16, 4, 1.0, &mut rng);
        let r = route(&Mat::zeros(0, 16), &wr, 2);
        assert!(r.experts.is_empty() && r.gates.is_empty());
        assert_eq!(r.aux_loss, 0.0);
        assert!(!r.aux_loss.is_nan());
    }

    #[test]
    fn aux_loss_at_least_one_for_balanced() {
        // aux = E·Σ f_e p_e ≥ 1 with equality iff perfectly balanced
        let mut rng = Rng::seed_from(2);
        let x = Mat::randn(512, 16, 1.0, &mut rng);
        let wr = Mat::randn(16, 4, 0.5, &mut rng);
        let r = route(&x, &wr, 1);
        assert!(r.aux_loss >= 0.9, "aux={}", r.aux_loss);
    }

    #[test]
    fn biased_router_concentrates() {
        // strongly biased router weights → one expert dominates
        let x = Mat::from_fn(64, 8, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let wr = Mat::from_fn(8, 4, |i, j| if i == 0 && j == 2 { 10.0 } else { 0.0 });
        let r = route(&x, &wr, 1);
        assert!(r.experts.iter().all(|e| e[0] == 2));
        assert!(r.aux_loss > 2.0, "concentration should inflate aux: {}", r.aux_loss);
    }

    #[test]
    fn frozen_selection_reproduces_route_bitwise() {
        let mut rng = Rng::seed_from(3);
        let x = Mat::randn(48, 16, 0.7, &mut rng);
        let wr = Mat::randn(16, 6, 0.5, &mut rng);
        for top_k in [1usize, 2, 3] {
            let a = route(&x, &wr, top_k);
            let b = route_with_selection(&x, &wr, &a.experts);
            assert_eq!(a.experts, b.experts);
            for t in 0..x.rows {
                for (u, v) in a.gates[t].iter().zip(&b.gates[t]) {
                    assert_eq!(u.to_bits(), v.to_bits(), "k={top_k} t={t}");
                }
            }
            assert_eq!(a.aux_loss.to_bits(), b.aux_loss.to_bits());
        }
    }

    #[test]
    fn top1_gate_path_is_exactly_zero() {
        // top-1 renormalized gate ≡ 1 ⇒ with aux off, the router gets
        // exactly zero gradient (the selection is discrete)
        let mut rng = Rng::seed_from(4);
        let x = Mat::randn(24, 8, 0.5, &mut rng);
        let wr = Mat::randn(8, 4, 0.5, &mut rng);
        let r = route(&x, &wr, 1);
        let d_gates: Vec<Vec<f32>> = (0..24).map(|t| vec![1.0 + t as f32]).collect();
        let rb = route_backward(&x, &wr, &r, &d_gates, 0.0);
        assert!(rb.d_router.data.iter().all(|&v| v == 0.0));
        assert!(rb.dx.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn route_backward_gradchecks_gate_and_aux_paths() {
        // surrogate: L = Σ_t Σ_k g_{t,k}·u_{t,k} + λ·aux under frozen
        // selection — pure routing, no expert math
        let mut rng = Rng::seed_from(5);
        let (t_n, d, e, k) = (12, 8, 4, 2);
        let x = Mat::randn(t_n, d, 0.5, &mut rng);
        let wr = Mat::randn(d, e, 0.4, &mut rng);
        let u = Mat::randn(t_n, k, 1.0, &mut rng); // ∂L/∂g directly
        let lam = 0.5f32;
        let base = route(&x, &wr, k);
        let sel = base.experts.clone();
        let d_gates: Vec<Vec<f32>> = (0..t_n).map(|t| u.row(t).to_vec()).collect();
        let rb = route_backward(&x, &wr, &base, &d_gates, lam);

        // flat output: gates [t_n·k] then aux; dy weights: u then λ
        let fwd = |xv: &Mat, wv: &Mat| -> Vec<f32> {
            let r = route_with_selection(xv, wv, &sel);
            let mut out: Vec<f32> = r.gates.iter().flatten().copied().collect();
            out.push(r.aux_loss);
            out
        };
        let mut dy: Vec<f32> = u.data.clone();
        dy.push(lam);

        gradcheck(
            "route_backward d_router",
            |ws| fwd(&x, &Mat::from_vec(d, e, ws.to_vec())),
            &wr.data,
            &dy,
            &rb.d_router.data,
            1e-2,
            2e-2,
            &probe_indices(d * e, 12),
        );
        gradcheck(
            "route_backward dx",
            |xs| fwd(&Mat::from_vec(t_n, d, xs.to_vec()), &wr),
            &x.data,
            &dy,
            &rb.dx.data,
            1e-2,
            2e-2,
            &probe_indices(t_n * d, 12),
        );
    }
}
