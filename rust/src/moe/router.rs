//! Top-k softmax router (§3.2 "routing" stage).

use crate::util::mat::Mat;

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// `[tokens, k]` expert index per token per slot.
    pub experts: Vec<Vec<usize>>,
    /// `[tokens, k]` normalized gate weights.
    pub gates: Vec<Vec<f32>>,
    /// Switch-style load-balancing auxiliary loss.
    pub aux_loss: f32,
}

/// Route `x [tokens, d]` through router weights `wr [d, E]`, top-k.
pub fn route(x: &Mat, wr: &Mat, top_k: usize) -> Routing {
    assert_eq!(x.cols, wr.rows);
    let e = wr.cols;
    assert!(top_k <= e);
    let logits = x.matmul(wr);
    let mut experts = Vec::with_capacity(x.rows);
    let mut gates = Vec::with_capacity(x.rows);
    let mut first_counts = vec![0usize; e];
    let mut prob_sums = vec![0f64; e];
    for t in 0..x.rows {
        let row = logits.row(t);
        // softmax
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|&v| v / z).collect();
        for (i, &p) in probs.iter().enumerate() {
            prob_sums[i] += p as f64;
        }
        // iterative top-k (ties broken by lower index — matches argmax)
        let mut chosen = Vec::with_capacity(top_k);
        let mut g = Vec::with_capacity(top_k);
        let mut masked = probs.clone();
        for _ in 0..top_k {
            let (bi, bv) = masked
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
                    if v > bv {
                        (i, v)
                    } else {
                        (bi, bv)
                    }
                });
            chosen.push(bi);
            g.push(bv);
            masked[bi] = f32::NEG_INFINITY;
        }
        first_counts[chosen[0]] += 1;
        let gz: f32 = g.iter().sum();
        let g: Vec<f32> = g.iter().map(|&v| v / gz).collect();
        experts.push(chosen);
        gates.push(g);
    }
    let n = x.rows as f64;
    let aux_loss = (e as f64
        * first_counts
            .iter()
            .zip(&prob_sums)
            .map(|(&f, &p)| (f as f64 / n) * (p / n))
            .sum::<f64>()) as f32;
    Routing { experts, gates, aux_loss }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn routes_all_tokens() {
        let mut rng = Rng::seed_from(1);
        let x = Mat::randn(32, 16, 1.0, &mut rng);
        let wr = Mat::randn(16, 4, 1.0, &mut rng);
        let r = route(&x, &wr, 2);
        assert_eq!(r.experts.len(), 32);
        for t in 0..32 {
            assert_eq!(r.experts[t].len(), 2);
            assert_ne!(r.experts[t][0], r.experts[t][1]);
            let gsum: f32 = r.gates[t].iter().sum();
            assert!((gsum - 1.0).abs() < 1e-5);
            assert!(r.gates[t][0] >= r.gates[t][1]); // top-1 has larger gate
        }
    }

    #[test]
    fn aux_loss_at_least_one_for_balanced() {
        // aux = E·Σ f_e p_e ≥ 1 with equality iff perfectly balanced
        let mut rng = Rng::seed_from(2);
        let x = Mat::randn(512, 16, 1.0, &mut rng);
        let wr = Mat::randn(16, 4, 0.5, &mut rng);
        let r = route(&x, &wr, 1);
        assert!(r.aux_loss >= 0.9, "aux={}", r.aux_loss);
    }

    #[test]
    fn biased_router_concentrates() {
        // strongly biased router weights → one expert dominates
        let x = Mat::from_fn(64, 8, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let wr = Mat::from_fn(8, 4, |i, j| if i == 0 && j == 2 { 10.0 } else { 0.0 });
        let r = route(&x, &wr, 1);
        assert!(r.experts.iter().all(|e| e[0] == 2));
        assert!(r.aux_loss > 2.0, "concentration should inflate aux: {}", r.aux_loss);
    }
}
