//! FP8 GEMM with per-tile (1×128) scaling — the DeepGEMM-style contraction
//! the expert FFN runs on (§3.2).
//!
//! Operand layout contract (the whole point of the transpose story):
//! * `a`: row-wise quantized `[M, K]` — scales tile along K;
//! * `b`: row-wise quantized **Bᵀ** `[N, K]` — also tiling along K, which
//!   is exactly what [`crate::fp8::transpose::direct_transpose`] produces.
//!
//! Accumulation is f32; each 128-wide k-tile's partial product is scaled
//! by the outer product of the two tile scales.
//!
//! Parallelism: M-row panels on the [`crate::exec`] scoped pool. Each
//! worker runs the identical serial tile loop over its own contiguous row
//! range (with a private decoded-B panel), so the parallel result is
//! **bit-identical** to the serial one — per output element the k-tile
//! accumulation order never changes (`tests/prop_parallel.rs`).

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::{e4m3, Fp8Format, TILE};
use crate::util::mat::Mat;

/// `A @ Bᵀ` over FP8 operands (see module docs for layout), parallelized
/// over M-row panels with the process-wide worker count.
pub fn fp8_matmul(a: &Fp8Tensor, b: &Fp8Tensor) -> Mat {
    fp8_matmul_with_threads(a, b, exec::threads())
}

/// [`fp8_matmul`] with an explicit worker count (1 = the serial kernel).
pub fn fp8_matmul_with_threads(a: &Fp8Tensor, b: &Fp8Tensor, threads: usize) -> Mat {
    assert_eq!(a.layout, TileLayout::RowWise);
    assert_eq!(b.layout, TileLayout::RowWise);
    assert_eq!(a.cols, b.cols, "contraction length mismatch");
    assert_eq!(a.fmt, Fp8Format::E4M3);
    let (m, n) = (a.rows, b.rows);
    let mut out = Mat::zeros(m, n);
    let p = Partition::even(m, exec::workers_for(threads, m));
    if p.len() <= 1 {
        matmul_row_panel(a, b, 0..m, &mut out.data);
        return out;
    }
    let tasks: Vec<_> = exec::split_parts(&p, n, &mut out.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(panel, rows)| matmul_row_panel(a, b, rows, panel));
    out
}

/// Serial kernel over one contiguous M-row panel; `out` holds exactly
/// `rows.len() * b.rows` elements (the panel's slice of the output).
///
/// §Perf structure: per 128-wide k-tile, the whole `B` panel (`n × 128`)
/// is decoded ONCE into a contiguous f32 scratch and reused across all
/// rows of the panel — amortizing the LUT decode that dominated the naive
/// per-(row,row) loop (before/after in EXPERIMENTS.md §Perf). The inner
/// dot over 128 f32 auto-vectorizes.
fn matmul_row_panel(a: &Fp8Tensor, b: &Fp8Tensor, rows: std::ops::Range<usize>, out: &mut [f32]) {
    let (k, n) = (a.cols, b.rows);
    let kt = n_tiles(k);
    debug_assert_eq!(out.len(), rows.len() * n);
    // decoded B panel for the current k-tile: [n][TILE], padded with zeros
    let mut bpanel = vec![0f32; n * TILE];
    let mut adec = [0f32; TILE];
    for t in 0..kt {
        let j0 = t * TILE;
        let j1 = (j0 + TILE).min(k);
        let w = j1 - j0;
        // decode B panel once per k-tile (scales folded in)
        for nn in 0..n {
            let brow = &b.data[nn * k + j0..nn * k + j1];
            let sb = b.scales[nn * kt + t];
            let dst = &mut bpanel[nn * TILE..nn * TILE + w];
            for (o, &c) in dst.iter_mut().zip(brow) {
                *o = e4m3::DECODE_LUT[c as usize] * sb;
            }
        }
        for i in rows.clone() {
            let arow = &a.data[i * k + j0..i * k + j1];
            let sa = a.scales[i * kt + t];
            for (o, &c) in adec.iter_mut().zip(arow) {
                *o = e4m3::DECODE_LUT[c as usize];
            }
            let r = i - rows.start;
            let orow = &mut out[r * n..(r + 1) * n];
            if w == TILE {
                // common case: 8 independent accumulators let the reduce
                // vectorize without float reassociation
                for (nn, bp) in bpanel.chunks_exact(TILE).enumerate() {
                    let mut acc = [0f32; 8];
                    for ch in 0..TILE / 8 {
                        for l in 0..8 {
                            acc[l] += adec[ch * 8 + l] * bp[ch * 8 + l];
                        }
                    }
                    orow[nn] += acc.iter().sum::<f32>() * sa;
                }
            } else {
                for nn in 0..n {
                    let bp = &bpanel[nn * TILE..nn * TILE + w];
                    let mut acc = 0f32;
                    for o in 0..w {
                        acc += adec[o] * bp[o];
                    }
                    orow[nn] += acc * sa;
                }
            }
        }
    }
}

/// Grouped (per-expert) FP8 GEMM: `out[e] = A[e] @ B[e]ᵀ`, one worker per
/// expert partition (each expert's GEMM runs the serial kernel — the
/// grouped dimension is the parallel axis).
///
/// `a`: one tensor per expert `[C, K]`; `b`: per-expert weights `[N, K]`.
pub fn grouped_fp8_matmul(a: &[Fp8Tensor], b: &[Fp8Tensor]) -> Vec<Mat> {
    grouped_fp8_matmul_with_threads(a, b, exec::threads())
}

/// [`grouped_fp8_matmul`] with an explicit worker count.
pub fn grouped_fp8_matmul_with_threads(
    a: &[Fp8Tensor],
    b: &[Fp8Tensor],
    threads: usize,
) -> Vec<Mat> {
    assert_eq!(a.len(), b.len());
    let p = Partition::even(a.len(), exec::workers_for(threads, a.len()));
    exec::map_parts(&p, |e| fp8_matmul_with_threads(&a[e], &b[e], 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::quantize_rowwise;
    use crate::fp8::ScaleMode;
    use crate::util::rng::Rng;

    #[test]
    fn close_to_f32_matmul() {
        let mut rng = Rng::seed_from(1);
        let x = Mat::randn(64, 256, 1.0, &mut rng);
        let w = Mat::randn(32, 256, 1.0, &mut rng); // = Wᵀ layout
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let got = fp8_matmul(&qa, &qb);
        let expect = x.matmul(&w.transpose());
        let rel = got.rel_err(&expect);
        assert!(rel < 0.08, "rel={rel}");
    }

    #[test]
    fn exact_on_quantized_inputs() {
        // If inputs are already on the FP8 grid with scale 1, the GEMM must
        // be exactly the f32 GEMM of the decoded values.
        let mut rng = Rng::seed_from(2);
        let x = Mat::randn(16, 128, 1.0, &mut rng)
            .map(|v| e4m3::decode(e4m3::encode(v.clamp(-3.0, 3.0))));
        let w = Mat::randn(8, 128, 1.0, &mut rng)
            .map(|v| e4m3::decode(e4m3::encode(v.clamp(-3.0, 3.0))));
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let got = fp8_matmul(&qa, &qb);
        let expect = qa.dequantize().matmul(&qb.dequantize().transpose());
        assert!(got.rel_err(&expect) < 1e-6);
    }

    #[test]
    fn ragged_k() {
        let mut rng = Rng::seed_from(3);
        let x = Mat::randn(8, 200, 1.0, &mut rng);
        let w = Mat::randn(4, 200, 1.0, &mut rng);
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let got = fp8_matmul(&qa, &qb);
        let expect = x.matmul(&w.transpose());
        assert!(got.rel_err(&expect) < 0.1);
    }

    #[test]
    fn grouped_matches_per_expert() {
        let mut rng = Rng::seed_from(4);
        let a: Vec<Fp8Tensor> = (0..3)
            .map(|_| quantize_rowwise(&Mat::randn(16, 128, 1.0, &mut rng), Fp8Format::E4M3, ScaleMode::Po2))
            .collect();
        let b: Vec<Fp8Tensor> = (0..3)
            .map(|_| quantize_rowwise(&Mat::randn(8, 128, 1.0, &mut rng), Fp8Format::E4M3, ScaleMode::Po2))
            .collect();
        let grouped = grouped_fp8_matmul(&a, &b);
        for e in 0..3 {
            assert_eq!(grouped[e], fp8_matmul(&a[e], &b[e]));
        }
    }

    #[test]
    fn parallel_panels_bit_identical_to_serial() {
        let mut rng = Rng::seed_from(5);
        let x = Mat::rand_log_uniform(77, 300, -4.0, 4.0, &mut rng); // ragged rows + k
        let w = Mat::randn(33, 300, 1.0, &mut rng);
        let qa = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qb = quantize_rowwise(&w, Fp8Format::E4M3, ScaleMode::Po2);
        let serial = fp8_matmul_with_threads(&qa, &qb, 1);
        for t in [2usize, 3, 8, 64] {
            let par = fp8_matmul_with_threads(&qa, &qb, t);
            for (a, b) in par.data.iter().zip(&serial.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={t}");
            }
        }
    }
}
