//! SwiGLU and the **fused SwiGLU+quantization** kernel (§3.3.2).
//!
//! The fused form computes `silu(gate) ⊙ up` and quantizes row-wise in the
//! same pass over the rows — one read of (gate, up), one write of
//! (codes, scales) — versus the unfused baseline's extra f32 activation
//! round-trip. Contract: bitwise-identical payload/scales to
//! `quantize(swiglu(gate, up))`.

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::tile::tile_scale;
use crate::fp8::{Fp8Format, ScaleMode, TILE};
use crate::util::mat::Mat;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Unfused SwiGLU (Fig. 5 baseline): `silu(gate) ⊙ up`, parallel over
/// token (row) chunks.
pub fn swiglu(gate: &Mat, up: &Mat) -> Mat {
    swiglu_with_threads(gate, up, exec::threads())
}

/// [`swiglu`] with an explicit worker count (elementwise ⇒ trivially
/// bit-identical across worker counts).
pub fn swiglu_with_threads(gate: &Mat, up: &Mat, threads: usize) -> Mat {
    assert_eq!((gate.rows, gate.cols), (up.rows, up.cols));
    let mut out = Mat::zeros(gate.rows, gate.cols);
    let p = Partition::even(gate.rows, exec::workers_for(threads, gate.rows));
    let cols = gate.cols;
    let tasks: Vec<_> = exec::split_parts(&p, cols, &mut out.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(chunk, rows)| {
        let g = &gate.data[rows.start * cols..rows.end * cols];
        let u = &up.data[rows.start * cols..rows.end * cols];
        for ((o, &gv), &uv) in chunk.iter_mut().zip(g).zip(u) {
            *o = silu(gv) * uv;
        }
    });
    out
}

/// One element of the SwiGLU backward — shared by the dense and the fused
/// quantizing kernels so both compute bit-identical values (same op order).
#[inline]
fn swiglu_bwd_elem(g: f32, u: f32, dyv: f32) -> (f32, f32) {
    let sig = 1.0 / (1.0 + (-g).exp());
    let dsilu = sig * (1.0 + g * (1.0 - sig));
    (dyv * u * dsilu, dyv * g * sig)
}

/// SwiGLU backward: `(d_gate, d_up)` given upstream `dy`.
pub fn swiglu_bwd(gate: &Mat, up: &Mat, dy: &Mat) -> (Mat, Mat) {
    swiglu_bwd_with_threads(gate, up, dy, exec::threads())
}

/// [`swiglu_bwd`] with an explicit worker count (elementwise ⇒ trivially
/// bit-identical across worker counts).
pub fn swiglu_bwd_with_threads(gate: &Mat, up: &Mat, dy: &Mat, threads: usize) -> (Mat, Mat) {
    assert_eq!((gate.rows, gate.cols), (up.rows, up.cols));
    assert_eq!((gate.rows, gate.cols), (dy.rows, dy.cols));
    let cols = gate.cols;
    let mut dg = Mat::zeros(gate.rows, gate.cols);
    let mut du = Mat::zeros(gate.rows, gate.cols);
    let p = Partition::even(gate.rows, exec::workers_for(threads, gate.rows));
    let tasks: Vec<_> = exec::split_parts(&p, cols, &mut dg.data)
        .into_iter()
        .zip(exec::split_parts(&p, cols, &mut du.data))
        .zip(p.ranges())
        .map(|((a, b), r)| (a, b, r))
        .collect();
    exec::run_tasks(tasks, |(dgc, duc, rows)| {
        let base = rows.start * cols;
        for k in 0..rows.len() * cols {
            let (a, b) = swiglu_bwd_elem(gate.data[base + k], up.data[base + k], dy.data[base + k]);
            dgc[k] = a;
            duc[k] = b;
        }
    });
    (dg, du)
}

/// **Fused SwiGLU-backward + row-wise FP8 quantization** (the
/// `FusedSwiGluBwdQuant` node of the Fp8Flow bwd graph): computes
/// `(d_gate, d_up)` and quantizes both per 1×128 row tile in the same
/// pass — the backward BF16 island ends inside the compute kernel, no
/// standalone cast launch. Contract: bitwise-identical payloads/scales to
/// `quantize_rowwise(swiglu_bwd(..))` applied to each output.
pub fn swiglu_bwd_quant(
    gate: &Mat,
    up: &Mat,
    dy: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
) -> (Fp8Tensor, Fp8Tensor) {
    swiglu_bwd_quant_with_threads(gate, up, dy, fmt, mode, exec::threads())
}

/// [`swiglu_bwd_quant`] with an explicit worker count (1 = serial). Row
/// tiles are independent, so the parallel payloads/scales are bit-identical
/// to the serial kernel's (`tests/prop_parallel.rs`).
pub fn swiglu_bwd_quant_with_threads(
    gate: &Mat,
    up: &Mat,
    dy: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    threads: usize,
) -> (Fp8Tensor, Fp8Tensor) {
    assert_eq!((gate.rows, gate.cols), (up.rows, up.cols));
    assert_eq!((gate.rows, gate.cols), (dy.rows, dy.cols));
    let (m, n) = (gate.rows, gate.cols);
    let tpr = n_tiles(n);
    let mut dg_data = vec![0u8; m * n];
    let mut dg_scales = vec![0.0f32; m * tpr];
    let mut dg_sexp = vec![0i32; m * tpr];
    let mut du_data = vec![0u8; m * n];
    let mut du_scales = vec![0.0f32; m * tpr];
    let mut du_sexp = vec![0i32; m * tpr];
    let p = Partition::even(m, exec::workers_for(threads, m));
    if p.len() <= 1 {
        swiglu_bwd_quant_rows(
            gate, up, dy, fmt, mode, 0..m,
            &mut dg_data, &mut dg_scales, &mut dg_sexp,
            &mut du_data, &mut du_scales, &mut du_sexp,
        );
    } else {
        let tasks: Vec<_> = exec::split_parts(&p, n, &mut dg_data)
            .into_iter()
            .zip(exec::split_parts(&p, tpr, &mut dg_scales))
            .zip(exec::split_parts(&p, tpr, &mut dg_sexp))
            .zip(exec::split_parts(&p, n, &mut du_data))
            .zip(exec::split_parts(&p, tpr, &mut du_scales))
            .zip(exec::split_parts(&p, tpr, &mut du_sexp))
            .zip(p.ranges())
            .map(|((((((a, b), c), d), e), f), r)| (a, b, c, d, e, f, r))
            .collect();
        exec::run_tasks(tasks, |(gd, gs, ge, ud, us, ue, r)| {
            swiglu_bwd_quant_rows(gate, up, dy, fmt, mode, r, gd, gs, ge, ud, us, ue)
        });
    }
    if mode == ScaleMode::Float {
        dg_sexp.clear();
        du_sexp.clear();
    }
    let mk = |data, scales, sexp| Fp8Tensor {
        rows: m,
        cols: n,
        fmt,
        mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    };
    (mk(dg_data, dg_scales, dg_sexp), mk(du_data, du_scales, du_sexp))
}

/// Serial fused backward kernel over one contiguous row chunk.
#[allow(clippy::too_many_arguments)]
fn swiglu_bwd_quant_rows(
    gate: &Mat,
    up: &Mat,
    dy: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    rows: std::ops::Range<usize>,
    dg_data: &mut [u8],
    dg_scales: &mut [f32],
    dg_sexp: &mut [i32],
    du_data: &mut [u8],
    du_scales: &mut [f32],
    du_sexp: &mut [i32],
) {
    let n = gate.cols;
    let tpr = n_tiles(n);
    let mut gbuf = [0f32; TILE];
    let mut ubuf = [0f32; TILE];
    for i in rows.clone() {
        let r = i - rows.start;
        for t in 0..tpr {
            let j0 = t * TILE;
            let j1 = (j0 + TILE).min(n);
            let w = j1 - j0;
            // compute both gradient tiles once, in registers/L1
            let mut gmax = 0f32;
            let mut umax = 0f32;
            for (bj, j) in (j0..j1).enumerate() {
                let (a, b) =
                    swiglu_bwd_elem(gate.data[i * n + j], up.data[i * n + j], dy.data[i * n + j]);
                gbuf[bj] = a;
                ubuf[bj] = b;
                gmax = gmax.max(a.abs());
                umax = umax.max(b.abs());
            }
            let (gs, gexp) = tile_scale(gmax, fmt, mode);
            let (us, uexp) = tile_scale(umax, fmt, mode);
            // same `v * (1/s)` scaling expression as `quantize_rowwise` —
            // part of the bitwise contract with the unfused pair
            let (ginv, uinv) = (1.0 / gs, 1.0 / us);
            match fmt {
                Fp8Format::E4M3 => {
                    crate::fp8::e4m3::encode_scaled_slice(
                        &gbuf[..w],
                        ginv,
                        &mut dg_data[r * n + j0..r * n + j1],
                    );
                    crate::fp8::e4m3::encode_scaled_slice(
                        &ubuf[..w],
                        uinv,
                        &mut du_data[r * n + j0..r * n + j1],
                    );
                }
                _ => {
                    for bj in 0..w {
                        dg_data[r * n + j0 + bj] = fmt.encode(gbuf[bj] * ginv);
                        du_data[r * n + j0 + bj] = fmt.encode(ubuf[bj] * uinv);
                    }
                }
            }
            dg_scales[r * tpr + t] = gs;
            dg_sexp[r * tpr + t] = gexp;
            du_scales[r * tpr + t] = us;
            du_sexp[r * tpr + t] = uexp;
        }
    }
}

/// **Fused SwiGLU + row-wise FP8 quantization** — single pass per row
/// tile: activation values never leave the working set between the
/// nonlinearity and the encode. Parallel over token (row) chunks.
pub fn swiglu_quant(gate: &Mat, up: &Mat, fmt: Fp8Format, mode: ScaleMode) -> Fp8Tensor {
    swiglu_quant_with_threads(gate, up, fmt, mode, exec::threads())
}

/// [`swiglu_quant`] with an explicit worker count (1 = serial). Row tiles
/// are independent, so the parallel payload/scales are bit-identical to
/// the serial kernel's (`tests/prop_parallel.rs`).
pub fn swiglu_quant_with_threads(
    gate: &Mat,
    up: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    threads: usize,
) -> Fp8Tensor {
    assert_eq!((gate.rows, gate.cols), (up.rows, up.cols));
    let (m, n) = (gate.rows, gate.cols);
    let tpr = n_tiles(n);
    let mut data = vec![0u8; m * n];
    let mut scales = vec![0.0f32; m * tpr];
    let mut sexp = vec![0i32; m * tpr];
    let p = Partition::even(m, exec::workers_for(threads, m));
    if p.len() <= 1 {
        swiglu_quant_rows(gate, up, fmt, mode, 0..m, &mut data, &mut scales, &mut sexp);
    } else {
        let d_parts = exec::split_parts(&p, n, &mut data);
        let s_parts = exec::split_parts(&p, tpr, &mut scales);
        let e_parts = exec::split_parts(&p, tpr, &mut sexp);
        let tasks: Vec<_> = d_parts
            .into_iter()
            .zip(s_parts)
            .zip(e_parts)
            .zip(p.ranges())
            .map(|(((d, s), e), r)| (d, s, e, r))
            .collect();
        exec::run_tasks(tasks, |(d, s, e, r)| {
            swiglu_quant_rows(gate, up, fmt, mode, r, d, s, e)
        });
    }
    if mode == ScaleMode::Float {
        sexp.clear();
    }
    Fp8Tensor {
        rows: m,
        cols: n,
        fmt,
        mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    }
}

/// Serial fused kernel over one contiguous row chunk.
#[allow(clippy::too_many_arguments)]
fn swiglu_quant_rows(
    gate: &Mat,
    up: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    rows: std::ops::Range<usize>,
    data: &mut [u8],
    scales: &mut [f32],
    sexp: &mut [i32],
) {
    let n = gate.cols;
    let tpr = n_tiles(n);
    let mut tilebuf = [0f32; TILE];
    for i in rows.clone() {
        let grow = gate.row(i);
        let urow = up.row(i);
        let r = i - rows.start;
        for t in 0..tpr {
            let j0 = t * TILE;
            let j1 = (j0 + TILE).min(n);
            let w = j1 - j0;
            // compute the activation tile once, in registers/L1
            let mut amax = 0f32;
            for (bj, j) in (j0..j1).enumerate() {
                let v = silu(grow[j]) * urow[j];
                tilebuf[bj] = v;
                amax = amax.max(v.abs());
            }
            let (s, e) = tile_scale(amax, fmt, mode);
            let inv = 1.0 / s;
            match fmt {
                Fp8Format::E4M3 => crate::fp8::e4m3::encode_scaled_slice(
                    &tilebuf[..w],
                    inv,
                    &mut data[r * n + j0..r * n + j1],
                ),
                _ => {
                    for bj in 0..w {
                        data[r * n + j0 + bj] = fmt.encode(tilebuf[bj] * inv);
                    }
                }
            }
            scales[r * tpr + t] = s;
            sexp[r * tpr + t] = e;
        }
    }
}

/// Unfused baseline: SwiGLU into an f32 buffer, then a separate
/// quantization pass (the extra activation round-trip the fusion removes).
pub fn swiglu_then_quant(gate: &Mat, up: &Mat, fmt: Fp8Format, mode: ScaleMode) -> Fp8Tensor {
    let act = swiglu(gate, up);
    crate::fp8::tile::quantize_rowwise(&act, fmt, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;
    use crate::util::rng::Rng;

    #[test]
    fn swiglu_known_values() {
        let g = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        let u = Mat::from_vec(1, 2, vec![5.0, 2.0]);
        let y = swiglu(&g, &u);
        assert_eq!(y.data[0], 0.0);
        let silu1 = 1.0 / (1.0 + (-1.0f32).exp());
        assert!((y.data[1] - 2.0 * silu1).abs() < 1e-6);
    }

    #[test]
    fn fused_equals_unfused_bitwise() {
        props("fused swiglu+quant == unfused", 24, |g| {
            let m = g.usize_in(1, 4) * 32;
            let n = g.usize_in(1, 3) * 128;
            let mut rng = Rng::seed_from(g.seed ^ 0x5157);
            let gate = Mat::randn(m, n, 2.0, &mut rng);
            let up = Mat::randn(m, n, 2.0, &mut rng);
            for mode in [ScaleMode::Po2, ScaleMode::Float] {
                let fused = swiglu_quant(&gate, &up, Fp8Format::E4M3, mode);
                let unfused = swiglu_then_quant(&gate, &up, Fp8Format::E4M3, mode);
                assert_eq!(fused.data, unfused.data, "payload mismatch ({mode:?})");
                assert_eq!(fused.scales, unfused.scales, "scales mismatch ({mode:?})");
            }
        });
    }

    #[test]
    fn bwd_matches_finite_difference() {
        let mut rng = Rng::seed_from(9);
        let g = Mat::randn(4, 8, 1.0, &mut rng);
        let u = Mat::randn(4, 8, 1.0, &mut rng);
        let dy = Mat::randn(4, 8, 1.0, &mut rng);
        let (dg, du) = swiglu_bwd(&g, &u, &dy);
        let eps = 1e-3f32;
        let f = |g: &Mat, u: &Mat| -> f64 {
            swiglu(g, u)
                .data
                .iter()
                .zip(&dy.data)
                .map(|(&y, &d)| (y * d) as f64)
                .sum()
        };
        for idx in [0usize, 5, 17, 31] {
            let mut gp = g.clone();
            gp.data[idx] += eps;
            let mut gm = g.clone();
            gm.data[idx] -= eps;
            let num = (f(&gp, &u) - f(&gm, &u)) / (2.0 * eps as f64);
            assert!(
                (num - dg.data[idx] as f64).abs() < 2e-2,
                "dg[{idx}]: fd={num} analytic={}",
                dg.data[idx]
            );
            let mut upp = u.clone();
            upp.data[idx] += eps;
            let mut upm = u.clone();
            upm.data[idx] -= eps;
            let numu = (f(&g, &upp) - f(&g, &upm)) / (2.0 * eps as f64);
            assert!(
                (numu - du.data[idx] as f64).abs() < 2e-2,
                "du[{idx}]: fd={numu} analytic={}",
                du.data[idx]
            );
        }
    }

    #[test]
    fn fused_bwd_quant_equals_unfused_bitwise() {
        props("fused swiglu_bwd+quant == unfused", 24, |g| {
            let m = g.usize_in(1, 96);
            let n = g.usize_in(1, 300);
            let mut rng = Rng::seed_from(g.seed ^ 0xB3D);
            let gate = Mat::randn(m, n, 2.0, &mut rng);
            let up = Mat::randn(m, n, 2.0, &mut rng);
            let dy = Mat::randn(m, n, 1.0, &mut rng);
            let (dg, du) = swiglu_bwd(&gate, &up, &dy);
            for mode in [ScaleMode::Po2, ScaleMode::Float] {
                let (fg, fu) = swiglu_bwd_quant(&gate, &up, &dy, Fp8Format::E4M3, mode);
                let ug = crate::fp8::tile::quantize_rowwise(&dg, Fp8Format::E4M3, mode);
                let uu = crate::fp8::tile::quantize_rowwise(&du, Fp8Format::E4M3, mode);
                assert_eq!(fg.data, ug.data, "dgate payload ({mode:?})");
                assert_eq!(fg.scales, ug.scales, "dgate scales ({mode:?})");
                assert_eq!(fg.sexp, ug.sexp, "dgate sexp ({mode:?})");
                assert_eq!(fu.data, uu.data, "dup payload ({mode:?})");
                assert_eq!(fu.scales, uu.scales, "dup scales ({mode:?})");
                assert_eq!(fu.sexp, uu.sexp, "dup sexp ({mode:?})");
            }
        });
    }

    #[test]
    fn ragged_cols() {
        let mut rng = Rng::seed_from(10);
        let gate = Mat::randn(8, 200, 1.0, &mut rng);
        let up = Mat::randn(8, 200, 1.0, &mut rng);
        let fused = swiglu_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
        let unfused = swiglu_then_quant(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(fused.data, unfused.data);
    }
}
