//! Native (L3) MoE substrate: router, fused permute+pad, SwiGLU(+quant),
//! grouped FP8 GEMM, and the full MoE layer in the three recipes.
//!
//! These are the Rust twins of the L1 Pallas kernels (`python/compile/
//! kernels/`) with identical semantics — the integration tests cross-check
//! them bitwise against the AOT-compiled HLO. They serve two purposes:
//! the native hot path for the coordinator, and the measurable kernels
//! behind the Fig. 1/3/4/5 benches.

pub mod backward;
pub mod gemm;
pub mod layer;
pub mod permute;
pub mod router;
pub mod swiglu;
