//! The expert-FFN **backward** stage — per-recipe dgrad/wgrad over a
//! rank-local dY batch, experts as the parallel axis (serial kernels
//! inside, so the result is bit-identical for any worker count and any
//! sharding of the expert range).
//!
//! Per expert (stashed fwd: `gate/up = x·W1/W3`, `a = swiglu(gate, up)`,
//! `y = a·W2`):
//!
//! ```text
//! d_a  = dY · W2ᵀ                    (fc2 dgrad)
//! dW2  = aᵀ · dY                     (fc2 wgrad — column-major operands!)
//! (d_gate, d_up) = swiglu_bwd(gate, up, d_a)
//! dX   = d_gate · W1ᵀ + d_up · W3ᵀ   (fc1 dgrad)
//! dW1  = Xᵀ · d_gate;  dW3 = Xᵀ · d_up
//! ```
//!
//! The wgrad GEMMs contract over the *token* axis, so both operands need
//! the column-wise FP8 layout — exactly the paper's Fig. 2 fork:
//!
//! * **Fp8Flow**: every wgrad operand comes from the scaling-aware
//!   [`crate::fp8::transpose::direct_transpose`] — pure exponent
//!   manipulation in code space, scale sidecars carried, **zero
//!   re-quantization** of already-FP8 tensors; the SwiGLU backward is the
//!   fused [`crate::moe::swiglu::swiglu_bwd_quant`] (the BF16 island ends
//!   inside the kernel). The only explicit bwd cast is the entry `Q(dy)`,
//!   counted by the driver.
//! * **Blockwise** (the measurable foil): wgrad operands go through
//!   [`crate::fp8::transpose::naive_transpose`] — dequantize → transpose →
//!   requantize onto fresh float scales, the double-quantization site —
//!   plus standalone `Q(dy)`/`Q(d_gate)`/`Q(d_up)` cast launches.
//! * **Bf16**: plain f32 reference math (the gradcheck oracle).

use std::ops::Range;

use crate::exec::{self, Partition};
use crate::fp8::tensor::Fp8Tensor;
use crate::fp8::tile::quantize_rowwise_with_threads;
use crate::fp8::transpose::{direct_transpose_with_threads, naive_transpose_with_threads};
use crate::fp8::{Fp8Format, ScaleMode};
use crate::moe::backward::stash::{mat_rows, ActStash, SlotStash};
use crate::moe::backward::BwdStats;
use crate::moe::gemm::fp8_matmul_with_threads;
use crate::moe::layer::{PreparedWeights, RankLocalBatch, Recipe, WirePayload};
use crate::moe::swiglu::{swiglu_bwd_quant_with_threads, swiglu_bwd_with_threads};
use crate::obs::{self, Counter};
use crate::util::mat::Mat;

/// Gradients of one expert's weights (f32 master-gradient layout).
pub struct ExpertGrads {
    /// Gate-projection gradient `[d, h]`.
    pub dw1: Mat, // [d, h]
    /// Up-projection gradient `[d, h]`.
    pub dw3: Mat, // [d, h]
    /// Down-projection gradient `[h, d]`.
    pub dw2: Mat, // [h, d]
}

/// Result of the expert backward stage over one expert range.
pub struct ExpertBwd {
    /// Global expert ids covered (mirrors the dY batch).
    pub experts: Range<usize>,
    /// Input gradients `[|experts|·capacity, d]` in dispatched row order
    /// (accumulator precision — ready for the unpermute scatter).
    pub dxk: Mat,
    /// Per local expert, in expert order.
    pub grads: Vec<ExpertGrads>,
    /// Executed cast/requant audit for this stage.
    pub stats: BwdStats,
}

/// Run the expert backward for the batch's expert range. `slot` is the
/// *global* forward stash; this stage reads only the rows of the experts
/// it covers, which is what makes it shardable (the EP runtime calls it
/// once per rank with that rank's dY batch).
pub fn expert_ffn_bwd(
    dyk: &RankLocalBatch,
    slot: &SlotStash,
    w: &PreparedWeights,
    threads: usize,
) -> ExpertBwd {
    let er = dyk.experts.clone();
    let el = er.len();
    let cap = dyk.capacity;
    assert_eq!(cap, slot.batch.capacity, "stash/batch capacity mismatch");
    let d = w.raw.w1[0].rows;
    let p = Partition::even(el, exec::workers_for(threads, el));
    let per: Vec<(Mat, ExpertGrads, BwdStats)> = exec::map_parts(&p, |lx| {
        let ge = er.start + lx;
        match (&dyk.payload, w.recipe) {
            (WirePayload::Fp8(dyg), Recipe::Fp8Flow) => {
                flow_expert_bwd(dyg.slice_rows(lx * cap, cap), slot, w, ge, cap)
            }
            (WirePayload::Dense(dyg), Recipe::Blockwise) => {
                blockwise_expert_bwd(mat_rows(dyg, lx * cap, cap), slot, w, ge, cap)
            }
            (WirePayload::Dense(dyg), Recipe::Bf16) => {
                bf16_expert_bwd(mat_rows(dyg, lx * cap, cap), slot, w, ge, cap)
            }
            _ => panic!("recipe/wire mismatch in expert_ffn_bwd: {:?}", w.recipe),
        }
    });
    let mut dxk = Mat::zeros(el * cap, d);
    let mut grads = Vec::with_capacity(el);
    let mut stats = BwdStats::default();
    for (lx, (dxe, g, s)) in per.into_iter().enumerate() {
        debug_assert_eq!((dxe.rows, dxe.cols), (cap, d));
        dxk.data[lx * cap * d..(lx + 1) * cap * d].copy_from_slice(&dxe.data);
        grads.push(g);
        stats.add(s);
    }
    // The audit above IS the counter semantics: Fp8Flow contributes (0, 0)
    // here, Blockwise (3, 5) per expert — same algebra as ExecPrediction.
    if obs::enabled() {
        obs::count(Counter::CastsBwd, stats.casts as u64);
        obs::count(Counter::RequantsBwd, stats.requants as u64);
    }
    ExpertBwd { experts: er, dxk, grads, stats }
}

/// Fp8Flow: the casting-free backward chain — FP8 operands in, f32
/// accumulators out, wgrad layouts via the scaling-aware direct transpose.
fn flow_expert_bwd(
    dye_q: Fp8Tensor,
    slot: &SlotStash,
    w: &PreparedWeights,
    ge: usize,
    cap: usize,
) -> (Mat, ExpertGrads, BwdStats) {
    let WirePayload::Fp8(xg) = &slot.batch.payload else {
        panic!("Fp8Flow backward needs the FP8 dispatched stash");
    };
    let ActStash::Fp8(aqg) = &slot.act else {
        panic!("Fp8Flow backward needs the quantized activation stash");
    };
    let xe_q = xg.slice_rows(ge * cap, cap);
    let aq_e = aqg.slice_rows(ge * cap, cap);
    let gate_e = mat_rows(&slot.gate, ge * cap, cap);
    let up_e = mat_rows(&slot.up, ge * cap, cap);

    // fc2 dgrad: dY consumed straight from the FP8 wire — BF16 island
    let d_act = fp8_matmul_with_threads(&dye_q, &w.w2_d[ge], 1);
    // fused SwiGLU-bwd+quant: grads re-enter FP8 inside the kernel
    let (dg_q, du_q) =
        swiglu_bwd_quant_with_threads(&gate_e, &up_e, &d_act, Fp8Format::E4M3, ScaleMode::Po2, 1);
    // fc1 dgrad (two projections share the FP8 grads)
    let dxe_g = fp8_matmul_with_threads(&dg_q, &w.w1_d[ge], 1);
    let dxe_u = fp8_matmul_with_threads(&du_q, &w.w3_d[ge], 1);
    let dxe = mat_add(&dxe_g, &dxe_u);
    // wgrad operands: scaling-aware transposes — code space only, the
    // scale sidecars ride along, nothing is re-quantized
    let xt = direct_transpose_with_threads(&xe_q, 1); // [d, cap]
    let dgt = direct_transpose_with_threads(&dg_q, 1); // [h, cap]
    let dut = direct_transpose_with_threads(&du_q, 1);
    let at = direct_transpose_with_threads(&aq_e, 1); // [h, cap]
    let dyt = direct_transpose_with_threads(&dye_q, 1); // [d, cap]
    let dw1 = fp8_matmul_with_threads(&xt, &dgt, 1); // [d, h]
    let dw3 = fp8_matmul_with_threads(&xt, &dut, 1);
    let dw2 = fp8_matmul_with_threads(&at, &dyt, 1); // [h, d]
    (dxe, ExpertGrads { dw1, dw3, dw2 }, BwdStats { casts: 0, requants: 0 })
}

/// Blockwise (TE-style): standalone casts at every GEMM boundary and
/// naive requantizing transposes for the wgrad operands — the
/// double-quantization error is executed, not just modeled.
fn blockwise_expert_bwd(
    dye: Mat,
    slot: &SlotStash,
    w: &PreparedWeights,
    ge: usize,
    cap: usize,
) -> (Mat, ExpertGrads, BwdStats) {
    let Some(xqg) = &slot.x_q else {
        panic!("Blockwise backward needs the quantized-input stash");
    };
    let ActStash::Fp8(aqg) = &slot.act else {
        panic!("Blockwise backward needs the quantized activation stash");
    };
    let xq_e = xqg.slice_rows(ge * cap, cap);
    let aq_e = aqg.slice_rows(ge * cap, cap);
    let gate_e = mat_rows(&slot.gate, ge * cap, cap);
    let up_e = mat_rows(&slot.up, ge * cap, cap);

    // Q(dy) for the fc2 grads — explicit cast #1
    let dyq = quantize_rowwise_with_threads(&dye, Fp8Format::E4M3, ScaleMode::Float, 1);
    let d_act = fp8_matmul_with_threads(&dyq, &w.w2_d[ge], 1);
    let (dg, du) = swiglu_bwd_with_threads(&gate_e, &up_e, &d_act, 1);
    // Q(d_gate)/Q(d_up) for the fc1 grads — explicit casts #2/#3
    let dgq = quantize_rowwise_with_threads(&dg, Fp8Format::E4M3, ScaleMode::Float, 1);
    let duq = quantize_rowwise_with_threads(&du, Fp8Format::E4M3, ScaleMode::Float, 1);
    let dxe_g = fp8_matmul_with_threads(&dgq, &w.w1_d[ge], 1);
    let dxe_u = fp8_matmul_with_threads(&duq, &w.w3_d[ge], 1);
    let dxe = mat_add(&dxe_g, &dxe_u);
    // wgrad operands: dequantize → transpose → requantize (fresh float
    // scales) — five requantizations of already-FP8 tensors per expert
    let xt = naive_transpose_with_threads(&xq_e, 1);
    let dgt = naive_transpose_with_threads(&dgq, 1);
    let dut = naive_transpose_with_threads(&duq, 1);
    let at = naive_transpose_with_threads(&aq_e, 1);
    let dyt = naive_transpose_with_threads(&dyq, 1);
    let dw1 = fp8_matmul_with_threads(&xt, &dgt, 1);
    let dw3 = fp8_matmul_with_threads(&xt, &dut, 1);
    let dw2 = fp8_matmul_with_threads(&at, &dyt, 1);
    (dxe, ExpertGrads { dw1, dw3, dw2 }, BwdStats { casts: 3, requants: 5 })
}

/// Bf16: the dense f32 reference backward (gradcheck oracle).
fn bf16_expert_bwd(
    dye: Mat,
    slot: &SlotStash,
    w: &PreparedWeights,
    ge: usize,
    cap: usize,
) -> (Mat, ExpertGrads, BwdStats) {
    let WirePayload::Dense(xg) = &slot.batch.payload else {
        panic!("Bf16 backward needs the dense dispatched stash");
    };
    let ActStash::Dense(actg) = &slot.act else {
        panic!("Bf16 backward needs the dense activation stash");
    };
    let xe = mat_rows(xg, ge * cap, cap);
    let act_e = mat_rows(actg, ge * cap, cap);
    let gate_e = mat_rows(&slot.gate, ge * cap, cap);
    let up_e = mat_rows(&slot.up, ge * cap, cap);

    let d_act = dye.matmul(&w.raw.w2[ge].transpose());
    let (dg, du) = swiglu_bwd_with_threads(&gate_e, &up_e, &d_act, 1);
    let dxe = mat_add(
        &dg.matmul(&w.raw.w1[ge].transpose()),
        &du.matmul(&w.raw.w3[ge].transpose()),
    );
    let dw1 = xe.transpose().matmul(&dg);
    let dw3 = xe.transpose().matmul(&du);
    let dw2 = act_e.transpose().matmul(&dye);
    (dxe, ExpertGrads { dw1, dw3, dw2 }, BwdStats { casts: 0, requants: 0 })
}

/// Elementwise `a + b` (fixed left-to-right order — part of the
/// bit-identity contract across thread counts and shardings).
fn mat_add(a: &Mat, b: &Mat) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut out = Mat::zeros(a.rows, a.cols);
    for ((o, &x), &y) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
        *o = x + y;
    }
    out
}
