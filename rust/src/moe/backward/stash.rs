//! The stashing forward: runs the MoE layer forward through the same
//! stage APIs as [`crate::moe::layer::moe_forward`] — bit-identical `y`,
//! cast and wire accounting — while keeping the per-slot intermediates the
//! backward needs:
//!
//! * the dispatched input batch (the recipe's wire payload — FP8 codes for
//!   Fp8Flow, dense rows otherwise);
//! * the per-expert quantized fc1 input (`x_q`, Blockwise only — Fp8Flow's
//!   dispatched payload already *is* the fc1 operand);
//! * the fc1 outputs `gate`/`up` (the BF16 islands, needed by SwiGLU-bwd);
//! * the fc2 input activation ([`ActStash`]): the FP8 codes+scales for the
//!   quantizing recipes (what the fwd GEMM actually consumed — stashing
//!   codes instead of f32 is the recipe's activation-memory saving), dense
//!   f32 for Bf16;
//! * the layer input `x` and each slot's pre-gate combined output `back`
//!   — the two tensors the router backward reads (`∂L/∂g = ⟨dy, back⟩`,
//!   probabilities re-derived from `x`).
//!
//! Per-expert math is call-for-call identical to the executing forward
//! (`tests/prop_backward.rs::stash_forward_matches_moe_forward_bitwise`).

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use crate::fp8::{Fp8Format, ScaleMode};
use crate::moe::gemm::fp8_matmul_with_threads;
use crate::moe::layer::{
    combine, dispatch, DispatchSource, PreparedWeights, RankLocalBatch, Recipe, WirePayload,
};
use crate::moe::permute::permute_pad_plan;
use crate::moe::router::{route, Routing};
use crate::moe::swiglu::{swiglu_quant_with_threads, swiglu_with_threads};
use crate::obs::{self, Counter};
use crate::util::mat::Mat;

/// The stashed fc2 input: exactly what the forward fc2 GEMM consumed.
#[derive(Clone, Debug)]
pub enum ActStash {
    /// Quantized activation codes + per-tile scales (Fp8Flow: po2,
    /// Blockwise: float).
    Fp8(Fp8Tensor),
    /// Dense f32 activation (Bf16 recipe).
    Dense(Mat),
}

/// Everything the backward needs from one top-k slot of the forward.
#[derive(Clone, Debug)]
pub struct SlotStash {
    /// The slot's permute+pad plan over the full expert range.
    pub plan: Vec<i64>,
    /// Dispatched input batch `[E·capacity, d]` (recipe wire payload).
    pub batch: RankLocalBatch,
    /// Blockwise only: the per-expert float-quantized fc1 input
    /// `[E·capacity, d]` (the fwd `Q(x)` whose transpose feeds fc1 wgrad).
    pub x_q: Option<Fp8Tensor>,
    /// fc1 gate-projection output `[E·capacity, h]` (BF16 island #1).
    pub gate: Mat,
    /// fc1 up-projection output `[E·capacity, h]`.
    pub up: Mat,
    /// fc2 input `[E·capacity, h]` (see [`ActStash`]).
    pub act: ActStash,
    /// Combined pre-gate slot output `[tokens, d]` (the `back` the forward
    /// scales by `g_k` before accumulating) — what the router backward
    /// needs: `∂L/∂g_{t,k} = ⟨dy_t, back[t]⟩`.
    pub back: Mat,
}

/// A completed stashing forward: output + accounting (bit-identical to
/// [`crate::moe::layer::moe_forward`]) plus the per-slot backward stash.
pub struct FwdStash {
    /// The routing decision of the forward.
    pub routing: Routing,
    /// Per-expert row budget used.
    pub capacity: usize,
    /// Per-slot (top-k) stashed intermediates.
    pub slots: Vec<SlotStash>,
    /// The undisturbed layer input `[tokens, d]` — the router backward
    /// re-derives the softmax probabilities from it.
    pub x: Mat,
    /// Forward output `[t, d]`.
    pub y: Mat,
    /// Load-balancing aux loss of the forward.
    pub aux_loss: f32,
    /// Bytes moved through dispatch.
    pub dispatch_bytes: usize,
    /// Explicit casts the forward executed.
    pub cast_ops: usize,
}

impl FwdStash {
    /// Routed slots per token.
    pub fn top_k(&self) -> usize {
        self.slots.len()
    }
}

/// Run the stashing forward with the layer's own routing.
pub fn forward_stash(x: &Mat, w: &PreparedWeights, top_k: usize, capacity: usize) -> FwdStash {
    let routing = route(x, &w.raw.router, top_k);
    forward_stash_with_routing(x, w, &routing, capacity)
}

/// Run the stashing forward under an explicit (possibly frozen) routing —
/// the gradcheck entry point: with routing held fixed the layer is a
/// smooth function of `x` and the weights, so central differences are
/// well-defined. [`crate::moe::backward::moe_backward`] matches this
/// frozen-gates surrogate; the full-path gradchecks instead freeze only
/// the *selection* ([`crate::moe::router::route_with_selection`]) and pair
/// with [`crate::moe::backward::moe_backward_with_router`].
pub fn forward_stash_with_routing(
    x: &Mat,
    w: &PreparedWeights,
    routing: &Routing,
    capacity: usize,
) -> FwdStash {
    let t = x.rows;
    let e = w.raw.n_experts();
    assert!(t >= 1, "forward_stash needs at least one token");
    assert_eq!(routing.experts.len(), t, "routing/token count mismatch");
    let top_k = routing.experts[0].len();
    let threads = exec::threads();
    let mut y = Mat::zeros(t, x.cols);
    let mut dispatch_bytes = 0usize;
    let mut cast_ops = 0usize;
    let mut slots = Vec::with_capacity(top_k);

    // fp8flow: ONE entry quantization (same call as moe_forward's)
    let x_q = if w.recipe == Recipe::Fp8Flow {
        cast_ops += 1;
        obs::count(Counter::CastsFwd, 1);
        Some(quantize_rowwise(x, Fp8Format::E4M3, ScaleMode::Po2))
    } else {
        None
    };

    for kk in 0..top_k {
        let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
        let plan = permute_pad_plan(&expert_of, e, capacity);
        let src = match &x_q {
            Some(xq) => DispatchSource::Fp8(xq),
            None => DispatchSource::Dense(x),
        };
        let batch = dispatch(src, &plan, 0..e, capacity, threads);
        dispatch_bytes += batch.wire_bytes();
        if w.recipe == Recipe::Blockwise {
            cast_ops += 2 * e;
        }

        let (yk, inter) = expert_ffn_stash(&batch, w, threads);
        let back = combine(&yk, &plan, 0..e, capacity, t, threads);
        for tt in 0..t {
            let g = routing.gates[tt][kk];
            for j in 0..x.cols {
                y.data[tt * x.cols + j] += g * back.data[tt * x.cols + j];
            }
        }
        slots.push(SlotStash {
            plan,
            batch,
            x_q: inter.x_q,
            gate: inter.gate,
            up: inter.up,
            act: inter.act,
            back,
        });
    }
    FwdStash {
        routing: routing.clone(),
        capacity,
        slots,
        x: x.clone(),
        y,
        aux_loss: routing.aux_loss,
        dispatch_bytes,
        cast_ops,
    }
}

/// Per-slot intermediates returned by the stashing expert stage.
struct Inter {
    x_q: Option<Fp8Tensor>,
    gate: Mat,
    up: Mat,
    act: ActStash,
}

/// The expert-FFN stage with stashing: per-expert math identical (same
/// kernel calls, same order) to [`crate::moe::layer::expert_ffn`], plus
/// slab copies of the intermediates. Experts are the parallel axis.
fn expert_ffn_stash(batch: &RankLocalBatch, w: &PreparedWeights, threads: usize) -> (Mat, Inter) {
    let er = batch.experts.clone();
    let el = er.len();
    let cap = batch.capacity;
    let p = Partition::even(el, exec::workers_for(threads, el));
    match (&batch.payload, w.recipe) {
        (WirePayload::Fp8(xg), Recipe::Fp8Flow) => {
            let per: Vec<(Mat, Mat, Mat, Fp8Tensor)> = exec::map_parts(&p, |lx| {
                let ge = er.start + lx;
                let xe = xg.slice_rows(lx * cap, cap);
                let gate = fp8_matmul_with_threads(&xe, &w.w1_t[ge], 1);
                let up = fp8_matmul_with_threads(&xe, &w.w3_t[ge], 1);
                let aq =
                    swiglu_quant_with_threads(&gate, &up, Fp8Format::E4M3, ScaleMode::Po2, 1);
                let ye = fp8_matmul_with_threads(&aq, &w.w2_t[ge], 1);
                (ye, gate, up, aq)
            });
            let (yk, gate, up, aqs) = unzip_stash(per);
            (yk, Inter { x_q: None, gate, up, act: ActStash::Fp8(concat_fp8_rows(aqs)) })
        }
        (WirePayload::Dense(xg), Recipe::Blockwise) => {
            let per: Vec<((Mat, Mat, Mat, Fp8Tensor), Fp8Tensor)> = exec::map_parts(&p, |lx| {
                let ge = er.start + lx;
                let xe = mat_rows(xg, lx * cap, cap);
                // same 2-casts-per-expert audit as layer::expert_ffn
                obs::count(Counter::CastsFwd, 2);
                let xq = quantize_rowwise_with_threads(&xe, Fp8Format::E4M3, ScaleMode::Float, 1);
                let gate = fp8_matmul_with_threads(&xq, &w.w1_t[ge], 1);
                let up = fp8_matmul_with_threads(&xq, &w.w3_t[ge], 1);
                let act = swiglu_with_threads(&gate, &up, 1);
                let aq = quantize_rowwise_with_threads(&act, Fp8Format::E4M3, ScaleMode::Float, 1);
                let ye = fp8_matmul_with_threads(&aq, &w.w2_t[ge], 1);
                ((ye, gate, up, aq), xq)
            });
            let (main, xqs): (Vec<_>, Vec<_>) = per.into_iter().unzip();
            let (yk, gate, up, aqs) = unzip_stash(main);
            (
                yk,
                Inter {
                    x_q: Some(concat_fp8_rows(xqs)),
                    gate,
                    up,
                    act: ActStash::Fp8(concat_fp8_rows(aqs)),
                },
            )
        }
        (WirePayload::Dense(xg), Recipe::Bf16) => {
            let per: Vec<(Mat, Mat, Mat, Mat)> = exec::map_parts(&p, |lx| {
                let ge = er.start + lx;
                let xe = mat_rows(xg, lx * cap, cap);
                let gate = xe.matmul(&w.raw.w1[ge]);
                let up = xe.matmul(&w.raw.w3[ge]);
                let act = swiglu_with_threads(&gate, &up, 1);
                let ye = act.matmul(&w.raw.w2[ge]);
                (ye, gate, up, act)
            });
            let mut yks = Vec::with_capacity(el);
            let mut gates = Vec::with_capacity(el);
            let mut ups = Vec::with_capacity(el);
            let mut acts = Vec::with_capacity(el);
            for (ye, g, u, a) in per {
                yks.push(ye);
                gates.push(g);
                ups.push(u);
                acts.push(a);
            }
            (
                concat_mat_rows(yks),
                Inter {
                    x_q: None,
                    gate: concat_mat_rows(gates),
                    up: concat_mat_rows(ups),
                    act: ActStash::Dense(concat_mat_rows(acts)),
                },
            )
        }
        _ => panic!("recipe/wire mismatch in expert_ffn_stash: {:?}", w.recipe),
    }
}

fn unzip_stash(per: Vec<(Mat, Mat, Mat, Fp8Tensor)>) -> (Mat, Mat, Mat, Vec<Fp8Tensor>) {
    let mut yks = Vec::with_capacity(per.len());
    let mut gates = Vec::with_capacity(per.len());
    let mut ups = Vec::with_capacity(per.len());
    let mut aqs = Vec::with_capacity(per.len());
    for (ye, g, u, a) in per {
        yks.push(ye);
        gates.push(g);
        ups.push(u);
        aqs.push(a);
    }
    (concat_mat_rows(yks), concat_mat_rows(gates), concat_mat_rows(ups), aqs)
}

/// Copy `rows` rows of `m` starting at `start` into a new matrix.
pub(crate) fn mat_rows(m: &Mat, start: usize, rows: usize) -> Mat {
    Mat::from_vec(rows, m.cols, m.data[start * m.cols..(start + rows) * m.cols].to_vec())
}

/// Stack same-width matrices along the row axis.
fn concat_mat_rows(parts: Vec<Mat>) -> Mat {
    assert!(!parts.is_empty());
    let cols = parts[0].cols;
    let rows: usize = parts.iter().map(|p| p.rows).sum();
    let mut data = Vec::with_capacity(rows * cols);
    for p in parts {
        assert_eq!(p.cols, cols);
        data.extend_from_slice(&p.data);
    }
    Mat::from_vec(rows, cols, data)
}

/// Stack same-width row-wise FP8 tensors along the row axis (payload,
/// scales and — when present — po2 exponents).
fn concat_fp8_rows(parts: Vec<Fp8Tensor>) -> Fp8Tensor {
    assert!(!parts.is_empty());
    let first = &parts[0];
    let (cols, fmt, mode) = (first.cols, first.fmt, first.mode);
    let has_sexp = !first.sexp.is_empty();
    let rows: usize = parts.iter().map(|p| p.rows).sum();
    let tpr = n_tiles(cols);
    let mut data = Vec::with_capacity(rows * cols);
    let mut scales = Vec::with_capacity(rows * tpr);
    let mut sexp = Vec::with_capacity(if has_sexp { rows * tpr } else { 0 });
    for p in parts {
        assert_eq!(p.layout, TileLayout::RowWise);
        assert_eq!((p.cols, p.fmt, p.mode), (cols, fmt, mode));
        assert_eq!(p.sexp.is_empty(), !has_sexp);
        data.extend_from_slice(&p.data);
        scales.extend_from_slice(&p.scales);
        sexp.extend_from_slice(&p.sexp);
    }
    Fp8Tensor { rows, cols, fmt, mode, layout: TileLayout::RowWise, data, scales, sexp }
}
