//! The MoE-layer **backward** on the native substrate — the executed form
//! of the Fig. 2 bwd graphs ([`crate::dataflow::variants`]), for all three
//! recipes.
//!
//! Stage decomposition mirrors the forward's dispatch/expert/combine split
//! (PR 2), with the data flowing the other way:
//!
//! ```text
//! combine-bwd   gate-scale dy  (+ Q(dy): Fp8Flow's single bwd entry cast)
//!               → permute+pad into expert order        == fwd `dispatch`
//! expert-bwd    per-expert dgrad + wgrad               (backward/expert.rs)
//! dispatch-bwd  unpermute dX back to token order       == fwd `combine`
//! ```
//!
//! [`combine_bwd`] and [`dispatch_bwd`] *are* the forward stage kernels
//! with the roles swapped — the backward of a gather is a scatter and vice
//! versa — so every bit-identity property the forward stages carry
//! (thread invariance, expert-range shardability) transfers for free.
//!
//! Scope: [`moe_backward`] produces gradients w.r.t. the layer input and
//! the expert weights with gates held constant (the Fig. 2 surrogate —
//! the graphs model the expert path only); [`moe_backward_with_router`]
//! removes that restriction, adding the softmax top-k gate gradient and
//! the aux-loss gradient ([`crate::moe::router::route_backward`]) so the
//! native trainer ([`crate::train::native`]) can learn the routing. The
//! router runs in f32 on every recipe, so the router path adds **zero**
//! casts and zero requantizations to the audit below.
//!
//! The executed cast audit ([`BwdStats`]) is the module's acceptance
//! contract: the Fp8Flow backward performs **zero** re-quantizations of
//! already-FP8 tensors and exactly the graph's explicit casts
//! (`tests/prop_backward.rs`).

pub mod expert;
pub mod stash;

pub use expert::{expert_ffn_bwd, ExpertBwd, ExpertGrads};
pub use stash::{forward_stash, forward_stash_with_routing, ActStash, FwdStash, SlotStash};

use std::ops::Range;
use std::time::Instant;

use crate::exec::{self, Partition};
use crate::fp8::tile::quantize_rowwise_with_threads;
use crate::fp8::{Fp8Format, ScaleMode};
use crate::moe::layer::{
    combine, dispatch, DispatchSource, PreparedWeights, RankLocalBatch, Recipe,
};
use crate::moe::router::{route_backward, RouterBwd, Routing};
use crate::obs::{self, Counter};
use crate::util::mat::Mat;

/// Executed cast accounting for one backward pass — the measured side of
/// the Fig. 2 audit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BwdStats {
    /// Standalone quantize launches of f32/BF16 tensors (explicit casts).
    pub casts: usize,
    /// Quantize launches whose input was *already* FP8 (the naive-transpose
    /// double-quantization site). Zero for Fp8Flow, by construction.
    pub requants: usize,
}

impl BwdStats {
    /// Accumulate another audit into this one.
    pub fn add(&mut self, o: BwdStats) {
        self.casts += o.casts;
        self.requants += o.requants;
    }
}

/// Accumulated wall-clock seconds per backward stage (summed over slots).
#[derive(Clone, Copy, Debug, Default)]
pub struct BwdStageTimes {
    /// Gate-scaling + (Fp8Flow) entry quantization + permute+pad.
    pub combine_bwd_s: f64,
    /// Per-expert dgrad/wgrad GEMMs + transposes.
    pub expert_bwd_s: f64,
    /// Unpermute scatter back to token order + accumulate.
    pub dispatch_bwd_s: f64,
}

impl BwdStageTimes {
    /// Sum of all stage times.
    pub fn total_s(&self) -> f64 {
        self.combine_bwd_s + self.expert_bwd_s + self.dispatch_bwd_s
    }
}

/// Gradients of one MoE layer. `d_router` is `None` on the frozen-gates
/// path ([`moe_backward`]) and populated by [`moe_backward_with_router`],
/// whose `dx` then also carries the routing contribution.
pub struct MoeGrads {
    /// `[tokens, d]` input gradient.
    pub dx: Mat,
    /// Per-expert gate-projection gradients, `E x [d, h]`.
    pub dw1: Vec<Mat>, // E × [d, h]
    /// Per-expert up-projection gradients, `E x [d, h]`.
    pub dw3: Vec<Mat>, // E × [d, h]
    /// Per-expert down-projection gradients, `E x [h, d]`.
    pub dw2: Vec<Mat>, // E × [h, d]
    /// `[d, E]` router weight gradient (router-aware path only).
    pub d_router: Option<Mat>,
    /// Cast/requant audit of the backward.
    pub stats: BwdStats,
    /// Per-stage wall-clock seconds.
    pub stages: BwdStageTimes,
}

/// Combine-backward stage: route the (already gate-scaled, per-recipe
/// quantized) output gradients into expert-grouped order for a contiguous
/// expert range. This is exactly the forward [`dispatch`] kernel — the
/// backward of the combine scatter is the dispatch gather.
pub fn combine_bwd(
    src: DispatchSource,
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    threads: usize,
) -> RankLocalBatch {
    dispatch(src, plan, experts, capacity, threads)
}

/// Dispatch-backward stage: scatter expert-order input gradients back to
/// token order. This is exactly the forward [`combine`] kernel — the
/// backward of the dispatch gather is the combine scatter.
pub fn dispatch_bwd(
    dxk: &Mat,
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    n_tokens: usize,
    threads: usize,
) -> Mat {
    combine(dxk, plan, experts, capacity, n_tokens, threads)
}

/// Gate-scale the upstream gradient for one top-k slot (the combine-bwd
/// entry): `out[t] = gates[t][kk] · dy[t]`. Row-independent ⇒
/// bit-identical across worker counts.
pub fn scale_by_gates_with_threads(
    dy: &Mat,
    routing: &Routing,
    kk: usize,
    threads: usize,
) -> Mat {
    assert_eq!(dy.rows, routing.gates.len(), "dy/routing token mismatch");
    let cols = dy.cols;
    let mut out = Mat::zeros(dy.rows, dy.cols);
    let p = Partition::even(dy.rows, exec::workers_for(threads, dy.rows));
    let tasks: Vec<_> = exec::split_parts(&p, cols, &mut out.data)
        .into_iter()
        .zip(p.ranges())
        .collect();
    exec::run_tasks(tasks, |(chunk, tr)| {
        for tt in tr.clone() {
            let g = routing.gates[tt][kk];
            let o = (tt - tr.start) * cols;
            for j in 0..cols {
                chunk[o + j] = g * dy.data[tt * cols + j];
            }
        }
    });
    out
}

/// Run the full layer backward single-rank (expert range `0..E`).
pub fn moe_backward(stash: &FwdStash, w: &PreparedWeights, dy: &Mat) -> MoeGrads {
    moe_backward_with_threads(stash, w, dy, exec::threads())
}

/// [`moe_backward`] with an explicit worker count (1 = fully serial) —
/// bit-identical across worker counts (`tests/prop_parallel.rs`).
pub fn moe_backward_with_threads(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    threads: usize,
) -> MoeGrads {
    let t = dy.rows;
    let d = dy.cols;
    let e = w.raw.n_experts();
    assert_eq!((t, d), (stash.y.rows, stash.y.cols), "dy must match the forward output shape");
    let cap = stash.capacity;
    let mut dx = Mat::zeros(t, d);
    let mut dw1: Vec<Mat> = w.raw.w1.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw3: Vec<Mat> = w.raw.w3.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw2: Vec<Mat> = w.raw.w2.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut stats = BwdStats::default();
    let mut stages = BwdStageTimes::default();

    for (kk, slot) in stash.slots.iter().enumerate() {
        // ---- combine-bwd: gate-scale (+ entry quant) → permute+pad ----
        let tc = Instant::now();
        let sc = obs::enabled().then(|| {
            obs::span(format!("combine-bwd k{kk}"), obs::SpanMeta::stage("combine-bwd").step(kk))
        });
        let dyg = scale_by_gates_with_threads(dy, &stash.routing, kk, threads);
        let dyk = if w.recipe == Recipe::Fp8Flow {
            // Q(dy): the recipe's single explicit backward cast (§3.2 —
            // everything downstream stays in FP8 code space)
            stats.casts += 1;
            obs::count(Counter::CastsBwd, 1);
            let dyq =
                quantize_rowwise_with_threads(&dyg, Fp8Format::E4M3, ScaleMode::Po2, threads);
            combine_bwd(DispatchSource::Fp8(&dyq), &slot.plan, 0..e, cap, threads)
        } else {
            combine_bwd(DispatchSource::Dense(&dyg), &slot.plan, 0..e, cap, threads)
        };
        drop(sc);
        stages.combine_bwd_s += tc.elapsed().as_secs_f64();

        // ---- expert backward: dgrad + wgrad, experts parallel ----
        let te = Instant::now();
        let se = obs::enabled().then(|| {
            obs::span(format!("expert-bwd k{kk}"), obs::SpanMeta::stage("expert-bwd").step(kk))
        });
        let eb = expert_ffn_bwd(&dyk, slot, w, threads);
        stats.add(eb.stats);
        for (lx, g) in eb.grads.iter().enumerate() {
            mat_add_assign(&mut dw1[lx], &g.dw1);
            mat_add_assign(&mut dw3[lx], &g.dw3);
            mat_add_assign(&mut dw2[lx], &g.dw2);
        }
        drop(se);
        stages.expert_bwd_s += te.elapsed().as_secs_f64();

        // ---- dispatch-bwd: scatter dX back to token order ----
        let td = Instant::now();
        let sd = obs::enabled().then(|| {
            obs::span(format!("dispatch-bwd k{kk}"), obs::SpanMeta::stage("dispatch-bwd").step(kk))
        });
        let dxs = dispatch_bwd(&eb.dxk, &slot.plan, 0..e, cap, t, threads);
        for (a, b) in dx.data.iter_mut().zip(&dxs.data) {
            *a += b;
        }
        drop(sd);
        stages.dispatch_bwd_s += td.elapsed().as_secs_f64();
    }
    MoeGrads { dx, dw1, dw3, dw2, d_router: None, stats, stages }
}

/// The routing-path backward from a stashed forward: assemble the
/// per-slot gate gradients `∂L/∂g_{t,k} = ⟨dy_t, back_k[t]⟩` and chain
/// them (plus the aux loss, coefficient `aux_coef`) through the softmax
/// top-k router. Dense f32, serial and deterministic — identical on the
/// single-rank and EP-sharded paths, which is what keeps the EP training
/// step bitwise equal to single-rank.
pub fn router_backward_from_stash(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    aux_coef: f32,
) -> RouterBwd {
    let t = dy.rows;
    let k = stash.top_k();
    let mut d_gates = vec![vec![0f32; k]; t];
    for (kk, slot) in stash.slots.iter().enumerate() {
        for (tt, dg) in d_gates.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for j in 0..dy.cols {
                acc += dy.data[tt * dy.cols + j] * slot.back.data[tt * dy.cols + j];
            }
            dg[kk] = acc;
        }
    }
    route_backward(&stash.x, &w.raw.router, &stash.routing, &d_gates, aux_coef)
}

/// [`moe_backward`] plus the routing path: the full layer backward the
/// native training loop consumes. `dx` includes the router contribution;
/// `d_router` is populated.
pub fn moe_backward_with_router(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    aux_coef: f32,
) -> MoeGrads {
    moe_backward_with_router_threads(stash, w, dy, aux_coef, exec::threads())
}

/// [`moe_backward_with_router`] with an explicit worker count.
pub fn moe_backward_with_router_threads(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    aux_coef: f32,
    threads: usize,
) -> MoeGrads {
    let mut g = moe_backward_with_threads(stash, w, dy, threads);
    let rb = router_backward_from_stash(stash, w, dy, aux_coef);
    mat_add_assign(&mut g.dx, &rb.dx);
    g.d_router = Some(rb.d_router);
    g
}

/// `a += b` elementwise (slot-order accumulation of weight gradients —
/// the fixed order is part of the EP bit-identity contract).
pub(crate) fn mat_add_assign(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::{moe_forward, MoeWeights};
    use crate::util::prop::assert_mat_bits_eq;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Mat, MoeWeights, Mat) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e) = (48, 64, 48, 4);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let dy = Mat::randn(t, d, 1.0, &mut rng);
        (x, w, dy)
    }

    #[test]
    fn stash_forward_bit_matches_plain_forward() {
        let (x, w, _) = setup(31);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let plain = moe_forward(&x, &pw, 2, 16);
            let st = forward_stash(&x, &pw, 2, 16);
            assert_mat_bits_eq(&st.y, &plain.y, &format!("{recipe:?} stash fwd"));
            assert_eq!(st.cast_ops, plain.cast_ops, "{recipe:?}");
            assert_eq!(st.dispatch_bytes, plain.dispatch_bytes, "{recipe:?}");
            assert_eq!(st.aux_loss.to_bits(), plain.aux_loss.to_bits());
        }
    }

    #[test]
    fn backward_shapes_and_finiteness() {
        let (x, w, dy) = setup(32);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let st = forward_stash(&x, &pw, 2, 16);
            let g = moe_backward(&st, &pw, &dy);
            assert_eq!((g.dx.rows, g.dx.cols), (x.rows, x.cols));
            assert_eq!(g.dw1.len(), w.n_experts());
            for e in 0..w.n_experts() {
                assert_eq!((g.dw1[e].rows, g.dw1[e].cols), (w.w1[e].rows, w.w1[e].cols));
                assert_eq!((g.dw2[e].rows, g.dw2[e].cols), (w.w2[e].rows, w.w2[e].cols));
                assert!(g.dw1[e].data.iter().all(|v| v.is_finite()), "{recipe:?}");
            }
            assert!(g.dx.data.iter().all(|v| v.is_finite()), "{recipe:?}");
            assert!(g.dx.frobenius() > 0.0, "{recipe:?}: dx is all zero");
        }
    }

    #[test]
    fn flow_backward_is_casting_free() {
        let (x, w, dy) = setup(33);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let st = forward_stash(&x, &pw, 1, 16);
        let g = moe_backward(&st, &pw, &dy);
        assert_eq!(g.stats.requants, 0, "Fp8Flow must never requantize FP8 data");
        assert_eq!(g.stats.casts, 1, "one Q(dy) entry cast per slot");
    }

    #[test]
    fn blockwise_backward_requantizes() {
        let (x, w, dy) = setup(34);
        let e = w.n_experts();
        let pw = PreparedWeights::new(w, Recipe::Blockwise);
        let st = forward_stash(&x, &pw, 1, 16);
        let g = moe_backward(&st, &pw, &dy);
        assert_eq!(g.stats.casts, 3 * e, "Q(dy), Q(d_gate), Q(d_up) per expert");
        assert_eq!(g.stats.requants, 5 * e, "five naive wgrad-operand transposes per expert");
    }
}
