//! Synthetic byte-level corpus for the convergence experiment (Fig. 6
//! scaled per DESIGN.md §Hardware-Adaptation).
//!
//! The stream is a noisy order-2 Markov source over a planted transition
//! table: enough structure that cross-entropy falls well below the uniform
//! floor `ln(V)` within a few hundred steps, with a matched noise floor so
//! BF16-vs-FP8 curve *differences* are attributable to numerics, not data.

use crate::util::rng::Rng;

/// Deterministic synthetic corpus generator.
pub struct Corpus {
    vocab: usize,
    table: Vec<u32>, // [vocab*vocab] -> next-token mode
    rng: Rng,
    s1: u32,
    s2: u32,
    noise_pct: usize,
}

impl Corpus {
    /// `noise_pct` ∈ [0,100]: chance a token is uniform noise instead of
    /// the planted transition.
    pub fn new(vocab: usize, seed: u64, noise_pct: usize) -> Corpus {
        let mut rng = Rng::seed_from(seed ^ 0xC0DE);
        let table = (0..vocab * vocab).map(|_| rng.below(vocab) as u32).collect();
        Corpus { vocab, table, rng, s1: 0, s2: 1, noise_pct }
    }

    /// Snapshot the stream position — RNG state plus the order-2 Markov
    /// context — for checkpointing. The planted table is *not* part of
    /// the snapshot: it is a pure function of `(vocab, seed)`, so
    /// [`Corpus::restore`] on a fresh same-seed corpus resumes the token
    /// stream bitwise (`tests/prop_fault.rs` pins resume identity).
    pub fn stream_state(&self) -> ([u64; 4], u32, u32) {
        (self.rng.state(), self.s1, self.s2)
    }

    /// Restore a [`Corpus::stream_state`] snapshot onto this corpus
    /// (which must have been built with the same `(vocab, seed,
    /// noise_pct)` for the planted table to match).
    pub fn restore(&mut self, state: ([u64; 4], u32, u32)) {
        self.rng = Rng::from_state(state.0);
        self.s1 = state.1;
        self.s2 = state.2;
    }

    /// Next batch of `[batch, seq]` tokens (row-major i32).
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            for _ in 0..seq {
                let t = if self.rng.below(100) < self.noise_pct {
                    self.rng.below(self.vocab) as u32
                } else {
                    self.table[(self.s1 as usize) * self.vocab + self.s2 as usize]
                };
                out.push(t as i32);
                self.s1 = self.s2;
                self.s2 = t;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Corpus::new(64, 7, 10);
        let mut b = Corpus::new(64, 7, 10);
        assert_eq!(a.next_batch(2, 32), b.next_batch(2, 32));
    }

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(64, 1, 10);
        assert!(c.next_batch(4, 128).iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn has_structure() {
        // the planted Markov structure compresses: bigram conditional
        // entropy must be far below uniform
        let mut c = Corpus::new(64, 3, 10);
        let toks = c.next_batch(1, 20_000);
        let mut counts = vec![0f64; 64 * 64];
        let mut prev = toks[0] as usize;
        for &t in &toks[1..] {
            counts[prev * 64 + t as usize] += 1.0;
            prev = t as usize;
        }
        let mut h = 0.0;
        let total: f64 = counts.iter().sum();
        for p in 0..64 {
            let row: f64 = counts[p * 64..(p + 1) * 64].iter().sum();
            if row == 0.0 {
                continue;
            }
            for n in 0..64 {
                let c = counts[p * 64 + n];
                if c > 0.0 {
                    h -= (c / total) * (c / row).ln();
                }
            }
        }
        let uniform = (64f64).ln();
        assert!(h < 0.75 * uniform, "conditional entropy {h} vs uniform {uniform}");
    }

    #[test]
    fn different_seeds_different_tables() {
        let mut a = Corpus::new(64, 1, 0);
        let mut b = Corpus::new(64, 2, 0);
        assert_ne!(a.next_batch(1, 64), b.next_batch(1, 64));
    }
}
