//! The AOT training driver: the loop over an ahead-of-time compiled
//! `train_step` executable (L2 JAX graph with L1 kernels inside).
//!
//! This path needs `make artifacts` plus real `xla` bindings behind the
//! vendored stub; until both exist, [`AotTrainer::new`] fails with a
//! message pointing at the working alternative — the native driver
//! ([`crate::train::native::NativeTrainer`]), which runs the same
//! experiment entirely on the in-repo substrate.

use anyhow::{Context, Result};

use crate::runtime::{literal, Executable, Runtime};
use crate::train::data::Corpus;
use crate::train::{TrainDriver, TrainOutcome};

/// Drives `init_<cfg>` + `train_step_<recipe>_<cfg>` from Rust.
pub struct AotTrainer {
    step_exe: Executable,
    state: Vec<xla::Literal>,
    n_leaves: usize,
    batch: usize,
    seq: usize,
    recipe: String,
}

impl AotTrainer {
    /// Initialize from artifacts: runs `init_<cfg>` with `seed`.
    pub fn new(rt: &Runtime, cfg: &str, recipe: &str, seed: u32) -> Result<AotTrainer> {
        let ctx = "AOT artifacts unavailable — run `make artifacts`, or use the \
                   native trainer (train/native/: `fp8-flow-moe train` without --aot), \
                   which needs none";
        let init = rt.load(&format!("init_{cfg}")).context(ctx)?;
        let step_exe = rt.load(&format!("train_step_{recipe}_{cfg}")).context(ctx)?;
        let state = init
            .run(&[literal::u32_scalar(seed)?])
            .context("running init")?;
        anyhow::ensure!(state.len() % 3 == 0, "init output not 3P leaves");
        let n_leaves = state.len() / 3;
        let tok_spec = &step_exe.spec.inputs[3 * n_leaves + 1];
        let (batch, seq) = (tok_spec.shape[0], tok_spec.shape[1]);
        Ok(AotTrainer { step_exe, state, n_leaves, batch, seq, recipe: recipe.to_string() })
    }

    /// Run `steps` optimization steps against `corpus`, returning the loss
    /// trajectory. `log_every > 0` prints progress lines.
    pub fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize) -> Result<TrainOutcome> {
        let p = self.n_leaves;
        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for s in 1..=steps {
            let tokens = corpus.next_batch(self.batch, self.seq);
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * p + 2);
            for lit in self.state.iter().take(3 * p) {
                inputs.push(lit.clone());
            }
            inputs.push(literal::i32_scalar(s as i32)?);
            inputs.push(literal::i32_literal(&[self.batch, self.seq], &tokens)?);
            let out = self.step_exe.run(&inputs).with_context(|| format!("step {s}"))?;
            let loss = literal::to_f32_scalar(&out[3 * p])?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {s}: {loss}");
            losses.push(loss);
            self.state = out[..3 * p].to_vec();
            if log_every > 0 && s % log_every == 0 {
                println!(
                    "[{}] step {s:>5}  loss {loss:.4}  ({:.2} s/step)",
                    self.recipe,
                    t0.elapsed().as_secs_f64() / s as f64
                );
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let tokens_per_s = (steps * self.batch * self.seq) as f64 / wall_s;
        Ok(TrainOutcome { recipe: self.recipe.clone(), losses, steps, wall_s, tokens_per_s })
    }
}

impl TrainDriver for AotTrainer {
    fn recipe(&self) -> &str {
        &self.recipe
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize) -> Result<TrainOutcome> {
        AotTrainer::run(self, corpus, steps, log_every)
    }
}
