//! Versioned checkpoint/restore for [`NativeTrainer`] — the
//! crash-recovery half of the fault story.
//!
//! A checkpoint is everything the step loop is a pure function of: the
//! **f32 masters** (embed, head, router, per-expert w1/w3/w2 — the FP8
//! layouts are *not* stored; `PreparedWeights::requantize_from_masters`
//! regenerates them bit-identically, which is the paper's own
//! master-sourced weight-cast discipline doing double duty as the
//! restore path), the **optimizer state** (t, m, v), the completed step
//! count, and the **corpus stream state** (xoshiro256** words + the
//! order-2 Markov context). Restoring all of it makes
//! resume-after-crash **bitwise identical** to the uninterrupted run —
//! `tests/prop_fault.rs` pins the property.
//!
//! **Wire format**: one `runs/`-schema JSON document
//! ([`Json::run_doc`]`("checkpoint")` + [`CKPT_VERSION`]) whose payload
//! is guarded by a CRC32 ([`crate::cluster::fault::checksum`]) over the
//! canonical payload rendering — render/parse is byte-stable, so the
//! load path re-renders and compares. Masters and optimizer moments
//! travel as JSON numbers (f32 → f64 → shortest-round-trip text is
//! exact); the RNG words travel as hex strings because u64 does not fit
//! in an f64 mantissa. Every load failure — truncation, bit flip,
//! version skew, shape drift — is a clean schema-versioned `Err`, never
//! a panic (the CLI maps it to exit 2).

use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::cluster::fault::checksum;
use crate::moe::layer::Recipe;
use crate::train::native::train_loop::NativeTrainer;
use crate::train::Corpus;
use crate::util::json::{Json, RUN_SCHEMA_VERSION};
use crate::util::mat::Mat;

/// Version of the checkpoint payload layout (nested inside the unified
/// `runs/` schema header). Bump on incompatible layout changes.
pub const CKPT_VERSION: u64 = 1;

fn mat_json(m: &Mat) -> Json {
    Json::obj()
        .set("rows", m.rows)
        .set("cols", m.cols)
        .set("data", Json::Arr(m.data.iter().map(|&v| Json::Num(v as f64)).collect()))
}

fn mat_from(j: Option<&Json>, what: &str) -> Result<Mat> {
    let j = j.ok_or_else(|| anyhow!("checkpoint: missing tensor '{what}'"))?;
    let dim = |k: &str| {
        j.get(k)
            .and_then(Json::as_u64)
            .map(|v| v as usize)
            .ok_or_else(|| anyhow!("checkpoint: tensor '{what}' missing {k}"))
    };
    let (rows, cols) = (dim("rows")?, dim("cols")?);
    let data = f32s_from(j.get("data"), what)?;
    ensure!(
        data.len() == rows * cols,
        "checkpoint: tensor '{what}' has {} values, wants {rows}x{cols}",
        data.len()
    );
    Ok(Mat::from_vec(rows, cols, data))
}

fn f32s_json(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f32s_from(j: Option<&Json>, what: &str) -> Result<Vec<f32>> {
    j.and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checkpoint: '{what}' is not a numeric array"))?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| anyhow!("checkpoint: non-numeric value in '{what}'"))
}

fn mats_from(j: Option<&Json>, what: &str, want: usize) -> Result<Vec<Mat>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checkpoint: missing expert tensor list '{what}'"))?;
    ensure!(arr.len() == want, "checkpoint: '{what}' has {} experts, wants {want}", arr.len());
    arr.iter().enumerate().map(|(i, m)| mat_from(Some(m), &format!("{what}[{i}]"))).collect()
}

/// The serialized payload (everything the CRC32 covers).
fn checkpoint_payload(tr: &NativeTrainer, corpus: &Corpus) -> Json {
    let cfg = tr.cfg;
    let (t, m, v) = tr.opt_state();
    let (rng, s1, s2) = corpus.stream_state();
    let experts = |ws: &[Mat]| Json::Arr(ws.iter().map(mat_json).collect());
    let name = match tr.recipe_enum() {
        Recipe::Bf16 => "bf16",
        Recipe::Blockwise => "blockwise",
        Recipe::Fp8Flow => "fp8flow",
    };
    Json::obj()
        .set("recipe", name)
        .set("step", tr.steps_done())
        .set(
            "dims",
            Json::obj()
                .set("vocab", cfg.vocab)
                .set("d_model", cfg.d_model)
                .set("ffn", cfg.ffn)
                .set("n_experts", cfg.n_experts)
                .set("top_k", cfg.top_k),
        )
        .set("embed", mat_json(&tr.embed))
        .set("head", mat_json(&tr.head))
        .set("router", mat_json(&tr.pw.raw.router))
        .set("w1", experts(&tr.pw.raw.w1))
        .set("w3", experts(&tr.pw.raw.w3))
        .set("w2", experts(&tr.pw.raw.w2))
        .set(
            "opt",
            Json::obj()
                .set("t", t)
                .set("m", Json::Arr(m.iter().map(|b| f32s_json(b)).collect()))
                .set("v", Json::Arr(v.iter().map(|b| f32s_json(b)).collect())),
        )
        .set(
            "corpus",
            Json::obj()
                .set("rng", Json::Arr(rng.iter().map(|&w| Json::Str(format!("{w:016x}"))).collect()))
                .set("s1", u64::from(s1))
                .set("s2", u64::from(s2)),
        )
}

/// Serialize a checkpoint of `tr` + `corpus` to a JSON string (the file
/// image [`save_checkpoint`] writes).
pub fn checkpoint_text(tr: &NativeTrainer, corpus: &Corpus) -> String {
    let payload = checkpoint_payload(tr, corpus);
    let crc = checksum(payload.render().as_bytes());
    Json::run_doc("checkpoint")
        .set("ckpt_version", CKPT_VERSION)
        .set("crc32", format!("{crc:08x}"))
        .set("payload", payload)
        .render()
}

/// Write a checkpoint of `tr` + `corpus` to `path`.
pub fn save_checkpoint(tr: &NativeTrainer, corpus: &Corpus, path: &Path) -> Result<()> {
    std::fs::write(path, checkpoint_text(tr, corpus))
        .with_context(|| format!("write checkpoint {}", path.display()))
}

/// Parse + validate a checkpoint file image: schema header, checkpoint
/// version, and the payload CRC32 (re-rendered — render/parse is
/// byte-stable). Returns the validated payload.
pub fn load_checkpoint_text(text: &str) -> Result<Json> {
    let doc = Json::parse(text).map_err(|e| anyhow!("checkpoint parse error: {e}"))?;
    let kind = doc.get("kind").and_then(Json::as_str);
    ensure!(kind == Some("checkpoint"), "not a checkpoint document (kind {kind:?})");
    let sv = doc.get("schema_version").and_then(Json::as_u64);
    ensure!(
        sv == Some(RUN_SCHEMA_VERSION),
        "unsupported schema_version {sv:?} (this build reads {RUN_SCHEMA_VERSION})"
    );
    let cv = doc.get("ckpt_version").and_then(Json::as_u64);
    ensure!(cv == Some(CKPT_VERSION), "unsupported ckpt_version {cv:?} (this build reads {CKPT_VERSION})");
    let recorded = doc
        .get("crc32")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("checkpoint: missing crc32"))?;
    let payload = doc.get("payload").ok_or_else(|| anyhow!("checkpoint: missing payload"))?;
    let actual = format!("{:08x}", checksum(payload.render().as_bytes()));
    ensure!(
        recorded == actual,
        "checkpoint corrupted: payload crc32 {actual} != recorded {recorded}"
    );
    Ok(payload.clone())
}

/// Read + validate the checkpoint at `path` ([`load_checkpoint_text`]).
pub fn load_checkpoint(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read checkpoint {}", path.display()))?;
    load_checkpoint_text(&text).with_context(|| format!("load checkpoint {}", path.display()))
}

/// Restore `tr` + `corpus` from the checkpoint at `path` and return the
/// completed step count. `tr` must be a fresh trainer built with the
/// same `TrainConfig` + recipe the checkpoint was taken from, and
/// `corpus` one built with the same `(vocab, seed, noise_pct)` (its
/// planted table is a pure function of those — only the stream position
/// is stored). The next `step_batch` then continues **bitwise** where
/// the checkpointed run left off: masters are overwritten and the FP8
/// layouts regenerated from them, the optimizer moments and step
/// counter restored, the data stream repositioned. Per-step metrics
/// restart empty (they describe the resumed segment only).
pub fn restore_trainer(tr: &mut NativeTrainer, corpus: &mut Corpus, path: &Path) -> Result<usize> {
    let p = load_checkpoint(path)?;
    let want = match tr.recipe_enum() {
        Recipe::Bf16 => "bf16",
        Recipe::Blockwise => "blockwise",
        Recipe::Fp8Flow => "fp8flow",
    };
    let got = p
        .get("recipe")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("checkpoint: missing recipe"))?;
    ensure!(got == want, "checkpoint recipe '{got}' != trainer recipe '{want}'");

    let cfg = tr.cfg;
    let dims = p.get("dims").ok_or_else(|| anyhow!("checkpoint: missing dims"))?;
    for (key, val) in [
        ("vocab", cfg.vocab),
        ("d_model", cfg.d_model),
        ("ffn", cfg.ffn),
        ("n_experts", cfg.n_experts),
        ("top_k", cfg.top_k),
    ] {
        let have = dims.get(key).and_then(Json::as_u64);
        ensure!(
            have == Some(val as u64),
            "checkpoint dim mismatch: {key} is {have:?}, trainer wants {val}"
        );
    }

    let e = cfg.n_experts;
    let embed = mat_from(p.get("embed"), "embed")?;
    let head = mat_from(p.get("head"), "head")?;
    let router = mat_from(p.get("router"), "router")?;
    let w1 = mats_from(p.get("w1"), "w1", e)?;
    let w3 = mats_from(p.get("w3"), "w3", e)?;
    let w2 = mats_from(p.get("w2"), "w2", e)?;

    let opt = p.get("opt").ok_or_else(|| anyhow!("checkpoint: missing opt state"))?;
    let t = opt
        .get("t")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("checkpoint: missing opt.t"))? as usize;
    let moments = |key: &str| -> Result<Vec<Vec<f32>>> {
        opt.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint: missing opt.{key}"))?
            .iter()
            .enumerate()
            .map(|(i, b)| f32s_from(Some(b), &format!("opt.{key}[{i}]")))
            .collect()
    };
    let (m, v) = (moments("m")?, moments("v")?);

    let step = p
        .get("step")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("checkpoint: missing step"))? as usize;
    ensure!(t == step, "checkpoint: opt.t {t} != step {step} (inconsistent state)");

    let cj = p.get("corpus").ok_or_else(|| anyhow!("checkpoint: missing corpus state"))?;
    let words = cj
        .get("rng")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checkpoint: missing corpus.rng"))?;
    ensure!(words.len() == 4, "checkpoint: corpus.rng wants 4 words, has {}", words.len());
    let mut rng = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        let s = w.as_str().ok_or_else(|| anyhow!("checkpoint: corpus.rng[{i}] not a string"))?;
        rng[i] = u64::from_str_radix(s, 16)
            .map_err(|_| anyhow!("checkpoint: corpus.rng[{i}] '{s}' is not hex"))?;
    }
    let ctx = |key: &str| -> Result<u32> {
        let v = cj
            .get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("checkpoint: missing corpus.{key}"))?;
        u32::try_from(v).map_err(|_| anyhow!("checkpoint: corpus.{key} {v} overflows u32"))
    };
    let (s1, s2) = (ctx("s1")?, ctx("s2")?);

    // every field validated — now mutate (no partially-restored trainer
    // escapes on the error paths above)
    tr.embed = embed;
    tr.head = head;
    tr.pw.raw.router = router;
    tr.pw.raw.w1 = w1;
    tr.pw.raw.w3 = w3;
    tr.pw.raw.w2 = w2;
    let _ = tr.pw.requantize_from_masters();
    tr.restore_opt(t, m, v);
    tr.set_step(step);
    tr.metrics.clear();
    corpus.restore((rng, s1, s2));
    Ok(step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::native::train_loop::TrainConfig;
    use crate::train::native::OptConfig;

    fn small_cfg() -> TrainConfig {
        let (batch, seq) = (2, 4);
        TrainConfig {
            vocab: 8,
            d_model: 4,
            ffn: 4,
            n_experts: 2,
            top_k: 1,
            batch,
            seq,
            capacity: batch * (seq - 1),
            aux_coef: 0.01,
            opt: OptConfig::adamw(0.01),
            ranks: 1,
            threads: 1,
        }
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fp8ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn resume_is_bitwise_identical_to_uninterrupted() {
        let cfg = TrainConfig::tiny();
        let steps = |tr: &mut NativeTrainer, corpus: &mut Corpus, n: usize| -> Vec<u32> {
            (0..n)
                .map(|_| {
                    let toks = corpus.next_batch(cfg.batch, cfg.seq);
                    tr.step_batch(&toks).loss.to_bits()
                })
                .collect()
        };

        // reference: 6 uninterrupted steps
        let mut a = NativeTrainer::new(cfg, Recipe::Fp8Flow, 5);
        let mut ca = Corpus::new(cfg.vocab, 5, 10);
        let losses_a = steps(&mut a, &mut ca, 6);

        // crashed run: 3 steps, checkpoint, "crash", restore into a
        // trainer deliberately built from a DIFFERENT seed (restore must
        // overwrite every weight), 3 more steps
        let path = tmp_path("resume.json");
        let mut b = NativeTrainer::new(cfg, Recipe::Fp8Flow, 5);
        let mut cb = Corpus::new(cfg.vocab, 5, 10);
        let head = steps(&mut b, &mut cb, 3);
        save_checkpoint(&b, &cb, &path).expect("save");
        drop((b, cb)); // the crash

        let mut b2 = NativeTrainer::new(cfg, Recipe::Fp8Flow, 999);
        let mut cb2 = Corpus::new(cfg.vocab, 5, 10);
        let step = restore_trainer(&mut b2, &mut cb2, &path).expect("restore");
        assert_eq!(step, 3);
        assert_eq!(b2.steps_done(), 3);
        let tail = steps(&mut b2, &mut cb2, 3);

        let losses_b: Vec<u32> = head.into_iter().chain(tail).collect();
        assert_eq!(losses_a, losses_b, "resumed losses must match bitwise");
        assert_eq!(a.embed.data, b2.embed.data, "masters must match bitwise");
        assert_eq!(a.head.data, b2.head.data);
        assert_eq!(a.pw.w1_t[0].data, b2.pw.w1_t[0].data, "FP8 layouts must match");
        assert_eq!(a.pw.w1_t[0].sexp, b2.pw.w1_t[0].sexp);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn restore_rejects_mismatched_trainer() {
        let cfg = small_cfg();
        let tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, 1);
        let corpus = Corpus::new(cfg.vocab, 1, 10);
        let text = checkpoint_text(&tr, &corpus);
        let path = tmp_path("mismatch.json");
        std::fs::write(&path, &text).unwrap();

        // wrong recipe
        let mut wrong = NativeTrainer::new(cfg, Recipe::Bf16, 1);
        let mut c = Corpus::new(cfg.vocab, 1, 10);
        let err = restore_trainer(&mut wrong, &mut c, &path).unwrap_err();
        assert!(err.to_string().contains("recipe"), "{err}");

        // wrong dims
        let mut cfg2 = cfg;
        cfg2.n_experts = 4;
        let mut wrong = NativeTrainer::new(cfg2, Recipe::Fp8Flow, 1);
        let err = restore_trainer(&mut wrong, &mut c, &path).unwrap_err();
        assert!(err.to_string().contains("dim mismatch"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_skew_is_a_clean_error() {
        let cfg = small_cfg();
        let tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, 2);
        let corpus = Corpus::new(cfg.vocab, 2, 10);
        let text = checkpoint_text(&tr, &corpus);
        let skew = text.replacen("\"ckpt_version\":1", "\"ckpt_version\":99", 1);
        assert!(load_checkpoint_text(&skew).unwrap_err().to_string().contains("ckpt_version"));
        let skew = text.replacen("\"schema_version\":1", "\"schema_version\":99", 1);
        assert!(load_checkpoint_text(&skew).unwrap_err().to_string().contains("schema_version"));
        let other = text.replacen("\"kind\":\"checkpoint\"", "\"kind\":\"train\"", 1);
        assert!(load_checkpoint_text(&other).unwrap_err().to_string().contains("kind"));
    }

    #[test]
    fn every_truncation_and_byte_flip_is_a_clean_error() {
        // the satellite fuzz property: a small but complete checkpoint,
        // mutated at EVERY byte offset, must always load to Err — never
        // a panic, never silently-accepted corrupt state
        let cfg = small_cfg();
        let mut tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, 3);
        let mut corpus = Corpus::new(cfg.vocab, 3, 10);
        let toks = corpus.next_batch(cfg.batch, cfg.seq);
        let _ = tr.step_batch(&toks); // non-trivial opt state
        let text = checkpoint_text(&tr, &corpus);
        let pristine = load_checkpoint_text(&text).expect("pristine image must load").render();

        let bytes = text.as_bytes();
        for cut in 0..bytes.len() {
            let truncated = std::str::from_utf8(&bytes[..cut]).expect("ascii image");
            assert!(
                load_checkpoint_text(truncated).is_err(),
                "truncation at byte {cut} must be detected"
            );
        }
        for (i, &b) in bytes.iter().enumerate() {
            let mut mutant = bytes.to_vec();
            mutant[i] = b ^ 0x01; // ASCII image stays ASCII under bit-0 flips
            let mutant = String::from_utf8(mutant).expect("ascii image");
            // Either the mutation is detected, or it was value-silent (a
            // ±1 flip in the last digit of a 17-digit float repr can
            // round to the SAME f64, re-render identically, and pass the
            // CRC — that is acceptance of an identical state, not of
            // corruption) — in which case the loaded payload must be
            // byte-for-byte the pristine one.
            if let Ok(p) = load_checkpoint_text(&mutant) {
                assert_eq!(
                    p.render(),
                    pristine,
                    "bit flip at byte {i} ('{}' -> '{}') accepted a CHANGED state",
                    b as char,
                    (b ^ 0x01) as char
                );
            }
        }
    }
}
