//! The native step loop ([`NativeTrainer`]): loss, LR schedule, the
//! per-step cast audit, and the executed Fig. 6 three-recipe convergence
//! run.
//!
//! One step:
//!
//! ```text
//! fwd   embed → stashing MoE forward (live routing) → residual → head
//!       → softmax cross-entropy (+ λ·aux load-balancing loss)
//! bwd   head/residual grads → MoE backward WITH the router path
//!       (moe_backward_with_router; EP-sharded: ep_exec::ep_train_step)
//! opt   AdamW/SGD over every f32 master → requantize_from_masters
//!       (FP8 layouts regenerated from the masters — 0 requants)
//! ```
//!
//! [`TrainMetrics`] measures each step: per-stage seconds and the full
//! cast audit — fwd casts + bwd casts stay at the Fig. 2 headline (one
//! entry quantization each way for Fp8Flow) and the optimizer adds zero
//! requantizations, per `tests/prop_train.rs`.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::exec;
use crate::moe::backward::{
    forward_stash, mat_add_assign, moe_backward_with_router_threads, FwdStash, MoeGrads,
};
use crate::moe::layer::{PreparedWeights, Recipe};
use crate::train::native::model::{embed_grad, embed_rows, next_token_pairs, NativeLm};
use crate::train::native::opt::{OptConfig, Optimizer};
use crate::train::{Corpus, TrainDriver, TrainOutcome};
use crate::util::json::Json;
use crate::util::mat::Mat;

/// Shape + hyperparameters of one native training run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Per-expert FFN hidden size.
    pub ffn: usize,
    /// Expert count.
    pub n_experts: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Rows per step.
    pub batch: usize,
    /// Tokens per row.
    pub seq: usize,
    /// Per-expert row budget of the dispatched buffer. The named configs
    /// set it to [`Self::positions`] so no token is ever capacity-dropped
    /// — convergence differences stay attributable to numerics.
    pub capacity: usize,
    /// Aux load-balancing loss coefficient (λ).
    pub aux_coef: f32,
    /// Optimizer hyperparameters.
    pub opt: OptConfig,
    /// Simulated EP ranks for the training step (1 = single-rank;
    /// bit-identical either way — `tests/prop_train.rs`).
    pub ranks: usize,
    /// Worker budget for the backward kernels (0 = auto).
    pub threads: usize,
}

impl TrainConfig {
    /// The Fig. 6 testbed config: top-1 routing, so the executed per-step
    /// cast audit is exactly the paper's headline 2 (one entry cast per
    /// direction).
    pub fn tiny() -> TrainConfig {
        let (batch, seq) = (8, 16);
        TrainConfig {
            vocab: 64,
            d_model: 32,
            ffn: 32,
            n_experts: 4,
            top_k: 1,
            batch,
            seq,
            capacity: batch * (seq - 1),
            aux_coef: 0.01,
            opt: OptConfig::adamw(0.01),
            ranks: 1,
            threads: 0,
        }
    }

    /// A wider config with top-2 routing (the gate gradient is live, not
    /// just the aux path).
    pub fn small() -> TrainConfig {
        let (batch, seq) = (8, 32);
        TrainConfig {
            vocab: 256,
            d_model: 64,
            ffn: 64,
            n_experts: 8,
            top_k: 2,
            batch,
            seq,
            capacity: batch * (seq - 1),
            aux_coef: 0.01,
            opt: OptConfig::adamw(0.01),
            ranks: 1,
            threads: 0,
        }
    }

    /// A named preset (`tiny` / `small`).
    pub fn named(name: &str) -> Option<TrainConfig> {
        match name {
            "tiny" => Some(TrainConfig::tiny()),
            "small" => Some(TrainConfig::small()),
            _ => None,
        }
    }

    /// Next-token positions per step (= tokens entering the MoE layer).
    pub fn positions(&self) -> usize {
        self.batch * (self.seq - 1)
    }
}

/// Everything one optimization step measured — the per-step row of the
/// Fig. 6 audit table.
#[derive(Clone, Copy, Debug)]
pub struct TrainMetrics {
    /// 1-based step index.
    pub step: usize,
    /// Total loss (CE + λ·aux).
    pub loss: f32,
    /// Cross-entropy part of the loss.
    pub ce: f32,
    /// Load-balancing aux loss (pre-lambda).
    pub aux: f32,
    /// Learning rate applied this step.
    pub lr: f32,
    /// Executed explicit casts, forward pass (entry quantization only for
    /// Fp8Flow).
    pub casts_fwd: usize,
    /// Executed explicit casts, backward pass.
    pub casts_bwd: usize,
    /// Requantizations of already-FP8 tensors in the backward (0 for
    /// Fp8Flow, the naive-transpose count for Blockwise).
    pub requants_bwd: usize,
    /// Master-sourced weight quantizations in the optimizer step.
    pub opt_weight_quants: usize,
    /// Requantizations in the optimizer step — 0 for every recipe on the
    /// native substrate (layouts are regenerated from the f32 masters).
    pub opt_requants: usize,
    /// Forward wall-clock seconds.
    pub fwd_s: f64,
    /// Backward wall-clock seconds.
    pub bwd_s: f64,
    /// Optimizer wall-clock seconds.
    pub opt_s: f64,
}

impl TrainMetrics {
    /// Serialize one metrics row for `runs/*.json`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("step", self.step)
            .set("loss", self.loss)
            .set("ce", self.ce)
            .set("aux", self.aux)
            .set("lr", self.lr)
            .set("casts_fwd", self.casts_fwd)
            .set("casts_bwd", self.casts_bwd)
            .set("requants_bwd", self.requants_bwd)
            .set("opt_weight_quants", self.opt_weight_quants)
            .set("opt_requants", self.opt_requants)
            .set("fwd_ms", self.fwd_s * 1e3)
            .set("bwd_ms", self.bwd_s * 1e3)
            .set("opt_ms", self.opt_s * 1e3)
    }
}

/// The native training driver: masters in f32 (`embed`, `head`,
/// `pw.raw`), per-recipe FP8 layouts in `pw`, optimizer state in `opt`.
pub struct NativeTrainer {
    /// Run configuration.
    pub cfg: TrainConfig,
    recipe: Recipe,
    name: String,
    /// f32 master embedding table `[vocab, d]`.
    pub embed: Mat,
    /// f32 master output head `[d, vocab]`.
    pub head: Mat,
    /// MoE weights: f32 masters plus per-recipe FP8 layouts.
    pub pw: PreparedWeights,
    opt: Optimizer,
    step: usize,
    /// Per-step measurements of every step taken so far.
    pub metrics: Vec<TrainMetrics>,
}

impl NativeTrainer {
    /// Deterministic init from `seed`: the same f32 masters for every
    /// recipe (quantized per-recipe afterwards), so loss curves differ by
    /// numerics only — the Fig. 6 premise.
    pub fn new(cfg: TrainConfig, recipe: Recipe, seed: u64) -> NativeTrainer {
        assert!(cfg.top_k >= 1 && cfg.top_k <= cfg.n_experts, "bad top_k");
        assert!(cfg.ranks >= 1 && cfg.n_experts >= cfg.ranks, "bad ranks");
        assert!(cfg.seq >= 2, "need at least two positions per row");
        let lm = NativeLm::init(cfg.vocab, cfg.d_model, cfg.ffn, cfg.n_experts, seed);
        let name = match recipe {
            Recipe::Bf16 => "bf16",
            Recipe::Blockwise => "blockwise",
            Recipe::Fp8Flow => "fp8flow",
        };
        NativeTrainer {
            cfg,
            recipe,
            name: name.to_string(),
            embed: lm.embed,
            head: lm.head,
            pw: PreparedWeights::new(lm.moe, recipe),
            opt: Optimizer::new(cfg.opt),
            step: 0,
            metrics: Vec::new(),
        }
    }

    /// The recipe being trained.
    pub fn recipe_enum(&self) -> Recipe {
        self.recipe
    }

    /// Completed step count.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    // Checkpoint plumbing (`train::native::checkpoint`): the optimizer
    // and step counter stay private; these views exist so the checkpoint
    // module can snapshot/restore them without widening the public API.
    pub(crate) fn opt_state(&self) -> (usize, &[Vec<f32>], &[Vec<f32>]) {
        self.opt.state()
    }

    pub(crate) fn restore_opt(&mut self, t: usize, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) {
        self.opt.restore(t, m, v);
    }

    pub(crate) fn set_step(&mut self, step: usize) {
        self.step = step;
    }

    /// One optimization step on a `[batch, seq]` token grid. Dispatches
    /// to the EP-sharded step when `cfg.ranks > 1` (bit-identical).
    pub fn step_batch(&mut self, tokens: &[i32]) -> TrainMetrics {
        if self.cfg.ranks > 1 {
            crate::cluster::ep_exec::ep_train_step(self, tokens)
        } else {
            let threads = self.cfg.threads;
            self.step_with_backward(tokens, |stash, pw, dy, aux| {
                let t = if threads == 0 { exec::threads() } else { threads };
                moe_backward_with_router_threads(stash, pw, dy, aux, t)
            })
        }
    }

    /// The step core, parameterized over the MoE-layer backward — the
    /// single-rank and EP-sharded steps differ ONLY in the closure passed
    /// here (`cluster::ep_exec::ep_train_step` supplies the sharded one),
    /// which is what makes their bit-identity an inheritance from the
    /// backward's rather than a fresh proof obligation.
    pub fn step_with_backward(
        &mut self,
        tokens: &[i32],
        moe_bwd: impl FnOnce(&FwdStash, &PreparedWeights, &Mat, f32) -> MoeGrads,
    ) -> TrainMetrics {
        let cfg = self.cfg;
        let (inputs, targets) = next_token_pairs(tokens, cfg.batch, cfg.seq);

        let sk = self.step; // 0-based index of the step being taken
        // ---- forward ----
        let tf = Instant::now();
        let sp = crate::obs::enabled().then(|| {
            crate::obs::span(format!("fwd s{sk}"), crate::obs::SpanMeta::stage("fwd").step(sk))
        });
        let x = embed_rows(&self.embed, &inputs);
        let stash = forward_stash(&x, &self.pw, cfg.top_k, cfg.capacity);
        let mut z = stash.y.clone();
        mat_add_assign(&mut z, &x);
        let logits = z.matmul(&self.head);
        let (ce, dlogits) = crate::train::native::model::softmax_xent(&logits, &targets);
        let aux = stash.aux_loss;
        let loss = ce + cfg.aux_coef * aux;
        drop(sp);
        let fwd_s = tf.elapsed().as_secs_f64();

        // ---- backward ----
        let tb = Instant::now();
        let sp = crate::obs::enabled().then(|| {
            crate::obs::span(format!("bwd s{sk}"), crate::obs::SpanMeta::stage("bwd").step(sk))
        });
        let dhead = z.transpose().matmul(&dlogits);
        let dz = dlogits.matmul(&self.head.transpose());
        let grads = moe_bwd(&stash, &self.pw, &dz, cfg.aux_coef);
        let d_router = grads
            .d_router
            .as_ref()
            .expect("native training step needs the router-aware backward");
        // residual: dL/dx = MoE dx (incl. router path) + the skip branch
        let mut dx = grads.dx.clone();
        mat_add_assign(&mut dx, &dz);
        let dembed = embed_grad(cfg.vocab, &inputs, &dx);
        drop(sp);
        let bwd_s = tb.elapsed().as_secs_f64();

        // ---- optimizer: masters update, then ONE quantization per FP8
        // layout straight from the masters ----
        let to = Instant::now();
        let sp = crate::obs::enabled().then(|| {
            crate::obs::span(format!("opt s{sk}"), crate::obs::SpanMeta::stage("opt").step(sk))
        });
        let mut params: Vec<&mut Mat> = vec![&mut self.embed, &mut self.head];
        params.push(&mut self.pw.raw.router);
        params.extend(self.pw.raw.w1.iter_mut());
        params.extend(self.pw.raw.w3.iter_mut());
        params.extend(self.pw.raw.w2.iter_mut());
        let mut grad_refs: Vec<&Mat> = vec![&dembed, &dhead, d_router];
        grad_refs.extend(grads.dw1.iter());
        grad_refs.extend(grads.dw3.iter());
        grad_refs.extend(grads.dw2.iter());
        let lr = self.opt.step(&mut params, &grad_refs);
        let prep = self.pw.requantize_from_masters();
        drop(sp);
        let opt_s = to.elapsed().as_secs_f64();

        self.step += 1;
        let m = TrainMetrics {
            step: self.step,
            loss,
            ce,
            aux,
            lr,
            casts_fwd: stash.cast_ops,
            casts_bwd: grads.stats.casts,
            requants_bwd: grads.stats.requants,
            opt_weight_quants: prep.weight_quants,
            opt_requants: prep.requants,
            fwd_s,
            bwd_s,
            opt_s,
        };
        self.metrics.push(m);
        m
    }

    /// Run `steps` optimization steps against `corpus`.
    pub fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize) -> Result<TrainOutcome> {
        let (b, s) = (self.cfg.batch, self.cfg.seq);
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for k in 1..=steps {
            let tokens = corpus.next_batch(b, s);
            let m = self.step_batch(&tokens);
            ensure!(m.loss.is_finite(), "loss diverged at step {k}: {}", m.loss);
            losses.push(m.loss);
            if log_every > 0 && k % log_every == 0 {
                println!(
                    "[{}] step {k:>5}  loss {:.4}  (ce {:.4} aux {:.3}, lr {:.4}, \
                     casts {}+{} req {}, {:.1} ms/step)",
                    self.name,
                    m.loss,
                    m.ce,
                    m.aux,
                    m.lr,
                    m.casts_fwd,
                    m.casts_bwd,
                    m.requants_bwd,
                    t0.elapsed().as_secs_f64() / k as f64 * 1e3
                );
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let tokens_per_s = (steps * b * s) as f64 / wall_s.max(1e-12);
        Ok(TrainOutcome {
            recipe: self.name.clone(),
            losses,
            steps,
            wall_s,
            tokens_per_s,
        })
    }

    /// Aggregate run document: outcome + the per-step audit totals and
    /// stage seconds (written to `runs/train_<recipe>.json`).
    pub fn report_json(&self, outcome: &TrainOutcome) -> Json {
        let n = self.metrics.len().max(1);
        let sum = |f: fn(&TrainMetrics) -> f64| self.metrics.iter().map(f).sum::<f64>();
        let last = self.metrics.last();
        Json::run_doc("train")
            .set("outcome", outcome.to_json())
            .set("ranks", self.cfg.ranks)
            .set("top_k", self.cfg.top_k)
            .set("n_experts", self.cfg.n_experts)
            .set("final_loss", outcome.tail_mean(10))
            .set("casts_fwd_per_step", last.map_or(0, |m| m.casts_fwd))
            .set("casts_bwd_per_step", last.map_or(0, |m| m.casts_bwd))
            .set("requants_bwd_per_step", last.map_or(0, |m| m.requants_bwd))
            .set("opt_weight_quants_per_step", last.map_or(0, |m| m.opt_weight_quants))
            .set("opt_requants_per_step", last.map_or(0, |m| m.opt_requants))
            .set("fwd_ms_mean", sum(|m| m.fwd_s) / n as f64 * 1e3)
            .set("bwd_ms_mean", sum(|m| m.bwd_s) / n as f64 * 1e3)
            .set("opt_ms_mean", sum(|m| m.opt_s) / n as f64 * 1e3)
    }
}

impl TrainDriver for NativeTrainer {
    fn recipe(&self) -> &str {
        &self.name
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.cfg.batch, self.cfg.seq)
    }

    fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize) -> Result<TrainOutcome> {
        NativeTrainer::run(self, corpus, steps, log_every)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_configs_resolve() {
        assert!(TrainConfig::named("tiny").is_some());
        assert!(TrainConfig::named("small").is_some());
        assert!(TrainConfig::named("huge").is_none());
        let t = TrainConfig::tiny();
        assert_eq!(t.positions(), 120);
        assert_eq!(t.capacity, t.positions(), "tiny must never capacity-drop");
        assert_eq!(t.top_k, 1, "tiny carries the headline-2 cast audit");
    }

    #[test]
    fn one_step_runs_and_audits_for_every_recipe() {
        let cfg = TrainConfig::tiny();
        let mut corpus = Corpus::new(cfg.vocab, 9, 10);
        let tokens = corpus.next_batch(cfg.batch, cfg.seq);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let mut tr = NativeTrainer::new(cfg, recipe, 9);
            let m = tr.step_batch(&tokens);
            assert!(m.loss.is_finite());
            assert!(m.loss > 0.0);
            assert_eq!(m.step, 1);
            assert_eq!(m.opt_requants, 0, "{recipe:?}: optimizer must never requantize");
            match recipe {
                Recipe::Fp8Flow => {
                    assert_eq!(m.casts_fwd + m.casts_bwd, 2, "the Fig. 2 headline");
                    assert_eq!(m.requants_bwd, 0);
                    assert_eq!(m.opt_weight_quants, 6 * cfg.n_experts);
                }
                Recipe::Blockwise => {
                    assert!(m.requants_bwd > 0, "the executed DQE foil");
                }
                Recipe::Bf16 => {
                    assert_eq!(m.casts_fwd + m.casts_bwd, 0);
                    assert_eq!(m.opt_weight_quants, 0);
                }
            }
        }
    }

    #[test]
    fn identical_seed_and_data_reproduce_bitwise() {
        let cfg = TrainConfig::tiny();
        let run = || {
            let mut tr = NativeTrainer::new(cfg, Recipe::Fp8Flow, 3);
            let mut corpus = Corpus::new(cfg.vocab, 3, 10);
            let mut out = Vec::new();
            for _ in 0..3 {
                let toks = corpus.next_batch(cfg.batch, cfg.seq);
                out.push(tr.step_batch(&toks).loss.to_bits());
            }
            (out, tr.embed.data, tr.pw.w1_t[0].data.clone())
        };
        assert_eq!(run(), run(), "the step must be a pure function of seed + data");
    }
}
