//! The **native training subsystem**: the whole loop — forward, router
//! backward, FP8-consistent optimizer step — on the in-repo substrate,
//! zero AOT artifacts.
//!
//! * [`opt`] — SGD-momentum / AdamW over the f32 master weights, with an
//!   LR warmup schedule; the step ends in
//!   `PreparedWeights::requantize_from_masters`, the paper's weight-cast
//!   discipline (each FP8 layout is one quantization from the master —
//!   zero requantization of FP8 data, audited against
//!   `dataflow::variants::build_train_step`).
//! * [`model`] — the tiny MoE language model (embedding → MoE layer with
//!   residual → output head → cross-entropy); everything outside the MoE
//!   layer stays f32, matching the paper's high-precision non-expert
//!   parts.
//! * [`loop`](self::train_loop) — [`NativeTrainer`]: the step loop, the
//!   per-step [`TrainMetrics`] cast audit (fwd + bwd + optimizer), and
//!   the Fig. 6 three-recipe convergence run.
//!
//! * [`checkpoint`] — versioned save/restore of the full loop state
//!   (f32 masters + optimizer + RNG streams) with CRC-guarded payloads;
//!   resume-after-crash is bitwise identical to the uninterrupted run.
//!
//! The EP-sharded form of the step lives in
//! [`crate::cluster::ep_exec::ep_train_step`] and is bit-identical to the
//! single-rank loop for any rank count (`tests/prop_train.rs`).

pub mod checkpoint;
pub mod model;
pub mod opt;
#[path = "loop.rs"]
pub mod train_loop;

pub use checkpoint::{load_checkpoint, restore_trainer, save_checkpoint, CKPT_VERSION};
pub use model::NativeLm;
pub use opt::{OptAlgo, OptConfig, Optimizer};
pub use train_loop::{NativeTrainer, TrainConfig, TrainMetrics};
