//! Optimizers over f32 master weights (the update half of the training
//! step; the other half — casting the updated masters back to FP8
//! layouts — is `PreparedWeights::requantize_from_masters`).
//!
//! Deterministic by construction: parameters are visited in a fixed
//! order, element updates are straight-line f32 (no reductions), so the
//! update is bit-identical across thread budgets and EP rank counts —
//! the "replicated optimizer step" of the EP-sharded training step is
//! simply this step executed once on the (identical) reduced gradients.
//!
//! `tests/prop_train.rs` pins both algorithms to closed-form scalar
//! references.

use crate::util::mat::Mat;

/// Update rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptAlgo {
    /// `buf = μ·buf + g;  p -= lr·(buf + wd·p)`
    SgdMomentum { momentum: f32 },
    /// Decoupled weight decay Adam:
    /// `m = β1·m + (1−β1)·g;  v = β2·v + (1−β2)·g²;`
    /// `p -= lr·(m̂/(√v̂ + ε) + wd·p)` with bias-corrected `m̂`, `v̂`.
    AdamW { beta1: f32, beta2: f32, eps: f32 },
}

/// Optimizer hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct OptConfig {
    /// Update rule.
    pub algo: OptAlgo,
    /// Peak learning rate (after warmup).
    pub lr: f32,
    /// Decoupled weight decay coefficient.
    pub weight_decay: f32,
    /// Linear warmup steps (0 = none); constant `lr` afterwards.
    pub warmup: usize,
}

impl OptConfig {
    /// The convergence-run default: AdamW, the Fig. 6 hyperparameters.
    pub fn adamw(lr: f32) -> OptConfig {
        OptConfig {
            algo: OptAlgo::AdamW { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            lr,
            weight_decay: 0.01,
            warmup: 5,
        }
    }

    /// SGD-momentum config (ablation baseline).
    pub fn sgd(lr: f32, momentum: f32) -> OptConfig {
        OptConfig { algo: OptAlgo::SgdMomentum { momentum }, lr, weight_decay: 0.0, warmup: 5 }
    }
}

/// Stateful optimizer over an ordered parameter list. State slots are
/// lazily sized on the first step and keyed by position, so callers must
/// pass the same tensors in the same order every step.
pub struct Optimizer {
    /// Hyperparameters.
    pub cfg: OptConfig,
    /// Completed steps (1-based inside the update math).
    t: usize,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Optimizer {
    /// Fresh optimizer state for `cfg`.
    pub fn new(cfg: OptConfig) -> Optimizer {
        Optimizer { cfg, t: 0, m: Vec::new(), v: Vec::new() }
    }

    /// Completed step count.
    pub fn steps_done(&self) -> usize {
        self.t
    }

    /// Checkpoint view of the full state: `(t, m, v)` (first/second
    /// moment buffers in parameter order; `v` is empty for SGD).
    pub fn state(&self) -> (usize, &[Vec<f32>], &[Vec<f32>]) {
        (self.t, &self.m, &self.v)
    }

    /// Restore a [`Optimizer::state`] snapshot. The buffers are keyed by
    /// position, so the caller must resume with the same parameter list
    /// order it checkpointed with; the next [`Optimizer::step`] then
    /// continues bitwise (shape drift is caught by the step asserts).
    pub fn restore(&mut self, t: usize, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) {
        self.t = t;
        self.m = m;
        self.v = v;
    }

    /// Learning rate at (1-based) step `step`: linear warmup to `lr`,
    /// constant afterwards.
    pub fn lr_at(&self, step: usize) -> f32 {
        if self.cfg.warmup == 0 || step >= self.cfg.warmup {
            self.cfg.lr
        } else {
            self.cfg.lr * (step as f32 / self.cfg.warmup as f32)
        }
    }

    /// Apply one update step: `params[i] -= f(grads[i])` under the
    /// configured algorithm. Returns the learning rate used.
    pub fn step(&mut self, params: &mut [&mut Mat], grads: &[&Mat]) -> f32 {
        assert_eq!(params.len(), grads.len(), "param/grad list mismatch");
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0f32; p.data.len()]).collect();
            if matches!(self.cfg.algo, OptAlgo::AdamW { .. }) {
                self.v = params.iter().map(|p| vec![0.0f32; p.data.len()]).collect();
            }
        }
        assert_eq!(self.m.len(), params.len(), "optimizer state/param count drifted");
        self.t += 1;
        let lr = self.lr_at(self.t);
        let wd = self.cfg.weight_decay;
        match self.cfg.algo {
            OptAlgo::SgdMomentum { momentum } => {
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    assert_eq!(p.data.len(), g.data.len(), "param {i} shape drifted");
                    let buf = &mut self.m[i];
                    for ((pv, &gv), bv) in
                        p.data.iter_mut().zip(&g.data).zip(buf.iter_mut())
                    {
                        *bv = momentum * *bv + gv;
                        *pv -= lr * (*bv + wd * *pv);
                    }
                }
            }
            OptAlgo::AdamW { beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    assert_eq!(p.data.len(), g.data.len(), "param {i} shape drifted");
                    let (ms, vs) = (&mut self.m[i], &mut self.v[i]);
                    for (((pv, &gv), mv), vv) in
                        p.data.iter_mut().zip(&g.data).zip(ms.iter_mut()).zip(vs.iter_mut())
                    {
                        *mv = beta1 * *mv + (1.0 - beta1) * gv;
                        *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
                        let mh = *mv / bc1;
                        let vh = *vv / bc2;
                        *pv -= lr * (mh / (vh.sqrt() + eps) + wd * *pv);
                    }
                }
            }
        }
        lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly_then_holds() {
        let o = Optimizer::new(OptConfig::adamw(0.01));
        assert_eq!(o.lr_at(1), 0.01 * (1.0 / 5.0));
        assert_eq!(o.lr_at(4), 0.01 * (4.0 / 5.0));
        assert_eq!(o.lr_at(5), 0.01);
        assert_eq!(o.lr_at(500), 0.01);
        let c = Optimizer::new(OptConfig { warmup: 0, ..OptConfig::adamw(0.02) });
        assert_eq!(c.lr_at(1), 0.02);
    }

    #[test]
    fn state_is_lazily_shaped_and_sticky() {
        let mut o = Optimizer::new(OptConfig::adamw(0.1));
        let mut p = Mat::zeros(2, 3);
        let g = Mat::from_fn(2, 3, |i, j| (i + j) as f32);
        o.step(&mut [&mut p], &[&g]);
        assert_eq!(o.steps_done(), 1);
        assert_eq!(o.m.len(), 1);
        assert_eq!(o.m[0].len(), 6);
        assert_eq!(o.v[0].len(), 6);
    }

    #[test]
    fn sgd_momentum_first_step_is_plain_sgd() {
        let mut o = Optimizer::new(OptConfig { warmup: 0, ..OptConfig::sgd(0.5, 0.9) });
        let mut p = Mat::from_vec(1, 2, vec![1.0, -2.0]);
        let g = Mat::from_vec(1, 2, vec![0.2, -0.4]);
        o.step(&mut [&mut p], &[&g]);
        assert_eq!(p.data, vec![1.0 - 0.5 * 0.2, -2.0 + 0.5 * 0.4]);
    }
}
