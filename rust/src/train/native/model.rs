//! The native LM the convergence run trains: embedding → one MoE layer
//! with a residual connection → output head → softmax cross-entropy on
//! next-token prediction.
//!
//! Only the MoE layer is recipe-quantized; embedding, router and head
//! stay f32 (the paper keeps the non-expert parts in high precision).
//! All kernels here are straight-line serial f32 — deterministic, so the
//! training step's bit-identity contracts (threads, EP ranks) hinge only
//! on the already-proven MoE kernels.

use crate::moe::layer::MoeWeights;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Master (f32) parameters of the native LM.
pub struct NativeLm {
    /// `[vocab, d]` token embedding.
    pub embed: Mat,
    /// MoE layer masters (router + experts).
    pub moe: MoeWeights,
    /// `[d, vocab]` output projection.
    pub head: Mat,
}

impl NativeLm {
    /// Deterministic init from `seed` — identical masters for every
    /// recipe, so Fig. 6 curves differ by numerics only.
    pub fn init(vocab: usize, d: usize, ffn: usize, experts: usize, seed: u64) -> NativeLm {
        let mut rng = Rng::seed_from(seed);
        let s = 1.0 / (d as f32).sqrt();
        NativeLm {
            embed: Mat::randn(vocab, d, 0.5, &mut rng),
            moe: MoeWeights::random(d, ffn, experts, &mut rng),
            head: Mat::randn(d, vocab, s, &mut rng),
        }
    }

    /// Vocabulary size (embedding row count).
    pub fn vocab(&self) -> usize {
        self.embed.rows
    }
}

/// Gather embedding rows for a token id sequence: `[tokens, d]`.
pub fn embed_rows(embed: &Mat, tokens: &[usize]) -> Mat {
    let d = embed.cols;
    let mut out = Mat::zeros(tokens.len(), d);
    for (t, &id) in tokens.iter().enumerate() {
        assert!(id < embed.rows, "token id {id} outside vocab {}", embed.rows);
        out.data[t * d..(t + 1) * d].copy_from_slice(embed.row(id));
    }
    out
}

/// Embedding backward: scatter-add the per-position input gradients back
/// onto the rows of the embedding table (fixed position order — part of
/// the step's bit-identity contract).
pub fn embed_grad(vocab: usize, tokens: &[usize], dx: &Mat) -> Mat {
    assert_eq!(tokens.len(), dx.rows);
    let d = dx.cols;
    let mut out = Mat::zeros(vocab, d);
    for (t, &id) in tokens.iter().enumerate() {
        for j in 0..d {
            out.data[id * d + j] += dx.data[t * d + j];
        }
    }
    out
}

/// Mean softmax cross-entropy and its logits gradient in one pass.
///
/// Loss is accumulated in f64 (the per-token `ln Z − z_target` terms are
/// f32); the returned gradient is `(softmax(logits) − onehot) / T`.
pub fn softmax_xent(logits: &Mat, targets: &[usize]) -> (f32, Mat) {
    let t_n = logits.rows;
    let v = logits.cols;
    assert_eq!(targets.len(), t_n, "targets/logits mismatch");
    let mut dlogits = Mat::zeros(t_n, v);
    let mut loss = 0.0f64;
    let inv_t = 1.0 / t_n as f32;
    for t in 0..t_n {
        let row = logits.row(t);
        let tgt = targets[t];
        assert!(tgt < v, "target {tgt} outside vocab {v}");
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let out = &mut dlogits.data[t * v..(t + 1) * v];
        let mut z = 0.0f32;
        for (o, &x) in out.iter_mut().zip(row) {
            *o = (x - mx).exp();
            z += *o;
        }
        loss += (z.ln() - (row[tgt] - mx)) as f64;
        for o in out.iter_mut() {
            *o = *o / z * inv_t;
        }
        out[tgt] -= inv_t;
    }
    ((loss / t_n as f64) as f32, dlogits)
}

/// Split a `[batch, seq]` token grid into next-token (input, target)
/// pairs: per row, positions `0..seq-1` predict positions `1..seq`.
pub fn next_token_pairs(tokens: &[i32], batch: usize, seq: usize) -> (Vec<usize>, Vec<usize>) {
    assert_eq!(tokens.len(), batch * seq, "token grid shape mismatch");
    assert!(seq >= 2, "need at least two positions per row");
    let mut inputs = Vec::with_capacity(batch * (seq - 1));
    let mut targets = Vec::with_capacity(batch * (seq - 1));
    for b in 0..batch {
        for i in 0..seq - 1 {
            inputs.push(tokens[b * seq + i] as usize);
            targets.push(tokens[b * seq + i + 1] as usize);
        }
    }
    (inputs, targets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gradcheck, probe_indices};

    #[test]
    fn embed_gather_scatter_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let embed = Mat::randn(8, 4, 1.0, &mut rng);
        let toks = [3usize, 1, 3, 7];
        let x = embed_rows(&embed, &toks);
        assert_eq!(x.row(0), embed.row(3));
        assert_eq!(x.row(2), embed.row(3));
        // scatter-add of ones counts occurrences
        let dx = Mat::from_fn(4, 4, |_, _| 1.0);
        let g = embed_grad(8, &toks, &dx);
        assert_eq!(g.at(3, 0), 2.0);
        assert_eq!(g.at(1, 0), 1.0);
        assert_eq!(g.at(0, 0), 0.0);
    }

    #[test]
    fn xent_matches_uniform_floor_and_gradchecks() {
        let (t_n, v) = (6, 16);
        let logits = Mat::zeros(t_n, v);
        let targets: Vec<usize> = (0..t_n).map(|t| t % v).collect();
        let (loss, _) = softmax_xent(&logits, &targets);
        assert!((loss - (v as f32).ln()).abs() < 1e-5, "uniform logits → ln V");

        let mut rng = Rng::seed_from(2);
        let logits = Mat::randn(t_n, v, 1.0, &mut rng);
        let (_, dl) = softmax_xent(&logits, &targets);
        // gradcheck: L = mean CE; probe through a scalar output vector
        gradcheck(
            "softmax_xent dlogits",
            |xs| vec![softmax_xent(&Mat::from_vec(t_n, v, xs.to_vec()), &targets).0],
            &logits.data,
            &[1.0],
            &dl.data,
            1e-2,
            1e-2,
            &probe_indices(t_n * v, 12),
        );
    }

    #[test]
    fn xent_gradient_rows_sum_to_zero() {
        let mut rng = Rng::seed_from(3);
        let logits = Mat::randn(5, 8, 2.0, &mut rng);
        let targets = vec![0usize, 3, 7, 2, 5];
        let (_, dl) = softmax_xent(&logits, &targets);
        for t in 0..5 {
            let s: f32 = dl.row(t).iter().sum();
            assert!(s.abs() < 1e-6, "row {t} sums to {s}");
        }
    }

    #[test]
    fn next_token_pairs_shift_within_rows() {
        let toks: Vec<i32> = (0..8).collect();
        let (inp, tgt) = next_token_pairs(&toks, 2, 4);
        assert_eq!(inp, vec![0, 1, 2, 4, 5, 6]);
        assert_eq!(tgt, vec![1, 2, 3, 5, 6, 7]);
    }
}
