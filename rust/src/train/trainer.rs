//! The training loop over an AOT `train_step` executable.

use anyhow::{Context, Result};

use crate::runtime::{literal, Executable, Runtime};
use crate::train::data::Corpus;
use crate::util::json::Json;

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub recipe: String,
    pub losses: Vec<f32>,
    pub steps: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
}

/// Drives `init_<cfg>` + `train_step_<recipe>_<cfg>` from Rust.
pub struct Trainer {
    step_exe: Executable,
    state: Vec<xla::Literal>,
    n_leaves: usize,
    batch: usize,
    seq: usize,
    recipe: String,
}

impl Trainer {
    /// Initialize from artifacts: runs `init_<cfg>` with `seed`.
    pub fn new(rt: &Runtime, cfg: &str, recipe: &str, seed: u32) -> Result<Trainer> {
        let init = rt.load(&format!("init_{cfg}"))?;
        let step_exe = rt.load(&format!("train_step_{recipe}_{cfg}"))?;
        let state = init
            .run(&[literal::u32_scalar(seed)?])
            .context("running init")?;
        anyhow::ensure!(state.len() % 3 == 0, "init output not 3P leaves");
        let n_leaves = state.len() / 3;
        let tok_spec = &step_exe.spec.inputs[3 * n_leaves + 1];
        let (batch, seq) = (tok_spec.shape[0], tok_spec.shape[1]);
        Ok(Trainer { step_exe, state, n_leaves, batch, seq, recipe: recipe.to_string() })
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    /// Run `steps` optimization steps against `corpus`, returning the loss
    /// trajectory. `log_every > 0` prints progress lines.
    pub fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize) -> Result<TrainOutcome> {
        let p = self.n_leaves;
        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for s in 1..=steps {
            let tokens = corpus.next_batch(self.batch, self.seq);
            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * p + 2);
            for lit in self.state.iter().take(3 * p) {
                inputs.push(lit.clone());
            }
            inputs.push(literal::i32_scalar(s as i32)?);
            inputs.push(literal::i32_literal(&[self.batch, self.seq], &tokens)?);
            let out = self.step_exe.run(&inputs).with_context(|| format!("step {s}"))?;
            let loss = literal::to_f32_scalar(&out[3 * p])?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {s}: {loss}");
            losses.push(loss);
            self.state = out[..3 * p].to_vec();
            if log_every > 0 && s % log_every == 0 {
                println!(
                    "[{}] step {s:>5}  loss {loss:.4}  ({:.2} s/step)",
                    self.recipe,
                    t0.elapsed().as_secs_f64() / s as f64
                );
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let tokens_per_s = (steps * self.batch * self.seq) as f64 / wall_s;
        Ok(TrainOutcome { recipe: self.recipe.clone(), losses, steps, wall_s, tokens_per_s })
    }
}

impl TrainOutcome {
    /// Serialize to JSON (written into runs/*.json by the examples/CLI).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("recipe", self.recipe.as_str())
            .set("steps", self.steps)
            .set("wall_s", self.wall_s)
            .set("tokens_per_s", self.tokens_per_s)
            .set("losses", self.losses.iter().map(|&l| l as f64).collect::<Vec<f64>>())
    }

    /// Mean loss over the final `n` steps (the convergence comparison stat).
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = self.losses.len().saturating_sub(n);
        let tail = &self.losses[k..];
        tail.iter().map(|&l| l as f64).sum::<f64>() / tail.len().max(1) as f64
    }
}
