//! Training drivers — the Fig. 6 convergence experiment's engine.
//!
//! Two drivers share one API ([`TrainDriver`]):
//!
//! * [`native::NativeTrainer`] — the **native** subsystem ([`native`]):
//!   loss, router/gate backward, FP8-consistent optimizer and the step
//!   loop all run on the in-repo substrate. No artifacts needed; this is
//!   the path that executes the three-recipe Fig. 6 comparison.
//! * [`aot::AotTrainer`] — the AOT path: Rust owns the loop, the compute
//!   is the `train_step_<recipe>_<cfg>` XLA executable. Requires
//!   `make artifacts` + real `xla` bindings; until then it fails loudly
//!   and points at the native driver.

pub mod aot;
pub mod data;
pub mod native;

pub use aot::AotTrainer;
pub use data::Corpus;
pub use native::{NativeTrainer, TrainConfig, TrainMetrics};

use anyhow::Result;

use crate::util::json::Json;

/// Outcome of a training run (shared by both drivers).
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// Recipe label (`bf16` / `blockwise` / `fp8flow`).
    pub recipe: String,
    /// Per-step total loss.
    pub losses: Vec<f32>,
    /// Steps taken.
    pub steps: usize,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Training throughput.
    pub tokens_per_s: f64,
}

/// One training driver: step loop over a [`Corpus`], loss trajectory out.
/// Both the AOT-artifact path and the native path expose exactly this
/// API, so experiments are written once and run on either engine.
pub trait TrainDriver {
    /// Recipe label (`bf16` / `blockwise` / `fp8flow`).
    fn recipe(&self) -> &str;

    /// `(batch, seq)` token shape one step consumes.
    fn batch_shape(&self) -> (usize, usize);

    /// Run `steps` optimization steps against `corpus`, returning the
    /// loss trajectory. `log_every > 0` prints progress lines.
    fn run(&mut self, corpus: &mut Corpus, steps: usize, log_every: usize)
        -> Result<TrainOutcome>;
}

impl TrainOutcome {
    /// Serialize to JSON (written into runs/*.json by the examples/CLI).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("recipe", self.recipe.as_str())
            .set("steps", self.steps)
            .set("wall_s", self.wall_s)
            .set("tokens_per_s", self.tokens_per_s)
            .set("losses", self.losses.iter().map(|&l| l as f64).collect::<Vec<f64>>())
    }

    /// Mean loss over the final `n` steps (the convergence comparison stat).
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = self.losses.len().saturating_sub(n);
        let tail = &self.losses[k..];
        tail.iter().map(|&l| l as f64).sum::<f64>() / tail.len().max(1) as f64
    }
}
