//! Training driver — the Fig. 6 convergence experiment's engine.
//!
//! Rust owns the loop: data generation, step scheduling, metrics; the
//! compute is the AOT `train_step_<recipe>_<cfg>` executable (L2 graph
//! with L1 kernels inside). Python never runs here.

pub mod data;
pub mod trainer;

pub use data::Corpus;
pub use trainer::{TrainOutcome, Trainer};
