//! Chrome trace-event export and the `trace validate` / `trace summarize`
//! back end.
//!
//! A trace file is one JSON object in the Chrome trace-event **object
//! format** — a `traceEvents` array of complete (`"ph":"X"`) events plus
//! metadata — so `chrome://tracing` and [Perfetto](https://ui.perfetto.dev)
//! load it directly. Extra top-level keys carry the run's structured
//! metrics under the unified `runs/` schema
//! ([`crate::util::json::RUN_SCHEMA_VERSION`]):
//!
//! * `pid` = simulated rank ([`DRIVER_RANK`] renders as the `driver`
//!   pseudo-process), `tid` = lane, `cat` = stage, `ts`/`dur` in µs;
//! * `args` carries the span's step/slot and pipeline chunk;
//! * `counters` is the recorder's monotonic-counter block;
//! * `histograms` summarizes each scalar sample series with exact
//!   quantiles (same pick convention as the serving reporter);
//! * `cross_check` (when the driver ran one) records the live
//!   counters-vs-`analysis::ExecPrediction` comparison — [`validate`]
//!   fails a trace whose cross-check failed.

use crate::obs::recorder::{Counter, CounterTotals, Recorder, DRIVER_RANK};
use crate::util::json::{Json, RUN_SCHEMA_VERSION};

/// Render a counter-totals snapshot as the trace `counters` object.
pub fn counters_json(t: &CounterTotals) -> Json {
    let mut j = Json::obj();
    for c in Counter::ALL {
        j = j.set(c.name(), t[c as usize]);
    }
    j
}

/// Exact quantile over a sorted sample slice (the serving convention:
/// index `round(q · (n-1))`).
fn pick(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn histograms_json(rec: &Recorder) -> Json {
    let mut by_series: Vec<(&'static str, Vec<f64>)> = Vec::new();
    for (name, v) in rec.samples() {
        match by_series.iter_mut().find(|(n, _)| *n == name) {
            Some((_, vs)) => vs.push(v),
            None => by_series.push((name, vec![v])),
        }
    }
    let mut j = Json::obj();
    for (name, mut vs) in by_series {
        vs.sort_by(f64::total_cmp);
        let n = vs.len();
        let mean = vs.iter().sum::<f64>() / n as f64;
        j = j.set(
            name,
            Json::obj()
                .set("count", n)
                .set("mean", mean)
                .set("min", vs[0])
                .set("p50", pick(&vs, 0.50))
                .set("p99", pick(&vs, 0.99))
                .set("max", vs[n - 1]),
        );
    }
    j
}

/// Build the full trace document for one recorded run. `command` is the
/// CLI subcommand that produced it; `config` is its shape/knob object
/// (consumed by `calibrate`). Append run-specific blocks (e.g.
/// `cross_check`) with [`Json::set`] before writing.
pub fn trace_doc(command: &str, config: Json, rec: &Recorder) -> Json {
    let spans = rec.spans();
    let mut events = Vec::with_capacity(spans.len() + 8);
    // metadata: name each (pid, tid) pair once, pids once
    let mut pids: Vec<u32> = Vec::new();
    let mut threads: Vec<(u32, u32)> = Vec::new();
    for s in &spans {
        if !pids.contains(&s.meta.rank) {
            pids.push(s.meta.rank);
        }
        if !threads.contains(&(s.meta.rank, s.meta.lane)) {
            threads.push((s.meta.rank, s.meta.lane));
        }
    }
    pids.sort_unstable();
    threads.sort_unstable();
    for pid in &pids {
        let pname =
            if *pid == DRIVER_RANK { "driver".to_string() } else { format!("rank {pid}") };
        events.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", u64::from(*pid))
                .set("tid", 0u64)
                .set("args", Json::obj().set("name", pname)),
        );
    }
    for (pid, tid) in &threads {
        events.push(
            Json::obj()
                .set("name", "thread_name")
                .set("ph", "M")
                .set("pid", u64::from(*pid))
                .set("tid", u64::from(*tid))
                .set("args", Json::obj().set("name", format!("lane {tid}"))),
        );
    }
    for s in &spans {
        let mut args = Json::obj().set("step", u64::from(s.meta.step));
        if s.meta.chunk >= 0 {
            args = args.set("chunk", s.meta.chunk);
        }
        events.push(
            Json::obj()
                .set("name", s.name.as_str())
                .set("cat", s.meta.stage)
                .set("ph", "X")
                .set("ts", s.t0_s * 1e6)
                .set("dur", s.dur_s().max(0.0) * 1e6)
                .set("pid", u64::from(s.meta.rank))
                .set("tid", u64::from(s.meta.lane))
                .set("args", args),
        );
    }
    Json::run_doc("trace")
        .set("command", command)
        .set("config", config)
        .set("elapsed_s", rec.elapsed_s())
        .set("counters", counters_json(&rec.totals()))
        .set("histograms", histograms_json(rec))
        .set("traceEvents", Json::Arr(events))
}

/// Structured result of validating (and summarizing) a trace or runs
/// document.
#[derive(Clone, Debug)]
pub struct TraceSummary {
    /// Document kind (`trace`, `epshard`, `serve`, …).
    pub kind: String,
    /// Subcommand recorded in the trace (empty for plain runs docs).
    pub command: String,
    /// Complete (`ph:"X"`) events.
    pub n_events: usize,
    /// Distinct simulated ranks (driver pseudo-process excluded).
    pub n_ranks: usize,
    /// Trace extent: max(ts+dur) − min(ts), seconds (0 when eventless).
    pub wall_s: f64,
    /// Per-stage busy seconds (summed span durations), descending.
    pub busy_by_stage: Vec<(String, f64)>,
    /// Counter totals, in catalog order.
    pub counters: Vec<(String, u64)>,
    /// Live cross-check verdict, when the trace carries one.
    pub cross_check_ok: Option<bool>,
}

fn need<'a>(doc: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    doc.get(key).ok_or_else(|| format!("missing `{key}` ({what})"))
}

/// Validate a parsed document against the unified schema. Rejects unknown
/// `schema_version`s, malformed trace events, negative durations,
/// non-integer counters, and traces whose recorded live cross-check
/// failed. Plain `runs/` documents (any non-`trace` kind) validate on
/// the schema header alone.
pub fn validate(doc: &Json) -> Result<TraceSummary, String> {
    let ver = need(doc, "schema_version", "unified runs/trace schema")?
        .as_u64()
        .ok_or("`schema_version` must be a non-negative integer")?;
    if ver != RUN_SCHEMA_VERSION {
        return Err(format!(
            "unknown schema_version {ver} (this binary speaks {RUN_SCHEMA_VERSION})"
        ));
    }
    let kind = need(doc, "kind", "document kind tag")?
        .as_str()
        .ok_or("`kind` must be a string")?
        .to_string();
    let mut summary = TraceSummary {
        kind: kind.clone(),
        command: String::new(),
        n_events: 0,
        n_ranks: 0,
        wall_s: 0.0,
        busy_by_stage: Vec::new(),
        counters: Vec::new(),
        cross_check_ok: None,
    };
    if kind != "trace" {
        return Ok(summary);
    }
    summary.command =
        need(doc, "command", "producing subcommand")?.as_str().unwrap_or("").to_string();

    let counters = need(doc, "counters", "recorder counter block")?
        .as_obj()
        .ok_or("`counters` must be an object")?;
    for (k, v) in counters {
        let n = v.as_u64().ok_or_else(|| format!("counter `{k}` must be a u64"))?;
        summary.counters.push((k.clone(), n));
    }

    let events = need(doc, "traceEvents", "Chrome trace-event array")?
        .as_arr()
        .ok_or("`traceEvents` must be an array")?;
    let mut ranks: Vec<u64> = Vec::new();
    let (mut t_min, mut t_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for (i, ev) in events.iter().enumerate() {
        let at = |k: &str| ev.get(k).ok_or_else(|| format!("event {i}: missing `{k}`"));
        let ph = at("ph")?.as_str().ok_or_else(|| format!("event {i}: `ph` not a string"))?;
        match ph {
            "M" => {} // metadata events carry only name/args
            "X" => {
                at("name")?.as_str().ok_or_else(|| format!("event {i}: unnamed"))?;
                let cat = at("cat")?
                    .as_str()
                    .ok_or_else(|| format!("event {i}: `cat` not a string"))?;
                let ts = at("ts")?
                    .as_f64()
                    .filter(|t| t.is_finite() && *t >= 0.0)
                    .ok_or_else(|| format!("event {i}: bad `ts`"))?;
                let dur = at("dur")?
                    .as_f64()
                    .filter(|d| d.is_finite() && *d >= 0.0)
                    .ok_or_else(|| format!("event {i}: negative or non-finite `dur`"))?;
                let pid =
                    at("pid")?.as_u64().ok_or_else(|| format!("event {i}: bad `pid`"))?;
                at("tid")?.as_u64().ok_or_else(|| format!("event {i}: bad `tid`"))?;
                summary.n_events += 1;
                if pid != u64::from(DRIVER_RANK) && !ranks.contains(&pid) {
                    ranks.push(pid);
                }
                t_min = t_min.min(ts);
                t_max = t_max.max(ts + dur);
                let busy_s = dur / 1e6;
                match summary.busy_by_stage.iter_mut().find(|(c, _)| c == cat) {
                    Some((_, b)) => *b += busy_s,
                    None => summary.busy_by_stage.push((cat.to_string(), busy_s)),
                }
            }
            other => return Err(format!("event {i}: unsupported phase `{other}`")),
        }
    }
    summary.n_ranks = ranks.len();
    if summary.n_events > 0 {
        summary.wall_s = (t_max - t_min) / 1e6;
    }
    summary.busy_by_stage.sort_by(|a, b| b.1.total_cmp(&a.1));

    if let Some(cc) = doc.get("cross_check") {
        let ok = cc
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or("`cross_check` must carry a bool `ok`")?;
        summary.cross_check_ok = Some(ok);
        if !ok {
            return Err("trace records a FAILED live counter cross-check".to_string());
        }
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{self, SpanMeta};

    fn recorded() -> std::sync::Arc<Recorder> {
        let rec = Recorder::new(1);
        {
            let _g = recorder::install(rec.clone());
            {
                let _a = recorder::span("route", SpanMeta::stage("route"));
                let _b = recorder::span("pack r0 c0", SpanMeta::stage("pack").rank(0).chunk(0));
            }
            recorder::count(Counter::CastsFwd, 1);
            recorder::count(Counter::WirePayloadBytes, 4096);
            recorder::sample("latency_s", 0.5);
            recorder::sample("latency_s", 1.5);
        }
        rec
    }

    #[test]
    fn trace_doc_round_trips_and_validates() {
        let rec = recorded();
        let doc = trace_doc("epshard", Json::obj().set("ranks", 2usize), &rec);
        let text = doc.render();
        let back = Json::parse(&text).expect("trace parses");
        let sum = validate(&back).expect("trace validates");
        assert_eq!(sum.kind, "trace");
        assert_eq!(sum.command, "epshard");
        assert_eq!(sum.n_events, 2);
        assert_eq!(sum.n_ranks, 1, "driver pseudo-process not counted");
        assert!(sum.busy_by_stage.iter().any(|(c, _)| c == "route"));
        assert!(sum
            .counters
            .iter()
            .any(|(k, v)| k == "wire_payload_bytes" && *v == 4096));
        let hist = back.get("histograms").and_then(|h| h.get("latency_s")).unwrap();
        assert_eq!(hist.get("count").and_then(Json::as_u64), Some(2));
        assert_eq!(hist.get("mean").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn validate_rejects_unknown_schema_version() {
        let doc = Json::obj().set("schema_version", 999u64).set("kind", "trace");
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("unknown schema_version"), "{err}");
    }

    #[test]
    fn validate_rejects_missing_header_and_bad_events() {
        assert!(validate(&Json::obj()).is_err());
        let doc = Json::run_doc("trace")
            .set("command", "x")
            .set("counters", Json::obj())
            .set(
                "traceEvents",
                Json::Arr(vec![Json::obj().set("ph", "X").set("name", "a")]),
            );
        assert!(validate(&doc).is_err(), "X event without cat/ts/dur must fail");
        let doc = Json::run_doc("trace")
            .set("command", "x")
            .set("counters", Json::obj().set("casts_fwd", -1i64))
            .set("traceEvents", Json::Arr(vec![]));
        assert!(validate(&doc).is_err(), "negative counter must fail");
    }

    #[test]
    fn validate_accepts_plain_runs_docs_by_header() {
        let doc = Json::run_doc("epshard").set("ranks", 2usize);
        let sum = validate(&doc).expect("runs doc validates on header");
        assert_eq!(sum.kind, "epshard");
        assert_eq!(sum.n_events, 0);
    }

    #[test]
    fn validate_fails_a_failed_cross_check() {
        let rec = recorded();
        let doc = trace_doc("epshard", Json::obj(), &rec)
            .set("cross_check", Json::obj().set("ok", false));
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("FAILED"), "{err}");
        let doc2 = trace_doc("epshard", Json::obj(), &recorded())
            .set("cross_check", Json::obj().set("ok", true));
        assert_eq!(validate(&doc2).unwrap().cross_check_ok, Some(true));
    }
}
