//! Fit [`crate::cluster::sim::CostTable`] per-op costs from recorded
//! traces — the back end of the `calibrate` subcommand.
//!
//! Each trace contributes one observation per pipeline stage: the
//! stage's **measured busy seconds** (summed span durations, straight
//! from [`crate::obs::trace::validate`]'s per-`cat` totals) paired with
//! an **analytic op count** for that stage. Wire-bound stages (`pack`,
//! `a2a`, `assemble`, `combine`) take their op counts from the
//! recorder's own byte counters — the very numbers the live cross-check
//! pins against `analysis` — while compute stages take them from
//! `feat_*` keys the driver writes into the trace `config` block
//! (`feat_tokens_routed`, `feat_quant_bytes`, `feat_ffn_flops`).
//!
//! The fit is per-stage scalar least squares through the origin:
//! `cost = Σ busyᵢ·xᵢ / Σ xᵢ²` over all traces, which for a single
//! trace degenerates to the exact ratio `busy / x`. A stage whose op
//! count is zero everywhere (e.g. `quant` in a BF16-only trace) fits to
//! zero rather than poisoning the table with 0/0. Residual rows report
//! `fitted·x − busy` per (trace, stage) so a bad fit is visible in
//! `runs/calibrate.json` instead of silently mispredicting sweeps.

use crate::cluster::sim::CostTable;
use crate::obs::trace::{validate, TraceSummary};
use crate::util::json::Json;

/// The stages `calibrate` knows how to cost, with the op-count feature
/// each one is regressed against. Stages in a trace outside this set
/// (e.g. backward-pass stages) are ignored by the fit but preserved in
/// the trace itself.
pub const FITTED_STAGES: [&str; 7] =
    ["route", "quant", "pack", "a2a", "assemble", "ffn", "combine"];

fn counter(sum: &TraceSummary, name: &str) -> f64 {
    sum.counters.iter().find(|(k, _)| k == name).map_or(0.0, |(_, v)| *v as f64)
}

fn busy(sum: &TraceSummary, stage: &str) -> f64 {
    sum.busy_by_stage.iter().find(|(c, _)| c == stage).map_or(0.0, |(_, b)| *b)
}

fn feat(doc: &Json, key: &str) -> f64 {
    doc.get("config").and_then(|c| c.get(key)).and_then(Json::as_f64).unwrap_or(0.0)
}

/// One (trace, stage) observation after fitting: how far the fitted
/// cost's prediction lands from the measured busy time.
#[derive(Clone, Debug)]
pub struct ResidualRow {
    /// Trace label (file path as given to [`fit`]).
    pub trace: String,
    /// Stage name (member of [`FITTED_STAGES`]).
    pub stage: String,
    /// Analytic op count regressed against (tokens, bytes, or FLOPs).
    pub feature: f64,
    /// Measured busy seconds (summed span durations across ranks).
    pub busy_s: f64,
    /// `fitted_cost · feature`.
    pub predicted_s: f64,
}

impl ResidualRow {
    /// Signed prediction error in seconds.
    pub fn residual_s(&self) -> f64 {
        self.predicted_s - self.busy_s
    }
}

/// A completed calibration: the fitted cost table plus its per-stage
/// residuals against every input trace.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// Fitted per-op costs, ready for [`CostTable::predict_ep_stages`].
    pub table: CostTable,
    /// One row per (trace, stage) with a nonzero feature or busy time.
    pub rows: Vec<ResidualRow>,
    /// Number of traces the fit consumed.
    pub n_traces: usize,
}

impl CalibrationReport {
    /// Render as the `runs/calibrate.json` document (unified schema,
    /// kind `calibrate`).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::obj()
                    .set("trace", r.trace.as_str())
                    .set("stage", r.stage.as_str())
                    .set("feature", r.feature)
                    .set("busy_s", r.busy_s)
                    .set("predicted_s", r.predicted_s)
                    .set("residual_s", r.residual_s())
            })
            .collect();
        Json::run_doc("calibrate")
            .set("n_traces", self.n_traces)
            .set("fitted", self.table.to_json())
            .set("stages", Json::Arr(rows))
    }
}

/// Per-stage op count for one validated trace. Wire stages read the
/// recorder's byte counters; compute stages read the driver-written
/// `feat_*` config keys.
fn feature_of(stage: &str, doc: &Json, sum: &TraceSummary) -> f64 {
    let wire = counter(sum, "wire_payload_bytes") + counter(sum, "wire_sidecar_bytes");
    match stage {
        "route" => feat(doc, "feat_tokens_routed"),
        "quant" => feat(doc, "feat_quant_bytes"),
        "pack" | "a2a" | "assemble" => wire,
        "ffn" => feat(doc, "feat_ffn_flops"),
        "combine" => counter(sum, "combine_bytes"),
        _ => 0.0,
    }
}

/// Fit a [`CostTable`] from one or more parsed trace documents. Every
/// document must validate and be of kind `trace`; anything else is an
/// error naming the offending file.
pub fn fit(traces: &[(String, Json)]) -> Result<CalibrationReport, String> {
    if traces.is_empty() {
        return Err("calibrate needs at least one trace file".to_string());
    }
    let mut obs: Vec<(String, TraceSummary, &Json)> = Vec::with_capacity(traces.len());
    for (path, doc) in traces {
        let sum = validate(doc).map_err(|e| format!("{path}: {e}"))?;
        if sum.kind != "trace" {
            return Err(format!(
                "{path}: kind `{}` is a runs document, not a trace — re-run with --trace",
                sum.kind
            ));
        }
        obs.push((path.clone(), sum, doc));
    }

    // Per-stage least squares through the origin over all traces.
    let mut costs = [0.0f64; FITTED_STAGES.len()];
    for (si, stage) in FITTED_STAGES.iter().enumerate() {
        let (mut sum_bx, mut sum_xx) = (0.0f64, 0.0f64);
        for (_, sum, doc) in &obs {
            let x = feature_of(stage, doc, sum);
            sum_bx += busy(sum, stage) * x;
            sum_xx += x * x;
        }
        if sum_xx > 0.0 {
            costs[si] = sum_bx / sum_xx;
        }
    }
    let table = CostTable {
        route_s_per_token: costs[0],
        quant_s_per_byte: costs[1],
        pack_s_per_byte: costs[2],
        a2a_s_per_byte: costs[3],
        assemble_s_per_byte: costs[4],
        gemm_s_per_flop: costs[5],
        combine_s_per_byte: costs[6],
    };

    let mut rows = Vec::new();
    for (path, sum, doc) in &obs {
        for (si, stage) in FITTED_STAGES.iter().enumerate() {
            let x = feature_of(stage, doc, sum);
            let b = busy(sum, stage);
            if x == 0.0 && b == 0.0 {
                continue; // stage absent from this trace
            }
            rows.push(ResidualRow {
                trace: path.clone(),
                stage: (*stage).to_string(),
                feature: x,
                busy_s: b,
                predicted_s: costs[si] * x,
            });
        }
    }
    Ok(CalibrationReport { table, rows, n_traces: obs.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a valid trace doc with exact per-stage busy times (µs
    /// event durations), byte counters, and config features.
    fn synthetic(
        stage_busy_us: &[(&str, f64)],
        wire_bytes: u64,
        combine_bytes: u64,
        feats: &[(&str, f64)],
    ) -> Json {
        let events = stage_busy_us
            .iter()
            .map(|(stage, us)| {
                Json::obj()
                    .set("name", *stage)
                    .set("cat", *stage)
                    .set("ph", "X")
                    .set("ts", 0.0)
                    .set("dur", *us)
                    .set("pid", 0u64)
                    .set("tid", 0u64)
            })
            .collect();
        let mut config = Json::obj();
        for (k, v) in feats {
            config = config.set(k, *v);
        }
        Json::run_doc("trace")
            .set("command", "epshard")
            .set("config", config)
            .set(
                "counters",
                Json::obj()
                    .set("wire_payload_bytes", wire_bytes)
                    .set("wire_sidecar_bytes", 0u64)
                    .set("combine_bytes", combine_bytes),
            )
            .set("traceEvents", Json::Arr(events))
    }

    #[test]
    fn single_trace_fit_is_the_exact_ratio() {
        // 2 s of ffn busy over 1e12 FLOPs → 2e-12 s/FLOP, residual 0.
        let doc = synthetic(
            &[("ffn", 2e6), ("a2a", 1e6), ("combine", 5e5)],
            1_000_000,
            500_000,
            &[("feat_ffn_flops", 1e12)],
        );
        let rep = fit(&[("t.json".to_string(), doc)]).expect("fit");
        assert!((rep.table.gemm_s_per_flop - 2e-12).abs() < 1e-24);
        assert!((rep.table.a2a_s_per_byte - 1e-6).abs() < 1e-18);
        assert!((rep.table.combine_s_per_byte - 1e-6).abs() < 1e-18);
        for r in &rep.rows {
            assert!(r.residual_s().abs() < 1e-12, "{}: {}", r.stage, r.residual_s());
        }
    }

    #[test]
    fn two_consistent_traces_recover_the_common_cost() {
        // Both traces generated from cost 3e-7 s/byte on a2a.
        let a = synthetic(&[("a2a", 0.3e6)], 1_000_000, 0, &[]);
        let b = synthetic(&[("a2a", 1.2e6)], 4_000_000, 0, &[]);
        let rep =
            fit(&[("a.json".to_string(), a), ("b.json".to_string(), b)]).expect("fit");
        assert!((rep.table.a2a_s_per_byte - 3e-7).abs() < 1e-18);
        assert_eq!(rep.n_traces, 2);
    }

    #[test]
    fn zero_feature_stage_fits_to_zero_without_nan() {
        let doc = synthetic(&[("quant", 1e6)], 0, 0, &[]);
        let rep = fit(&[("t.json".to_string(), doc)]).expect("fit");
        assert_eq!(rep.table.quant_s_per_byte, 0.0);
        assert!(rep.table.quant_s_per_byte.is_finite());
        // the mismatch is still visible as a residual row
        assert!(rep
            .rows
            .iter()
            .any(|r| r.stage == "quant" && r.busy_s > 0.0 && r.predicted_s == 0.0));
    }

    #[test]
    fn rejects_runs_docs_and_empty_input() {
        assert!(fit(&[]).is_err());
        let runs = Json::run_doc("epshard");
        let err = fit(&[("r.json".to_string(), runs)]).unwrap_err();
        assert!(err.contains("not a trace"), "{err}");
    }

    #[test]
    fn report_json_carries_schema_header_and_fitted_table() {
        let doc = synthetic(&[("route", 1e5)], 0, 0, &[("feat_tokens_routed", 1024.0)]);
        let rep = fit(&[("t.json".to_string(), doc)]).expect("fit");
        let j = rep.to_json();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("calibrate"));
        assert!(j.get("schema_version").is_some());
        let fitted = j.get("fitted").expect("fitted block");
        let c = fitted.get("route_s_per_token").and_then(Json::as_f64).unwrap();
        assert!((c - 0.1 / 1024.0).abs() < 1e-12);
    }
}
