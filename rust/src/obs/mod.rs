//! Unified tracing & metrics: span recorder, Chrome-trace export,
//! counter cross-checks, and the calibrated sim feed.
//!
//! The repo's measurement claims (throughput, casts, wire bytes) used to
//! flow through four ad-hoc stopwatch piles; this module replaces them
//! with one structured stream:
//!
//! * [`recorder`] — a thread-safe global [`recorder::Recorder`] of
//!   hierarchical spans (step → rank → lane → stage → chunk), monotonic
//!   counters, and scalar sample series. When no recorder is installed
//!   every hook is a single relaxed atomic load — provably
//!   non-perturbing, pinned bitwise by `tests/prop_obs.rs`.
//! * [`trace`] — renders a recording as a Chrome trace-event JSON file
//!   (Perfetto-loadable) under the unified `runs/` schema, and validates
//!   / summarizes such files for the `trace` subcommand.
//! * [`calibrate`] — fits [`crate::cluster::sim::CostTable`] per-op
//!   costs from recorded spans, closing the loop from measurement back
//!   into the analytic model.
//!
//! Counter semantics deliberately mirror [`crate::analysis`]'s
//! `ExecPrediction` algebra: drivers snapshot-diff the recorder around
//! each run and hard-fail on any divergence, so a trace that validates
//! is also a trace whose cast/requant/wire accounting is proven against
//! the static analyzer.

pub mod calibrate;
pub mod recorder;
pub mod trace;

pub use recorder::{
    count, detail, enabled, install, sample, session_token, span, Counter, CounterTotals,
    InstallGuard, Recorder, SessionToken, SpanGuard, SpanMeta, SpanRec, DRIVER_RANK,
};
