//! The span/counter/histogram recorder and its process-global install
//! point.
//!
//! **Non-perturbation contract.** When no recorder is installed (the
//! default), every instrumentation call — [`span`], [`count`],
//! [`sample`] — is a single relaxed atomic load followed by an immediate
//! return: no allocation, no lock, no clock read. Instrumentation sits
//! *around* kernels, never inside their arithmetic, so recording on vs.
//! off cannot change a single output bit; `tests/prop_obs.rs` pins that
//! bitwise across thread budgets and rank counts.
//!
//! **Threading.** Counters are per-recorder atomics (lock-free
//! increments from any lane); spans and samples append under a mutex
//! (spans are recorded at stage granularity, so contention is cold).
//! [`install`] holds a process-wide session lock for the lifetime of the
//! returned [`InstallGuard`] — concurrent recording sessions (e.g.
//! parallel `cargo test` threads) serialize instead of polluting each
//! other's counters.
//!
//! **Session scoping.** Recording is additionally scoped to the
//! installing thread's *thread tree*: a thread participates only if it
//! installed the recorder or was spawned by a participating thread
//! through one of the `exec` spawn sites (which propagate a
//! [`SessionToken`]). An unrelated concurrent workload in the same
//! process — another test running instrumented code while a session is
//! active — therefore cannot cross-count into the installed recorder,
//! which is what makes exact-totals assertions deterministic under a
//! parallel test harness.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Number of distinct [`Counter`]s.
pub const N_COUNTERS: usize = 15;

/// Monotonic event counters, incremented at the executed op sites
/// (quantize launches, wire packing, serving drop accounting). The five
/// cast/requant counters use the exact counting convention of the
/// `analysis::ExecPrediction` audit fields, which is what makes the live
/// trace↔lint cross-check an equality, not an approximation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Forward-path explicit casts (entry quant; blockwise per-expert Q).
    CastsFwd = 0,
    /// Backward-path explicit casts (Q(dy); blockwise per-expert Qs).
    CastsBwd = 1,
    /// Backward requantizations of already-FP8 tensors (naive transposes).
    RequantsBwd = 2,
    /// Optimizer-tail weight quantizations from the f32 masters.
    OptWeightQuants = 3,
    /// Optimizer-tail requantizations (zero for every executed recipe).
    OptRequants = 4,
    /// All-to-all payload bytes actually packed onto the wire.
    WirePayloadBytes = 5,
    /// Scale-sidecar bytes actually packed onto the wire.
    WireSidecarBytes = 6,
    /// Wire buffers shipped (FP8 ships codes + sidecar = 2 per message).
    WireBuffers = 7,
    /// Bytes reduced in the combine stage (BF16-accounted partial rows).
    CombineBytes = 8,
    /// Serving: slots dropped by capacity truncation.
    DroppedSlots = 9,
    /// Serving: tokens served with all top-k slots intact.
    ServedTokens = 10,
    /// Serving: tokens served with at least one dropped slot.
    DegradedTokens = 11,
    /// Wire integrity: all-to-all buffers whose CRC32 failed on receive
    /// (codes and sidecar checked separately; each failed check counts 1).
    WireChecksumFail = 12,
    /// Wire integrity: bounded retransmissions after a detected
    /// corruption, timeout, or dropped message.
    A2aRetries = 13,
    /// Wire integrity: rank failovers after retry exhaustion.
    Failovers = 14,
}

impl Counter {
    /// Every counter, in index order.
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::CastsFwd,
        Counter::CastsBwd,
        Counter::RequantsBwd,
        Counter::OptWeightQuants,
        Counter::OptRequants,
        Counter::WirePayloadBytes,
        Counter::WireSidecarBytes,
        Counter::WireBuffers,
        Counter::CombineBytes,
        Counter::DroppedSlots,
        Counter::ServedTokens,
        Counter::DegradedTokens,
        Counter::WireChecksumFail,
        Counter::A2aRetries,
        Counter::Failovers,
    ];

    /// Stable snake_case name (JSON key in the trace `counters` block).
    pub fn name(self) -> &'static str {
        match self {
            Counter::CastsFwd => "casts_fwd",
            Counter::CastsBwd => "casts_bwd",
            Counter::RequantsBwd => "requants_bwd",
            Counter::OptWeightQuants => "opt_weight_quants",
            Counter::OptRequants => "opt_requants",
            Counter::WirePayloadBytes => "wire_payload_bytes",
            Counter::WireSidecarBytes => "wire_sidecar_bytes",
            Counter::WireBuffers => "wire_buffers",
            Counter::CombineBytes => "combine_bytes",
            Counter::DroppedSlots => "dropped_slots",
            Counter::ServedTokens => "served_tokens",
            Counter::DegradedTokens => "degraded_tokens",
            Counter::WireChecksumFail => "wire_checksum_fail",
            Counter::A2aRetries => "a2a_retries",
            Counter::Failovers => "failovers",
        }
    }
}

/// Snapshot of all counter totals (index = `Counter as usize`), used for
/// before/after diffing around a measured section.
pub type CounterTotals = [u64; N_COUNTERS];

/// Pseudo-rank for driver-side spans (route, entry quant, step
/// orchestration) — rendered as the `driver` process in the Chrome trace.
pub const DRIVER_RANK: u32 = u32::MAX;

/// Span coordinates in the step → rank → lane → stage → chunk hierarchy.
/// `stage` is the Chrome-trace category; rank maps to the trace `pid`
/// ([`DRIVER_RANK`] → the driver pseudo-process) and lane to `tid`.
#[derive(Clone, Copy, Debug)]
pub struct SpanMeta {
    /// Pipeline stage (trace category): `route`, `quant`, `pack`, `a2a`,
    /// `assemble`, `ffn`, `combine`, `combine-bwd`, `expert-bwd`,
    /// `dispatch-bwd`, `fwd`, `bwd`, `opt`, `tick`, …
    pub stage: &'static str,
    /// Simulated rank ([`DRIVER_RANK`] for driver-side work).
    pub rank: u32,
    /// Execution lane within the rank (0 when unlaned).
    pub lane: u32,
    /// Outer iteration: train step, serve tick, or top-k slot.
    pub step: u32,
    /// Pipeline chunk within the slot; -1 when not chunked.
    pub chunk: i64,
}

impl SpanMeta {
    /// Driver-side meta for `stage` (rank = [`DRIVER_RANK`], lane 0,
    /// step 0, no chunk). Narrow with the builder methods.
    pub fn stage(stage: &'static str) -> SpanMeta {
        SpanMeta { stage, rank: DRIVER_RANK, lane: 0, step: 0, chunk: -1 }
    }

    /// Set the simulated rank.
    pub fn rank(mut self, r: usize) -> SpanMeta {
        self.rank = r as u32;
        self
    }

    /// Set the lane.
    pub fn lane(mut self, l: usize) -> SpanMeta {
        self.lane = l as u32;
        self
    }

    /// Set the outer iteration (train step / serve tick / top-k slot).
    pub fn step(mut self, s: usize) -> SpanMeta {
        self.step = s as u32;
        self
    }

    /// Set the pipeline chunk.
    pub fn chunk(mut self, c: usize) -> SpanMeta {
        self.chunk = c as i64;
        self
    }
}

/// One recorded span: a closed `[t0_s, t1_s]` interval with its
/// coordinates. Times are seconds since the recorder's epoch
/// ([`Recorder::new`]).
#[derive(Clone, Debug)]
pub struct SpanRec {
    /// Display name (Chrome-trace `name`).
    pub name: String,
    /// Coordinates (stage/rank/lane/step/chunk).
    pub meta: SpanMeta,
    /// Start offset, seconds since the recorder epoch.
    pub t0_s: f64,
    /// End offset, seconds since the recorder epoch.
    pub t1_s: f64,
}

impl SpanRec {
    /// Busy seconds of this span.
    pub fn dur_s(&self) -> f64 {
        self.t1_s - self.t0_s
    }
}

/// The in-memory trace sink: spans + counters + scalar samples, shared
/// by every instrumented layer while installed.
pub struct Recorder {
    epoch: Instant,
    detail: u8,
    counters: [AtomicU64; N_COUNTERS],
    spans: Mutex<Vec<SpanRec>>,
    samples: Mutex<Vec<(&'static str, f64)>>,
}

impl Recorder {
    /// A fresh recorder. `detail` gates span granularity: 1 records
    /// stage-level spans (the `--trace` default); ≥ 2 additionally
    /// records fine-grained kernel-part spans ([`detail`]).
    pub fn new(detail: u8) -> Arc<Recorder> {
        Arc::new(Recorder {
            epoch: Instant::now(),
            detail,
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            spans: Mutex::new(Vec::new()),
            samples: Mutex::new(Vec::new()),
        })
    }

    /// Seconds since this recorder was created.
    pub fn elapsed_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// The configured detail level.
    pub fn detail_level(&self) -> u8 {
        self.detail
    }

    /// Current totals of every counter (a consistent-enough snapshot:
    /// callers snapshot outside the measured section).
    pub fn totals(&self) -> CounterTotals {
        std::array::from_fn(|i| self.counters[i].load(Ordering::Relaxed))
    }

    /// Clone of every recorded span, in completion order.
    pub fn spans(&self) -> Vec<SpanRec> {
        lock(&self.spans).clone()
    }

    /// Number of spans recorded so far.
    pub fn n_spans(&self) -> usize {
        lock(&self.spans).len()
    }

    /// Clone of every recorded scalar sample `(series, value)`.
    pub fn samples(&self) -> Vec<(&'static str, f64)> {
        lock(&self.samples).clone()
    }

    fn push_span(&self, s: SpanRec) {
        lock(&self.spans).push(s);
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// --- process-global install point --------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static DETAIL: AtomicU8 = AtomicU8::new(0);
static CURRENT: Mutex<Option<Arc<Recorder>>> = Mutex::new(None);
static SESSION: Mutex<()> = Mutex::new(());

thread_local! {
    /// Whether the current thread belongs to the active session's thread
    /// tree (set by [`install`] on the installing thread and replayed on
    /// spawned workers via [`SessionToken::adopt`]).
    static IN_SESSION: Cell<bool> = const { Cell::new(false) };
}

/// A thread's session membership, captured at a spawn site with
/// [`session_token`] and replayed on the spawned worker with
/// [`SessionToken::adopt`]. The `exec` pool sites do this for every
/// scoped worker, so a whole EP run records; threads outside the tree
/// (an unrelated concurrent workload) see every hook as a no-op.
#[derive(Clone, Copy, Debug)]
pub struct SessionToken(bool);

/// Capture the calling thread's session membership for a worker it is
/// about to spawn.
pub fn session_token() -> SessionToken {
    SessionToken(IN_SESSION.with(Cell::get))
}

impl SessionToken {
    /// Adopt the captured membership on the current (freshly spawned)
    /// thread. Scoped workers die with their scope, so no reset is
    /// needed.
    pub fn adopt(self) {
        IN_SESSION.with(|c| c.set(self.0));
    }
}

/// Keeps a recorder installed; uninstalls on drop. Holds the process-wide
/// recording-session lock for its whole lifetime, so overlapping sessions
/// (parallel tests) serialize instead of cross-counting. Must be dropped
/// on the thread that called [`install`] (it clears that thread's
/// session membership).
pub struct InstallGuard {
    _session: MutexGuard<'static, ()>,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        DETAIL.store(0, Ordering::SeqCst);
        IN_SESSION.with(|c| c.set(false));
        *lock(&CURRENT) = None;
    }
}

/// Install `rec` as the process-global recorder until the guard drops.
/// Blocks if another session is active (see [`InstallGuard`]).
pub fn install(rec: Arc<Recorder>) -> InstallGuard {
    let session = SESSION.lock().unwrap_or_else(|e| e.into_inner());
    DETAIL.store(rec.detail, Ordering::SeqCst);
    *lock(&CURRENT) = Some(rec);
    IN_SESSION.with(|c| c.set(true));
    ENABLED.store(true, Ordering::SeqCst);
    InstallGuard { _session: session }
}

/// Whether a recorder is installed *and* the calling thread is part of
/// its session — the fast path every instrumentation site checks first.
/// With no session active anywhere (the production default when `--trace`
/// is off) this is a single relaxed atomic load; the thread-local
/// membership bit is consulted only while some session is live.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed) && IN_SESSION.with(Cell::get)
}

/// Installed detail level (0 when off): fine-grained sites record only
/// at `detail() >= 2`, keeping the default span volume bounded.
#[inline]
pub fn detail() -> u8 {
    if !enabled() {
        return 0;
    }
    DETAIL.load(Ordering::Relaxed)
}

fn current() -> Option<Arc<Recorder>> {
    if !enabled() {
        return None;
    }
    lock(&CURRENT).clone()
}

/// Add `n` to counter `c` on the installed recorder (no-op when off).
#[inline]
pub fn count(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    if let Some(r) = current() {
        r.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Record scalar `v` into the named sample series (no-op when off).
/// Serving uses this for per-request latencies — the exact-histogram
/// feed behind the trace file's quantile block.
#[inline]
pub fn sample(series: &'static str, v: f64) {
    if !enabled() {
        return;
    }
    if let Some(r) = current() {
        lock(&r.samples).push((series, v));
    }
}

/// Open a span; it closes (and is recorded) when the returned guard
/// drops. When no recorder is installed this is the no-op fast path.
#[inline]
pub fn span(name: impl Into<String>, meta: SpanMeta) -> SpanGuard {
    if !enabled() {
        return SpanGuard { inner: None };
    }
    let Some(rec) = current() else {
        return SpanGuard { inner: None };
    };
    let t0_s = rec.elapsed_s();
    SpanGuard { inner: Some(SpanInner { rec, name: name.into(), meta, t0_s }) }
}

struct SpanInner {
    rec: Arc<Recorder>,
    name: String,
    meta: SpanMeta,
    t0_s: f64,
}

/// RAII handle for an open span (see [`span`]).
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(SpanInner { rec, name, meta, t0_s }) = self.inner.take() {
            let t1_s = rec.elapsed_s();
            rec.push_span(SpanRec { name, meta, t0_s, t1_s });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_paths_are_noops() {
        // Not installed ⇒ nothing observable happens (and nothing panics).
        assert!(!enabled());
        assert_eq!(detail(), 0);
        count(Counter::CastsFwd, 5);
        sample("x", 1.0);
        let g = span("dead", SpanMeta::stage("route"));
        drop(g);
        assert!(!enabled());
    }

    #[test]
    fn install_records_and_uninstall_restores() {
        let rec = Recorder::new(1);
        {
            let _g = install(rec.clone());
            assert!(enabled());
            assert_eq!(detail(), 1);
            count(Counter::CastsFwd, 2);
            count(Counter::CastsFwd, 3);
            count(Counter::WireBuffers, 7);
            sample("lat_s", 0.25);
            {
                let _s = span("pack r0 c0", SpanMeta::stage("pack").rank(0).lane(1).chunk(0));
            }
        }
        assert!(!enabled(), "guard drop must disable recording");
        let t = rec.totals();
        assert_eq!(t[Counter::CastsFwd as usize], 5);
        assert_eq!(t[Counter::WireBuffers as usize], 7);
        assert_eq!(t[Counter::CastsBwd as usize], 0);
        let spans = rec.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "pack r0 c0");
        assert_eq!(spans[0].meta.stage, "pack");
        assert_eq!(spans[0].meta.rank, 0);
        assert_eq!(spans[0].meta.lane, 1);
        assert_eq!(spans[0].meta.chunk, 0);
        assert!(spans[0].t1_s >= spans[0].t0_s);
        assert_eq!(rec.samples(), vec![("lat_s", 0.25)]);
    }

    #[test]
    fn sessions_serialize_and_do_not_cross_count() {
        let a = Recorder::new(1);
        {
            let _g = install(a.clone());
            count(Counter::DroppedSlots, 1);
        }
        let b = Recorder::new(1);
        {
            let _g = install(b.clone());
            count(Counter::DroppedSlots, 10);
        }
        assert_eq!(a.totals()[Counter::DroppedSlots as usize], 1);
        assert_eq!(b.totals()[Counter::DroppedSlots as usize], 10);
    }

    #[test]
    fn counters_accumulate_across_threads() {
        let rec = Recorder::new(1);
        let _g = install(rec.clone());
        let tok = session_token();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(move || {
                    tok.adopt();
                    for _ in 0..100 {
                        count(Counter::ServedTokens, 1);
                    }
                });
            }
        });
        assert_eq!(rec.totals()[Counter::ServedTokens as usize], 400);
    }

    #[test]
    fn threads_outside_the_session_tree_do_not_record() {
        let rec = Recorder::new(1);
        let _g = install(rec.clone());
        count(Counter::ServedTokens, 1);
        std::thread::scope(|s| {
            s.spawn(|| {
                // no adopt(): this thread models an unrelated concurrent
                // workload — its hooks must be no-ops
                assert!(!enabled());
                count(Counter::ServedTokens, 100);
                sample("stray", 1.0);
                drop(span("stray", SpanMeta::stage("route")));
            });
        });
        assert_eq!(rec.totals()[Counter::ServedTokens as usize], 1);
        assert_eq!(rec.n_spans(), 0);
        assert!(rec.samples().is_empty());
    }

    #[test]
    fn counter_names_are_unique() {
        let mut names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), N_COUNTERS);
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i, "ALL must be in index order");
        }
    }
}
