//! Artifact manifest — the typed index over `artifacts/manifest.json`
//! written by `python/compile/aot.py`.
//!
//! Parsing is a purpose-built micro-parser for the manifest's fixed shape
//! (serde is not vendored in this image): an object of
//! `name → {file, inputs: [{shape, dtype}…], outputs: […]}`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of an artifact boundary tensor (the HLO entry interface is
/// restricted to these — `f8e4m3fn` exists only *inside* graphs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    S32,
    /// 8-bit unsigned integer (packed FP8 codes at the boundary).
    U8,
    /// 32-bit unsigned integer.
    U32,
}

impl Dtype {
    /// Parse a manifest dtype string (`f32`/`s32`/`u8`/`u32`).
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "s32" => Dtype::S32,
            "u8" => Dtype::U8,
            "u32" => Dtype::U32,
            other => bail!("unsupported boundary dtype {other:?}"),
        })
    }

    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::U8 => 1,
            _ => 4,
        }
    }
}

/// Shape + dtype of one boundary tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// HLO text filename within the artifacts directory.
    pub file: String,
    /// Entry-parameter specs, in order.
    pub inputs: Vec<TensorSpec>,
    /// Flattened output-tuple specs, in order.
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Read and parse `manifest.json` under `path`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&text)
    }

    /// Spec of artifact `name`, if present.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.get(name)
    }

    /// Spec of artifact `name`, or a reportable error naming it — the
    /// fallible lookup every CLI path must use (an `unwrap` here turned
    /// a registry inconsistency into a panic instead of the error
    /// contract's stderr message + exit 2).
    pub fn lookup(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries
            .get(name)
            .with_context(|| format!("manifest has no spec for artifact {name:?}"))
    }

    /// All artifact names.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest has no artifacts.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse the manifest JSON (fixed schema; see module docs).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut p = P { b: text.as_bytes(), i: 0 };
        p.ws();
        p.expect(b'{')?;
        let mut entries = BTreeMap::new();
        loop {
            p.ws();
            if p.peek() == Some(b'}') {
                p.i += 1;
                break;
            }
            let name = p.string()?;
            p.ws();
            p.expect(b':')?;
            let spec = p.artifact()?;
            entries.insert(name, spec);
            p.ws();
            if p.peek() == Some(b',') {
                p.i += 1;
            }
        }
        Ok(Manifest { entries })
    }
}

/// Micro JSON parser over the manifest's fixed schema.
struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\n' | b'\t' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        self.ws();
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "manifest parse error at byte {}: expected {:?} found {:?}",
                self.i,
                c as char,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            if c == b'"' {
                let s = std::str::from_utf8(&self.b[start..self.i])?.to_string();
                self.i += 1;
                return Ok(s);
            }
            // manifest strings never contain escapes (paths + dtype names)
            anyhow::ensure!(c != b'\\', "unexpected escape in manifest string");
            self.i += 1;
        }
        bail!("unterminated string")
    }

    fn number(&mut self) -> Result<usize> {
        self.ws();
        let start = self.i;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        anyhow::ensure!(self.i > start, "expected number at byte {}", self.i);
        Ok(std::str::from_utf8(&self.b[start..self.i])?.parse()?)
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        loop {
            self.ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                break;
            }
            v.push(self.number()?);
            self.ws();
            if self.peek() == Some(b',') {
                self.i += 1;
            }
        }
        Ok(v)
    }

    fn tensor(&mut self) -> Result<TensorSpec> {
        self.expect(b'{')?;
        let mut shape = None;
        let mut dtype = None;
        loop {
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                break;
            }
            let key = self.string()?;
            self.expect(b':')?;
            match key.as_str() {
                "shape" => shape = Some(self.shape()?),
                "dtype" => dtype = Some(Dtype::parse(&self.string()?)?),
                other => bail!("unknown tensor key {other:?}"),
            }
            self.ws();
            if self.peek() == Some(b',') {
                self.i += 1;
            }
        }
        Ok(TensorSpec {
            shape: shape.context("tensor missing shape")?,
            dtype: dtype.context("tensor missing dtype")?,
        })
    }

    fn tensor_list(&mut self) -> Result<Vec<TensorSpec>> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        loop {
            self.ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                break;
            }
            v.push(self.tensor()?);
            self.ws();
            if self.peek() == Some(b',') {
                self.i += 1;
            }
        }
        Ok(v)
    }

    fn artifact(&mut self) -> Result<ArtifactSpec> {
        self.expect(b'{')?;
        let mut file = None;
        let mut inputs = None;
        let mut outputs = None;
        loop {
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                break;
            }
            let key = self.string()?;
            self.expect(b':')?;
            match key.as_str() {
                "file" => file = Some(self.string()?),
                "inputs" => inputs = Some(self.tensor_list()?),
                "outputs" => outputs = Some(self.tensor_list()?),
                other => bail!("unknown artifact key {other:?}"),
            }
            self.ws();
            if self.peek() == Some(b',') {
                self.i += 1;
            }
        }
        Ok(ArtifactSpec {
            file: file.context("artifact missing file")?,
            inputs: inputs.context("artifact missing inputs")?,
            outputs: outputs.context("artifact missing outputs")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "init_tiny": {
        "file": "init_tiny.hlo.txt",
        "inputs": [{"dtype": "u32", "shape": []}],
        "outputs": [{"dtype": "f32", "shape": [64, 128]}, {"dtype": "f32", "shape": [128]}]
      },
      "k_quantize_1024x2048": {
        "file": "k_quantize_1024x2048.hlo.txt",
        "inputs": [{"dtype": "f32", "shape": [1024, 2048]}],
        "outputs": [
          {"dtype": "u8", "shape": [1024, 2048]},
          {"dtype": "f32", "shape": [1024, 16]},
          {"dtype": "s32", "shape": [1024, 16]}
        ]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let a = m.get("init_tiny").unwrap();
        assert_eq!(a.file, "init_tiny.hlo.txt");
        assert_eq!(a.inputs.len(), 1);
        assert_eq!(a.inputs[0].dtype, Dtype::U32);
        assert_eq!(a.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].shape, vec![64, 128]);
        let k = m.get("k_quantize_1024x2048").unwrap();
        assert_eq!(k.outputs[1].dtype, Dtype::F32);
        assert_eq!(k.outputs[2].dtype, Dtype::S32);
    }

    #[test]
    fn lookup_is_fallible_not_panicking() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.lookup("init_tiny").is_ok());
        let err = m.lookup("no_such_artifact").unwrap_err();
        assert!(
            err.to_string().contains("no_such_artifact"),
            "error should name the missing spec: {err}"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"a": {"file": "x"}}"#).is_err()); // missing fields
    }

    #[test]
    fn n_elements() {
        let t = TensorSpec { shape: vec![4, 8, 2], dtype: Dtype::F32 };
        assert_eq!(t.n_elements(), 64);
        let s = TensorSpec { shape: vec![], dtype: Dtype::U32 };
        assert_eq!(s.n_elements(), 1);
    }
}
