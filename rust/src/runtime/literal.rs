//! Literal construction/extraction helpers for the restricted boundary
//! dtype set (f32 / s32 / u8 / u32) used by every artifact.

use anyhow::{bail, Result};

use crate::runtime::artifact::{Dtype, TensorSpec};
use crate::util::mat::Mat;

/// Build a literal of `spec`'s shape from raw bytes (row-major).
pub fn from_bytes(spec: &TensorSpec, bytes: &[u8]) -> Result<xla::Literal> {
    let want = spec.n_elements() * spec.dtype.size_bytes();
    anyhow::ensure!(bytes.len() == want, "byte length {} != expected {want}", bytes.len());
    let ty = prim(spec.dtype);
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        ty,
        &spec.shape,
        bytes,
    )?)
}

fn prim(d: Dtype) -> xla::ElementType {
    match d {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::S32 => xla::ElementType::S32,
        Dtype::U8 => xla::ElementType::U8,
        Dtype::U32 => xla::ElementType::U32,
    }
}

/// f32 tensor literal from a slice.
pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let spec = TensorSpec { shape: shape.to_vec(), dtype: Dtype::F32 };
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    from_bytes(&spec, &bytes)
}

/// i32 tensor literal from a slice.
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let spec = TensorSpec { shape: shape.to_vec(), dtype: Dtype::S32 };
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    from_bytes(&spec, &bytes)
}

/// u8 tensor literal from a slice.
pub fn u8_literal(shape: &[usize], data: &[u8]) -> Result<xla::Literal> {
    let spec = TensorSpec { shape: shape.to_vec(), dtype: Dtype::U8 };
    from_bytes(&spec, data)
}

/// u32 scalar literal (seeds).
pub fn u32_scalar(v: u32) -> Result<xla::Literal> {
    let spec = TensorSpec { shape: vec![], dtype: Dtype::U32 };
    from_bytes(&spec, &v.to_le_bytes())
}

/// i32 scalar literal (step counters).
pub fn i32_scalar(v: i32) -> Result<xla::Literal> {
    let spec = TensorSpec { shape: vec![], dtype: Dtype::S32 };
    from_bytes(&spec, &v.to_le_bytes())
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a u8 vector.
pub fn to_u8_vec(lit: &xla::Literal) -> Result<Vec<u8>> {
    Ok(lit.to_vec::<u8>()?)
}

/// Extract an i32 vector.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract a 2-D f32 literal into a [`Mat`].
pub fn to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v = to_f32_vec(lit)?;
    if v.len() != rows * cols {
        bail!("literal has {} elements, expected {rows}x{cols}", v.len());
    }
    Ok(Mat::from_vec(rows, cols, v))
}

/// Scalar f32 (losses).
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = to_f32_vec(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let lit = f32_literal(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = to_mat(&lit, 2, 3).unwrap();
        assert_eq!(m.at(1, 2), 6.0);
    }

    #[test]
    fn u8_roundtrip() {
        let lit = u8_literal(&[4], &[7, 8, 9, 255]).unwrap();
        assert_eq!(to_u8_vec(&lit).unwrap(), vec![7, 8, 9, 255]);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = i32_scalar(-42).unwrap();
        assert_eq!(to_i32_vec(&lit).unwrap(), vec![-42]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[2, 2], &[1.0]).is_err());
    }
}
