//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the request path. Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO **text** →
//! [`xla::HloModuleProto::from_text_file`] → compile on the CPU PJRT
//! client → execute. Device-resident buffers ([`xla::PjRtBuffer`]) are
//! kept across steps by the training loop (`run_b`) so parameters and
//! optimizer state never round-trip through the host.

pub mod artifact;
pub mod literal;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};

/// A compiled, loaded XLA executable plus its manifest entry.
pub struct Executable {
    /// Artifact name (manifest key).
    pub name: String,
    /// Manifest entry this executable was compiled from.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let out = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device buffers (inputs stay on device); returns the
    /// output buffers (still a 1-tuple wrapper is NOT unpacked here — the
    /// caller decides when to fetch).
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(out.remove(0))
    }
}

/// The runtime: one PJRT client plus the artifact registry.
pub struct Runtime {
    /// The PJRT client every executable compiles against.
    pub client: xla::PjRtClient,
    /// Parsed artifact registry.
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifacts directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} — run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, dir })
    }

    /// The artifacts dir: `$FP8_FLOW_ARTIFACTS` or `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("FP8_FLOW_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load + compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.lookup(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable { name: name.to_string(), spec, exe })
    }

    /// Copy a host literal to the device.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}
