//! `fp8-flow-moe` — the L3 leader binary.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! ```text
//! fp8-flow-moe train --cfg tiny|small --recipe bf16|blockwise|fp8flow
//!                    [--steps N] [--seed S] [--log-every K]   # Fig. 6
//! fp8-flow-moe table1|table2|table3                           # Tables 1–3
//! fp8-flow-moe dataflow                                       # Fig. 2 audit
//! fp8-flow-moe dqe [--size N]                                 # Eq. 1 demo
//! fp8-flow-moe artifacts                                      # list manifest
//! ```

use anyhow::Result;
use fp8_flow_moe::coordinator::{reports, write_run_json};
use fp8_flow_moe::exec;
use fp8_flow_moe::dataflow::{build, Variant};
use fp8_flow_moe::fp8::error::dqe_report;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::runtime::Runtime;
use fp8_flow_moe::train::{Corpus, Trainer};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::json::Json;
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

const USAGE: &str = "\
fp8-flow-moe — FP8-Flow-MoE reproduction (see README.md)

USAGE:
  fp8-flow-moe train --cfg <tiny|small> --recipe <bf16|blockwise|fp8flow>
                     [--steps N] [--seed S] [--noise PCT] [--log-every K]
  fp8-flow-moe table1 | table2 | table3
  fp8-flow-moe dataflow
  fp8-flow-moe dqe [--size N]
  fp8-flow-moe artifacts

Global flags:
  --threads N   worker count for the native kernels (0 = auto; also
                FP8_THREADS env var)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    exec::set_threads(args.usize_or("threads", 0));
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("table1") => {
            print!("{}", reports::table1());
            Ok(())
        }
        Some("table2") => {
            print!("{}", reports::table2());
            Ok(())
        }
        Some("table3") => {
            print!("{}", reports::table3());
            Ok(())
        }
        Some("dataflow") => {
            for v in Variant::all() {
                let g = build(v);
                print!("{}", g.render());
                println!();
            }
            Ok(())
        }
        Some("dqe") => cmd_dqe(&args),
        Some("artifacts") => {
            let rt = Runtime::open(Runtime::default_dir())?;
            for name in rt.manifest.names() {
                let spec = rt.manifest.get(name).unwrap();
                println!("{name}: {} in / {} out", spec.inputs.len(), spec.outputs.len());
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let recipe = args.get_or("recipe", "fp8flow");
    let steps = args.usize_or("steps", 50);
    let seed = args.u64_or("seed", 42);
    let noise = args.usize_or("noise", 10);
    let log_every = args.usize_or("log-every", 10);

    let rt = Runtime::open(Runtime::default_dir())?;
    let mut trainer = Trainer::new(&rt, &cfg, &recipe, seed as u32)?;
    let (b, s) = trainer.batch_shape();
    println!("training {recipe}/{cfg}: {steps} steps of [{b}, {s}] tokens");
    let vocab = if cfg == "tiny" { 64 } else { 256 };
    let mut corpus = Corpus::new(vocab, seed, noise);
    let out = trainer.run(&mut corpus, steps, log_every)?;
    println!(
        "done: first loss {:.4}, tail mean {:.4}, {:.0} tokens/s",
        out.losses[0],
        out.tail_mean(10),
        out.tokens_per_s
    );
    let path = write_run_json(&format!("train_{recipe}_{cfg}_s{seed}"), &out.to_json())?;
    println!("wrote {path:?}");
    Ok(())
}

fn cmd_dqe(args: &Args) -> Result<()> {
    let n = args.usize_or("size", 512);
    let mut rng = Rng::seed_from(7);
    let x = Mat::rand_log_uniform(n, n, -6.0, 6.0, &mut rng);
    println!("double-quantization error (Eq. 1) on a [{n},{n}] log-uniform tensor:\n");
    let mut doc = Json::obj();
    for (label, mode) in
        [("float scales (incumbent)", ScaleMode::Float), ("po2 scales (ours)", ScaleMode::Po2)]
    {
        let r = dqe_report(&x, Fp8Format::E4M3, mode);
        println!("{label}:");
        println!(
            "  naive dequant->T->requant vs one-rounding ref: rel={:.3e} frac_changed={:.3}",
            r.naive_vs_ref.rel_fro, r.naive_vs_ref.frac_nonzero
        );
        println!(
            "  direct transpose          vs one-rounding ref: rel={:.3e} frac_changed={:.3}\n",
            r.direct_vs_ref.rel_fro, r.direct_vs_ref.frac_nonzero
        );
        doc = doc.set(
            label,
            Json::obj()
                .set("naive_rel", r.naive_vs_ref.rel_fro)
                .set("direct_rel", r.direct_vs_ref.rel_fro),
        );
    }
    let path = write_run_json("dqe_demo", &doc)?;
    println!("wrote {path:?}");
    Ok(())
}
