//! `fp8-flow-moe` — the L3 leader binary.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! ```text
//! fp8-flow-moe train [--cfg tiny|small] [--recipe all|bf16|blockwise|fp8flow]
//!                    [--steps N] [--ranks R] [--seed S]       # Fig. 6, native
//! fp8-flow-moe train --aot --cfg tiny --recipe fp8flow        # AOT-artifact path
//! fp8-flow-moe table1|table2|table3                           # Tables 1–3
//! fp8-flow-moe epshard [--ranks R] [--recipe ...] [--tokens N]  # executed EP
//! fp8-flow-moe bwd [--ranks R] [--recipe ...] [--tokens N]    # executed backward
//! fp8-flow-moe dataflow                                       # Fig. 2 audit
//! fp8-flow-moe lint [--recipe all|...] [--experts E] [--top-k K]  # static analyzer
//! fp8-flow-moe serve [--requests N] [--ranks R] [--sweep]     # serving loop
//! fp8-flow-moe chaos [--ranks R] [--seed S]                   # fault injection
//! fp8-flow-moe dqe [--size N]                                 # Eq. 1 demo
//! fp8-flow-moe artifacts                                      # list manifest
//! ```
//!
//! Unknown or missing subcommands print usage to **stderr** and exit
//! nonzero; `--help` / `-h` / `help` print it to stdout and exit 0. Every
//! other failure follows the same error contract: one `error:` line on
//! stderr and exit code 2 (never a panic).

use anyhow::{bail, ensure, Context, Result};
use fp8_flow_moe::analysis::{
    cross_check, diagnostics_to_json, lint_graph, tally, CastSummary, Diagnostic, ExecPrediction,
    ExecutedAudit,
};
use fp8_flow_moe::cluster::ep_exec::{
    ep_backward, ep_forward, ep_forward_with_faults, EpBackward, EpConfig, EpForward, EpShape,
};
use fp8_flow_moe::cluster::fault::{wire_tick, Fault, FaultKind, FaultPlan, ANY_DST};
use fp8_flow_moe::cluster::sim::{
    ep_measured_vs_modeled, ep_overlap_report, per_rank_imbalance, serve_measured_vs_modeled,
    CostTable,
};
use fp8_flow_moe::coordinator::{reports, write_run_json};
use fp8_flow_moe::dataflow::{build, build_train_step, Variant};
use fp8_flow_moe::exec;
use fp8_flow_moe::fp8::error::dqe_report;
use fp8_flow_moe::fp8::{Fp8Format, ScaleMode};
use fp8_flow_moe::moe::backward::{forward_stash, moe_backward, FwdStash, MoeGrads};
use fp8_flow_moe::moe::layer::{moe_forward, MoeWeights, PreparedWeights, Recipe};
use fp8_flow_moe::obs::{self, Counter};
use fp8_flow_moe::runtime::Runtime;
use fp8_flow_moe::serve::{
    generate_requests, serve_trace, ArrivalMode, DropPolicy, FailoverPolicy, GenConfig,
    ServeConfig, ServeEngine, SloPolicy, TokenEmbed,
};
use fp8_flow_moe::train::native::{restore_trainer, save_checkpoint};
use fp8_flow_moe::train::{AotTrainer, Corpus, NativeTrainer, TrainConfig, TrainOutcome};
use fp8_flow_moe::util::cli::Args;
use fp8_flow_moe::util::json::{Json, RUN_SCHEMA_VERSION};
use fp8_flow_moe::util::mat::Mat;
use fp8_flow_moe::util::rng::Rng;

const USAGE: &str = "\
fp8-flow-moe — FP8-Flow-MoE reproduction (see README.md)

USAGE:
  fp8-flow-moe train [--cfg <tiny|small>] [--recipe <all|bf16|blockwise|fp8flow>]
                     [--steps N] [--ranks R] [--seed S] [--noise PCT]
                     [--log-every K] [--lr X] [--aot]
                     (native Fig. 6 convergence run; --aot drives the
                      AOT-artifact executable instead)
  fp8-flow-moe table1 | table2 | table3
  fp8-flow-moe epshard [--ranks R] [--recipe <all|bf16|blockwise|fp8flow>]
                       [--tokens N] [--experts E] [--top-k K] [--capacity C]
                       [--d-model D] [--ffn H] [--seed S]
                       [--overlap <on|off>] [--chunks C]
                       (--overlap on runs the double-buffered pipeline next
                        to the serialized baseline and reports measured
                        overlap efficiency beside the sim's model)
  fp8-flow-moe bwd     [--ranks R] [--recipe <all|bf16|blockwise|fp8flow>]
                       [--tokens N] [--experts E] [--top-k K] [--capacity C]
                       [--d-model D] [--ffn H] [--seed S]
                       [--overlap <on|off>] [--chunks C]
  fp8-flow-moe dataflow
  fp8-flow-moe lint    [--recipe <all|bf16|blockwise|deepseek|fp8flow>]
                       [--experts E] [--top-k K] [--ranks R] [--chunks C]
                       (scale-lineage static analyzer over the Fig. 2
                        graphs + executed cross-check; writes runs/lint.json
                        and exits nonzero on any error-severity finding)
  fp8-flow-moe serve   [--requests N] [--ranks R] [--recipe <all|bf16|blockwise|fp8flow>]
                       [--arrivals <poisson|bursty>] [--rate REQ_PER_S] [--burst X]
                       [--zipf S] [--min-len N] [--max-len N] [--vocab V] [--noise PCT]
                       [--max-wait-ms W] [--max-tokens T]
                       [--capacity-factor F | --cf F] [--drop <capacity|none>] [--sweep]
                       [--experts E] [--top-k K] [--d-model D] [--ffn H] [--seed S]
                       [--overlap <on|off>] [--chunks C]
                       (heavy-traffic serving loop: seeded arrivals, SLO
                        micro-batching, EP-sharded forward; --sweep runs a
                        capacity-factor sweep; writes runs/serve_r<R>.json)
  fp8-flow-moe chaos   [--ranks R] [--seed S] [--steps N]
                       (seeded fault-injection matrix over the EP wire,
                        the serving loop, and the native trainer: CRC32
                        wire recovery must be bitwise clean, the degraded
                        drop ledger must balance, and crash+resume from a
                        checkpoint must replay the uninterrupted loss
                        trajectory bit-for-bit; writes runs/chaos_r<R>.json)
  fp8-flow-moe dqe [--size N]
  fp8-flow-moe trace <file.json> [<file.json> ...]
                       (validate + summarize trace / runs documents:
                        schema-version gate, event well-formedness, counter
                        sanity, and the embedded cross-check verdict)
  fp8-flow-moe calibrate <trace.json> [<trace.json> ...]
                       (fit the sim's per-op CostTable from recorded spans;
                        writes runs/calibrate.json with per-stage residuals)
  fp8-flow-moe artifacts
  fp8-flow-moe help | --help | -h

Global flags:
  --threads N   worker count for the native kernels (0 = auto; also
                FP8_THREADS env var)
  --trace PATH  (train | epshard | bwd | serve) record spans + counters and
                write a Chrome trace-event JSON at PATH (open in Perfetto);
                the embedded counter cross-check against the analytic
                accounting hard-fails the run on any divergence
  --trace-detail N   span detail level 0..=2 with --trace (default 1;
                2 adds per-worker kernel part spans)
";

fn main() {
    if let Err(e) = run() {
        // the uniform error contract: message on stderr, exit 2 (same
        // path the unknown-subcommand branch takes)
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
}

/// `--key` as `usize` through the error contract: a malformed value is
/// one `error:` line on stderr and exit 2, never a panic (the `*_or`
/// getters panic and stay test/tool conveniences).
fn arg_usize(args: &Args, key: &str, default: usize) -> Result<usize> {
    args.try_usize(key, default).map_err(anyhow::Error::msg)
}

/// `--key` as `u64` through the error contract (see [`arg_usize`]).
fn arg_u64(args: &Args, key: &str, default: u64) -> Result<u64> {
    args.try_u64(key, default).map_err(anyhow::Error::msg)
}

/// `--key` as a finite `f64` through the error contract (see
/// [`arg_usize`]).
fn arg_f64(args: &Args, key: &str, default: f64) -> Result<f64> {
    args.try_f64(key, default).map_err(anyhow::Error::msg)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    // --help wins over everything, including malformed global flags
    if args.help_requested() {
        print!("{USAGE}");
        return Ok(());
    }
    exec::set_threads(arg_usize(&args, "threads", 0)?);
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("table1") => {
            print!("{}", reports::table1());
            Ok(())
        }
        Some("table2") => {
            print!("{}", reports::table2());
            Ok(())
        }
        Some("table3") => {
            print!("{}", reports::table3());
            Ok(())
        }
        Some("epshard") => cmd_epshard(&args),
        Some("bwd") => cmd_bwd(&args),
        Some("dataflow") => {
            for v in Variant::all() {
                let g = build(v);
                print!("{}", g.render());
                println!();
            }
            Ok(())
        }
        Some("lint") => cmd_lint(&args),
        Some("dqe") => cmd_dqe(&args),
        Some("serve") => cmd_serve(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("trace") => cmd_trace(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("artifacts") => {
            let rt = Runtime::open(Runtime::default_dir())?;
            for name in rt.manifest.names() {
                // fallible lookup, not unwrap: a registry naming a missing
                // spec is an error-contract exit, not a panic
                let spec = rt.manifest.lookup(name)?;
                println!("{name}: {} in / {} out", spec.inputs.len(), spec.outputs.len());
            }
            Ok(())
        }
        Some(unknown) => {
            eprintln!("error: unknown subcommand '{unknown}'\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
        None => {
            eprintln!("error: missing subcommand\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// The native Fig. 6 convergence run (default), or the AOT-artifact path
/// with `--aot`.
fn cmd_train(args: &Args) -> Result<()> {
    if args.flag("aot") {
        return cmd_train_aot(args);
    }
    let cfg_name = args.get_or("cfg", "tiny");
    let Some(mut cfg) = TrainConfig::named(&cfg_name) else {
        bail!("unknown --cfg {cfg_name:?} (want tiny|small)");
    };
    cfg.ranks = arg_usize(args, "ranks", 1)?;
    cfg.opt.lr = arg_f64(args, "lr", cfg.opt.lr as f64)? as f32;
    ensure!((1..=cfg.n_experts).contains(&cfg.ranks), "--ranks must be in 1..=E");
    let steps = arg_usize(args, "steps", 200)?;
    ensure!(steps >= 1, "--steps must be at least 1");
    let seed = arg_u64(args, "seed", 42)?;
    let noise = arg_usize(args, "noise", 10)?;
    let log_every = arg_usize(args, "log-every", 20)?;
    let recipes = match args.get_or("recipe", "all").as_str() {
        "all" => vec![Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow],
        other => match Recipe::parse(other) {
            Some(r) => vec![r],
            None => bail!("unknown recipe {other:?} (want all|bf16|blockwise|fp8flow)"),
        },
    };
    println!(
        "native train/{cfg_name}: {steps} steps of [{}, {}] tokens, top-{} over {} experts, \
         {} rank(s), {} workers",
        cfg.batch,
        cfg.seq,
        cfg.top_k,
        cfg.n_experts,
        cfg.ranks,
        exec::threads()
    );

    let mut ts = TraceSession::start(args)?;
    let mut outcomes: Vec<(Recipe, TrainOutcome)> = Vec::new();
    for recipe in recipes {
        // identical init seed + identical corpus stream per recipe
        let mut trainer = NativeTrainer::new(cfg, recipe, seed);
        let mut corpus = Corpus::new(cfg.vocab, seed, noise);
        let out = trainer.run(&mut corpus, steps, log_every)?;
        if let Some(ts) = ts.as_mut() {
            // trainer construction quantized the initial weight layouts,
            // then each step's own audit fields predict the counters
            ts.expect_weight_prep(recipe, cfg.n_experts);
            for m in &trainer.metrics {
                ts.expect(Counter::CastsFwd, m.casts_fwd as u64);
                ts.expect(Counter::CastsBwd, m.casts_bwd as u64);
                ts.expect(Counter::RequantsBwd, m.requants_bwd as u64);
                ts.expect(Counter::OptWeightQuants, m.opt_weight_quants as u64);
                ts.expect(Counter::OptRequants, m.opt_requants as u64);
            }
        }
        let m = trainer.metrics.last().unwrap();
        println!(
            "[{}] first {:.4} → tail-mean {:.4}  ({:.0} tokens/s; per step: \
             casts {}+{}, bwd requants {}, opt requants {})",
            out.recipe,
            out.losses[0],
            out.tail_mean(10),
            out.tokens_per_s,
            m.casts_fwd,
            m.casts_bwd,
            m.requants_bwd,
            m.opt_requants,
        );
        let path =
            write_run_json(&format!("train_{}", out.recipe), &trainer.report_json(&out))?;
        println!("wrote {path:?}\n");
        outcomes.push((recipe, out));
    }

    // Fig. 6 parity summary when the oracle and at least one FP8 recipe ran
    if let Some((_, bf16)) = outcomes.iter().find(|(r, _)| *r == Recipe::Bf16) {
        println!("== Fig. 6 convergence summary (tail-mean over the last 10 steps) ==");
        for (_, out) in &outcomes {
            println!(
                "{:>10}: final {:.4}  gap vs bf16 {:+.4}",
                out.recipe,
                out.tail_mean(10),
                out.tail_mean(10) - bf16.tail_mean(10)
            );
        }
    }
    if let Some(ts) = ts {
        let config = Json::obj()
            .set("cfg", cfg_name.as_str())
            .set("steps", steps)
            .set("ranks", cfg.ranks)
            .set("experts", cfg.n_experts)
            .set("top_k", cfg.top_k)
            .set("seed", seed);
        ts.finish("train", config)?;
    }
    Ok(())
}

/// The AOT path: loop in Rust, compute in `train_step_<recipe>_<cfg>`.
fn cmd_train_aot(args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let recipe = args.get_or("recipe", "fp8flow");
    let steps = arg_usize(args, "steps", 50)?;
    let seed = arg_u64(args, "seed", 42)?;
    let noise = arg_usize(args, "noise", 10)?;
    let log_every = arg_usize(args, "log-every", 10)?;

    let rt = Runtime::open(Runtime::default_dir()).context(
        "AOT artifacts unavailable — run `make artifacts`, or drop --aot to use the \
         native trainer (train/native/), which needs none",
    )?;
    let mut trainer = AotTrainer::new(&rt, &cfg, &recipe, seed as u32)?;
    let (b, s) = trainer.batch_shape();
    println!("training {recipe}/{cfg} (AOT): {steps} steps of [{b}, {s}] tokens");
    let vocab = if cfg == "tiny" { 64 } else { 256 };
    let mut corpus = Corpus::new(vocab, seed, noise);
    let out = trainer.run(&mut corpus, steps, log_every)?;
    println!(
        "done: first loss {:.4}, tail mean {:.4}, {:.0} tokens/s",
        out.losses[0],
        out.tail_mean(10),
        out.tokens_per_s
    );
    let doc = out
        .to_json()
        .set("schema_version", RUN_SCHEMA_VERSION)
        .set("kind", "train_aot");
    let path = write_run_json(&format!("train_{recipe}_{cfg}_s{seed}"), &doc)?;
    println!("wrote {path:?}");
    Ok(())
}

/// Shared shape/recipe arguments of the executed-layer subcommands
/// (`epshard`, `bwd`): one parse + validation site so the two commands
/// cannot drift.
struct ShardArgs {
    ranks: usize,
    tokens: usize,
    experts: usize,
    top_k: usize,
    d_model: usize,
    ffn: usize,
    capacity: usize,
    seed: u64,
    chunks: usize,
    overlap: bool,
    recipes: Vec<Recipe>,
}

impl ShardArgs {
    fn parse(args: &Args, default_ranks: usize) -> Result<ShardArgs> {
        let ranks = arg_usize(args, "ranks", default_ranks)?;
        let tokens = arg_usize(args, "tokens", 512)?;
        let experts = arg_usize(args, "experts", 8)?;
        let top_k = arg_usize(args, "top-k", 2)?;
        let d_model = arg_usize(args, "d-model", 256)?;
        let ffn = arg_usize(args, "ffn", 256)?;
        let capacity = arg_usize(args, "capacity", (tokens * top_k).div_ceil(experts))?;
        let seed = arg_u64(args, "seed", 42)?;
        let chunks = arg_usize(args, "chunks", 1)?;
        let overlap = match args.get_or("overlap", "off").as_str() {
            "on" | "true" => true,
            "off" | "false" => false,
            other => bail!("unknown --overlap {other:?} (want on|off)"),
        };
        ensure!(ranks >= 1, "--ranks must be at least 1");
        ensure!(tokens >= 1, "--tokens must be at least 1");
        ensure!(capacity >= 1, "--capacity must be at least 1");
        ensure!(chunks >= 1, "--chunks must be at least 1");
        ensure!(experts >= ranks, "need at least as many experts ({experts}) as ranks ({ranks})");
        ensure!((1..=experts).contains(&top_k), "--top-k must be in 1..=--experts");
        let recipes = match args.get_or("recipe", "all").as_str() {
            "all" => vec![Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow],
            other => match Recipe::parse(other) {
                Some(r) => vec![r],
                None => bail!("unknown recipe {other:?} (want all|bf16|blockwise|fp8flow)"),
            },
        };
        Ok(ShardArgs {
            ranks,
            tokens,
            experts,
            top_k,
            d_model,
            ffn,
            capacity,
            seed,
            chunks,
            overlap,
            recipes,
        })
    }

    /// True when a chunked/overlapped pipeline run was requested next to
    /// the serialized baseline.
    fn pipeline_requested(&self) -> bool {
        self.overlap || self.chunks > 1
    }

    /// The shared run-JSON header under the unified `runs/` schema
    /// (`schema_version` + `kind` first, then the shape/flag fields).
    fn to_json(&self, kind: &str) -> Json {
        Json::run_doc(kind)
            .set("ranks", self.ranks)
            .set("tokens", self.tokens)
            .set("experts", self.experts)
            .set("top_k", self.top_k)
            .set("capacity", self.capacity)
            .set("d_model", self.d_model)
            .set("ffn", self.ffn)
            .set("seed", self.seed)
            .set("chunks", self.chunks)
            .set("overlap", self.overlap)
    }
}

/// Bitwise equality of two f32 buffers (the CLI-level spot check of the
/// bit-identity contract the property tests pin exhaustively).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A `--trace <path>` session: installs a fresh [`obs::Recorder`] for the
/// duration of a subcommand, accumulates the analytically expected totals
/// of every counter the command can predict exactly, and on [`finish`]
/// writes the Chrome-trace document with the cross-check verdict embedded
/// — then enforces that verdict through the error contract. The file is
/// written *before* any bail so a failing trace can still be inspected.
///
/// [`finish`]: TraceSession::finish
struct TraceSession {
    rec: std::sync::Arc<obs::Recorder>,
    _guard: obs::InstallGuard,
    path: String,
    exp: obs::CounterTotals,
    checked: Vec<Counter>,
    /// Analytic per-stage op counts for `calibrate` (see
    /// `obs::calibrate::FITTED_STAGES`): tokens through the router, bytes
    /// through explicit entry/Q(dy) quants, FLOPs through the expert FFNs.
    feat_tokens_routed: f64,
    feat_quant_bytes: f64,
    feat_ffn_flops: f64,
}

impl TraceSession {
    /// Open a session when `--trace <path>` was given; `--trace-detail N`
    /// picks the span detail level (default 1, 2 adds kernel part spans).
    fn start(args: &Args) -> Result<Option<TraceSession>> {
        let Some(path) = args.get("trace") else { return Ok(None) };
        ensure!(!path.is_empty(), "--trace needs a file path");
        let detail = arg_usize(args, "trace-detail", 1)?;
        ensure!(detail <= 2, "--trace-detail must be 0, 1, or 2");
        let rec = obs::Recorder::new(detail as u8);
        let guard = obs::install(rec.clone());
        Ok(Some(TraceSession {
            rec,
            _guard: guard,
            path: path.to_string(),
            exp: Default::default(),
            checked: Vec::new(),
            feat_tokens_routed: 0.0,
            feat_quant_bytes: 0.0,
            feat_ffn_flops: 0.0,
        }))
    }

    /// Record that the command's own analytic accounting expects counter
    /// `c` to end the session `n` higher, and enroll `c` in the
    /// cross-check (an `expect(c, 0)` pins a counter at zero).
    fn expect(&mut self, c: Counter, n: u64) {
        self.exp[c as usize] += n;
        if !self.checked.contains(&c) {
            self.checked.push(c);
        }
    }

    /// Expected optimizer-tail quants of one `PreparedWeights::new` /
    /// `requantize_from_masters` under the recorder: 6 master-sourced
    /// layouts per expert for either FP8 recipe, none for BF16, and zero
    /// requants for all three (the casting-free tail, §3.4).
    fn expect_weight_prep(&mut self, recipe: Recipe, experts: usize) {
        let quants = if recipe == Recipe::Bf16 { 0 } else { 6 * experts as u64 };
        self.expect(Counter::OptWeightQuants, quants);
        self.expect(Counter::OptRequants, 0);
    }

    /// Account one executed EP forward: cast counts from the variant's
    /// lint graph (`ExecPrediction`), wire counts from the run's own
    /// exact byte accounting — two independent derivations the recorded
    /// counters must both agree with.
    fn expect_ep_forward(
        &mut self,
        variant: Variant,
        experts: usize,
        top_k: usize,
        shape: &EpShape,
        out: &EpForward,
    ) {
        let pred = ExecPrediction::of(&build(variant), experts, top_k);
        self.expect(Counter::CastsFwd, pred.casts_fwd as u64);
        self.expect(Counter::CastsBwd, 0);
        self.expect(Counter::RequantsBwd, 0);
        self.expect(Counter::WirePayloadBytes, out.dispatch_payload_bytes as u64);
        self.expect(Counter::WireSidecarBytes, out.dispatch_sidecar_bytes as u64);
        self.expect(Counter::WireBuffers, out.dispatch_buffers as u64);
        self.expect(Counter::CombineBytes, out.combine_bytes as u64);
        self.feat_tokens_routed += shape.tokens as f64;
        if variant == Variant::Fp8Flow {
            // the single entry quant is the only explicit fwd cast
            self.feat_quant_bytes += (shape.tokens * shape.d_model) as f64;
        }
        self.feat_ffn_flops += CostTable::expert_flops(shape);
    }

    /// Account one executed EP backward (same split: casts/requants from
    /// the lint graph, wire bytes from the run).
    fn expect_ep_backward(&mut self, pred: &ExecPrediction, out: &EpBackward) {
        self.expect(Counter::CastsBwd, pred.casts_bwd as u64);
        self.expect(Counter::RequantsBwd, pred.requants_bwd as u64);
        self.expect(Counter::WirePayloadBytes, out.dy_payload_bytes as u64);
        self.expect(Counter::WireSidecarBytes, out.dy_sidecar_bytes as u64);
        self.expect(Counter::WireBuffers, out.dy_buffers as u64);
        self.expect(Counter::CombineBytes, out.dx_bytes as u64);
    }

    /// Build the trace document, embed the cross-check verdict, write the
    /// file, and enforce the verdict.
    fn finish(self, command: &str, config: Json) -> Result<()> {
        let config = config
            .set("feat_tokens_routed", self.feat_tokens_routed)
            .set("feat_quant_bytes", self.feat_quant_bytes)
            .set("feat_ffn_flops", self.feat_ffn_flops);
        let totals = self.rec.totals();
        let mut rows = Json::obj();
        let mut ok = true;
        for &c in &self.checked {
            let (want, got) = (self.exp[c as usize], totals[c as usize]);
            ok &= want == got;
            rows = rows.set(
                c.name(),
                Json::obj().set("expected", want).set("recorded", got).set("ok", want == got),
            );
        }
        let doc = obs::trace::trace_doc(command, config, &self.rec)
            .set("cross_check", Json::obj().set("ok", ok).set("counters", rows));
        std::fs::write(&self.path, doc.render())
            .with_context(|| format!("writing trace to {:?}", self.path))?;
        println!("wrote trace {:?} ({} spans)", self.path, self.rec.n_spans());
        if !ok {
            for &c in &self.checked {
                let (want, got) = (self.exp[c as usize], totals[c as usize]);
                if want != got {
                    eprintln!("cross-check: {} recorded {got} != expected {want}", c.name());
                }
            }
            bail!("trace counter cross-check failed (trace kept at {:?})", self.path);
        }
        println!(
            "    counter cross-check: {} counters agree with the analytic accounting",
            self.checked.len()
        );
        Ok(())
    }
}

/// Validate and summarize trace / runs documents.
fn cmd_trace(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    ensure!(!files.is_empty(), "usage: fp8-flow-moe trace <file.json> [<file.json> ...]");
    for f in files {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f:?}"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{f}: not JSON: {e}"))?;
        let s = obs::trace::validate(&doc).map_err(|e| anyhow::anyhow!("{f}: invalid: {e}"))?;
        println!("{f}: OK — kind {:?}, {} event(s)", s.kind, s.n_events);
        if s.kind == "trace" {
            println!(
                "    command {:?}, {} rank(s), wall {:.3} ms{}",
                s.command,
                s.n_ranks,
                s.wall_s * 1e3,
                match s.cross_check_ok {
                    Some(true) => ", cross-check ok",
                    Some(false) => ", cross-check FAILED",
                    None => "",
                }
            );
            for (stage, busy) in s.busy_by_stage.iter().take(8) {
                println!("    busy {stage:<12} {:>10.3} ms", busy * 1e3);
            }
            let nz: Vec<String> = s
                .counters
                .iter()
                .filter(|(_, v)| *v > 0)
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            if !nz.is_empty() {
                println!("    counters: {}", nz.join(", "));
            }
        }
    }
    Ok(())
}

/// Fit the sim's per-op [`CostTable`] from recorded traces and write
/// `runs/calibrate.json` (see `obs::calibrate`).
fn cmd_calibrate(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    ensure!(!files.is_empty(), "usage: fp8-flow-moe calibrate <trace.json> [<trace.json> ...]");
    let mut traces: Vec<(String, Json)> = Vec::new();
    for f in files {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f:?}"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{f}: not JSON: {e}"))?;
        traces.push((f.clone(), doc));
    }
    let report = obs::calibrate::fit(&traces).map_err(|e| anyhow::anyhow!("calibrate: {e}"))?;
    println!("calibrate: fitted per-op costs from {} trace(s):", report.n_traces);
    let t = &report.table;
    for (name, unit, v) in [
        ("route", "s/token", t.route_s_per_token),
        ("quant", "s/byte", t.quant_s_per_byte),
        ("pack", "s/byte", t.pack_s_per_byte),
        ("a2a", "s/byte", t.a2a_s_per_byte),
        ("assemble", "s/byte", t.assemble_s_per_byte),
        ("ffn", "s/flop", t.gemm_s_per_flop),
        ("combine", "s/byte", t.combine_s_per_byte),
    ] {
        println!("    {name:<8} {v:>12.3e} {unit}");
    }
    let mut worst: Vec<&fp8_flow_moe::obs::calibrate::ResidualRow> = report.rows.iter().collect();
    worst.sort_by(|a, b| {
        b.residual_s().abs().partial_cmp(&a.residual_s().abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    for r in worst.iter().take(5) {
        println!(
            "    residual {:<8} {:>10.4} ms (busy {:.4} ms, predicted {:.4} ms) [{}]",
            r.stage,
            r.residual_s() * 1e3,
            r.busy_s * 1e3,
            r.predicted_s * 1e3,
            r.trace
        );
    }
    let path = write_run_json("calibrate", &report.to_json())?;
    println!("wrote {path:?}");
    Ok(())
}

/// Execute the EP-sharded forward and report measured vs modeled
/// per-stage times (see `rust/EXPERIMENTS.md` §"Measured vs modeled EP
/// dispatch").
fn cmd_epshard(args: &Args) -> Result<()> {
    let sa = ShardArgs::parse(args, 2)?;
    let (ranks, tokens, experts, top_k, d_model, ffn, capacity, seed) =
        (sa.ranks, sa.tokens, sa.experts, sa.top_k, sa.d_model, sa.ffn, sa.capacity, sa.seed);

    let mut rng = Rng::seed_from(seed);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    println!(
        "epshard: {ranks} simulated ranks sharing {} workers (--threads to change)",
        exec::threads()
    );
    let mut ts = TraceSession::start(args)?;

    let mut doc = sa.to_json("epshard");
    for recipe in sa.recipes.iter().copied() {
        let (key, variant) = match recipe {
            Recipe::Bf16 => ("bf16", Variant::Bf16),
            Recipe::Blockwise => ("blockwise", Variant::TeBlockwise),
            Recipe::Fp8Flow => ("fp8flow", Variant::Fp8Flow),
        };
        let pw = PreparedWeights::new(w.clone(), recipe);
        if let Some(ts) = ts.as_mut() {
            ts.expect_weight_prep(recipe, experts);
        }
        let cfg = EpConfig::serial(ranks, top_k, capacity, 0);
        let shape = EpShape::of(&x, &pw, &cfg);
        let out = ep_forward(&x, &pw, &cfg);
        if let Some(ts) = ts.as_mut() {
            ts.expect_ep_forward(variant, experts, top_k, &shape, &out);
        }
        print!("{}", ep_measured_vs_modeled(recipe, ranks, &shape, &out));
        println!();
        doc = doc.set(key, out.to_json());
        if sa.pipeline_requested() {
            let over = ep_forward(&x, &pw, &cfg.with_pipeline(sa.chunks, sa.overlap));
            if let Some(ts) = ts.as_mut() {
                ts.expect_ep_forward(variant, experts, top_k, &shape, &over);
            }
            ensure!(
                bits_eq(&over.y.data, &out.y.data),
                "{key}: pipelined output diverged bitwise from the serialized baseline"
            );
            print!("{}", ep_overlap_report(recipe, ranks, &shape, &out, &over));
            println!("    bit-identity: pipelined output == serialized baseline\n");
            doc = doc.set(&format!("{key}_overlap"), over.to_json());
        }
    }
    let path = write_run_json(&format!("epshard_r{ranks}"), &doc)?;
    println!("wrote {path:?}");
    if let Some(ts) = ts {
        ts.finish("epshard", sa.to_json("config"))?;
    }
    Ok(())
}

/// Execute the full fwd+bwd MoE layer per recipe — single-rank or
/// EP-sharded — and report per-stage times, the Fig. 2 cast audit (graph
/// vs executed, the 12→2 table), and gradient deviation from the BF16
/// reference (see `rust/EXPERIMENTS.md` §Backward).
fn cmd_bwd(args: &Args) -> Result<()> {
    let sa = ShardArgs::parse(args, 1)?;
    let (ranks, tokens, experts, top_k, d_model, ffn, capacity, seed) =
        (sa.ranks, sa.tokens, sa.experts, sa.top_k, sa.d_model, sa.ffn, sa.capacity, sa.seed);

    let mut rng = Rng::seed_from(seed);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let dy = Mat::randn(tokens, d_model, 1.0, &mut rng);
    println!(
        "bwd: {tokens} tokens, {experts} experts, top-{top_k}, {ranks} rank(s), \
         {} workers",
        exec::threads()
    );

    let mut ts = TraceSession::start(args)?;

    // BF16 reference gradients for the deviation report (contributes
    // nothing to any checked counter: BF16 executes zero casts and the
    // single-rank backward never touches the wire)
    let pw_ref = PreparedWeights::new(w.clone(), Recipe::Bf16);
    let stash_ref = forward_stash(&x, &pw_ref, top_k, capacity);
    let ref_grads = moe_backward(&stash_ref, &pw_ref, &dy);

    let mut doc = sa.to_json("bwd");
    for recipe in sa.recipes.iter().copied() {
        let (key, variant) = match recipe {
            Recipe::Bf16 => ("bf16", Variant::Bf16),
            Recipe::Blockwise => ("blockwise", Variant::TeBlockwise),
            Recipe::Fp8Flow => ("fp8flow", Variant::Fp8Flow),
        };
        println!("== bwd {key}: R={ranks} ==");
        let pred = ExecPrediction::of_chunked(&build(variant), experts, top_k, sa.chunks);
        // Single-rank BF16 *is* the deviation reference — reuse it rather
        // than recomputing the identical forward+backward.
        let computed: Option<(FwdStash, MoeGrads, Option<Json>)> =
            if recipe == Recipe::Bf16 && ranks == 1 && !sa.pipeline_requested() {
                None
            } else {
                let pw = PreparedWeights::new(w.clone(), recipe);
                let stash = forward_stash(&x, &pw, top_k, capacity);
                if let Some(ts) = ts.as_mut() {
                    ts.expect_weight_prep(recipe, experts);
                    ts.expect(Counter::CastsFwd, pred.casts_fwd as u64);
                }
                let (grads, wj) = if ranks > 1 || sa.pipeline_requested() {
                    let cfg = EpConfig::serial(ranks, top_k, capacity, 0);
                    let out = ep_backward(&stash, &pw, &dy, &cfg);
                    if let Some(ts) = ts.as_mut() {
                        ts.expect_ep_backward(&pred, &out);
                    }
                    let mut j = out.to_json();
                    println!(
                        "    combine-bwd wire {} B payload + {} B sidecar in {} buffers; \
                         dispatch-bwd {} B",
                        out.dy_payload_bytes, out.dy_sidecar_bytes, out.dy_buffers, out.dx_bytes
                    );
                    if sa.pipeline_requested() {
                        let pcfg = cfg.with_pipeline(sa.chunks, sa.overlap);
                        let over = ep_backward(&stash, &pw, &dy, &pcfg);
                        if let Some(ts) = ts.as_mut() {
                            ts.expect_ep_backward(&pred, &over);
                        }
                        ensure!(
                            bits_eq(&over.grads.dx.data, &out.grads.dx.data),
                            "{key}: pipelined backward diverged bitwise from serialized"
                        );
                        println!(
                            "ROW bwd-wall serialized {:>9.4} ms | overlapped (C={}) {:>9.4} ms \
                             | speedup {:.3}x  [bit-identical grads]",
                            out.pipeline_wall_s * 1e3,
                            over.chunks,
                            over.pipeline_wall_s * 1e3,
                            out.pipeline_wall_s / over.pipeline_wall_s
                        );
                        j = j.set("overlap_run", over.to_json());
                    }
                    (out.grads, Some(j))
                } else {
                    if let Some(ts) = ts.as_mut() {
                        ts.expect(Counter::CastsBwd, pred.casts_bwd as u64);
                        ts.expect(Counter::RequantsBwd, pred.requants_bwd as u64);
                    }
                    (moe_backward(&stash, &pw, &dy), None)
                };
                Some((stash, grads, wj))
            };
        let (stash, grads, wire_json) = match &computed {
            Some((s, g, wj)) => (s, g, wj.clone()),
            None => (&stash_ref, &ref_grads, None),
        };
        let g = build(variant);
        let dx_rel = grads.dx.rel_err(&ref_grads.dx);
        let dw_rel: f64 = (0..experts)
            .map(|e| grads.dw1[e].rel_err(&ref_grads.dw1[e]))
            .sum::<f64>()
            / experts as f64;
        println!(
            "ROW combine-bwd {:>9.4} ms | expert-bwd {:>9.4} ms | dispatch-bwd {:>9.4} ms",
            grads.stages.combine_bwd_s * 1e3,
            grads.stages.expert_bwd_s * 1e3,
            grads.stages.dispatch_bwd_s * 1e3,
        );
        println!(
            "    casts fwd+bwd: {} + {} executed (graph: {} + {} = {}); requants: {}",
            stash.cast_ops,
            grads.stats.casts,
            g.explicit_casts_fwd(),
            g.explicit_casts_bwd(),
            g.explicit_casts(),
            grads.stats.requants,
        );
        println!("    vs bf16 grads: dx rel {dx_rel:.4}, mean dw1 rel {dw_rel:.4}\n");
        let mut rj = Json::obj()
            .set("combine_bwd_ms", grads.stages.combine_bwd_s * 1e3)
            .set("expert_bwd_ms", grads.stages.expert_bwd_s * 1e3)
            .set("dispatch_bwd_ms", grads.stages.dispatch_bwd_s * 1e3)
            .set("casts_fwd", stash.cast_ops)
            .set("casts_bwd", grads.stats.casts)
            .set("requants_bwd", grads.stats.requants)
            .set("graph_casts_total", g.explicit_casts())
            .set("dx_rel_vs_bf16", dx_rel)
            .set("dw1_rel_vs_bf16", dw_rel);
        if let Some(wj) = wire_json {
            rj = rj.set("ep", wj);
        }
        doc = doc.set(key, rj);
    }
    let path = write_run_json(&format!("bwd_r{ranks}"), &doc)?;
    println!("wrote {path:?}");
    if let Some(ts) = ts {
        ts.finish("bwd", sa.to_json("config"))?;
    }
    Ok(())
}

/// The scale-lineage static analyzer: lint every requested recipe's layer
/// and train-step graphs, print the analyzer-derived Fig. 2 cast table,
/// cross-check predicted counts against the executed audits, write
/// `runs/lint.json`, and exit nonzero if any error-severity diagnostic
/// fired (see `rust/EXPERIMENTS.md` §Lint).
fn cmd_lint(args: &Args) -> Result<()> {
    let experts = arg_usize(args, "experts", 8)?;
    let top_k = arg_usize(args, "top-k", 2)?;
    let ranks = arg_usize(args, "ranks", 1)?;
    let chunks = arg_usize(args, "chunks", 1)?;
    ensure!(experts >= 1, "--experts must be at least 1");
    ensure!((1..=experts).contains(&top_k), "--top-k must be in 1..=--experts");
    ensure!((1..=experts).contains(&ranks), "--ranks must be in 1..=--experts");
    ensure!(chunks >= 1, "--chunks must be at least 1");
    let variants: Vec<Variant> = match args.get_or("recipe", "all").as_str() {
        "all" => Variant::all().to_vec(),
        other => match Variant::parse(other) {
            Some(v) => vec![v],
            None => bail!("unknown recipe {other:?} (want all|bf16|blockwise|deepseek|fp8flow)"),
        },
    };

    println!("scale-lineage lint: E={experts}, K={top_k}, R={ranks}, C={chunks}\n");
    let mut doc = Json::run_doc("lint")
        .set("experts", experts)
        .set("top_k", top_k)
        .set("ranks", ranks)
        .set("chunks", chunks);
    let (mut errors, mut warnings) = (0usize, 0usize);
    // the executed weight prep is master-sourced for EVERY FP8 recipe
    // (`requantize_from_masters` never derives a layout from FP8), so the
    // casting-free optimizer tail is the reference prediction for all of
    // them; the incumbent graphs' storage-derived tails stay as schematic
    // foils the lint flags (SL001).
    let master_tail = ExecPrediction::of(&build_train_step(Variant::Fp8Flow), experts, top_k);

    for v in variants {
        let mut vj = Json::obj();
        for (phase, g) in [("layer", build(v)), ("train", build_train_step(v))] {
            g.validate().map_err(|e| anyhow::anyhow!("{} {phase}: {e}", v.name()))?;
            let diags = lint_graph(&g);
            let (e, w) = tally(&diags);
            errors += e;
            warnings += w;
            let s = CastSummary::of(&g);
            println!(
                "== {} {phase}: casts fwd/bwd/opt {}/{}/{}, requants bwd/opt {}/{} — {} \
                 error(s), {} warning(s)",
                v.name(), s.casts_fwd, s.casts_bwd, s.casts_opt, s.requants_bwd, s.requants_opt,
                e, w
            );
            for d in &diags {
                println!("  {}", d.render());
            }
            vj = vj.set(
                phase,
                Json::obj()
                    .set("casts_fwd", s.casts_fwd)
                    .set("casts_bwd", s.casts_bwd)
                    .set("casts_opt", s.casts_opt)
                    .set("requants_bwd", s.requants_bwd)
                    .set("requants_opt", s.requants_opt)
                    .set("errors", e)
                    .set("warnings", w)
                    .set("diagnostics", diagnostics_to_json(&diags)),
            );
        }

        // static ↔ executed cross-check (DeepSeek-V3 is schematic-only)
        let recipe = match v {
            Variant::Bf16 => Some(Recipe::Bf16),
            Variant::TeBlockwise => Some(Recipe::Blockwise),
            Variant::Fp8Flow => Some(Recipe::Fp8Flow),
            Variant::DeepSeekV3 => None,
        };
        if let Some(recipe) = recipe {
            // chunk multiplicity: the prediction is chunk-invariant by
            // contract, and the executed audit below runs the actual
            // chunked EP backward when R or C > 1 — so the cross-check
            // fails loudly if chunking ever inflates a cast counter
            let layer = ExecPrediction::of_chunked(&build(v), experts, top_k, chunks);
            let tail = if v == Variant::Bf16 {
                ExecPrediction::of(&build_train_step(v), experts, top_k)
            } else {
                master_tail
            };
            let predicted = ExecPrediction {
                opt_weight_quants: tail.opt_weight_quants,
                opt_requants: tail.opt_requants,
                ..layer
            };
            let executed = executed_audit(recipe, experts, top_k, ranks, chunks);
            let divergences: Vec<Diagnostic> = cross_check(v.name(), &predicted, &executed);
            errors += divergences.len();
            println!(
                "   cross-check vs executed: predicted {}+{} casts, {} bwd requants, \
                 {}+{} opt quants/requants — {}",
                predicted.casts_fwd,
                predicted.casts_bwd,
                predicted.requants_bwd,
                predicted.opt_weight_quants,
                predicted.opt_requants,
                if divergences.is_empty() { "agrees" } else { "DIVERGES" }
            );
            for d in &divergences {
                println!("  {}", d.render());
            }
            vj = vj.set(
                "cross_check",
                Json::obj()
                    .set("predicted", predicted.to_json())
                    .set(
                        "executed",
                        Json::obj()
                            .set("casts_fwd", executed.casts_fwd)
                            .set("casts_bwd", executed.casts_bwd)
                            .set("requants_bwd", executed.requants_bwd)
                            .set("opt_weight_quants", executed.opt_weight_quants)
                            .set("opt_requants", executed.opt_requants),
                    )
                    .set("divergences", diagnostics_to_json(&divergences)),
            );
        } else {
            println!("   cross-check: schematic-only variant (no executed recipe) — skipped");
        }
        println!();
        doc = doc.set(v.name(), vj);
    }

    doc = doc.set("errors", errors).set("warnings", warnings);
    let path = write_run_json("lint", &doc)?;
    println!("lint: {errors} error(s), {warnings} warning(s); wrote {path:?}");
    if errors > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// Run the executed layer + weight prep at a small fixed shape and
/// collect the runtime's own cast/requant audit for [`cmd_lint`]'s
/// cross-check. Counts depend only on `(experts, top_k)`, not on the
/// token/feature dims, the rank count, or the pipeline chunking
/// (`tests/prop_lint.rs` pins this) — with `--ranks`/`--chunks` > 1 the
/// backward runs through the chunked (and overlapped, when C > 1) EP
/// pipeline so the invariance is checked against the real schedule.
fn executed_audit(
    recipe: Recipe,
    experts: usize,
    top_k: usize,
    ranks: usize,
    chunks: usize,
) -> ExecutedAudit {
    let tokens = 64.max(experts);
    let capacity = (tokens * top_k).div_ceil(experts);
    let mut rng = Rng::seed_from(42);
    let x = Mat::randn(tokens, 32, 0.5, &mut rng);
    let w = MoeWeights::random(32, 32, experts, &mut rng);
    let dy = Mat::randn(tokens, 32, 1.0, &mut rng);
    let mut pw = PreparedWeights::new(w, recipe);
    let stash = forward_stash(&x, &pw, top_k, capacity);
    let grads = if ranks > 1 || chunks > 1 {
        let cfg = EpConfig::serial(ranks, top_k, capacity, 0).with_pipeline(chunks, chunks > 1);
        ep_backward(&stash, &pw, &dy, &cfg).grads
    } else {
        moe_backward(&stash, &pw, &dy)
    };
    let prep = pw.requantize_from_masters();
    ExecutedAudit {
        casts_fwd: stash.cast_ops,
        casts_bwd: grads.stats.casts,
        requants_bwd: grads.stats.requants,
        opt_weight_quants: prep.weight_quants,
        opt_requants: prep.requants,
    }
}

/// The heavy-traffic serving loop: seeded arrivals → SLO micro-batching →
/// EP-sharded forward per flush tick, with exact capacity-drop accounting
/// and a CLI-level bit-identity gate — every fully served token must match
/// one-shot [`moe_forward`] over the whole trace bit-for-bit (see
/// `rust/EXPERIMENTS.md` §Serving). `--sweep` runs the capacity-factor
/// sweep that maps the quality/throughput trade.
fn cmd_serve(args: &Args) -> Result<()> {
    let ranks = arg_usize(args, "ranks", 2)?;
    let n_requests = arg_usize(args, "requests", 64)?;
    let experts = arg_usize(args, "experts", 8)?;
    let top_k = arg_usize(args, "top-k", 2)?;
    let d_model = arg_usize(args, "d-model", 128)?;
    let ffn = arg_usize(args, "ffn", 128)?;
    let seed = arg_u64(args, "seed", 42)?;
    let chunks = arg_usize(args, "chunks", 1)?;
    let overlap = match args.get_or("overlap", "off").as_str() {
        "on" | "true" => true,
        "off" | "false" => false,
        other => bail!("unknown --overlap {other:?} (want on|off)"),
    };
    ensure!(ranks >= 1, "--ranks must be at least 1");
    ensure!(n_requests >= 1, "--requests must be at least 1");
    ensure!(experts >= ranks, "need at least as many experts ({experts}) as ranks ({ranks})");
    ensure!((1..=experts).contains(&top_k), "--top-k must be in 1..=--experts");
    ensure!(chunks >= 1, "--chunks must be at least 1");

    let arrivals = args.get_or("arrivals", "poisson");
    let Some(mode) = ArrivalMode::parse(&arrivals) else {
        bail!("unknown --arrivals {arrivals:?} (want poisson|bursty)");
    };
    let gen = GenConfig {
        seed,
        mode,
        rate: arg_f64(args, "rate", 200.0)?,
        burst: arg_f64(args, "burst", 4.0)?,
        burst_period_s: arg_f64(args, "burst-period-ms", 50.0)? / 1e3,
        zipf_s: arg_f64(args, "zipf", 1.1)?,
        min_len: arg_usize(args, "min-len", 4)?,
        max_len: arg_usize(args, "max-len", 64)?,
        vocab: arg_usize(args, "vocab", 64)?,
        noise_pct: arg_usize(args, "noise", 10)?,
    };
    // re-check the generator's invariants here so a bad flag takes the
    // error contract (stderr + exit 2) instead of the library assert
    ensure!(gen.rate > 0.0, "--rate must be positive");
    ensure!(gen.burst >= 1.0, "--burst must be at least 1");
    ensure!(gen.burst_period_s > 0.0, "--burst-period-ms must be positive");
    ensure!(
        1 <= gen.min_len && gen.min_len <= gen.max_len,
        "need 1 <= --min-len <= --max-len"
    );
    ensure!(gen.vocab >= 1, "--vocab must be at least 1");

    let slo = SloPolicy {
        max_wait_s: arg_f64(args, "max-wait-ms", 5.0)? / 1e3,
        max_tokens: arg_usize(args, "max-tokens", 128)?,
    };
    ensure!(slo.max_wait_s >= 0.0, "--max-wait-ms must be non-negative");
    ensure!(slo.max_tokens >= 1, "--max-tokens must be at least 1");

    let drop_s = args.get_or("drop", "capacity");
    let Some(drop_policy) = DropPolicy::parse(&drop_s) else {
        bail!("unknown --drop {drop_s:?} (want capacity|none)");
    };
    // --cf is the short alias for --capacity-factor; both spellings go
    // through the same parse + positivity gate
    ensure!(
        !(args.get("cf").is_some() && args.get("capacity-factor").is_some()),
        "--cf is an alias for --capacity-factor; pass only one of them"
    );
    let cf_key = if args.get("cf").is_some() { "cf" } else { "capacity-factor" };
    let cf = arg_f64(args, cf_key, 1.0)?;
    ensure!(cf > 0.0, "--{cf_key} must be positive");
    let cfs: Vec<f64> =
        if args.flag("sweep") { vec![0.5, 0.75, 1.0, 1.25, 1.5] } else { vec![cf] };
    let recipes = match args.get_or("recipe", "fp8flow").as_str() {
        "all" => vec![Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow],
        other => match Recipe::parse(other) {
            Some(r) => vec![r],
            None => bail!("unknown recipe {other:?} (want all|bf16|blockwise|fp8flow)"),
        },
    };

    let requests = generate_requests(&gen, n_requests);
    let total_tokens: usize = requests.iter().map(|r| r.len()).sum();
    println!(
        "serve: {n_requests} requests ({total_tokens} tokens), {} arrivals at {:.0} req/s, \
         R={ranks}, E={experts}, top-{top_k}, drop={}, {} workers",
        mode.name(),
        gen.rate,
        drop_policy.name(),
        exec::threads()
    );

    let mut ts = TraceSession::start(args)?;
    let mut rng = Rng::seed_from(seed);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let all_ids: Vec<i32> = requests.iter().flat_map(|r| r.tokens.iter().copied()).collect();
    let x_all = TokenEmbed::new(gen.vocab, d_model, seed).embed(&all_ids);

    let mut doc = Json::run_doc("serve")
        .set("requests", n_requests)
        .set("total_tokens", total_tokens)
        .set("ranks", ranks)
        .set("experts", experts)
        .set("top_k", top_k)
        .set("d_model", d_model)
        .set("ffn", ffn)
        .set("seed", seed)
        .set("arrivals", mode.name())
        .set("rate", gen.rate)
        .set("drop", drop_policy.name())
        .set("max_wait_ms", slo.max_wait_s * 1e3)
        .set("max_tokens", slo.max_tokens)
        .set("chunks", chunks)
        .set("overlap", overlap);
    for recipe in recipes {
        let (key, variant) = match recipe {
            Recipe::Bf16 => ("bf16", Variant::Bf16),
            Recipe::Blockwise => ("blockwise", Variant::TeBlockwise),
            Recipe::Fp8Flow => ("fp8flow", Variant::Fp8Flow),
        };
        // per-layer-invocation cast count: both serve paths (staged and
        // pipelined) execute the same explicit casts as one moe_forward,
        // independent of batch occupancy, so each flush tick adds exactly
        // one prediction's worth
        let pred = ExecPrediction::of(&build(variant), experts, top_k);
        let pw = PreparedWeights::new(w.clone(), recipe);
        if let Some(ts) = ts.as_mut() {
            ts.expect_weight_prep(recipe, experts);
        }
        // one-shot reference over the whole trace: capacity = token count,
        // the drop-free upper bound, so every slot materializes
        let one = moe_forward(&x_all, &pw, top_k, x_all.rows.max(1));
        if let Some(ts) = ts.as_mut() {
            ts.expect(Counter::CastsFwd, pred.casts_fwd as u64);
        }
        let mut engine = ServeEngine::new(
            pw,
            TokenEmbed::new(gen.vocab, d_model, seed),
            ServeConfig {
                ranks,
                top_k,
                capacity_factor: cfs[0],
                drop_policy,
                threads: 0,
                chunks,
                overlap,
            },
        );
        println!(
            "== serve {key}: R={ranks} arrivals={} drop={}{} ==",
            mode.name(),
            drop_policy.name(),
            if engine.cfg.pipelined() { " [overlap pipeline]" } else { "" }
        );
        let mut rj = Json::obj();
        for &cf in &cfs {
            engine.cfg.capacity_factor = cf;
            let s = serve_trace(&engine, &requests, &slo);
            if let Some(ts) = ts.as_mut() {
                ts.expect(Counter::CastsFwd, (pred.casts_fwd * s.ticks) as u64);
                ts.expect(Counter::ServedTokens, s.served_tokens as u64);
                ts.expect(Counter::DegradedTokens, s.degraded_tokens as u64);
                ts.expect(Counter::DroppedSlots, s.dropped_slots as u64);
                ts.feat_tokens_routed += s.mean_batch_tokens * s.ticks as f64;
            }
            // the bit-identity gate: every fully served token must equal
            // the one-shot forward bit-for-bit (prop_serve pins the same
            // property across rank counts and arrival modes)
            for (tt, &ok) in s.fully_served.iter().enumerate() {
                if ok {
                    ensure!(
                        bits_eq(
                            &s.y.data[tt * d_model..(tt + 1) * d_model],
                            &one.y.data[tt * d_model..(tt + 1) * d_model]
                        ),
                        "{key} cf={cf}: served token {tt} diverged bitwise from one-shot \
                         moe_forward"
                    );
                }
            }
            let rows_f: Vec<f64> = s.rank_rows.iter().map(|&r| r as f64).collect();
            let imb = per_rank_imbalance(&rows_f);
            println!(
                "ROW serve cf {cf:>4.2} | {:>9.0} tok/s | p50 {:>8.3} ms | p99 {:>8.3} ms | \
                 dropped {:>5.1}% | imbalance {imb:.3}x",
                s.tokens_per_s,
                s.p50_s * 1e3,
                s.p99_s * 1e3,
                s.drop_frac(top_k) * 100.0,
            );
            println!(
                "    {} ticks, mean batch {:.1} tok, capacity {}..{}; served {} / degraded {} \
                 tokens ({} slot drops)",
                s.ticks,
                s.mean_batch_tokens,
                s.capacity_range.0,
                s.capacity_range.1,
                s.served_tokens,
                s.degraded_tokens,
                s.dropped_slots
            );
            println!(
                "    per-rank dispatched rows [{}] | expert-time imbalance {:.3}x",
                s.rank_rows.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(", "),
                per_rank_imbalance(&s.rank_expert_s),
            );
            let shape = EpShape {
                tokens: (s.mean_batch_tokens.round() as usize).max(1),
                d_model,
                ffn,
                n_experts: experts,
                top_k,
                capacity: s.capacity_range.1.max(1),
            };
            print!("{}", serve_measured_vs_modeled(recipe, ranks, &shape, s.tokens_per_s));
            println!(
                "    bit-identity: {} served rows == one-shot moe_forward\n",
                s.served_tokens
            );
            rj = rj.set(
                &format!("cf{cf:.2}"),
                Json::obj()
                    .set("capacity_factor", cf)
                    .set("ticks", s.ticks)
                    .set("tokens_per_s", s.tokens_per_s)
                    .set("p50_ms", s.p50_s * 1e3)
                    .set("p99_ms", s.p99_s * 1e3)
                    .set("served_tokens", s.served_tokens)
                    .set("degraded_tokens", s.degraded_tokens)
                    .set("dropped_slots", s.dropped_slots)
                    .set("drop_frac", s.drop_frac(top_k))
                    .set("rank_rows", s.rank_rows.clone())
                    .set("imbalance", imb)
                    .set("mean_batch_tokens", s.mean_batch_tokens)
                    .set("capacity_min", s.capacity_range.0)
                    .set("capacity_max", s.capacity_range.1)
                    .set("sim_elapsed_s", s.sim_elapsed_s)
                    .set("busy_s", s.busy_s),
            );
        }
        doc = doc.set(key, rj);
    }
    let path = write_run_json(&format!("serve_r{ranks}"), &doc)?;
    println!("wrote {path:?}");
    if let Some(ts) = ts {
        let config = Json::obj()
            .set("requests", n_requests)
            .set("total_tokens", total_tokens)
            .set("ranks", ranks)
            .set("experts", experts)
            .set("top_k", top_k)
            .set("d_model", d_model)
            .set("ffn", ffn)
            .set("seed", seed)
            .set("chunks", chunks)
            .set("overlap", overlap);
        ts.finish("serve", config)?;
    }
    Ok(())
}

/// The chaos driver: replay a seeded fault-injection matrix over the
/// three executed surfaces and assert the recovery contracts end to end
/// (see `rust/EXPERIMENTS.md` §Robustness):
///
/// * **epshard** — payload/sidecar bit flips, a dropped message and a
///   straggler on the EP dispatch wire; the recovered output must be
///   bitwise identical to the fault-free run, with the recovery visible
///   only in the counters and the virtual clock.
/// * **serve** — a rank crash mid-trace under both failover policies;
///   the extended drop ledger (Σ rank rows + dropped slots +
///   failed-rank drops = tokens·top_k) must balance exactly.
/// * **train** — crash at the midpoint step, resume from the versioned
///   checkpoint; the resumed loss trajectory must replay the
///   uninterrupted run bit-for-bit.
///
/// Writes `runs/chaos_r<R>.json` (a unified-schema runs document, so it
/// validates under `fp8-flow-moe trace`).
fn cmd_chaos(args: &Args) -> Result<()> {
    let ranks = arg_usize(args, "ranks", 2)?;
    let seed = arg_u64(args, "seed", 42)?;
    let steps = arg_usize(args, "steps", 6)?;
    ensure!(
        (1..=8usize).contains(&ranks),
        "--ranks must be in 1..=8 (the chaos shape has 8 experts)"
    );
    ensure!(
        steps >= 2 && steps % 2 == 0,
        "--steps must be even and at least 2 (the crash lands at the midpoint)"
    );

    println!(
        "chaos: seeded fault injection over epshard/serve/train — R={ranks}, seed={seed}, \
         {} workers",
        exec::threads()
    );
    let mut doc = Json::run_doc("chaos").set("ranks", ranks).set("seed", seed);

    // ---- epshard: wire corruption on the EP dispatch, recovered bitwise
    let (tokens, experts, top_k, d_model, ffn) = (128usize, 8usize, 2usize, 64usize, 64usize);
    let capacity = (tokens * top_k).div_ceil(experts);
    let mut rng = Rng::seed_from(seed);
    let x = Mat::randn(tokens, d_model, 0.5, &mut rng);
    let w = MoeWeights::random(d_model, ffn, experts, &mut rng);
    let pw = PreparedWeights::new(w.clone(), Recipe::Fp8Flow);
    let cfg = EpConfig::serial(ranks, top_k, capacity, 0);
    let clean = ep_forward(&x, &pw, &cfg);
    let plan = FaultPlan::new(vec![
        // transient FP8-code flip: CRC32 detects it, one retransmission
        Fault {
            tick: wire_tick(0, 0, false),
            src: 0,
            dst: ANY_DST,
            kind: FaultKind::FlipPayloadBit { offset: seed as usize, bit: (seed % 8) as u8 },
            attempts: 1,
        },
        // UE8M0 sidecar flip — the silent 2^±k tile-scale error class —
        // held across two receptions (two retries, still no failover)
        Fault {
            tick: wire_tick(top_k - 1, 0, false),
            src: ranks - 1,
            dst: ANY_DST,
            kind: FaultKind::FlipSidecarBit { offset: seed as usize + 1, bit: (seed % 7) as u8 },
            attempts: 2,
        },
        // dropped message: virtual-clock timeout, then retransmission
        Fault {
            tick: wire_tick(0, 0, false),
            src: ranks - 1,
            dst: 0,
            kind: FaultKind::DropMessage,
            attempts: 1,
        },
        // straggler: late delivery, clock cost only
        Fault {
            tick: wire_tick(0, 0, false),
            src: 0,
            dst: 0,
            kind: FaultKind::Straggler { delay_ns: 3 << 20 },
            attempts: 1,
        },
    ]);
    let faulty = ep_forward_with_faults(&x, &pw, &cfg, &plan);
    ensure!(
        bits_eq(&faulty.y.data, &clean.y.data),
        "chaos epshard: recovered output diverged bitwise from the fault-free run"
    );
    let st = plan.stats();
    ensure!(st.checksum_fails >= 1, "chaos epshard: no wire corruption was detected");
    ensure!(st.retries >= 1, "chaos epshard: recovery issued no retransmissions");
    ensure!(st.failovers == 0, "chaos epshard: transient faults must not escalate to failover");
    println!(
        "  epshard  R={ranks}: bit-identical after recovery — checksum fails {}, retries {}, \
         recovery clock {} ns",
        st.checksum_fails, st.retries, st.clock_ns
    );
    doc = doc.set(
        "epshard",
        st.to_json().set("faults", plan.faults().len()).set("bit_identical", true),
    );

    // ---- serve: rank crash mid-trace under both failover policies
    let mode = ArrivalMode::parse("poisson").context("poisson arrivals")?;
    let gen = GenConfig {
        seed,
        mode,
        rate: 200.0,
        burst: 4.0,
        burst_period_s: 0.05,
        zipf_s: 1.1,
        min_len: 4,
        max_len: 32,
        vocab: 64,
        noise_pct: 10,
    };
    let requests = generate_requests(&gen, 32);
    let total_tokens: usize = requests.iter().map(|r| r.len()).sum();
    let total_slots = total_tokens * top_k;
    let slo = SloPolicy { max_wait_s: 5.0 / 1e3, max_tokens: 64 };
    let drop_policy = DropPolicy::parse("capacity").context("capacity drop policy")?;
    let mut sj = Json::obj();
    for (pname, policy) in [("reroute", FailoverPolicy::Reroute), ("drop", FailoverPolicy::Drop)] {
        let plan = FaultPlan::new(vec![
            Fault {
                tick: 1,
                src: ranks - 1,
                dst: ANY_DST,
                kind: FaultKind::CrashRank,
                attempts: 1,
            },
            Fault {
                tick: 2,
                src: 0,
                dst: ANY_DST,
                kind: FaultKind::FlipSidecarBit { offset: 17, bit: 2 },
                attempts: 1,
            },
        ]);
        let engine = ServeEngine::new(
            PreparedWeights::new(w.clone(), Recipe::Fp8Flow),
            TokenEmbed::new(gen.vocab, d_model, seed),
            ServeConfig {
                ranks,
                top_k,
                capacity_factor: 1.0,
                drop_policy,
                threads: 0,
                chunks: 1,
                overlap: false,
            },
        )
        .with_faults(plan, policy);
        let s = serve_trace(&engine, &requests, &slo);
        let st = engine.fault_stats();
        let slots = s.rank_rows.iter().sum::<usize>() + s.dropped_slots + s.failed_rank_drops;
        ensure!(
            slots == total_slots,
            "chaos serve/{pname}: drop ledger does not balance ({slots} != {total_slots} slots)"
        );
        ensure!(st.failovers >= 1, "chaos serve/{pname}: the scheduled rank crash never fired");
        ensure!(s.degraded_ticks >= 1, "chaos serve/{pname}: no tick ran in degraded mode");
        println!(
            "  serve    {pname:>7}: ledger balances over {total_slots} slots — degraded ticks \
             {}, failed-rank drops {}, checksum fails {}, failovers {}",
            s.degraded_ticks, s.failed_rank_drops, st.checksum_fails, st.failovers
        );
        sj = sj.set(
            pname,
            st.to_json()
                .set("ledger_slots", total_slots)
                .set("served_tokens", s.served_tokens)
                .set("dropped_slots", s.dropped_slots)
                .set("failed_rank_drops", s.failed_rank_drops)
                .set("degraded_ticks", s.degraded_ticks),
        );
    }
    doc = doc.set("serve", sj);

    // ---- train: crash at the midpoint, resume from checkpoint, replay
    let crash_at = steps / 2;
    let Some(mut tcfg) = TrainConfig::named("tiny") else { bail!("tiny config missing") };
    tcfg.ranks = ranks.min(tcfg.n_experts);
    let recipe = Recipe::Fp8Flow;

    let mut gold = NativeTrainer::new(tcfg, recipe, seed);
    let mut gold_corpus = Corpus::new(tcfg.vocab, seed, 10);
    let gold_out = gold.run(&mut gold_corpus, steps, 0)?;

    let mut pre = NativeTrainer::new(tcfg, recipe, seed);
    let mut pre_corpus = Corpus::new(tcfg.vocab, seed, 10);
    let pre_out = pre.run(&mut pre_corpus, crash_at, 0)?;
    let ckpt = {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("runs");
        std::fs::create_dir_all(&dir)?;
        dir.join(format!("chaos_ckpt_r{ranks}.json"))
    };
    save_checkpoint(&pre, &pre_corpus, &ckpt)?;
    drop(pre); // the injected crash: in-memory training state is gone

    // deliberately different init seed: restore must overwrite everything
    let mut resumed = NativeTrainer::new(tcfg, recipe, seed ^ 0x5EED_BEEF);
    let mut res_corpus = Corpus::new(tcfg.vocab, seed ^ 0x5EED_BEEF, 10);
    let at = restore_trainer(&mut resumed, &mut res_corpus, &ckpt)?;
    ensure!(at == crash_at, "chaos train: checkpoint resumed at step {at}, expected {crash_at}");
    let post_out = resumed.run(&mut res_corpus, steps - crash_at, 0)?;

    let replay: Vec<f32> = pre_out.losses.iter().chain(&post_out.losses).copied().collect();
    ensure!(
        bits_eq(&replay, &gold_out.losses),
        "chaos train: resumed loss trajectory diverged bitwise from the uninterrupted run"
    );
    println!(
        "  train    R={}: crash at step {crash_at}/{steps}, resumed from {ckpt:?} — loss \
         trajectory bit-identical",
        tcfg.ranks
    );
    doc = doc.set(
        "train",
        Json::obj()
            .set("steps", steps)
            .set("crash_at_step", crash_at)
            .set("ranks", tcfg.ranks)
            .set("checkpoint", ckpt.to_string_lossy().as_ref())
            .set("bit_identical", true),
    );

    let path = write_run_json(&format!("chaos_r{ranks}"), &doc)?;
    println!("wrote {path:?}");
    Ok(())
}

fn cmd_dqe(args: &Args) -> Result<()> {
    let n = arg_usize(args, "size", 512)?;
    let mut rng = Rng::seed_from(7);
    let x = Mat::rand_log_uniform(n, n, -6.0, 6.0, &mut rng);
    println!("double-quantization error (Eq. 1) on a [{n},{n}] log-uniform tensor:\n");
    let mut doc = Json::run_doc("dqe");
    for (label, mode) in
        [("float scales (incumbent)", ScaleMode::Float), ("po2 scales (ours)", ScaleMode::Po2)]
    {
        let r = dqe_report(&x, Fp8Format::E4M3, mode);
        println!("{label}:");
        println!(
            "  naive dequant->T->requant vs one-rounding ref: rel={:.3e} frac_changed={:.3}",
            r.naive_vs_ref.rel_fro, r.naive_vs_ref.frac_nonzero
        );
        println!(
            "  direct transpose          vs one-rounding ref: rel={:.3e} frac_changed={:.3}\n",
            r.direct_vs_ref.rel_fro, r.direct_vs_ref.frac_nonzero
        );
        doc = doc.set(
            label,
            Json::obj()
                .set("naive_rel", r.naive_vs_ref.rel_fro)
                .set("direct_rel", r.direct_vs_ref.rel_fro),
        );
    }
    let path = write_run_json("dqe_demo", &doc)?;
    println!("wrote {path:?}");
    Ok(())
}
