//! Executed-count prediction and the static↔runtime cross-check.
//!
//! The schematic graphs draw one node per logical op; the executed layer
//! launches each once per routed slot and/or per expert. Every [`Node`]
//! carries its multiplicity model (`units` × [`Mult`]), so the analyzer
//! can *predict* the executed cast/requant audits — `FwdStash::cast_ops`,
//! `BwdStats`, `WeightPrepStats`, `TrainMetrics` — from the graph alone.
//! [`cross_check`] compares a prediction against an executed audit and
//! emits an `SL009` error per divergent counter: the static pass and the
//! runtime must agree on the 12→2 story or the lint gate fails.
//!
//! One deliberate asymmetry: the executed weight prep
//! (`PreparedWeights::requantize_from_masters`) is **master-sourced for
//! every FP8 recipe** — both GEMM layouts are quantized straight from the
//! f32 masters, never derived by requantization. The incumbent *graphs*
//! (TeBlockwise/DeepSeekV3) draw the storage-derived tail the recipes
//! describe on paper (Q then naive-T). Executed audits are therefore
//! checked against the master-sourced (Fp8Flow-tail) prediction for every
//! FP8 recipe; the incumbent tails remain as schematic foils the lint
//! flags (`SL001`).

use crate::analysis::lineage::{classify, is_requant, propagate, OpClass};
use crate::analysis::rules::{Diagnostic, RuleId};
use crate::dataflow::graph::{DataflowGraph, Mult, Node, Stage};
use crate::util::json::Json;

/// Analyzer-predicted executed cast/requant counts for one graph at a
/// given `(experts, top_k)` shape.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecPrediction {
    /// Forward-path explicit casts (`FwdStash::cast_ops`).
    pub casts_fwd: usize,
    /// Backward-path explicit casts (`BwdStats::casts`).
    pub casts_bwd: usize,
    /// Backward-path requantizations (`BwdStats::requants`).
    pub requants_bwd: usize,
    /// Optimizer-tail weight quantizations (`WeightPrepStats::weight_quants`).
    pub opt_weight_quants: usize,
    /// Optimizer-tail requantizations (`WeightPrepStats::requants`).
    pub opt_requants: usize,
}

impl ExecPrediction {
    /// Predict the executed audits of `g` for `experts` experts and
    /// `top_k` routed slots: each node contributes
    /// `units × mult.count(experts, top_k)` kernel instances to the
    /// counter its lineage class lands in.
    pub fn of(g: &DataflowGraph, experts: usize, top_k: usize) -> ExecPrediction {
        let lin = propagate(g);
        let mut p = ExecPrediction::default();
        for n in &g.nodes {
            let inst = n.units * n.mult.count(experts, top_k);
            let requant = is_requant(n, &lin);
            if requant {
                if n.stage == Stage::Optimizer {
                    p.opt_requants += inst;
                } else if n.backward {
                    p.requants_bwd += inst;
                }
            }
            if classify(n.op) == OpClass::Conversion && !requant {
                if n.stage == Stage::Optimizer {
                    p.opt_weight_quants += inst;
                } else if n.backward {
                    p.casts_bwd += inst;
                } else {
                    p.casts_fwd += inst;
                }
            }
        }
        p
    }

    /// [`ExecPrediction::of`] with an explicit pipeline-chunk count —
    /// the EP runtime's `--chunks C` knob. Cast/requant totals are
    /// **chunk-invariant**: the entry quant runs once per batch and
    /// `Q(dy)` once per slot (both outside the chunk loop), and every
    /// per-expert counter fires once per expert regardless of how
    /// experts are grouped into pipeline units — so the prediction is
    /// `of(...)` for every `C`. Taking `chunks` explicitly (and
    /// asserting it) keeps that invariance a stated contract the lint
    /// runtime cross-check exercises at C > 1, not an accident.
    pub fn of_chunked(
        g: &DataflowGraph,
        experts: usize,
        top_k: usize,
        chunks: usize,
    ) -> ExecPrediction {
        assert!(chunks >= 1, "need at least one pipeline chunk");
        Self::of(g, experts, top_k)
    }

    /// JSON rendering for `runs/lint.json`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("casts_fwd", self.casts_fwd)
            .set("casts_bwd", self.casts_bwd)
            .set("requants_bwd", self.requants_bwd)
            .set("opt_weight_quants", self.opt_weight_quants)
            .set("opt_requants", self.opt_requants)
    }
}

/// Counts observed by actually running the layer/trainer — the
/// ground-truth side of [`cross_check`]. Same fields and units as
/// [`ExecPrediction`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecutedAudit {
    /// Forward-path explicit casts (`FwdStash::cast_ops`).
    pub casts_fwd: usize,
    /// Backward-path explicit casts (`BwdStats::casts`).
    pub casts_bwd: usize,
    /// Backward-path requantizations (`BwdStats::requants`).
    pub requants_bwd: usize,
    /// Optimizer-tail weight quantizations (`WeightPrepStats::weight_quants`).
    pub opt_weight_quants: usize,
    /// Optimizer-tail requantizations (`WeightPrepStats::requants`).
    pub opt_requants: usize,
}

/// Compare a static prediction against an executed audit; one `SL009`
/// error diagnostic per divergent counter (empty when they agree).
pub fn cross_check(
    recipe: &str,
    predicted: &ExecPrediction,
    executed: &ExecutedAudit,
) -> Vec<Diagnostic> {
    let pairs = [
        ("casts_fwd", predicted.casts_fwd, executed.casts_fwd),
        ("casts_bwd", predicted.casts_bwd, executed.casts_bwd),
        ("requants_bwd", predicted.requants_bwd, executed.requants_bwd),
        ("opt_weight_quants", predicted.opt_weight_quants, executed.opt_weight_quants),
        ("opt_requants", predicted.opt_requants, executed.opt_requants),
    ];
    pairs
        .iter()
        .filter(|(_, p, x)| p != x)
        .map(|(field, p, x)| Diagnostic {
            rule: RuleId::AuditDivergence,
            severity: RuleId::AuditDivergence.severity(),
            node: None,
            node_name: String::new(),
            stage: None,
            backward: false,
            message: format!(
                "{recipe}: analyzer predicts {field} = {p} but the executed audit \
                 reports {x} — the static pass and the runtime disagree"
            ),
            trace: String::new(),
        })
        .collect()
}

/// Render a diagnostic list as a JSON array (for `runs/lint.json`).
pub fn diagnostics_to_json(diags: &[Diagnostic]) -> Json {
    Json::Arr(
        diags
            .iter()
            .map(|d| {
                let mut j = Json::obj()
                    .set("rule", d.rule.code())
                    .set("name", d.rule.name())
                    .set("severity", d.severity.word());
                if let Some(id) = d.node {
                    j = j
                        .set("node", id)
                        .set("node_name", d.node_name.as_str())
                        .set("stage", format!("{:?}", d.stage.expect("anchored")))
                        .set("backward", d.backward);
                }
                j = j.set("message", d.message.as_str());
                if !d.trace.is_empty() {
                    j = j.set("lineage", d.trace.as_str());
                }
                j
            })
            .collect(),
    )
}

/// The analyzer's multiplicity ledger for one graph: per-node instance
/// counts at a given shape (debugging aid for the `lint -v` listing).
pub fn instance_ledger(g: &DataflowGraph, experts: usize, top_k: usize) -> Vec<(usize, usize)> {
    g.nodes.iter().map(|n| (n.id, instances(n, experts, top_k))).collect()
}

fn instances(n: &Node, experts: usize, top_k: usize) -> usize {
    n.units * n.mult.count(experts, top_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{build, build_train_step, Variant};

    #[test]
    fn predictions_reproduce_the_executed_algebra() {
        let (e, k) = (8, 2);
        let p = ExecPrediction::of(&build(Variant::TeBlockwise), e, k);
        assert_eq!(p.casts_fwd, 2 * e * k, "Q(x) + Q(act) per expert per slot");
        assert_eq!(p.casts_bwd, 3 * e * k, "Q(dy) + Q(d_gate) + Q(d_up)");
        assert_eq!(p.requants_bwd, 5 * e * k, "five naive wgrad-operand transposes");
        let p = ExecPrediction::of(&build(Variant::Fp8Flow), e, k);
        assert_eq!((p.casts_fwd, p.casts_bwd, p.requants_bwd), (1, k, 0));
        let p = ExecPrediction::of(&build(Variant::Bf16), e, k);
        assert_eq!(p, ExecPrediction::default());
    }

    #[test]
    fn train_tail_predictions() {
        let e = 4;
        let p = ExecPrediction::of(&build_train_step(Variant::Fp8Flow), e, 1);
        assert_eq!((p.opt_weight_quants, p.opt_requants), (6 * e, 0));
        let p = ExecPrediction::of(&build_train_step(Variant::TeBlockwise), e, 1);
        assert_eq!((p.opt_weight_quants, p.opt_requants), (3 * e, 3 * e));
        let p = ExecPrediction::of(&build_train_step(Variant::Bf16), e, 1);
        assert_eq!((p.opt_weight_quants, p.opt_requants), (0, 0));
    }

    #[test]
    fn chunked_prediction_is_chunk_invariant() {
        let (e, k) = (8, 2);
        for v in [Variant::Bf16, Variant::TeBlockwise, Variant::Fp8Flow] {
            let base = ExecPrediction::of(&build(v), e, k);
            for c in [1usize, 2, 4] {
                assert_eq!(ExecPrediction::of_chunked(&build(v), e, k, c), base, "{v:?} C={c}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least one pipeline chunk")]
    fn chunked_prediction_rejects_zero_chunks() {
        ExecPrediction::of_chunked(&build(Variant::Fp8Flow), 4, 1, 0);
    }

    #[test]
    fn cross_check_flags_each_divergent_field() {
        let p = ExecPrediction { casts_fwd: 1, casts_bwd: 2, ..Default::default() };
        let ok = ExecutedAudit { casts_fwd: 1, casts_bwd: 2, ..Default::default() };
        assert!(cross_check("fp8flow", &p, &ok).is_empty());
        let bad = ExecutedAudit { casts_fwd: 12, casts_bwd: 2, ..Default::default() };
        let d = cross_check("fp8flow", &p, &bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RuleId::AuditDivergence);
        assert!(d[0].message.contains("casts_fwd"));
    }
}
