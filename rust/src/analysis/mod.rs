//! Scale-lineage static analysis over the Fig. 2 dataflow graphs.
//!
//! The paper's core hazard — double quantization error from tensors
//! quantized along inconsistent axes (Eq. 4) — is a *structural* property
//! of the dataflow graph, so it can be caught before any kernel runs.
//! This module is that gate, in three layers:
//!
//! * [`lineage`] — an abstract interpreter: one pass over the graph
//!   propagating a per-edge [`Lineage`] (dtype, scale axis, originating
//!   quantize node, quantization-generation count, sidecar presence, and
//!   the ordered event history). [`CastSummary`] re-derives the graph's
//!   cast/requant counters as lineage queries — the counter methods on
//!   `DataflowGraph` delegate here, so the schematic numbers and the
//!   lint verdicts are one computation.
//! * [`rules`] — the rule engine (`SL001`–`SL009`): structured
//!   [`Diagnostic`]s with stable ids, severities, and lineage traces like
//!   "quantized row-wise at n5, requantized col-wise at n12". Errors mark
//!   structurally invalid graphs (the lint gate); warnings mark the
//!   numeric hazards the incumbent recipes knowingly ship. The Fp8Flow
//!   graphs produce zero of either.
//! * [`report`] — the static↔runtime bridge: [`ExecPrediction`] scales
//!   each schematic node by its `units × Mult` multiplicity to predict
//!   the executed cast/requant audits, and [`cross_check`] fails the
//!   build (`SL009`) if the runtime disagrees with the 12→2 story.
//!
//! Entry points: [`lint_graph`] for one graph, the `lint` CLI subcommand
//! for the full recipe sweep (`runs/lint.json`).

pub mod lineage;
pub mod report;
pub mod rules;

pub use lineage::{classify, is_requant, propagate, CastSummary, Lineage, OpClass, QuantEvent};
pub use report::{cross_check, diagnostics_to_json, instance_ledger, ExecPrediction, ExecutedAudit};
pub use rules::{lint_graph, tally, Diagnostic, RuleId, Severity};
