//! The abstract interpreter: per-edge scale lineage.
//!
//! [`propagate`] walks a [`DataflowGraph`] in topological order (node ids
//! are construction-ordered) and computes one [`Lineage`] per node output:
//! the value's dtype, scale-tile axis, originating quantize node,
//! quantization-generation count, sidecar presence, and the ordered list
//! of quantization events it has been through. The transfer function is
//! keyed on [`OpClass`], the coarse semantic class of each op.
//!
//! The central semantic choice is what survives a **dequantize**: the
//! value returns to dense, but its quantization *history* does not reset —
//! `qgen` is preserved. Quantizing a once-quantized-then-dequantized value
//! compounds rounding exactly like requantizing FP8 directly (Eq. 4), so
//! DeepSeek-V3's Q→wire→DQ→…→Q chains count as double quantization even
//! though no kernel ever consumes FP8 twice. Plain compute ops, by
//! contrast, produce *fresh* values (a GEMM output is new information, not
//! a re-encoding), so their lineage resets.

use crate::dataflow::graph::{DataflowGraph, Dtype, Node, OpKind, ScaleAxis, Stage};

/// Coarse semantic class of an op — the key of the lineage transfer
/// function (total over [`OpKind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    /// Graph source ([`OpKind::Input`]): a fresh external value.
    Source,
    /// Explicit cast kernels (`Quantize`/`Dequantize`/`Cast`) — the
    /// launches the Fig. 2 accounting counts.
    Conversion,
    /// Data movement (wire, permute/pad family): the value — and its
    /// lineage — passes through unchanged.
    Movement,
    /// Code-space transpose (`DirectTranspose`): flips the scale axis
    /// without touching the codes' values (no new generation).
    Transpose,
    /// `NaiveTransposeRequant`: dequantize→transpose→requantize in one
    /// node — one generation added, axis flipped.
    Requant,
    /// Quantization fused into a compute kernel (`FusedSwiGlu*Quant`):
    /// a fresh value born already quantized (generation 1).
    FusedQuant,
    /// Plain compute (GEMM, activation, scale/add, master update): the
    /// output is a fresh value — lineage resets.
    Compute,
}

/// Classify `op` into its [`OpClass`].
pub fn classify(op: OpKind) -> OpClass {
    use OpKind::*;
    match op {
        Input => OpClass::Source,
        Quantize | Dequantize | Cast => OpClass::Conversion,
        AllToAll | Permute | Pad | FusedPermutePad | Unpermute | Unpad | FusedUnpermuteUnpad => {
            OpClass::Movement
        }
        DirectTranspose => OpClass::Transpose,
        NaiveTransposeRequant => OpClass::Requant,
        FusedSwiGluQuant | FusedSwiGluBwdQuant => OpClass::FusedQuant,
        GroupedGemm | SwiGlu | SwiGluBwd | Scale | Add | MasterUpdate => OpClass::Compute,
    }
}

/// One quantization-relevant event in a value's history — the material of
/// the human-readable lineage trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantEvent {
    /// First quantization of a dense value at `node`.
    Quantized {
        /// Node performing the quantization.
        node: usize,
        /// Scale-tile orientation it produced.
        axis: ScaleAxis,
    },
    /// Re-quantization of an already-quantized value at `node` — a
    /// double-quantization-error site (Eq. 4).
    Requantized {
        /// Node performing the requantization.
        node: usize,
        /// Scale-tile orientation it produced.
        axis: ScaleAxis,
    },
    /// Dequantization back to dense at `node`. The value's quantization
    /// history survives this — requantizing later still compounds error.
    Dequantized {
        /// Node performing the dequantization.
        node: usize,
    },
}

/// The abstract value flowing along one edge.
#[derive(Clone, Debug)]
pub struct Lineage {
    /// Element type of the value (always the producing node's declared
    /// `out_dtype`).
    pub dtype: Dtype,
    /// Scale-tile axis — `Some` once the value has been quantized (kept
    /// through a dequantize as the last-known orientation).
    pub axis: Option<ScaleAxis>,
    /// The *first* quantize node in this value's history.
    pub origin: Option<usize>,
    /// Quantization-generation count: how many times this value has been
    /// pushed through a quantizer. ≥ 2 means double quantization.
    pub qgen: u32,
    /// Is the scale sidecar travelling with the payload? (FP8 only.)
    pub sidecar: bool,
    /// Ordered quantization history (drives the lineage traces).
    pub events: Vec<QuantEvent>,
}

impl Lineage {
    /// A fresh, never-quantized value of type `dtype`.
    fn fresh(dtype: Dtype) -> Lineage {
        Lineage { dtype, axis: None, origin: None, qgen: 0, sidecar: false, events: Vec::new() }
    }

    /// A fresh value born quantized inside the kernel of `n` (fused
    /// quantization, or a GEMM declared to emit FP8 directly).
    fn fresh_quantized(n: &Node) -> Lineage {
        let axis = n.axis.unwrap_or(ScaleAxis::RowWise);
        Lineage {
            dtype: n.out_dtype,
            axis: Some(axis),
            origin: Some(n.id),
            qgen: 1,
            sidecar: true,
            events: vec![QuantEvent::Quantized { node: n.id, axis }],
        }
    }
}

/// Run the abstract interpreter over `g`: one [`Lineage`] per node,
/// indexed by node id.
pub fn propagate(g: &DataflowGraph) -> Vec<Lineage> {
    let mut out: Vec<Lineage> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let input = n.inputs.first().map(|&i| out[i].clone());
        out.push(transfer(n, input));
    }
    out
}

/// The per-node transfer function: lineage of `n`'s output given the
/// lineage of its first input (rule checks inspect *all* input lineages
/// separately; the output lineage follows the primary data operand).
fn transfer(n: &Node, input: Option<Lineage>) -> Lineage {
    let inherit = || input.clone().unwrap_or_else(|| Lineage::fresh(n.out_dtype));
    match classify(n.op) {
        OpClass::Source => {
            let mut l = Lineage::fresh(n.out_dtype);
            if n.out_dtype == Dtype::Fp8 {
                // a pre-quantized external value: one generation, scales
                // attached, quantized before the graph began
                l.qgen = 1;
                l.axis = n.axis.or(Some(ScaleAxis::RowWise));
                l.sidecar = true;
            }
            l
        }
        OpClass::Conversion => match n.op {
            OpKind::Quantize => {
                let mut l = inherit();
                let axis = n.axis.unwrap_or(ScaleAxis::RowWise);
                l.events.push(if l.qgen >= 1 {
                    QuantEvent::Requantized { node: n.id, axis }
                } else {
                    QuantEvent::Quantized { node: n.id, axis }
                });
                l.qgen += 1;
                l.origin = l.origin.or(Some(n.id));
                l.axis = Some(axis);
                l.dtype = n.out_dtype;
                l.sidecar = true;
                l
            }
            OpKind::Dequantize => {
                let mut l = inherit();
                l.events.push(QuantEvent::Dequantized { node: n.id });
                l.dtype = n.out_dtype;
                l.sidecar = false;
                l
            }
            // Cast (bf16↔f32): value-preserving precision change
            _ => {
                let mut l = inherit();
                l.dtype = n.out_dtype;
                l
            }
        },
        OpClass::Movement => {
            let mut l = inherit();
            l.dtype = n.out_dtype;
            if n.op == OpKind::AllToAll && n.out_dtype == Dtype::Fp8 {
                // the wire either ships the sidecar or strands it
                l.sidecar = n.sidecar;
            }
            l
        }
        OpClass::Transpose => {
            let mut l = inherit();
            l.dtype = n.out_dtype;
            l.axis = n.axis.or(l.axis.map(ScaleAxis::flipped));
            l
        }
        OpClass::Requant => {
            let mut l = inherit();
            let axis = n.axis.or(l.axis.map(ScaleAxis::flipped)).unwrap_or(ScaleAxis::ColWise);
            l.events.push(QuantEvent::Dequantized { node: n.id });
            l.events.push(QuantEvent::Requantized { node: n.id, axis });
            l.qgen += 1;
            l.origin = l.origin.or(Some(n.id));
            l.axis = Some(axis);
            l.dtype = n.out_dtype;
            l.sidecar = true;
            l
        }
        OpClass::FusedQuant => Lineage::fresh_quantized(n),
        OpClass::Compute => {
            if n.out_dtype == Dtype::Fp8 {
                // a compute op declared to emit FP8 quantizes inside the
                // kernel (e.g. Fp8Flow's fc1-dgrad feeding the FP8 wire)
                Lineage::fresh_quantized(n)
            } else {
                Lineage::fresh(n.out_dtype)
            }
        }
    }
}

/// Is `n` a requantization — an op whose transfer re-quantizes already-FP8
/// data? Always true of the naive transpose (dequantize→requantize by
/// construction), and of an explicit `Quantize` whose input lineage is
/// still FP8. This is the lineage re-derivation of the graph's
/// `requant_nodes_*` counters.
pub fn is_requant(n: &Node, lineages: &[Lineage]) -> bool {
    match n.op {
        OpKind::NaiveTransposeRequant => true,
        OpKind::Quantize => {
            n.inputs.first().is_some_and(|&i| lineages[i].dtype == Dtype::Fp8)
        }
        _ => false,
    }
}

/// The graph's cast/requant counters, re-derived as lineage queries. The
/// counter methods on [`DataflowGraph`] delegate here, so the Fig. 2
/// numbers the tests pin and the analyzer's view are one computation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CastSummary {
    /// All explicit cast launches (conversion-class nodes).
    pub casts_total: usize,
    /// Explicit casts on the forward layer path (optimizer tail excluded).
    pub casts_fwd: usize,
    /// Explicit casts on the backward path.
    pub casts_bwd: usize,
    /// Explicit casts in the optimizer tail.
    pub casts_opt: usize,
    /// Backward requantizations of already-FP8 data ([`is_requant`]).
    pub requants_bwd: usize,
    /// Optimizer-tail requantizations of already-FP8 data.
    pub requants_opt: usize,
    /// Total Q/DQ events, counting the two hidden inside each naive
    /// transpose (fused in-kernel quantizations are *not* standalone
    /// events and are excluded, matching the executed accounting).
    pub qdq_events: usize,
}

impl CastSummary {
    /// Compute the summary for `g` from its propagated lineages.
    pub fn of(g: &DataflowGraph) -> CastSummary {
        let lin = propagate(g);
        let mut s = CastSummary::default();
        for n in &g.nodes {
            if classify(n.op) == OpClass::Conversion {
                s.casts_total += 1;
                if !n.backward && n.stage != Stage::Optimizer {
                    s.casts_fwd += 1;
                }
                if n.backward {
                    s.casts_bwd += 1;
                }
                if n.stage == Stage::Optimizer {
                    s.casts_opt += 1;
                }
            }
            if is_requant(n, &lin) {
                if n.backward {
                    s.requants_bwd += 1;
                }
                if n.stage == Stage::Optimizer {
                    s.requants_opt += 1;
                }
            }
            s.qdq_events += n.op.internal_qdq()
                + usize::from(matches!(n.op, OpKind::Quantize | OpKind::Dequantize));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{build, Variant};

    #[test]
    fn dequantize_preserves_generation() {
        let mut g = DataflowGraph::new("dq");
        let x = g.add("x", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("q", OpKind::Quantize, Stage::Dispatch, false, Dtype::Fp8, &[x]);
        let d = g.add("d", OpKind::Dequantize, Stage::Dispatch, false, Dtype::Bf16, &[q]);
        let q2 = g.add("q2", OpKind::Quantize, Stage::Fc1, false, Dtype::Fp8, &[d]);
        let lin = propagate(&g);
        assert_eq!(lin[q].qgen, 1);
        assert_eq!(lin[d].qgen, 1, "DQ must not launder the history");
        assert_eq!(lin[d].dtype, Dtype::Bf16);
        assert_eq!(lin[q2].qgen, 2, "Q after DQ is a double quantization");
        assert_eq!(lin[q2].origin, Some(q), "origin is the FIRST quantize");
        assert!(matches!(lin[q2].events.last(), Some(QuantEvent::Requantized { .. })));
    }

    #[test]
    fn compute_resets_lineage() {
        let mut g = DataflowGraph::new("fresh");
        let x = g.add("x", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("q", OpKind::Quantize, Stage::Fc1, false, Dtype::Fp8, &[x]);
        let mm = g.add("gemm", OpKind::GroupedGemm, Stage::Fc1, false, Dtype::Bf16, &[q]);
        let lin = propagate(&g);
        assert_eq!(lin[mm].qgen, 0, "a GEMM output is a fresh value");
        assert!(lin[mm].events.is_empty());
    }

    #[test]
    fn transposes_flip_the_axis() {
        let mut g = DataflowGraph::new("axis");
        let x = g.add("x", OpKind::Input, Stage::Router, false, Dtype::Bf16, &[]);
        let q = g.add("q", OpKind::Quantize, Stage::Fc1, false, Dtype::Fp8, &[x]);
        let dt = g.add("dt", OpKind::DirectTranspose, Stage::Fc1, true, Dtype::Fp8, &[q]);
        let nt = g.add("nt", OpKind::NaiveTransposeRequant, Stage::Fc1, true, Dtype::Fp8, &[q]);
        let lin = propagate(&g);
        assert_eq!(lin[q].axis, Some(ScaleAxis::RowWise));
        assert_eq!(lin[dt].axis, Some(ScaleAxis::ColWise));
        assert_eq!(lin[dt].qgen, 1, "direct transpose adds no generation");
        assert_eq!(lin[nt].axis, Some(ScaleAxis::ColWise));
        assert_eq!(lin[nt].qgen, 2, "naive transpose requantizes");
    }

    #[test]
    fn summary_matches_pinned_fig2_numbers() {
        // the lineage re-derivation must reproduce the Fig. 2 headline
        let s = CastSummary::of(&build(Variant::Fp8Flow));
        assert_eq!((s.casts_total, s.casts_fwd, s.casts_bwd), (2, 1, 1));
        assert_eq!(s.requants_bwd, 0);
        let s = CastSummary::of(&build(Variant::DeepSeekV3));
        assert_eq!((s.casts_total, s.casts_fwd, s.casts_bwd), (12, 6, 6));
        assert_eq!(s.requants_bwd, 2);
    }
}
