//! The rule engine: structured diagnostics over propagated lineages.
//!
//! Each rule has a stable id (`SL001`…), a fixed severity, and fires on a
//! structural pattern in the graph + lineage. The severity split is
//! deliberate:
//!
//! * **Error** — the graph is structurally invalid (undecodable wire
//!   payloads, dequantizing dense data, orphan nodes, type-confused
//!   kernels). No shipped variant contains one; the `lint` CLI exits
//!   nonzero on any.
//! * **Warning** — numerically hazardous but executable: the known
//!   double-quantization sites the incumbent recipes knowingly ship
//!   (naive transposes, re-quantization after a wire dequant, BF16
//!   islands). The Fp8Flow graphs produce **zero** of either.

use crate::analysis::lineage::{classify, propagate, Lineage, OpClass, QuantEvent};
use crate::dataflow::graph::{DataflowGraph, Dtype, Node, OpKind, ScaleAxis, Stage};

/// Diagnostic severity. `Error` fails the lint gate; `Warning` documents
/// a numeric hazard without failing the build.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Numerically hazardous but executable.
    Warning,
    /// Structurally invalid — fails the lint gate.
    Error,
}

impl Severity {
    /// Lowercase display form ("warning"/"error").
    pub fn word(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Stable rule identifiers of the scale-lineage analyzer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleId {
    /// SL001: (re)quantization of data whose lineage already carries a
    /// quantization generation — the paper's double-quantization error.
    DoubleQuant,
    /// SL002: a GEMM consuming FP8 operands whose scale axes disagree
    /// (e.g. a wgrad mixing a row-wise gradient with a requantized
    /// col-wise operand).
    AxisMismatchGemm,
    /// SL003: a dequantize whose input is not FP8.
    DequantNonFp8,
    /// SL004: a dequantize directly consuming a quantize — a redundant
    /// Q→DQ pair (pure rounding loss, no work in between).
    RedundantQdq,
    /// SL005: FP8 payload crossing an `AllToAll` without its scale
    /// sidecar — undecodable on the receiving rank.
    MissingSidecar,
    /// SL006: an op applied to an input of the wrong element type
    /// (quantizing FP8, activating FP8 codes, naive-transposing dense
    /// data, a GEMM mixing FP8 and dense operands).
    DtypeMismatch,
    /// SL007: a dense compute op inside the Fc1→Act→Fc2 span of an FP8
    /// graph — a BF16 island beyond the two legal GEMM-accumulator
    /// exceptions of §3.2.
    Bf16Island,
    /// SL008: a non-source node with no inputs.
    OrphanNode,
    /// SL009: the static prediction and an executed audit disagree
    /// (emitted by [`crate::analysis::cross_check`], not by the graph
    /// walk).
    AuditDivergence,
}

impl RuleId {
    /// Stable code string (diagnostic listings, `runs/lint.json`).
    pub fn code(self) -> &'static str {
        match self {
            RuleId::DoubleQuant => "SL001",
            RuleId::AxisMismatchGemm => "SL002",
            RuleId::DequantNonFp8 => "SL003",
            RuleId::RedundantQdq => "SL004",
            RuleId::MissingSidecar => "SL005",
            RuleId::DtypeMismatch => "SL006",
            RuleId::Bf16Island => "SL007",
            RuleId::OrphanNode => "SL008",
            RuleId::AuditDivergence => "SL009",
        }
    }

    /// Short name used in listings.
    pub fn name(self) -> &'static str {
        match self {
            RuleId::DoubleQuant => "double-quantization",
            RuleId::AxisMismatchGemm => "gemm-axis-mismatch",
            RuleId::DequantNonFp8 => "dequant-of-dense",
            RuleId::RedundantQdq => "redundant-q-dq",
            RuleId::MissingSidecar => "missing-scale-sidecar",
            RuleId::DtypeMismatch => "dtype-mismatch",
            RuleId::Bf16Island => "bf16-island",
            RuleId::OrphanNode => "orphan-node",
            RuleId::AuditDivergence => "audit-divergence",
        }
    }

    /// Fixed severity of the rule.
    pub fn severity(self) -> Severity {
        match self {
            RuleId::DoubleQuant
            | RuleId::AxisMismatchGemm
            | RuleId::RedundantQdq
            | RuleId::Bf16Island => Severity::Warning,
            RuleId::DequantNonFp8
            | RuleId::MissingSidecar
            | RuleId::DtypeMismatch
            | RuleId::OrphanNode
            | RuleId::AuditDivergence => Severity::Error,
        }
    }
}

/// One analyzer finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Which rule fired.
    pub rule: RuleId,
    /// Severity (== `rule.severity()`, denormalized for rendering).
    pub severity: Severity,
    /// Offending node id, when the finding anchors to one node.
    pub node: Option<usize>,
    /// Offending node's display name (empty for graph-level findings).
    pub node_name: String,
    /// Stage of the offending node.
    pub stage: Option<Stage>,
    /// Was the offending node on the backward path?
    pub backward: bool,
    /// Human-readable explanation.
    pub message: String,
    /// Lineage trace of the offending value, e.g. "quantized row-wise at
    /// n5 (Q(x) fc1-in), requantized col-wise at n12 (x naive-T)".
    pub trace: String,
}

impl Diagnostic {
    fn at(rule: RuleId, n: &Node, message: String, trace: String) -> Diagnostic {
        Diagnostic {
            rule,
            severity: rule.severity(),
            node: Some(n.id),
            node_name: n.name.clone(),
            stage: Some(n.stage),
            backward: n.backward,
            message,
            trace,
        }
    }

    /// One-line rendering: `SL001 warning [bwd Fc2 n17 'act naive-T'] …`.
    pub fn render(&self) -> String {
        let mut s = format!("{} {:<7}", self.rule.code(), self.severity.word());
        if let Some(id) = self.node {
            s.push_str(&format!(
                " [{} {:<10} n{id} '{}']",
                if self.backward { "bwd" } else { "fwd" },
                format!("{:?}", self.stage.expect("anchored diagnostic has a stage")),
                self.node_name
            ));
        }
        s.push_str(&format!(" {}", self.message));
        if !self.trace.is_empty() {
            s.push_str(&format!("\n      lineage: {}", self.trace));
        }
        s
    }
}

/// Render a lineage's event history as a trace string.
fn trace_of(l: &Lineage, g: &DataflowGraph) -> String {
    let step = |e: &QuantEvent| match *e {
        QuantEvent::Quantized { node, axis } => {
            format!("quantized {} at n{node} ({})", axis.word(), g.nodes[node].name)
        }
        QuantEvent::Requantized { node, axis } => {
            format!("requantized {} at n{node} ({})", axis.word(), g.nodes[node].name)
        }
        QuantEvent::Dequantized { node } => {
            format!("dequantized at n{node} ({})", g.nodes[node].name)
        }
    };
    l.events.iter().map(step).collect::<Vec<_>>().join(", ")
}

/// Run every graph rule over `g` and return the findings in node order.
pub fn lint_graph(g: &DataflowGraph) -> Vec<Diagnostic> {
    let lin = propagate(g);
    let uses_fp8 = g.nodes.iter().any(|n| n.out_dtype == Dtype::Fp8);
    let mut out = Vec::new();
    for n in &g.nodes {
        let in_lin = n.inputs.first().map(|&i| &lin[i]);

        // SL008 — a non-source node with nothing to consume
        if n.op != OpKind::Input && n.inputs.is_empty() {
            out.push(Diagnostic::at(
                RuleId::OrphanNode,
                n,
                format!("non-source op {:?} has no inputs", n.op),
                String::new(),
            ));
            continue; // every other rule needs an input lineage
        }

        // SL001 — explicit (re)quantization of already-quantized data
        if matches!(n.op, OpKind::Quantize | OpKind::NaiveTransposeRequant) {
            if let Some(l) = in_lin {
                if l.qgen >= 1 {
                    let new_axis = lin[n.id].axis.expect("quantizer output has an axis");
                    let relation = match l.axis {
                        Some(a) if a != new_axis => format!(
                            "re-quantizes {} after {} — cross-axis double \
                             quantization (the Eq. 4 error term)",
                            new_axis.word(),
                            a.word()
                        ),
                        _ => format!(
                            "re-quantizes {} along the same axis — benign only \
                             for exact power-of-two scales (Eq. 5–8)",
                            new_axis.word()
                        ),
                    };
                    out.push(Diagnostic::at(
                        RuleId::DoubleQuant,
                        n,
                        format!(
                            "input already carries quantization generation {}; {relation}",
                            l.qgen
                        ),
                        trace_of(&lin[n.id], g),
                    ));
                }
            }
        }

        // SL002 — GEMM operands with disagreeing scale axes
        if n.op == OpKind::GroupedGemm {
            let axes: Vec<(usize, ScaleAxis)> = n
                .inputs
                .iter()
                .filter_map(|&i| {
                    (lin[i].dtype == Dtype::Fp8).then(|| lin[i].axis.map(|a| (i, a))).flatten()
                })
                .collect();
            if axes.len() >= 2 && axes.iter().any(|&(_, a)| a != axes[0].1) {
                let desc = axes
                    .iter()
                    .map(|&(i, a)| format!("n{i} ({}) {}", g.nodes[i].name, a.word()))
                    .collect::<Vec<_>>()
                    .join(" vs ");
                out.push(Diagnostic::at(
                    RuleId::AxisMismatchGemm,
                    n,
                    format!("FP8 operands scaled along different axes: {desc}"),
                    n.inputs
                        .iter()
                        .map(|&i| trace_of(&lin[i], g))
                        .filter(|t| !t.is_empty())
                        .collect::<Vec<_>>()
                        .join(" | "),
                ));
            }
        }

        // SL003 / SL004 — dequantize sanity
        if n.op == OpKind::Dequantize {
            if let Some(l) = in_lin {
                if l.dtype != Dtype::Fp8 {
                    out.push(Diagnostic::at(
                        RuleId::DequantNonFp8,
                        n,
                        format!("dequantize applied to {:?} input (expects FP8)", l.dtype),
                        trace_of(l, g),
                    ));
                } else if n.inputs.first().is_some_and(|&i| g.nodes[i].op == OpKind::Quantize) {
                    out.push(Diagnostic::at(
                        RuleId::RedundantQdq,
                        n,
                        "dequantize directly consumes a quantize — a redundant Q→DQ \
                         pair (pure rounding loss, no work in between)"
                            .to_string(),
                        trace_of(&lin[n.id], g),
                    ));
                }
            }
        }

        // SL005 — FP8 on the wire without its scales
        if n.op == OpKind::AllToAll && n.out_dtype == Dtype::Fp8 && !n.sidecar {
            out.push(Diagnostic::at(
                RuleId::MissingSidecar,
                n,
                "FP8 payload crosses the all-to-all without its scale sidecar — \
                 undecodable on the receiving rank"
                    .to_string(),
                in_lin.map(|l| trace_of(l, g)).unwrap_or_default(),
            ));
        }

        // SL006 — element-type confusion at op inputs
        if let Some(l) = in_lin {
            let bad = match n.op {
                OpKind::Quantize
                | OpKind::FusedSwiGluQuant
                | OpKind::FusedSwiGluBwdQuant
                | OpKind::SwiGlu
                | OpKind::SwiGluBwd
                | OpKind::Cast => (l.dtype == Dtype::Fp8)
                    .then(|| format!("{:?} expects a dense input, got FP8 codes", n.op)),
                OpKind::NaiveTransposeRequant => (l.dtype != Dtype::Fp8).then(|| {
                    format!("naive transpose-requant expects FP8 input, got {:?}", l.dtype)
                }),
                OpKind::GroupedGemm => {
                    let has_fp8 = n.inputs.iter().any(|&i| lin[i].dtype == Dtype::Fp8);
                    let has_dense = n.inputs.iter().any(|&i| lin[i].dtype != Dtype::Fp8);
                    (has_fp8 && has_dense).then(|| {
                        "GEMM mixes FP8 and dense operands in one kernel".to_string()
                    })
                }
                _ => None,
            };
            if let Some(msg) = bad {
                out.push(Diagnostic::at(
                    RuleId::DtypeMismatch,
                    n,
                    msg,
                    trace_of(l, g),
                ));
            }
        }

        // SL007 — dense compute inside the quantized expert span
        if uses_fp8
            && matches!(n.stage, Stage::Fc1 | Stage::Activation | Stage::Fc2)
            && n.out_dtype != Dtype::Fp8
            && classify(n.op) == OpClass::Compute
            && n.op != OpKind::GroupedGemm
        {
            out.push(Diagnostic::at(
                RuleId::Bf16Island,
                n,
                format!(
                    "dense {:?} inside the Fc1→Act→Fc2 span of an FP8 graph — a BF16 \
                     island beyond the two legal GEMM-accumulator exceptions (§3.2)",
                    n.op
                ),
                in_lin.map(|l| trace_of(l, g)).unwrap_or_default(),
            ));
        }
    }
    out
}

/// Count `(errors, warnings)` in a diagnostic set.
pub fn tally(diags: &[Diagnostic]) -> (usize, usize) {
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    (errors, diags.len() - errors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{build, build_train_step, Variant};

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule.code()).collect()
    }

    #[test]
    fn fp8flow_and_bf16_are_clean() {
        for v in [Variant::Fp8Flow, Variant::Bf16] {
            assert!(lint_graph(&build(v)).is_empty(), "{} layer", v.name());
            assert!(lint_graph(&build_train_step(v)).is_empty(), "{} train", v.name());
        }
    }

    #[test]
    fn blockwise_reproduces_known_findings() {
        let diags = lint_graph(&build(Variant::TeBlockwise));
        assert_eq!(
            codes(&diags),
            vec!["SL007", "SL001", "SL002", "SL007", "SL001", "SL002"],
            "swiglu island, act naive-T, fc2-wgrad, swiglu-bwd island, x naive-T, fc1-wgrad"
        );
        assert_eq!(tally(&diags), (0, 6), "hazards, not structural errors");
    }

    #[test]
    fn deepseek_flags_wire_requants_too() {
        let diags = lint_graph(&build(Variant::DeepSeekV3));
        let dq = diags.iter().filter(|d| d.rule == RuleId::DoubleQuant).count();
        assert_eq!(dq, 4, "2 post-wire requants + 2 naive transposes");
        assert_eq!(diags.len(), 8);
        // the post-dispatch requant's trace tells the full story
        let requant = diags.iter().find(|d| d.node_name == "Q(x) fc1-in").unwrap();
        assert!(requant.trace.contains("quantized row-wise"), "{}", requant.trace);
        assert!(requant.trace.contains("dequantized"), "{}", requant.trace);
        assert!(requant.trace.contains("requantized"), "{}", requant.trace);
    }

    #[test]
    fn incumbent_train_tail_adds_weight_requant_finding() {
        let layer = lint_graph(&build(Variant::TeBlockwise)).len();
        let step = lint_graph(&build_train_step(Variant::TeBlockwise));
        assert_eq!(step.len(), layer + 1);
        assert_eq!(step.last().unwrap().rule, RuleId::DoubleQuant);
        assert_eq!(step.last().unwrap().node_name, "w naive-T dgrad-layout");
    }
}
