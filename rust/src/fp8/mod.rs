//! Software FP8 numeric substrate — the paper's numeric-format layer,
//! implemented bit-exactly (parity-tested against JAX/ml_dtypes, see
//! `python/tests/test_codec_parity.py`).
//!
//! Contents map directly onto §3.1 of the paper:
//!
//! * [`e4m3`] / [`e5m2`] — the FP8 codecs (OCP FP8, `float8_e4m3fn` /
//!   `float8_e5m2` semantics: RNE, E4M3 overflow→NaN, subnormals).
//! * [`ue8m0`] — power-of-two scale format used by the po2 recipe.
//! * [`tile`] — the 1×128-tile quantizer (Eq. 2–3), row- and column-wise,
//!   with float-scale and power-of-two-scale recipes.
//! * [`tensor`] — [`tensor::Fp8Tensor`]: payload + per-tile scales + layout.
//! * [`transpose`] — naive dequantize→transpose→requantize vs the paper's
//!   **scaling-aware direct transpose** (Alg. 1).
//! * [`error`] — the double-quantization-error metric (Eq. 1).

pub mod e4m3;
pub mod e5m2;
pub mod error;
pub mod tensor;
pub mod tile;
pub mod transpose;
pub mod ue8m0;

/// FP8 payload formats supported by the substrate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Format {
    /// OCP E4M3 (finite-only; max 448; NaN = S.1111.111). The paper's
    /// activation/weight format.
    E4M3,
    /// OCP E5M2 (IEEE-like; has ±Inf; max finite 57344). Wider range,
    /// coarser mantissa; conventional gradient format.
    E5M2,
}

impl Fp8Format {
    /// Largest finite representable magnitude (Eq. 2 denominator for E4M3).
    pub fn max_finite(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    /// Encode one f32 to this format's code.
    pub fn encode(self, x: f32) -> u8 {
        match self {
            Fp8Format::E4M3 => e4m3::encode(x),
            Fp8Format::E5M2 => e5m2::encode(x),
        }
    }

    /// Decode one code to f32.
    pub fn decode(self, c: u8) -> f32 {
        match self {
            Fp8Format::E4M3 => e4m3::decode(c),
            Fp8Format::E5M2 => e5m2::decode(c),
        }
    }
}

/// Scaling-factor recipe (the paper's pivotal design axis, §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleMode {
    /// `s = amax / fmax` exactly (finest use of the FP8 grid; transpose
    /// requires requantization → double quantization error).
    Float,
    /// `s = 2^ceil(log2(amax / fmax))` (UE8M0-compatible; enables the
    /// lossless scaling-aware direct transpose of Alg. 1).
    Po2,
}

/// Tile length used by every per-tile quantizer in the paper (128
/// contiguous elements per scaling factor, Eq. 2).
pub const TILE: usize = 128;
