//! Bit-exact software codec for OCP **FP8 E5M2** (`float8_e5m2` semantics).
//!
//! Layout: `S EEEEE MM`, exponent bias 15 — a miniature IEEE-754 float:
//!
//! * normals: `(-1)^S · 2^(E-15) · (1 + M/4)`, `E ∈ 1..=30`
//! * subnormals (`E = 0`): `(-1)^S · 2^-14 · (M/4)` — grid unit `2^-16`
//! * `E = 31`: ±Inf (`M = 0`) and NaNs (`M ≠ 0`)
//! * max finite: `S.11110.11` = ±57344
//! * conversion from f32: RNE; finite values that round above 57344 → ±Inf.

/// Exponent bias.
pub const BIAS: i32 = 15;
/// Smallest positive subnormal = 2^-16.
pub const MIN_SUBNORMAL: f32 = 1.52587890625e-5;
/// Smallest positive normal = 2^-14.
pub const MIN_NORMAL: f32 = 6.103515625e-5;
/// Largest finite magnitude.
pub const MAX_FINITE: f32 = 57344.0;
/// Positive infinity code.
pub const INF_CODE: u8 = 0x7C;
/// Canonical quiet NaN code.
pub const NAN_CODE: u8 = 0x7E;

#[inline]
/// Is `c` one of the NaN codes?
pub const fn is_nan(c: u8) -> bool {
    (c & 0x7C == 0x7C) && (c & 0x03 != 0)
}

#[inline]
/// Is `c` one of the Inf codes?
pub const fn is_inf(c: u8) -> bool {
    c & 0x7F == 0x7C
}

/// Decode a single E5M2 code to f32 (exact).
pub fn decode(c: u8) -> f32 {
    let sign = if c & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((c >> 2) & 0x1F) as i32;
    let m = (c & 0x03) as i32;
    if e == 31 {
        return if m == 0 { sign * f32::INFINITY } else { f32::NAN };
    }
    if e == 0 {
        sign * (m as f32 / 4.0) * (-14.0f32).exp2()
    } else {
        sign * (1.0 + m as f32 / 4.0) * ((e - BIAS) as f32).exp2()
    }
}

/// Encode an f32 to E5M2 with round-to-nearest-even.
pub fn encode(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    if x.is_nan() {
        return sign | NAN_CODE;
    }
    if x.is_infinite() {
        return sign | INF_CODE;
    }
    let abs_bits = bits & 0x7FFF_FFFF;
    if abs_bits == 0 {
        return sign;
    }
    let f32_exp = (abs_bits >> 23) as i32;
    let f32_man = abs_bits & 0x7F_FFFF;
    if f32_exp == 0 {
        return sign; // f32 subnormal < 2^-126 ≪ 2^-16 grid
    }
    let ue = f32_exp - 127;

    if ue >= -14 {
        // Round 23-bit mantissa to 2 bits, RNE.
        let mut m2 = f32_man >> 21;
        let low = f32_man & 0x1F_FFFF;
        const HALF: u32 = 0x10_0000;
        if low > HALF || (low == HALF && (m2 & 1) == 1) {
            m2 += 1;
        }
        let mut ue = ue;
        if m2 == 4 {
            m2 = 0;
            ue += 1;
        }
        if ue > 15 {
            return sign | INF_CODE; // overflow → ±Inf (IEEE-like)
        }
        let e_field = (ue + BIAS) as u8; // 1..=30
        sign | (e_field << 2) | m2 as u8
    } else {
        // Subnormal: RNE onto the 2^-16 grid; x·2^16 is exact in f32.
        let q = (f32::from_bits(abs_bits) * 65536.0).round_ties_even() as u32;
        sign | q as u8 // q ≤ 4 rolls into first normal (2^-14) — code 0x04
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(encode(57344.0), 0x7B);
        assert_eq!(encode(1.0), 0x3C);
        assert_eq!(encode(f32::INFINITY), INF_CODE);
        assert_eq!(encode(-f32::INFINITY), 0xFC);
        assert!(is_nan(encode(f32::NAN)));
        assert_eq!(encode(0.0), 0x00);
        assert_eq!(encode(-0.0), 0x80);
        assert_eq!(encode(MIN_NORMAL), 0x04);
        assert_eq!(encode(MIN_SUBNORMAL), 0x01);
        // overflow: midpoint between 57344 and would-be 65536 is 61440
        assert_eq!(encode(61440.0), 0x7C); // tie rounds to even (m=0 → next exp → Inf)
        assert_eq!(encode(61439.0), 0x7B);
        assert_eq!(encode(70000.0), INF_CODE);
    }

    #[test]
    fn roundtrip_all_finite_codes() {
        for c in 0..=255u8 {
            if is_nan(c) {
                assert!(decode(c).is_nan());
                continue;
            }
            assert_eq!(encode(decode(c)), c, "code {c:#04x} value {}", decode(c));
        }
    }

    #[test]
    fn wider_range_than_e4m3() {
        // E5M2 represents magnitudes E4M3 cannot.
        assert!(decode(encode(1000.0)).is_finite());
        assert!((decode(encode(1000.0)) - 1024.0).abs() < 1.0);
        assert!(decode(encode(3.0e-5)) > 0.0);
    }

    #[test]
    fn sign_symmetry() {
        let mut x = 1e-4f32;
        while x < 57344.0 {
            assert_eq!(encode(-x), encode(x) | 0x80);
            x *= 1.07;
        }
    }

    #[test]
    fn rne_ties() {
        // 1.125 is the midpoint between 1.0 (m=0) and 1.25 (m=1) → even (1.0)
        assert_eq!(decode(encode(1.125)), 1.0);
        // 1.375 is the midpoint between 1.25 (m=1) and 1.5 (m=2) → even (1.5)
        assert_eq!(decode(encode(1.375)), 1.5);
    }
}
