//! The per-tile quantizer (Eq. 2–3): one scaling factor per 128 contiguous
//! elements, `s = amax / fmax` (Float recipe) or `s = 2^ceil(log2(amax /
//! fmax))` (Po2 recipe, UE8M0-compatible — the recipe that makes the
//! scaling-aware transpose lossless).

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::{ue8m0, Fp8Format, ScaleMode, TILE};
use crate::util::mat::Mat;

/// Scale for one tile given its absolute maximum.
///
/// Returns `(scale, exponent)`; exponent is meaningful only in Po2 mode.
/// A zero tile gets scale 1 so payload stays exactly zero.
#[inline]
pub fn tile_scale(amax: f32, fmt: Fp8Format, mode: ScaleMode) -> (f32, i32) {
    debug_assert!(amax >= 0.0);
    if amax == 0.0 {
        return (1.0, 0);
    }
    match mode {
        ScaleMode::Float => (amax / fmt.max_finite(), 0),
        ScaleMode::Po2 => {
            let e = ue8m0::ceil_log2(amax / fmt.max_finite());
            ((e as f32).exp2(), e)
        }
    }
}

#[inline]
fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// `Q_row(X)` — row-wise per-tile quantization (Eq. 2–3), parallel over
/// row chunks on the [`crate::exec`] pool.
pub fn quantize_rowwise(x: &Mat, fmt: Fp8Format, mode: ScaleMode) -> Fp8Tensor {
    quantize_rowwise_with_threads(x, fmt, mode, exec::threads())
}

/// [`quantize_rowwise`] with an explicit worker count (1 = serial). Rows
/// are independent (one scale per 1×128 row tile), so the parallel result
/// is bit-identical to the serial one.
pub fn quantize_rowwise_with_threads(
    x: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    threads: usize,
) -> Fp8Tensor {
    let tpr = n_tiles(x.cols);
    let mut data = vec![0u8; x.rows * x.cols];
    let mut scales = vec![0.0f32; x.rows * tpr];
    let mut sexp = vec![0i32; x.rows * tpr];
    let p = Partition::even(x.rows, exec::workers_for(threads, x.rows));
    if p.len() <= 1 {
        quantize_rows(x, fmt, mode, 0..x.rows, &mut data, &mut scales, &mut sexp);
    } else {
        let d_parts = exec::split_parts(&p, x.cols, &mut data);
        let s_parts = exec::split_parts(&p, tpr, &mut scales);
        let e_parts = exec::split_parts(&p, tpr, &mut sexp);
        let tasks: Vec<_> = d_parts
            .into_iter()
            .zip(s_parts)
            .zip(e_parts)
            .zip(p.ranges())
            .map(|(((d, s), e), r)| (d, s, e, r))
            .collect();
        exec::run_tasks(tasks, |(d, s, e, r)| quantize_rows(x, fmt, mode, r, d, s, e));
    }
    if mode == ScaleMode::Float {
        sexp.clear();
    }
    Fp8Tensor {
        rows: x.rows,
        cols: x.cols,
        fmt,
        mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    }
}

/// Serial quantizer over one contiguous row chunk; the slices cover
/// exactly rows `rows` of the output.
fn quantize_rows(
    x: &Mat,
    fmt: Fp8Format,
    mode: ScaleMode,
    rows: std::ops::Range<usize>,
    data: &mut [u8],
    scales: &mut [f32],
    sexp: &mut [i32],
) {
    let tpr = n_tiles(x.cols);
    for i in rows.clone() {
        let row = x.row(i);
        let r = i - rows.start;
        for t in 0..tpr {
            let j0 = t * TILE;
            let j1 = (j0 + TILE).min(x.cols);
            let (s, e) = tile_scale(amax(&row[j0..j1]), fmt, mode);
            let inv = 1.0 / s;
            match fmt {
                // hot path: branch-free fused multiply+encode
                Fp8Format::E4M3 => crate::fp8::e4m3::encode_scaled_slice(
                    &row[j0..j1],
                    inv,
                    &mut data[r * x.cols + j0..r * x.cols + j1],
                ),
                _ => {
                    for j in j0..j1 {
                        data[r * x.cols + j] = fmt.encode(row[j] * inv);
                    }
                }
            }
            scales[r * tpr + t] = s;
            sexp[r * tpr + t] = e;
        }
    }
}

/// `Q_col(X)` — column-wise per-tile quantization (tiles run down columns).
pub fn quantize_colwise(x: &Mat, fmt: Fp8Format, mode: ScaleMode) -> Fp8Tensor {
    let rb = n_tiles(x.rows);
    let mut data = vec![0u8; x.rows * x.cols];
    let mut scales = vec![0.0f32; rb * x.cols];
    let mut sexp = vec![0i32; rb * x.cols];
    for b in 0..rb {
        let i0 = b * TILE;
        let i1 = (i0 + TILE).min(x.rows);
        for j in 0..x.cols {
            let mut m = 0.0f32;
            for i in i0..i1 {
                m = m.max(x.at(i, j).abs());
            }
            let (s, e) = tile_scale(m, fmt, mode);
            scales[b * x.cols + j] = s;
            sexp[b * x.cols + j] = e;
            let inv = 1.0 / s;
            for i in i0..i1 {
                data[i * x.cols + j] = fmt.encode(x.at(i, j) * inv);
            }
        }
    }
    if mode == ScaleMode::Float {
        sexp.clear();
    }
    Fp8Tensor {
        rows: x.rows,
        cols: x.cols,
        fmt,
        mode,
        layout: TileLayout::ColWise,
        data,
        scales,
        sexp,
    }
}

/// Quantize a flat vector as a single logical row (1-D convenience).
pub fn quantize_vec(xs: &[f32], fmt: Fp8Format, mode: ScaleMode) -> Fp8Tensor {
    quantize_rowwise(&Mat::from_vec(1, xs.len(), xs.to_vec()), fmt, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::e4m3;
    use crate::util::prop::props;
    use crate::util::rng::Rng;

    #[test]
    fn payload_within_range_po2() {
        let mut rng = Rng::seed_from(10);
        let x = Mat::rand_log_uniform(8, 256, -12.0, 9.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        for &c in &q.data {
            assert!(!e4m3::is_nan(c), "quantized payload must be finite");
        }
    }

    #[test]
    fn payload_within_range_float() {
        let mut rng = Rng::seed_from(11);
        let x = Mat::rand_log_uniform(8, 256, -12.0, 9.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        for &c in &q.data {
            assert!(!e4m3::is_nan(c));
        }
    }

    #[test]
    fn float_scale_uses_full_grid() {
        // With Float scales the tile amax maps exactly to ±448.
        let mut xs = vec![0.25f32; 128];
        xs[7] = 3.7;
        let q = quantize_vec(&xs, Fp8Format::E4M3, ScaleMode::Float);
        assert_eq!(q.data[7], e4m3::encode(448.0));
    }

    #[test]
    fn po2_scale_is_power_of_two() {
        let mut rng = Rng::seed_from(12);
        let x = Mat::randn(4, 256, 1.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        for (k, &s) in q.scales.iter().enumerate() {
            assert_eq!(s, (q.sexp[k] as f32).exp2());
            assert_eq!(s.to_bits() & 0x7F_FFFF, 0, "scale {s} not a power of two");
        }
    }

    #[test]
    fn zero_tile_stays_zero() {
        let x = Mat::zeros(2, 256);
        for mode in [ScaleMode::Float, ScaleMode::Po2] {
            let q = quantize_rowwise(&x, Fp8Format::E4M3, mode);
            assert!(q.data.iter().all(|&c| c == 0));
            assert!(q.scales.iter().all(|&s| s == 1.0));
            assert_eq!(q.dequantize(), x);
        }
    }

    #[test]
    fn quantization_error_bounded() {
        // One quantization step: |x − D(Q(x))| ≤ max(|x|/16, half subnormal
        // ULP at the tile scale). 3 mantissa bits → half-ULP ≤ 1/16 relative
        // for normal payloads; the absolute floor covers subnormal payloads.
        props("quant rel err bound", 64, |g| {
            let n = 128 * g.usize_in(1, 3);
            let xs = g.vec_of(n, |g| g.f32_normal() * 4.0);
            for mode in [ScaleMode::Float, ScaleMode::Po2] {
                let q = quantize_vec(&xs, Fp8Format::E4M3, mode);
                let d = q.dequantize();
                for (j, (a, b)) in xs.iter().zip(&d.data).enumerate() {
                    let s_tile = q.scale_at(0, j);
                    let tol = (a.abs() / 16.0).max(0.5 * e4m3::MIN_SUBNORMAL * s_tile);
                    assert!(
                        (a - b).abs() <= tol * (1.0 + 1e-5),
                        "mode={mode:?} j={j} a={a} b={b} tol={tol}"
                    );
                }
            }
        });
    }

    #[test]
    fn row_col_agree_on_transpose() {
        // Q_col(X) must equal Q_row(Xᵀ) transposed — the layout duality the
        // whole transpose story relies on.
        let mut rng = Rng::seed_from(13);
        let x = Mat::rand_log_uniform(256, 256, -6.0, 6.0, &mut rng);
        let qc = quantize_colwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let qr_t = quantize_rowwise(&x.transpose(), Fp8Format::E4M3, ScaleMode::Po2);
        for i in 0..x.rows {
            for j in 0..x.cols {
                assert_eq!(qc.code_at(i, j), qr_t.code_at(j, i));
                assert_eq!(qc.scale_at(i, j), qr_t.scale_at(j, i));
            }
        }
    }

    #[test]
    fn idempotence_eq5_to_8() {
        // Q_row(D(Q_row(x))) == Q_row(x): requantizing along the SAME
        // layout with deterministic rounding is exact (paper Eq. 5–8).
        props("row-quant idempotent", 48, |g| {
            let n = 128 * g.usize_in(1, 4);
            let xs = g.vec_of(n, |g| g.f32_wide());
            // NaN-free input (quantizer contract)
            let xs: Vec<f32> = xs.into_iter().map(|x| if x.is_finite() { x } else { 0.0 }).collect();
            // Po2 recipe: scales are exact powers of two, so dequantization
            // is exact (c·2^e) and requantization is a *bitwise* fixed
            // point — the property the scaling-aware transpose relies on.
            {
                let q1 = quantize_vec(&xs, Fp8Format::E4M3, ScaleMode::Po2);
                let d1 = q1.dequantize();
                let q2 = quantize_vec(&d1.data, Fp8Format::E4M3, ScaleMode::Po2);
                let d2 = q2.dequantize();
                for (a, b) in d1.data.iter().zip(&d2.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "po2 value drifted: {a} -> {b}");
                }
                if q1.scales == q2.scales {
                    assert_eq!(q1.data, q2.data, "po2 payload changed under requantization");
                }
            }
            // Float recipe: the recomputed scale may drift by an ulp or two
            // (448·s round-trips through f32 division), so the guarantee is
            // payload stability + tightly-bounded value drift.
            {
                let q1 = quantize_vec(&xs, Fp8Format::E4M3, ScaleMode::Float);
                let d1 = q1.dequantize();
                let q2 = quantize_vec(&d1.data, Fp8Format::E4M3, ScaleMode::Float);
                assert_eq!(q1.data, q2.data, "float payload changed under requantization");
                for (a, b) in q1.scales.iter().zip(&q2.scales) {
                    let rel = ((a - b) / a.abs().max(1e-38)).abs();
                    assert!(rel <= 4.0 * f32::EPSILON, "float scale drifted: {a} -> {b}");
                }
            }
        });
    }

    #[test]
    fn ragged_tail_tile() {
        let mut rng = Rng::seed_from(14);
        let x = Mat::randn(3, 200, 1.0, &mut rng); // 128 + 72
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let d = q.dequantize();
        assert!(d.rel_err(&x) < 0.05);
    }

    #[test]
    fn parallel_rows_bit_identical_to_serial() {
        let mut rng = Rng::seed_from(77);
        let x = Mat::rand_log_uniform(37, 300, -6.0, 6.0, &mut rng); // ragged both ways
        for mode in [ScaleMode::Float, ScaleMode::Po2] {
            let serial = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, 1);
            for t in [2usize, 8, 64] {
                let par = quantize_rowwise_with_threads(&x, Fp8Format::E4M3, mode, t);
                assert_eq!(par.data, serial.data, "{mode:?} threads={t}");
                assert_eq!(par.scales, serial.scales, "{mode:?} threads={t}");
                assert_eq!(par.sexp, serial.sexp, "{mode:?} threads={t}");
            }
        }
    }

    #[test]
    fn e5m2_roundtrip_reasonable() {
        let mut rng = Rng::seed_from(15);
        let x = Mat::randn(4, 256, 1.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E5M2, ScaleMode::Po2);
        let d = q.dequantize();
        // 2 mantissa bits → coarser than E4M3 but bounded
        assert!(d.rel_err(&x) < 0.12, "rel={}", d.rel_err(&x));
        let q3 = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        assert!(q3.dequantize().rel_err(&x) < d.rel_err(&x));
    }
}
