//! Double-quantization-error measurement (Eq. 1):
//!
//! `E = Q_col(D(Q_row(X))) − Q_col(X)`
//!
//! plus the information-preservation metric the direct transpose optimizes
//! (distance to the one-rounding reference `D(Q_row(X))`). Used by the
//! `ablation_dqe` bench and the convergence analysis.

use crate::fp8::tile::{quantize_colwise, quantize_rowwise};
use crate::fp8::transpose::{direct_transpose, direct_transpose_float, naive_transpose};
use crate::fp8::{Fp8Format, ScaleMode};
use crate::util::mat::Mat;

/// Elementwise error statistics between two same-shape matrices.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrStats {
    /// Largest absolute difference.
    pub max_abs: f64,
    /// Mean absolute difference.
    pub mean_abs: f64,
    /// Relative Frobenius-norm difference.
    pub rel_fro: f64,
    /// Fraction of elements with a nonzero (bitwise) difference.
    pub frac_nonzero: f64,
}

impl ErrStats {
    /// Compute stats between two same-shape matrices.
    pub fn between(a: &Mat, b: &Mat) -> ErrStats {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        let n = a.data.len().max(1);
        let mut max_abs = 0.0f64;
        let mut sum_abs = 0.0f64;
        let mut nz = 0usize;
        for (&x, &y) in a.data.iter().zip(&b.data) {
            let d = (x as f64 - y as f64).abs();
            max_abs = max_abs.max(d);
            sum_abs += d;
            if x.to_bits() != y.to_bits() {
                nz += 1;
            }
        }
        ErrStats {
            max_abs,
            mean_abs: sum_abs / n as f64,
            rel_fro: a.rel_err(b),
            frac_nonzero: nz as f64 / n as f64,
        }
    }
}

/// Eq. 1 and companions, for one input matrix and recipe.
#[derive(Clone, Copy, Debug)]
pub struct DqeReport {
    /// `Q_col(D(Q_row(X)))` vs `Q_col(X)` — the paper's E (naive path).
    pub naive_vs_qcol: ErrStats,
    /// Direct-transpose result vs `Q_col(X)`.
    pub direct_vs_qcol: ErrStats,
    /// Naive path vs the one-rounding reference `D(Q_row(X))ᵀ` — the
    /// *extra* error added by the second quantization.
    pub naive_vs_ref: ErrStats,
    /// Direct path vs the one-rounding reference (0 up to bounded
    /// underflow with po2 scales).
    pub direct_vs_ref: ErrStats,
}

/// Compute the full double-quantization-error report.
///
/// `mode` selects the recipe: in [`ScaleMode::Po2`] the direct path is the
/// paper's Alg. 1; in [`ScaleMode::Float`] it is the requantizing
/// `direct_transpose_float` ablation variant.
pub fn dqe_report(x: &Mat, fmt: Fp8Format, mode: ScaleMode) -> DqeReport {
    let q_row = quantize_rowwise(x, fmt, mode);
    let d_qrow = q_row.dequantize();
    let reference_t = d_qrow.transpose(); // one-rounding reference, transposed

    // Q_col(X) expressed in the transposed storage convention.
    let q_col_fresh = quantize_rowwise(&x.transpose(), fmt, mode).dequantize();

    let naive = naive_transpose(&q_row).dequantize();
    let direct = match mode {
        ScaleMode::Po2 => direct_transpose(&q_row).dequantize(),
        ScaleMode::Float => direct_transpose_float(&q_row).dequantize(),
    };

    DqeReport {
        naive_vs_qcol: ErrStats::between(&naive, &q_col_fresh),
        direct_vs_qcol: ErrStats::between(&direct, &q_col_fresh),
        naive_vs_ref: ErrStats::between(&naive, &reference_t),
        direct_vs_ref: ErrStats::between(&direct, &reference_t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(seed: u64) -> Mat {
        let mut rng = Rng::seed_from(seed);
        Mat::rand_log_uniform(256, 256, -6.0, 6.0, &mut rng)
    }

    #[test]
    fn float_recipe_shows_double_quant_error() {
        // The incumbent float-scale recipe: the second quantization of the
        // naive path perturbs a large fraction of elements (Eq. 9).
        let r = dqe_report(&sample(21), Fp8Format::E4M3, ScaleMode::Float);
        assert!(r.naive_vs_ref.frac_nonzero > 0.2, "{:?}", r.naive_vs_ref);
        assert!(r.naive_vs_ref.rel_fro > 1e-3, "{:?}", r.naive_vs_ref);
        // the float "direct" ablation still rounds once — same order
        assert!(r.direct_vs_ref.frac_nonzero > 0.01, "{:?}", r.direct_vs_ref);
        assert!(r.direct_vs_ref.rel_fro <= r.naive_vs_ref.rel_fro * 1.5);
    }

    #[test]
    fn po2_direct_eliminates_double_quant_error() {
        // The paper's recipe: po2 scales + direct transpose. The direct
        // path perturbs (almost) no element relative to the one-rounding
        // reference, and the few it does only at the subnormal grid.
        let rp = dqe_report(&sample(21), Fp8Format::E4M3, ScaleMode::Po2);
        assert!(rp.direct_vs_ref.frac_nonzero < 0.02, "{:?}", rp.direct_vs_ref);
        // po2 grids nest: even the naive path is near-exact in value space
        // (its cost is latency/casts, not numerics — see Fig. 1).
        assert!(rp.naive_vs_ref.rel_fro < 1e-3, "{:?}", rp.naive_vs_ref);
        // headline: paper recipe vs incumbent float recipe
        let rf = dqe_report(&sample(21), Fp8Format::E4M3, ScaleMode::Float);
        assert!(
            rp.direct_vs_ref.rel_fro < rf.naive_vs_ref.rel_fro / 50.0,
            "po2-direct {:?} should beat float-naive {:?}",
            rp.direct_vs_ref.rel_fro,
            rf.naive_vs_ref.rel_fro
        );
    }

    #[test]
    fn stats_identity() {
        let a = sample(23);
        let s = ErrStats::between(&a, &a);
        assert_eq!(s.max_abs, 0.0);
        assert_eq!(s.frac_nonzero, 0.0);
    }
}
