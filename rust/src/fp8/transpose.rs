//! Row-wise → column-wise FP8 layout conversion — the paper's core numeric
//! contribution (§3.1, Alg. 1).
//!
//! Two strategies are implemented, exactly as compared in Fig. 1:
//!
//! 1. [`naive_transpose`] — dequantize → transpose → requantize. Two
//!    independent quantizations with different scaling factors ⇒ the
//!    **double quantization error** of Eq. 1.
//! 2. [`direct_transpose`] — the **scaling-aware transpose**: with scales
//!    constrained to powers of two, align each 128×128 block's scales to
//!    the block maximum and move every payload between the two scaling
//!    domains by *exponent manipulation alone* (Eq. 10–17 /
//!    [`crate::fp8::e4m3::scale_down_code`]). No dequantization, no
//!    requantization, no second rounding.
//!
//! Conventions: input is a row-wise tensor for `X [M,N]`; the output is a
//! row-wise tensor for `Xᵀ [N,M]` — which *is* the column-wise quantization
//! layout of `X` (see `tile::tests::row_col_agree_on_transpose`).

use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::tile::{quantize_rowwise, quantize_rowwise_with_threads};
use crate::fp8::{e4m3, Fp8Format, ScaleMode, TILE};

/// Per-`k` scale-down lookup tables: `lut[k][c] = scale_down_code(c, k)`.
/// Built once per 128×128 block (k ≤ 15 distinct values, 256 B each).
struct ScaleDownLuts {
    tables: Vec<(u32, [u8; 256])>,
}

impl ScaleDownLuts {
    fn for_ks(ks: &[u32]) -> ScaleDownLuts {
        let mut tables: Vec<(u32, [u8; 256])> = Vec::new();
        for &k in ks {
            if tables.iter().any(|(tk, _)| *tk == k) {
                continue;
            }
            let mut t = [0u8; 256];
            for c in 0..=255u8 {
                t[c as usize] = e4m3::scale_down_code(c, k);
            }
            tables.push((k, t));
        }
        ScaleDownLuts { tables }
    }

    #[inline]
    fn get(&self, k: u32) -> &[u8; 256] {
        &self.tables.iter().find(|(tk, _)| *tk == k).unwrap().1
    }
}

/// Naive conversion (Fig. 1 strategy 1): `Q_col(D(Q_row(X)))`, i.e.
/// dequantize, transpose, requantize with fresh data-dependent scales.
pub fn naive_transpose(t: &Fp8Tensor) -> Fp8Tensor {
    naive_transpose_with_threads(t, exec::threads())
}

/// [`naive_transpose`] with an explicit worker count (1 = serial) — the
/// per-expert backward calls it with 1 so the grouped dimension stays the
/// only parallel axis.
pub fn naive_transpose_with_threads(t: &Fp8Tensor, threads: usize) -> Fp8Tensor {
    assert_eq!(t.layout, TileLayout::RowWise, "naive_transpose expects a row-wise input");
    let dq = t.dequantize();
    quantize_rowwise_with_threads(&dq.transpose(), t.fmt, t.mode, threads)
}

/// Batched scaling-aware transpose over equal row groups: each expert's
/// slab of a dispatched `[G·capacity, n]` buffer is transposed
/// independently (its own block-max scale alignment), yielding the
/// per-expert column-wise operands the grouped wgrad GEMM consumes.
///
/// This is the *standalone* batched form of the wgrad operand prep — the
/// executed backward (`moe::backward::expert`) streams exactly these
/// per-slab transposes inside its own expert-parallel loop (calling this
/// kernel there would nest two parallel axes), so this form exists for
/// callers that want the prep stage in isolation: `benches/bwd.rs` times
/// it, and the property suite pins its slab/parallel equivalences.
///
/// Groups are the parallel axis on the [`crate::exec`] pool (serial
/// Alg. 1 inside each slab), so the result is bit-identical for any
/// worker count (`tests/prop_parallel.rs`).
pub fn grouped_direct_transpose(t: &Fp8Tensor, groups: usize, threads: usize) -> Vec<Fp8Tensor> {
    assert!(groups > 0, "grouped_direct_transpose needs at least one group");
    assert_eq!(
        t.rows % groups,
        0,
        "rows ({}) must split evenly into {groups} groups",
        t.rows
    );
    let rpg = t.rows / groups;
    let p = Partition::even(groups, exec::workers_for(threads, groups));
    exec::map_parts(&p, |g| direct_transpose_with_threads(&t.slice_rows(g * rpg, rpg), 1))
}

/// The paper's **Direct Transpose** (Alg. 1), power-of-two scales required.
///
/// For each 128×128 block:
/// * `S_max = max_i S_i` over the block's 128 row scales (po2 ⇒ the max of
///   the exponents);
/// * all 128 output (column) scales of the block are set to `S_max` —
///   aligning *up* so payload magnitudes only shrink (no overflow);
/// * every payload code moves from scale `2^T` to `2^(T+k)` by
///   `scale_down_code(c, k)` — exponent-field subtraction while the value
///   stays normal, RNE mantissa shift if it crosses into subnormals (the
///   paper assumes no underflow; we handle it exactly rather than UB).
pub fn direct_transpose(t: &Fp8Tensor) -> Fp8Tensor {
    direct_transpose_with_threads(t, exec::threads())
}

/// [`direct_transpose`] with an explicit worker count (1 = serial).
///
/// Parallelism: output 128-row blocks (= input 128-column blocks). Every
/// 128×128 block is independent — its output payload rows, scales and
/// exponents are written by exactly one worker — so the parallel result is
/// bit-identical to the serial one (`tests/prop_parallel.rs`).
pub fn direct_transpose_with_threads(t: &Fp8Tensor, threads: usize) -> Fp8Tensor {
    assert_eq!(t.layout, TileLayout::RowWise, "direct_transpose expects a row-wise input");
    assert_eq!(t.mode, ScaleMode::Po2, "direct transpose requires power-of-two scales (Alg. 1)");
    assert_eq!(t.fmt, Fp8Format::E4M3, "direct transpose is specified for E4M3 payloads");
    let (m, n) = (t.rows, t.cols);
    let tpr_in = n_tiles(n); // input scale tiles per row
    let tpr_out = n_tiles(m); // output scale tiles per row (of Xᵀ)
    let mut data = vec![0u8; n * m];
    let mut scales = vec![0.0f32; n * tpr_out];
    let mut sexp = vec![0i32; n * tpr_out];

    // Partition the n output rows on 128-block boundaries so each worker
    // owns whole scale blocks (bj ranges) and contiguous output slices.
    let workers = exec::workers_for(threads, tpr_in);
    let p = Partition::blocks(n, TILE, workers);
    if p.len() <= 1 {
        transpose_out_rows(t, 0..n, &mut data, &mut scales, &mut sexp);
    } else {
        let d_parts = exec::split_parts(&p, m, &mut data);
        let s_parts = exec::split_parts(&p, tpr_out, &mut scales);
        let e_parts = exec::split_parts(&p, tpr_out, &mut sexp);
        let tasks: Vec<_> = d_parts
            .into_iter()
            .zip(s_parts)
            .zip(e_parts)
            .zip(p.ranges())
            .map(|(((d, s), e), r)| (d, s, e, r))
            .collect();
        exec::run_tasks(tasks, |(d, s, e, r)| transpose_out_rows(t, r, d, s, e));
    }
    Fp8Tensor {
        rows: n,
        cols: m,
        fmt: t.fmt,
        mode: t.mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    }
}

/// Serial Alg. 1 over the output rows `jr` (block-aligned: `jr.start` is a
/// multiple of 128). `data`/`scales`/`sexp` are the output slices covering
/// exactly those rows.
fn transpose_out_rows(
    t: &Fp8Tensor,
    jr: std::ops::Range<usize>,
    data: &mut [u8],
    scales: &mut [f32],
    sexp: &mut [i32],
) {
    let (m, n) = (t.rows, t.cols);
    let tpr_in = n_tiles(n);
    let tpr_out = n_tiles(m);
    debug_assert_eq!(jr.start % TILE, 0);
    debug_assert_eq!(data.len(), jr.len() * m);
    debug_assert_eq!(scales.len(), jr.len() * tpr_out);
    let jbase = jr.start;
    let (bj0, bj1) = (jr.start / TILE, jr.end.div_ceil(TILE));

    for bi in 0..tpr_out {
        // block rows of X: i ∈ [i0, i1)
        let i0 = bi * TILE;
        let i1 = (i0 + TILE).min(m);
        for bj in bj0..bj1 {
            // block cols of X: j ∈ [j0, j1)
            let j0 = bj * TILE;
            let j1 = (j0 + TILE).min(n).min(jr.end);
            // S_max over the block's row scales (exponent max — po2).
            let mut emax = i32::MIN;
            for i in i0..i1 {
                emax = emax.max(t.sexp[i * tpr_in + bj]);
            }
            // Output scales: rows j of Xᵀ, tile bi.
            let smax = (emax as f32).exp2();
            for j in j0..j1 {
                scales[(j - jbase) * tpr_out + bi] = smax;
                sexp[(j - jbase) * tpr_out + bi] = emax;
            }
            // Payload: out[j, i] = scale_down(in[i, j], emax − e_i).
            //
            // §Perf: per-k 256-entry code LUTs turn the inner loop into a
            // byte gather, and 16×16 sub-blocking keeps both the source
            // rows and the strided destination columns cache-resident
            // (before/after in EXPERIMENTS.md §Perf).
            let mut k_of_row = [0u32; TILE];
            let mut all_zero = true;
            for i in i0..i1 {
                let k = (emax - t.sexp[i * tpr_in + bj]) as u32;
                k_of_row[i - i0] = k;
                all_zero &= k == 0;
            }
            let luts = if all_zero { None } else { Some(ScaleDownLuts::for_ks(&k_of_row[..i1 - i0])) };
            // hoist the per-row LUT refs out of the element loops
            let row_luts: Vec<&[u8; 256]> = match &luts {
                Some(l) => (i0..i1).map(|i| l.get(k_of_row[i - i0])).collect(),
                None => Vec::new(),
            };
            const SB: usize = 16; // sub-block edge
            let mut si = i0;
            while si < i1 {
                let sie = (si + SB).min(i1);
                let mut sj = j0;
                while sj < j1 {
                    let sje = (sj + SB).min(j1);
                    // contiguous source reads, strided writes; the 16×16
                    // sub-block keeps the touched destination lines in L1
                    // (measured faster than the write-contiguous order —
                    // see EXPERIMENTS.md §Perf iteration log)
                    match &luts {
                        None => {
                            for i in si..sie {
                                let src = &t.data[i * n + sj..i * n + sje];
                                for (o, &c) in src.iter().enumerate() {
                                    data[(sj + o - jbase) * m + i] = c;
                                }
                            }
                        }
                        Some(_) => {
                            for i in si..sie {
                                let lut = row_luts[i - i0];
                                let src = &t.data[i * n + sj..i * n + sje];
                                for (o, &c) in src.iter().enumerate() {
                                    data[(sj + o - jbase) * m + i] = lut[c as usize];
                                }
                            }
                        }
                    }
                    sj = sje;
                }
                si = sie;
            }
        }
    }
}

/// Float-scale variant of the direct transpose (ablation): aligns each
/// block to its max *float* scale and requantizes each payload once
/// (`encode(decode(c)·s/S_max)`). Avoids the second *data-dependent* scale
/// computation of the naive path but — without the po2 constraint — must
/// still round once, so it is NOT lossless. Quantifies how much of the
/// paper's benefit comes specifically from po2 scales.
pub fn direct_transpose_float(t: &Fp8Tensor) -> Fp8Tensor {
    assert_eq!(t.layout, TileLayout::RowWise);
    let (m, n) = (t.rows, t.cols);
    let tpr_in = n_tiles(n);
    let tpr_out = n_tiles(m);
    let mut data = vec![0u8; n * m];
    let mut scales = vec![0.0f32; n * tpr_out];
    for bi in 0..tpr_out {
        let i0 = bi * TILE;
        let i1 = (i0 + TILE).min(m);
        for bj in 0..tpr_in {
            let j0 = bj * TILE;
            let j1 = (j0 + TILE).min(n);
            let mut smax = 0.0f32;
            for i in i0..i1 {
                smax = smax.max(t.scales[i * tpr_in + bj]);
            }
            let smax = if smax == 0.0 { 1.0 } else { smax };
            for j in j0..j1 {
                scales[j * tpr_out + bi] = smax;
            }
            for i in i0..i1 {
                let ratio = t.scales[i * tpr_in + bj] / smax;
                for j in j0..j1 {
                    let c = t.data[i * n + j];
                    data[j * m + i] = t.fmt.encode(t.fmt.decode(c) * ratio);
                }
            }
        }
    }
    Fp8Tensor {
        rows: n,
        cols: m,
        fmt: t.fmt,
        mode: ScaleMode::Float,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp: Vec::new(),
    }
}

/// Plain payload transpose *without* any scale handling — the buggy
/// "just transpose the bytes" strategy. Kept as a test foil: it produces
/// wrong values whenever scales differ across a block, demonstrating why
/// the transpose must be scaling-aware at all.
pub fn unaware_transpose(t: &Fp8Tensor) -> Fp8Tensor {
    assert_eq!(t.layout, TileLayout::RowWise);
    let (m, n) = (t.rows, t.cols);
    let tpr_in = n_tiles(n);
    let tpr_out = n_tiles(m);
    let mut data = vec![0u8; n * m];
    for i in 0..m {
        for j in 0..n {
            data[j * m + i] = t.data[i * n + j];
        }
    }
    // Take each block's FIRST row scale — arbitrary and generally wrong.
    let mut scales = vec![0.0f32; n * tpr_out];
    let mut sexp = vec![0i32; n * tpr_out];
    for bi in 0..tpr_out {
        let i0 = bi * TILE;
        for bj in 0..tpr_in {
            let j0 = bj * TILE;
            let j1 = (j0 + TILE).min(n);
            for j in j0..j1 {
                scales[j * tpr_out + bi] = t.scales[i0 * tpr_in + bj];
                if !t.sexp.is_empty() {
                    sexp[j * tpr_out + bi] = t.sexp[i0 * tpr_in + bj];
                }
            }
        }
    }
    Fp8Tensor {
        rows: n,
        cols: m,
        fmt: t.fmt,
        mode: t.mode,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::{quantize_colwise, quantize_rowwise};
    use crate::util::mat::Mat;
    use crate::util::prop::props;
    use crate::util::rng::Rng;

    fn sample(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from(seed);
        // Several binades of spread per tile so row scales genuinely differ.
        Mat::rand_log_uniform(rows, cols, -6.0, 6.0, &mut rng)
    }

    #[test]
    fn direct_shapes_and_layout() {
        let x = sample(256, 384, 1);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let t = direct_transpose(&q);
        assert_eq!((t.rows, t.cols), (384, 256));
        assert_eq!(t.layout, TileLayout::RowWise);
        assert_eq!(t.n_scales(), 384 * 2);
    }

    #[test]
    fn direct_is_lossless_when_no_underflow() {
        // Eq. 10–17: for elements that stay normal after the exponent
        // shift, D(direct_T(Q_row(X))) == D(Q_row(X))ᵀ EXACTLY (bitwise).
        let x = sample(256, 256, 2);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let dq = q.dequantize(); // one-rounding reference
        let t = direct_transpose(&q);
        let dt = t.dequantize();
        let mut exact = 0usize;
        let mut bounded = 0usize;
        for i in 0..q.rows {
            for j in 0..q.cols {
                let a = dq.at(i, j);
                let b = dt.at(j, i);
                if a.to_bits() == b.to_bits() {
                    exact += 1;
                } else {
                    // underflow into subnormal grid: |err| ≤ half grid unit
                    // at the aligned scale
                    let smax = t.scale_at(j, i);
                    assert!(
                        (a - b).abs() <= 0.5 * e4m3::MIN_SUBNORMAL * smax,
                        "({i},{j}): a={a} b={b} smax={smax}"
                    );
                    bounded += 1;
                }
            }
        }
        // The overwhelming majority must be bit-exact.
        assert!(exact * 10 >= (exact + bounded) * 9, "exact={exact} bounded={bounded}");
    }

    #[test]
    fn direct_exact_when_scales_uniform() {
        // If all row scales in each block agree, k=0 everywhere: the direct
        // transpose is a pure relayout — bitwise exact, zero exceptions.
        let mut rng = Rng::seed_from(3);
        let x = Mat::randn(256, 256, 1.0, &mut rng).map(|v| v.clamp(-3.9, 3.9));
        // Force uniform scales by planting the same amax in every tile.
        let mut x = x;
        for i in 0..x.rows {
            for t in 0..2 {
                *x.at_mut(i, t * 128) = 3.99;
            }
        }
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let t = direct_transpose(&q);
        let dq = q.dequantize();
        let dt = t.dequantize();
        for i in 0..q.rows {
            for j in 0..q.cols {
                assert_eq!(dq.at(i, j).to_bits(), dt.at(j, i).to_bits());
            }
        }
    }

    #[test]
    fn naive_has_double_quant_error_with_float_scales() {
        // The incumbent recipes (TE blockwise / DeepSeek-V3) use FLOAT
        // per-tile scales: requantizing along the other dimension re-rounds
        // onto an incommensurate grid — the double quantization error
        // (Eq. 9: "the two rounding operators cannot be combined").
        let x = sample(384, 384, 4);
        let qf = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        let ref_f = qf.dequantize().transpose();
        let naive_float_err = naive_transpose(&qf).dequantize().rel_err(&ref_f);
        assert!(
            naive_float_err > 1e-3,
            "float-scale naive path should show double-quant error, got {naive_float_err}"
        );
        // The paper's recipe (po2 scales + direct transpose) is exact.
        let qp = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let ref_p = qp.dequantize().transpose();
        let direct_err = direct_transpose(&qp).dequantize().rel_err(&ref_p);
        assert!(
            direct_err < naive_float_err / 50.0,
            "direct={direct_err} float-naive={naive_float_err}"
        );
    }

    #[test]
    fn po2_grids_nest_so_even_naive_is_value_exact() {
        // The po2 constraint alone already removes the *numerical* error:
        // requantizing po2-quantized values onto another po2 grid is an
        // exact exponent shift (the grids nest), up to the same bounded
        // subnormal underflow as the direct path. What the direct transpose
        // removes on top is the dequantize→requantize COMPUTE and the
        // extra casts (Fig. 1 is a latency comparison) — this test pins
        // down that reading of the paper.
        let x = sample(384, 384, 44);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let dq_t = q.dequantize().transpose();
        let naive_err = naive_transpose(&q).dequantize().rel_err(&dq_t);
        assert!(naive_err < 1e-3, "po2 naive should be near-exact, got {naive_err}");
    }

    #[test]
    fn double_transpose_roundtrips() {
        // direct_T(direct_T(Q)) represents the same values as Q: scales may
        // coarsen (block-max alignment) but values survive bit-for-bit up
        // to the bounded-underflow exception.
        let x = sample(256, 256, 5);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let tt = direct_transpose(&direct_transpose(&q));
        let a = q.dequantize();
        let b = tt.dequantize();
        assert!(b.rel_err(&a) < 1e-3, "rel={}", b.rel_err(&a));
    }

    #[test]
    fn matches_colwise_quantization_values() {
        // The output layout is the column-wise layout: compare against
        // Q_col computed from the one-rounding reference D(Q_row(X)).
        let x = sample(256, 128, 6);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let t = direct_transpose(&q);
        let qc = quantize_colwise(&q.dequantize(), Fp8Format::E4M3, ScaleMode::Po2);
        // Values agree within the subnormal-underflow bound (Q_col re-rounds
        // per-column; direct aligns per-block — both represent D(Q_row(X))
        // and may only disagree at the subnormal grid).
        let dt = t.dequantize();
        let dc = qc.dequantize();
        for i in 0..x.rows {
            for j in 0..x.cols {
                let a = dt.at(j, i);
                let b = dc.at(i, j);
                let tol = 0.5 * e4m3::MIN_SUBNORMAL * t.scale_at(j, i).max(qc.scale_at(i, j));
                assert!((a - b).abs() <= tol, "({i},{j}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn unaware_transpose_is_wrong() {
        // The foil: ignoring scales corrupts values whenever block scales
        // are non-uniform — this is why "scaling-aware" is in the name.
        let x = sample(256, 256, 7);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let dq_t = q.dequantize().transpose();
        let err = unaware_transpose(&q).dequantize().rel_err(&dq_t);
        assert!(err > 0.05, "unaware transpose should be badly wrong, got {err}");
    }

    #[test]
    fn float_direct_variant_rounds_once_like_naive() {
        // Ablation invariant: without the po2 constraint the "direct"
        // transpose still has to round once (it trades the naive path's
        // fresh per-tile scales for coarser block-max-aligned ones), so its
        // error is of the same order as the naive path — nonzero, within
        // 1.5×. This quantifies that the po2 constraint, not the fusion,
        // is what eliminates the numerical error.
        let x = sample(384, 256, 8);
        let qf = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        let dq_t = qf.dequantize().transpose();
        let naive_err = naive_transpose(&qf).dequantize().rel_err(&dq_t);
        let float_direct_err = direct_transpose_float(&qf).dequantize().rel_err(&dq_t);
        assert!(float_direct_err > 1e-4);
        assert!(
            float_direct_err <= naive_err * 1.5 && float_direct_err >= naive_err / 1.5,
            "float-direct {float_direct_err} should be same order as naive {naive_err}"
        );
    }

    #[test]
    fn ragged_shapes() {
        props("direct transpose ragged shapes", 16, |g| {
            let m = g.usize_in(1, 300);
            let n = g.usize_in(1, 300);
            let mut rng = Rng::seed_from(g.seed ^ 0xabcd);
            let x = Mat::rand_log_uniform(m, n, -4.0, 4.0, &mut rng);
            let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
            let t = direct_transpose(&q);
            assert_eq!((t.rows, t.cols), (n, m));
            let dq = q.dequantize();
            let dt = t.dequantize();
            for i in 0..m {
                for j in 0..n {
                    let a = dq.at(i, j);
                    let b = dt.at(j, i);
                    let tol = 0.5 * e4m3::MIN_SUBNORMAL * t.scale_at(j, i);
                    assert!((a - b).abs() <= tol, "({i},{j}): {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn grouped_transpose_equals_per_slab_transpose() {
        // the batched form is exactly G independent direct transposes
        let mut rng = Rng::seed_from(10);
        let (g, cap, n) = (4usize, 48usize, 200usize);
        let x = Mat::rand_log_uniform(g * cap, n, -5.0, 5.0, &mut rng);
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        let batched = grouped_direct_transpose(&q, g, 2);
        assert_eq!(batched.len(), g);
        for e in 0..g {
            let slab = direct_transpose(&q.slice_rows(e * cap, cap));
            assert_eq!(batched[e].data, slab.data, "group {e}");
            assert_eq!(batched[e].scales, slab.scales, "group {e}");
            assert_eq!(batched[e].sexp, slab.sexp, "group {e}");
            assert_eq!((batched[e].rows, batched[e].cols), (n, cap));
        }
    }

    #[test]
    fn preserves_nan_payloads() {
        // NaN codes (shouldn't occur post-quantization, but the operator
        // must not manufacture numbers from them) propagate as NaN.
        let x = sample(128, 128, 9);
        let mut q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        q.data[5] = e4m3::NAN_CODE;
        let t = direct_transpose(&q);
        // element (0,5) of X is (5,0) of Xᵀ
        assert!(e4m3::is_nan(t.code_at(5, 0)));
    }
}
