//! **UE8M0** — the unsigned, exponent-only 8-bit format used for
//! power-of-two scaling factors (§2.1: "encodes powers of two and is
//! typically used for scaling factors").
//!
//! A code `b` represents `2^(b − 127)`; there is no sign, no mantissa, no
//! NaN. This is the storage format for the po2 recipe's scales: the
//! scaling-aware transpose then only ever *adds integer deltas* to these
//! exponents (Alg. 1's `k = log2(S_max/s)`).

/// Exponent bias.
pub const BIAS: i32 = 127;

/// Decode code → scale value `2^(b-127)`.
#[inline]
pub fn decode(b: u8) -> f32 {
    ((b as i32 - BIAS) as f32).exp2()
}

/// Encode an exponent (log2 of the scale) to a UE8M0 code, saturating.
#[inline]
pub fn from_exponent(e: i32) -> u8 {
    (e + BIAS).clamp(0, 255) as u8
}

/// Extract the exponent (log2 of the scale) from a code.
#[inline]
pub fn exponent(b: u8) -> i32 {
    b as i32 - BIAS
}

/// Round a positive scale *up* to the next power of two and encode it.
///
/// "Up" (ceil) is the correct direction for quantization scales: a larger
/// scale can only shrink payload magnitudes, so `amax/s ≤ fmax` stays true
/// and overflow is impossible (the paper's overflow-avoidance argument for
/// aligning to `S_max`).
#[inline]
pub fn encode_ceil(s: f32) -> u8 {
    assert!(s > 0.0 && s.is_finite(), "UE8M0 scale must be positive finite, got {s}");
    from_exponent(ceil_log2(s))
}

/// `ceil(log2(s))` computed exactly from f32 bits (no libm rounding risk).
#[inline]
pub fn ceil_log2(s: f32) -> i32 {
    let bits = s.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;
    if exp == 0 {
        // subnormal: s = man · 2^-149
        let top = 31 - (man.leading_zeros() as i32);
        let e = top - 149;
        return if man == (1 << top) { e } else { e + 1 };
    }
    let e = exp - 127;
    if man == 0 {
        e
    } else {
        e + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_known() {
        assert_eq!(decode(127), 1.0);
        assert_eq!(decode(128), 2.0);
        assert_eq!(decode(126), 0.5);
    }

    #[test]
    fn ceil_log2_exact_powers() {
        for e in -30..30 {
            let s = (e as f32).exp2();
            assert_eq!(ceil_log2(s), e, "s={s}");
        }
    }

    #[test]
    fn ceil_log2_between_powers() {
        assert_eq!(ceil_log2(1.5), 1);
        assert_eq!(ceil_log2(3.0), 2);
        assert_eq!(ceil_log2(0.75), 0);
        assert_eq!(ceil_log2(0.51), 0);
        assert_eq!(ceil_log2(0.5), -1);
    }

    #[test]
    fn encode_roundtrip_is_geq() {
        // decoded(encode_ceil(s)) ≥ s always (never underestimates)
        let mut s = 1.7e-20f32;
        while s < 1e20 {
            let d = decode(encode_ceil(s));
            assert!(d >= s, "s={s} d={d}");
            assert!(d <= s * 2.0 + f32::EPSILON, "not tight: s={s} d={d}");
            s *= 1.31;
        }
    }

    #[test]
    fn subnormal_scales() {
        let s = f32::from_bits(1); // smallest positive subnormal
        let d = decode(encode_ceil(s));
        assert!(d >= s);
    }
}
