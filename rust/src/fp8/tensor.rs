//! [`Fp8Tensor`] — a quantized 2-D tensor: FP8 payload plus per-tile
//! scaling factors (1×128 tiles, Eq. 2), in either of the two layouts the
//! MoE dataflow needs:
//!
//! * **row-wise** — scales over contiguous 128-element segments of each row
//!   (consumed by `Fprop`/`Dgrad` grouped GEMMs);
//! * **column-wise** — scales over 128-element segments of each column
//!   (consumed by `Wgrad`).
//!
//! Payload is always stored row-major for the tensor's logical shape.

use crate::fp8::{Fp8Format, ScaleMode, TILE};
use crate::util::mat::Mat;

/// Which way the 1×128 scale tiles run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileLayout {
    /// One scale per (row, 128-column segment): shape `[rows, tiles_per_row]`.
    RowWise,
    /// One scale per (128-row segment, column): shape `[row_blocks, cols]`.
    ColWise,
}

/// A quantized 2-D FP8 tensor (payload + scales).
#[derive(Clone, Debug)]
pub struct Fp8Tensor {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Payload format.
    pub fmt: Fp8Format,
    /// Scale recipe the tensor was quantized with.
    pub mode: ScaleMode,
    /// Which way the scale tiles run.
    pub layout: TileLayout,
    /// Row-major FP8 codes, `rows * cols`.
    pub data: Vec<u8>,
    /// Per-tile scales (see [`TileLayout`] for shape).
    pub scales: Vec<f32>,
    /// Per-tile scale exponents (`scales[i] == 2^sexp[i]`); populated only
    /// for [`ScaleMode::Po2`].
    pub sexp: Vec<i32>,
}

pub(crate) fn n_tiles(len: usize) -> usize {
    len.div_ceil(TILE)
}

impl Fp8Tensor {
    /// Number of scale entries implied by shape and layout.
    pub fn n_scales(&self) -> usize {
        match self.layout {
            TileLayout::RowWise => self.rows * n_tiles(self.cols),
            TileLayout::ColWise => n_tiles(self.rows) * self.cols,
        }
    }

    /// Scale applied to element `(i, j)`.
    #[inline]
    pub fn scale_at(&self, i: usize, j: usize) -> f32 {
        match self.layout {
            TileLayout::RowWise => self.scales[i * n_tiles(self.cols) + j / TILE],
            TileLayout::ColWise => self.scales[(i / TILE) * self.cols + j],
        }
    }

    /// Scale exponent for element `(i, j)` (Po2 mode only).
    #[inline]
    pub fn sexp_at(&self, i: usize, j: usize) -> i32 {
        debug_assert_eq!(self.mode, ScaleMode::Po2);
        match self.layout {
            TileLayout::RowWise => self.sexp[i * n_tiles(self.cols) + j / TILE],
            TileLayout::ColWise => self.sexp[(i / TILE) * self.cols + j],
        }
    }

    #[inline]
    /// Raw FP8 code at `(i, j)`.
    pub fn code_at(&self, i: usize, j: usize) -> u8 {
        self.data[i * self.cols + j]
    }

    /// Dequantize element `(i, j)`.
    #[inline]
    pub fn value_at(&self, i: usize, j: usize) -> f32 {
        self.fmt.decode(self.code_at(i, j)) * self.scale_at(i, j)
    }

    /// Dequantize the whole tensor — `D(·)` of the paper.
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        match self.layout {
            TileLayout::RowWise => {
                let tpr = n_tiles(self.cols);
                for i in 0..self.rows {
                    for t in 0..tpr {
                        let s = self.scales[i * tpr + t];
                        let j0 = t * TILE;
                        let j1 = (j0 + TILE).min(self.cols);
                        for j in j0..j1 {
                            out.data[i * self.cols + j] =
                                self.fmt.decode(self.data[i * self.cols + j]) * s;
                        }
                    }
                }
            }
            TileLayout::ColWise => {
                for i in 0..self.rows {
                    let sb = (i / TILE) * self.cols;
                    for j in 0..self.cols {
                        out.data[i * self.cols + j] =
                            self.fmt.decode(self.data[i * self.cols + j]) * self.scales[sb + j];
                    }
                }
            }
        }
        out
    }

    /// Copy `rows` rows starting at `start` into a new row-wise tensor —
    /// payload, scales and (when present) po2 exponents move together.
    /// This is the expert-slab view the grouped kernels (fused expert FFN,
    /// grouped transpose, per-expert backward) are built on.
    pub fn slice_rows(&self, start: usize, rows: usize) -> Fp8Tensor {
        assert_eq!(self.layout, TileLayout::RowWise, "slice_rows is defined for row-wise tensors");
        assert!(start + rows <= self.rows, "slice_rows out of range");
        let tpr = n_tiles(self.cols);
        Fp8Tensor {
            rows,
            cols: self.cols,
            fmt: self.fmt,
            mode: self.mode,
            layout: self.layout,
            data: self.data[start * self.cols..(start + rows) * self.cols].to_vec(),
            scales: self.scales[start * tpr..(start + rows) * tpr].to_vec(),
            sexp: if self.sexp.is_empty() {
                Vec::new()
            } else {
                self.sexp[start * tpr..(start + rows) * tpr].to_vec()
            },
        }
    }

    /// Payload bytes + scale bytes (memory accounting for the cluster sim;
    /// scales are 4 B in Float mode, 1 B (UE8M0) in Po2 mode).
    pub fn nbytes(&self) -> usize {
        let scale_bytes = match self.mode {
            ScaleMode::Float => 4,
            ScaleMode::Po2 => 1,
        };
        self.data.len() + self.n_scales() * scale_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::tile::{quantize_colwise, quantize_rowwise};
    use crate::util::rng::Rng;

    #[test]
    fn scale_indexing_rowwise() {
        let mut rng = Rng::seed_from(1);
        let x = Mat::randn(4, 300, 1.0, &mut rng); // ragged: 300 = 2*128 + 44
        let q = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(q.n_scales(), 4 * 3);
        assert_eq!(q.scales.len(), 12);
        assert_eq!(q.sexp.len(), 12);
        // elements in the same tile share a scale
        assert_eq!(q.scale_at(2, 0), q.scale_at(2, 127));
        assert_eq!(q.scale_at(2, 128), q.scale_at(2, 255));
        assert_eq!(q.scale_at(2, 256), q.scale_at(2, 299));
    }

    #[test]
    fn scale_indexing_colwise() {
        let mut rng = Rng::seed_from(2);
        let x = Mat::randn(300, 4, 1.0, &mut rng);
        let q = quantize_colwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(q.n_scales(), 3 * 4);
        assert_eq!(q.scale_at(0, 2), q.scale_at(127, 2));
        assert_eq!(q.scale_at(128, 2), q.scale_at(255, 2));
    }

    #[test]
    fn slice_rows_matches_elementwise() {
        let mut rng = Rng::seed_from(4);
        let x = Mat::randn(12, 300, 1.0, &mut rng); // ragged tail tile
        for mode in [crate::fp8::ScaleMode::Po2, crate::fp8::ScaleMode::Float] {
            let q = quantize_rowwise(&x, Fp8Format::E4M3, mode);
            let s = q.slice_rows(3, 5);
            assert_eq!((s.rows, s.cols), (5, 300));
            assert_eq!(s.sexp.is_empty(), q.sexp.is_empty());
            for i in 0..5 {
                for j in 0..300 {
                    assert_eq!(s.code_at(i, j), q.code_at(i + 3, j));
                    assert_eq!(s.scale_at(i, j), q.scale_at(i + 3, j));
                }
            }
        }
    }

    #[test]
    fn nbytes_accounting() {
        let mut rng = Rng::seed_from(3);
        let x = Mat::randn(128, 256, 1.0, &mut rng);
        let qf = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Float);
        let qp = quantize_rowwise(&x, Fp8Format::E4M3, ScaleMode::Po2);
        assert_eq!(qf.nbytes(), 128 * 256 + 128 * 2 * 4);
        assert_eq!(qp.nbytes(), 128 * 256 + 128 * 2);
    }
}
