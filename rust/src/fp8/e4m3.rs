//! Bit-exact software codec for OCP **FP8 E4M3** (`float8_e4m3fn`
//! semantics, matching JAX/ml_dtypes — verified exhaustively by
//! `python/tests/test_codec_parity.py` and the tests below).
//!
//! Layout: `S EEEE MMM`, exponent bias 7.
//!
//! * normals: `(-1)^S · 2^(E-7) · (1 + M/8)`, `E ∈ 1..=15`
//! * subnormals (`E = 0`): `(-1)^S · 2^-6 · (M/8)` — grid unit `2^-9`
//! * **no infinities**; the only NaN codes are `0x7F`/`0xFF` (`S.1111.111`)
//! * max finite: `S.1111.110` = ±448
//! * conversion from f32: round-to-nearest-even; values that round (with
//!   unbounded exponent) above 448 become NaN (so 449→448, 464→448 via the
//!   tie-to-even at the 448/480 midpoint, 465→NaN); ±Inf→NaN.

/// Exponent bias.
pub const BIAS: i32 = 7;
/// Smallest positive subnormal = 2^-9.
pub const MIN_SUBNORMAL: f32 = 0.001953125;
/// Smallest positive normal = 2^-6.
pub const MIN_NORMAL: f32 = 0.015625;
/// Largest finite magnitude.
pub const MAX_FINITE: f32 = 448.0;
/// The canonical positive NaN code.
pub const NAN_CODE: u8 = 0x7F;

/// Is `c` one of the two NaN codes?
#[inline]
pub const fn is_nan(c: u8) -> bool {
    c & 0x7F == 0x7F
}

/// Decode a single E4M3 code to f32 (exact — every E4M3 value is an f32).
#[inline]
pub fn decode(c: u8) -> f32 {
    DECODE_LUT[c as usize]
}

/// Decode without the LUT — the executable specification used to build and
/// cross-check the table.
pub fn decode_spec(c: u8) -> f32 {
    let sign = if c & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((c >> 3) & 0x0F) as i32;
    let m = (c & 0x07) as i32;
    if e == 15 && m == 7 {
        return f32::NAN;
    }
    if e == 0 {
        sign * (m as f32 / 8.0) * (-6.0f32).exp2()
    } else {
        sign * (1.0 + m as f32 / 8.0) * ((e - BIAS) as f32).exp2()
    }
}

/// 256-entry decode table (hot path: dequantization / GEMM operand decode).
pub static DECODE_LUT: [f32; 256] = build_lut();

const fn build_lut() -> [f32; 256] {
    let mut lut = [0.0f32; 256];
    let mut i = 0usize;
    while i < 256 {
        let c = i as u8;
        let e = ((c >> 3) & 0x0F) as i32;
        let m = (c & 0x07) as u32;
        let v = if e == 15 && m == 7 {
            f32::NAN
        } else if e == 0 {
            // m / 8 * 2^-6 = m * 2^-9
            (m as f32) * 0.001953125
        } else {
            // (8 + m) / 8 * 2^(e-7) = (8+m) * 2^(e-10)
            let mant = (8 + m) as f32;
            // 2^(e-10) for e in 1..=15 → exponent -9..=5
            let mut p = 1.0f32;
            let mut k = e - 10;
            while k > 0 {
                p *= 2.0;
                k -= 1;
            }
            while k < 0 {
                p *= 0.5;
                k += 1;
            }
            mant * p
        };
        lut[i] = if c & 0x80 != 0 {
            // note: -NaN stays NaN; -0.0 for code 0x80
            if e == 15 && m == 7 { f32::NAN } else { -v }
        } else {
            v
        };
        i += 1;
    }
    lut
}

/// Encode an f32 to E4M3 with round-to-nearest-even (ml_dtypes semantics).
#[inline]
pub fn encode(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    if x.is_nan() {
        return sign | NAN_CODE;
    }
    let abs_bits = bits & 0x7FFF_FFFF;
    if abs_bits == 0 {
        return sign; // ±0
    }
    if x.is_infinite() {
        return sign | NAN_CODE; // E4M3 has no Inf: overflow → NaN
    }
    let f32_exp = (abs_bits >> 23) as i32; // biased f32 exponent
    let f32_man = abs_bits & 0x7F_FFFF;

    // f32 subnormals are < 2^-126, far below the E4M3 subnormal grid → 0.
    if f32_exp == 0 {
        return sign;
    }
    let ue = f32_exp - 127; // unbiased exponent of x

    if ue >= -6 {
        // Normal-range candidate: round the 23-bit mantissa to 3 bits, RNE.
        let mut m3 = f32_man >> 20;
        let low = f32_man & 0xF_FFFF;
        const HALF: u32 = 0x8_0000;
        if low > HALF || (low == HALF && (m3 & 1) == 1) {
            m3 += 1;
        }
        let mut ue = ue;
        if m3 == 8 {
            m3 = 0;
            ue += 1;
        }
        if ue > 8 || (ue == 8 && m3 == 7) {
            return sign | NAN_CODE; // overflow (449..464 already rounded to 448)
        }
        let e_field = (ue + BIAS) as u8; // 1..=15
        sign | (e_field << 3) | m3 as u8
    } else {
        // Subnormal range: RNE onto the 2^-9 grid. x·512 is exact in f32.
        let q = (f32::from_bits(abs_bits) * 512.0).round_ties_even() as u32;
        // q ≤ 8 by construction (ue < -6 ⇒ |x| < 2^-6 ⇒ x·512 < 8.0 ⇒ q ≤ 8,
        // where q = 8 rolls into the first normal code 2^-6).
        sign | q as u8
    }
}

/// Fast encode for **finite** inputs (the quantizer's post-scaling
/// contract: `|x| ≤ 448·(1+ε)`, no NaN/Inf). Branch-free in the normal
/// range via an integer round-to-nearest-even trick: adding
/// `0x7FFFF + keep_bit` to the f32 bits rounds the 20 discarded mantissa
/// bits with ties-to-even, letting the carry ripple into the exponent.
///
/// Bit-identical to [`encode`] on its domain (exhaustive + property
/// tested); ~6× faster — the §Perf fix for the fused SwiGLU+quant and
/// quantizer hot paths.
#[inline(always)]
pub fn encode_finite(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= (121u32 << 23) {
        // |x| ≥ 2^-6: normal-range candidate
        let t = abs + 0x7FFFF + ((abs >> 20) & 1); // RNE incl. carry
        let e = (t >> 23) as i32 - 120; // biased E4M3 exponent
        let m = ((t >> 20) & 7) as u8;
        if e >= 16 || (e == 15 && m == 7) {
            return sign | NAN_CODE; // overflow (449.. after rounding)
        }
        sign | ((e as u8) << 3) | m
    } else {
        // subnormal grid: RNE onto 2^-9 (x·512 exact)
        let q = (f32::from_bits(abs) * 512.0).round_ties_even() as u32;
        sign | q as u8
    }
}

/// Encode a scaled slice: `out[i] = encode_finite(xs[i] * inv_scale)` —
/// the fused multiply+encode inner loop shared by the quantizer and the
/// fused SwiGLU+quant kernel.
#[inline]
pub fn encode_scaled_slice(xs: &[f32], inv_scale: f32, out: &mut [u8]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &v) in out.iter_mut().zip(xs) {
        *o = encode_finite(v * inv_scale);
    }
}

/// Multiply an E4M3 code by `2^-k` (k ≥ 0) **exactly in code space** with
/// RNE when the value shifts into the subnormal grid.
///
/// This is the inner operation of the paper's scaling-aware direct
/// transpose (Alg. 1): after aligning a block's scales to the max `S_max`,
/// each payload moves from scale `s = 2^T` to `S_max = 2^(T+k)` by dividing
/// its *value* by `2^k` — pure exponent manipulation while the code stays
/// normal, mantissa shift with RNE once it goes subnormal.
///
/// Equivalent (bit-for-bit, tested exhaustively) to
/// `encode(decode(c) * 2^-k)`.
#[inline]
pub fn scale_down_code(c: u8, k: u32) -> u8 {
    if k == 0 || is_nan(c) {
        return c;
    }
    let sign = c & 0x80;
    let e = ((c >> 3) & 0x0F) as u32;
    let m = (c & 0x07) as u32;
    if e > k {
        // stays normal: exponent field just decreases (the paper's Eq. 12–16)
        return sign | (((e - k) as u8) << 3) | m as u8;
    }
    // Shifts into the subnormal grid. Value in units of 2^-9:
    //   normal (e ≥ 1):  (8+m)·2^(e-1); subnormal (e = 0): m.
    // Divide by 2^k with round-to-nearest-even.
    let (q0, shift) = if e == 0 {
        (m, k)
    } else {
        (8 + m, k - (e - 1))
    };
    let q = rne_shr(q0, shift);
    // q ≤ 8 always: q0 ≤ 15 and shift ≥ 1 ⇒ q ≤ round(15/2) = 8 = code of
    // 2^-6 (first normal) — exactly representable.
    sign | q as u8
}

/// `round_ties_even(x / 2^s)` for unsigned integers.
#[inline]
fn rne_shr(x: u32, s: u32) -> u32 {
    if s == 0 {
        return x;
    }
    if s > 31 {
        return 0;
    }
    let floor = x >> s;
    let rem = x & ((1 << s) - 1);
    let half = 1u32 << (s - 1);
    if rem > half || (rem == half && (floor & 1) == 1) {
        floor + 1
    } else {
        floor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_spec_all_codes() {
        for c in 0..=255u8 {
            let a = decode(c);
            let b = decode_spec(c);
            assert!(
                (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits(),
                "code {c:#04x}: lut={a} spec={b}"
            );
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(encode(448.0), 0x7E);
        assert_eq!(encode(449.0), 0x7E); // rounds down to max
        assert_eq!(encode(464.0), 0x7E); // tie at midpoint → even (448)
        assert_eq!(encode(465.0), NAN_CODE); // overflow → NaN
        assert_eq!(encode(f32::INFINITY), NAN_CODE);
        assert_eq!(encode(-449.0), 0xFE);
        assert_eq!(encode(-1000.0), 0xFF);
        assert_eq!(encode(0.0), 0x00);
        assert_eq!(encode(-0.0), 0x80);
        assert_eq!(encode(MIN_NORMAL), 0x08);
        assert_eq!(encode(MIN_SUBNORMAL), 0x01);
        assert_eq!(encode(MIN_SUBNORMAL / 2.0), 0x00); // tie → even(0)
        assert_eq!(encode(MIN_SUBNORMAL * 0.75), 0x01);
        assert_eq!(encode(1.0), 0x38);
        assert_eq!(encode(1.0625), 0x38); // tie → even (1.0)
        assert_eq!(encode(1.1875), 0x3A); // tie → even (1.25)
        assert_eq!(encode(240.0), 0x77);
        assert_eq!(encode(216.0), 0x76); // tie → even (224)
        assert_eq!(encode(0.0029296875), 0x02); // subnormal tie → even (2)
    }

    #[test]
    fn roundtrip_all_codes() {
        // decode→encode is the identity on every non-NaN code
        for c in 0..=255u8 {
            if is_nan(c) {
                assert!(decode(c).is_nan());
                continue;
            }
            assert_eq!(encode(decode(c)), c, "code {c:#04x} value {}", decode(c));
        }
    }

    #[test]
    fn rne_against_f64_reference() {
        // Exhaustive-ish RNE check against an f64 nearest-even reference
        // over a dense sweep of magnitudes.
        let grid: Vec<f32> = (0..=255u8).filter(|&c| !is_nan(c)).map(decode).collect();
        let mut sorted: Vec<f32> = grid.iter().cloned().filter(|v| *v >= 0.0).collect();
        sorted.sort_by(f32::total_cmp);
        sorted.dedup();
        let nearest = |x: f64| -> f32 {
            let mut best = sorted[0];
            let mut bd = f64::INFINITY;
            for &g in &sorted {
                let d = (x - g as f64).abs();
                if d < bd - 1e-30 {
                    bd = d;
                    best = g;
                } else if (d - bd).abs() <= 1e-30 {
                    // tie: pick even mantissa
                    let cb = encode(best);
                    let cg = encode(g);
                    if cg & 1 == 0 && cb & 1 == 1 {
                        best = g;
                    }
                }
            }
            best
        };
        let mut x = 1e-4f64;
        while x < 460.0 {
            let e = decode(encode(x as f32));
            let r = nearest(x);
            assert!(
                (e - r).abs() <= f32::EPSILON * r.abs().max(1e-6),
                "x={x} enc={e} ref={r}"
            );
            x *= 1.037;
        }
    }

    #[test]
    fn scale_down_matches_decode_multiply_encode_exhaustive() {
        for c in 0..=255u8 {
            for k in 0..20u32 {
                let fast = scale_down_code(c, k);
                let slow = encode(decode(c) * (-(k as f32)).exp2());
                if is_nan(c) {
                    assert!(is_nan(fast));
                    continue;
                }
                assert_eq!(
                    fast, slow,
                    "c={c:#04x} ({}) k={k}: fast={fast:#04x} slow={slow:#04x}",
                    decode(c)
                );
            }
        }
    }

    #[test]
    fn encode_finite_matches_encode_exhaustive_sweep() {
        // dense magnitude sweep over the finite contract domain
        let mut x = 1e-12f32;
        while x < 465.0 {
            for v in [x, -x] {
                assert_eq!(
                    encode_finite(v),
                    encode(v),
                    "v={v} ({}, {})",
                    encode_finite(v),
                    encode(v)
                );
            }
            x *= 1.000731; // hits many mantissa patterns per binade
        }
        assert_eq!(encode_finite(0.0), 0x00);
        assert_eq!(encode_finite(-0.0), 0x80);
    }

    #[test]
    fn encode_finite_all_code_values_roundtrip() {
        for c in 0..=255u8 {
            if is_nan(c) {
                continue;
            }
            assert_eq!(encode_finite(decode(c)), c);
        }
    }

    #[test]
    fn encode_scaled_slice_matches_scalar() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.7).collect();
        let mut out = vec![0u8; xs.len()];
        let inv = 1.0f32 / 1.3;
        encode_scaled_slice(&xs, inv, &mut out);
        for (i, &v) in xs.iter().enumerate() {
            assert_eq!(out[i], encode(v * inv), "i={i}");
        }
    }

    #[test]
    fn scale_down_k0_identity() {
        for c in 0..=255u8 {
            assert_eq!(scale_down_code(c, 0), c);
        }
    }

    #[test]
    fn monotone_on_positives() {
        // encode is monotone non-decreasing over positive finite inputs
        let mut prev = 0u8;
        let mut x = 1e-5f32;
        while x < 448.0 {
            let c = encode(x);
            assert!(c >= prev, "monotonicity violated at {x}");
            prev = c;
            x *= 1.01;
        }
    }

    #[test]
    fn sign_symmetry() {
        let mut x = 1e-5f32;
        while x < 448.0 {
            assert_eq!(encode(-x), encode(x) | 0x80);
            x *= 1.07;
        }
    }
}
