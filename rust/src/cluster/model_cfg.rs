//! Model shape configurations for the three DeepSeek reference models the
//! paper benchmarks against (§3.3, §4).

/// Transformer/MoE shape parameters (decoder-only, MoE FFN).
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    /// Model label.
    pub name: &'static str,
    /// Total decoder layers.
    pub n_layers: usize,
    /// Layers with MoE FFN (the rest are dense).
    pub n_moe_layers: usize,
    /// Model width.
    pub d_model: usize,
    /// Per-expert FFN hidden size.
    pub moe_ffn: usize,
    /// Dense-FFN hidden (first layers / shared).
    pub dense_ffn: usize,
    /// Routed experts per MoE layer.
    pub n_experts: usize,
    /// Always-active shared experts.
    pub n_shared_experts: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Total parameter count (for memory accounting), in billions.
    pub params_b: f64,
    /// Active parameters per token, in billions.
    pub active_params_b: f64,
}

/// DeepSeek-V2-Lite (the 16 B convergence model of §4.1).
pub const DEEPSEEK_V2_LITE: ModelCfg = ModelCfg {
    name: "deepseek-v2-lite",
    n_layers: 27,
    n_moe_layers: 26,
    d_model: 2048,
    moe_ffn: 1408,
    dense_ffn: 10944,
    n_experts: 64,
    n_shared_experts: 2,
    top_k: 6,
    params_b: 15.7,
    active_params_b: 2.4,
};

/// DeepSeek-V2 (236 B).
pub const DEEPSEEK_V2: ModelCfg = ModelCfg {
    name: "deepseek-v2",
    n_layers: 60,
    n_moe_layers: 59,
    d_model: 5120,
    moe_ffn: 1536,
    dense_ffn: 12288,
    n_experts: 160,
    n_shared_experts: 2,
    top_k: 6,
    params_b: 236.0,
    active_params_b: 21.0,
};

/// DeepSeek-V3 (671 B — the Tables 2–3 model).
pub const DEEPSEEK_V3: ModelCfg = ModelCfg {
    name: "deepseek-v3",
    n_layers: 61,
    n_moe_layers: 58,
    d_model: 7168,
    moe_ffn: 2048,
    dense_ffn: 18432,
    n_experts: 256,
    n_shared_experts: 1,
    top_k: 8,
    params_b: 671.0,
    active_params_b: 37.0,
};

impl ModelCfg {
    /// Parameters of one expert (gate+up+down SwiGLU projections).
    pub fn expert_params(&self) -> usize {
        3 * self.d_model * self.moe_ffn
    }

    /// Dense (non-expert) parameters per layer: attention (MLA approximated
    /// as 4 d²) + norms + router.
    pub fn dense_params_per_layer(&self) -> usize {
        4 * self.d_model * self.d_model + 2 * self.d_model + self.d_model * self.n_experts
    }

    /// Total MoE-expert parameters.
    pub fn total_expert_params(&self) -> f64 {
        (self.n_moe_layers * (self.n_experts + self.n_shared_experts)) as f64
            * self.expert_params() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v3_param_count_order_of_magnitude() {
        // experts dominate: n_moe_layers × 257 × 3·7168·2048 ≈ 656 B
        let total = DEEPSEEK_V3.total_expert_params()
            + (DEEPSEEK_V3.n_layers * DEEPSEEK_V3.dense_params_per_layer()) as f64;
        let b = total / 1e9;
        assert!(
            (b - DEEPSEEK_V3.params_b).abs() / DEEPSEEK_V3.params_b < 0.15,
            "derived {b}B vs reported {}B",
            DEEPSEEK_V3.params_b
        );
    }

    #[test]
    fn lite_is_smallest() {
        assert!(DEEPSEEK_V2_LITE.params_b < DEEPSEEK_V2.params_b);
        assert!(DEEPSEEK_V2.params_b < DEEPSEEK_V3.params_b);
    }
}
