//! Per-GPU memory accounting under EP×PP sharding and activation-
//! checkpointing policies — the Tables 2–3 "Mem" column and the OOM
//! detector.
//!
//! Components (per GPU):
//! * parameters: dense params of this PP stage's layers + this EP rank's
//!   expert slice (BF16 working copy);
//! * optimizer: f32 master + two Adam moments over the same shard;
//! * gradients: BF16 over the shard;
//! * activations: per in-flight microbatch, policy-dependent — AC=full
//!   stores only layer-boundary tensors; AC=sel(+MoE expert) additionally
//!   stores the MoE layer's internals EXCEPT the expert FFN buffers; the
//!   fp8-flow recipe stores FP8 checkpoints (half of BF16) for the
//!   expert-path tensors it keeps (the paper's "FP8 activation
//!   compression").

use crate::cluster::model_cfg::ModelCfg;
use crate::cluster::topology::Layout;
use crate::moe::layer::Recipe;

/// Activation-checkpointing policy (§4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcMode {
    /// Full recompute: everything except layer boundaries is rebuilt.
    Full,
    /// Selective: checkpoint the MoE layer excluding experts.
    SelMoeExpert,
}

/// Workload shape per GPU.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Sequence length per sample.
    pub seq: usize,
    /// Microbatch size (samples per microbatch).
    pub micro_batch: usize,
    /// Number of microbatches per global step (per pipeline).
    pub n_micro: usize,
}

/// The paper's training workload: seq 4096, 64 microbatches.
pub const DEFAULT_WORKLOAD: Workload = Workload { seq: 4096, micro_batch: 1, n_micro: 64 };

/// Memory report (bytes).
#[derive(Clone, Copy, Debug)]
pub struct MemReport {
    /// Parameter bytes.
    pub params: u64,
    /// Optimizer-state bytes.
    pub optimizer: u64,
    /// Gradient bytes.
    pub gradients: u64,
    /// Activation bytes (checkpoint-aware).
    pub activations: u64,
    /// Workspace and fragmentation bytes.
    pub workspace: u64,
}

impl MemReport {
    /// Total bytes.
    pub fn total(&self) -> u64 {
        self.params + self.optimizer + self.gradients + self.activations + self.workspace
    }

    /// Total in GiB.
    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }

    /// Does the total exceed the layout's HBM capacity?
    pub fn oom(&self, l: &Layout) -> bool {
        self.total() > l.hw.hbm_bytes
    }
}

/// Layers resident on one PP stage (ceiling).
pub fn layers_per_stage(m: &ModelCfg, l: &Layout) -> usize {
    m.n_layers.div_ceil(l.pp)
}

/// Expert count per GPU (EP sharding of the expert set).
pub fn experts_per_gpu(m: &ModelCfg, l: &Layout) -> usize {
    m.n_experts.div_ceil(l.ep) + m.n_shared_experts
}

fn params_per_gpu(m: &ModelCfg, l: &Layout) -> (u64, u64) {
    let layers = layers_per_stage(m, l) as f64;
    let dense = (layers * m.dense_params_per_layer() as f64) as u64;
    let moe_layers = layers * (m.n_moe_layers as f64 / m.n_layers as f64);
    let experts =
        (moe_layers * experts_per_gpu(m, l) as f64 * m.expert_params() as f64) as u64;
    // (dense, expert) split — dense params are replicated across the EP
    // group (which doubles as the data-parallel group), so their optimizer
    // state shards EP-wide (Megatron distributed optimizer); expert params
    // are unique per rank.
    (dense, experts)
}

/// Bytes of activation checkpoints per microbatch per layer.
fn act_bytes_per_layer(m: &ModelCfg, _l: &Layout, w: &Workload, recipe: Recipe, ac: AcMode) -> u64 {
    let tokens = (w.seq * w.micro_batch) as u64;
    let d = m.d_model as u64;
    let k = m.top_k as u64;
    // element size of the checkpointed expert-path tensors
    let elt_expert: f64 = match recipe {
        Recipe::Fp8Flow => 1.0 + 1.0 / 128.0, // FP8 checkpoint compression
        _ => 2.0,                             // BF16
    };
    let boundary = tokens * d * 2; // layer-boundary tensor, always BF16
    // effective dispatched rows after capacity truncation/drop
    let cap_factor = 1.0;
    match ac {
        AcMode::Full => boundary,
        AcMode::SelMoeExpert => {
            // "checkpoint the MoE layer excluding experts": store the
            // layer boundary plus the dispatched expert-input buffer
            // (k·tokens×d) so the expert FFN can be recomputed; the FFN
            // internals themselves are NOT stored.
            let dispatched = (k * tokens * d) as f64 * cap_factor * elt_expert;
            // blockwise (TE) additionally caches FP8 operand copies for
            // the wgrad pass instead of recomputing them — the paper's
            // "extra activation copies" of naive FP8 integration.
            let te_cache = if recipe == Recipe::Blockwise {
                dispatched * 0.15
            } else {
                0.0
            };
            boundary + (dispatched + te_cache) as u64
        }
    }
}

/// In-flight microbatches at the deepest (first) stage of a 1F1B pipeline.
pub fn inflight_microbatches(l: &Layout, w: &Workload) -> usize {
    l.pp.min(w.n_micro)
}

/// Full per-GPU memory report.
pub fn memory_report(
    m: &ModelCfg,
    l: &Layout,
    w: &Workload,
    recipe: Recipe,
    ac: AcMode,
) -> MemReport {
    let (dense_p, expert_p) = params_per_gpu(m, l);
    let p = dense_p + expert_p;
    let params = p * 2; // BF16 working copy
    // f32 master + bf16 moments for expert params (Megatron's moment
    // compression for the dominant expert share); dense share replicated
    // across EP ⇒ its f32 optimizer shards EP-wide.
    let optimizer = expert_p * 9 + (dense_p * 12) / l.ep as u64;
    let gradients = p * 2; // BF16 grads
    let layers = layers_per_stage(m, l) as u64;
    let per_micro = layers * act_bytes_per_layer(m, l, w, recipe, ac);
    let activations = per_micro * inflight_microbatches(l, w) as u64;
    // comm workspace: DeepEP reserves per-peer send/recv rings, so the
    // buffer pool grows with the EP degree — the term that pushes the
    // baselines over 80 GB at EP32 (Table 3's OOM column).
    let tokens = (w.seq * w.micro_batch) as u64;
    let wire = match recipe {
        Recipe::Fp8Flow => 1.05,
        _ => 2.0,
    };
    let payload = (m.top_k as u64 * tokens * m.d_model as u64) as f64 * wire;
    let workspace = (payload * (1.0 + l.ep as f64 / 2.5)) as u64 + (1u64 << 30);
    MemReport { params, optimizer, gradients, activations, workspace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::model_cfg::DEEPSEEK_V3;

    fn layouts() -> [Layout; 3] {
        [Layout::new(8, 32), Layout::new(16, 16), Layout::new(32, 8)]
    }

    #[test]
    fn ac_full_fits_everywhere_for_all_recipes() {
        // Table 2: no OOM in any cell
        for l in layouts() {
            for r in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
                let rep = memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, r, AcMode::Full);
                assert!(!rep.oom(&l), "{r:?} EP{} should fit: {:.1} GB", l.ep, rep.total_gb());
                assert!(rep.total_gb() > 20.0, "unrealistically small: {:.1}", rep.total_gb());
            }
        }
    }

    #[test]
    fn ac_sel_ooms_baselines_at_ep32_but_not_fp8flow() {
        // Table 3's headline OOM pattern
        let l = Layout::new(32, 8);
        let bf16 = memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, Recipe::Bf16, AcMode::SelMoeExpert);
        let blockwise =
            memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, Recipe::Blockwise, AcMode::SelMoeExpert);
        let flow =
            memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, Recipe::Fp8Flow, AcMode::SelMoeExpert);
        assert!(bf16.oom(&l), "bf16 should OOM at EP32/AC=sel: {:.1} GB", bf16.total_gb());
        assert!(blockwise.oom(&l), "blockwise should OOM: {:.1} GB", blockwise.total_gb());
        assert!(!flow.oom(&l), "fp8-flow must fit: {:.1} GB", flow.total_gb());
    }

    #[test]
    fn fp8_checkpoint_compression_saves_gb_at_ep8() {
        // Table 3 EP8: fp8-flow ~8 GB below BF16
        let l = Layout::new(8, 32);
        let bf16 = memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, Recipe::Bf16, AcMode::SelMoeExpert);
        let flow =
            memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, Recipe::Fp8Flow, AcMode::SelMoeExpert);
        let saving = bf16.total_gb() - flow.total_gb();
        assert!(saving > 3.0, "saving {saving:.1} GB too small");
        assert!(saving < 30.0, "saving {saving:.1} GB implausibly large");
    }

    #[test]
    fn sel_uses_more_memory_than_full() {
        for l in layouts() {
            for r in [Recipe::Bf16, Recipe::Fp8Flow] {
                let f = memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, r, AcMode::Full);
                let s = memory_report(&DEEPSEEK_V3, &l, &DEFAULT_WORKLOAD, r, AcMode::SelMoeExpert);
                assert!(s.total() > f.total(), "{r:?} EP{}", l.ep);
            }
        }
    }

    #[test]
    fn expert_sharding_shrinks_with_ep() {
        assert!(
            experts_per_gpu(&DEEPSEEK_V3, &Layout::new(32, 8))
                < experts_per_gpu(&DEEPSEEK_V3, &Layout::new(8, 32))
        );
    }
}
