//! Expert-parallel cluster simulator — the substrate behind the paper's
//! evaluation (§4): DeepEP-style all-to-all costing (Table 1) and
//! end-to-end 671B throughput/memory under EP×PP and activation-
//! checkpointing policies (Tables 2–3).
//!
//! The paper measured a 32-node H100 cluster we do not have; per the
//! substitution rule (DESIGN.md §Hardware-Adaptation) the simulator holds
//! the *hardware* constant across recipes and varies only the dataflow —
//! which is the paper's own experimental control. Absolute milliseconds
//! are calibrated to the same order as the paper's testbed; the asserted
//! results are orderings, ratios and crossovers.
//!
//! Two substrates live here:
//!
//! * the **analytic** side ([`comm`], [`sim`], [`memory`], [`schedule`],
//!   [`topology`]) — the Tables 1–3 cost model;
//! * the **executed** side ([`rank`], [`ep_exec`]) — simulated ranks as
//!   disjoint worker groups running the real FP8-code-space dispatch, so
//!   the model's comm/compute claims can be measured
//!   ([`sim::ep_measured_vs_modeled`]).

pub mod comm;
pub mod ep_exec;
pub mod fault;
pub mod memory;
pub mod model_cfg;
pub mod rank;
pub mod schedule;
pub mod sim;
pub mod topology;

pub use model_cfg::{ModelCfg, DEEPSEEK_V2, DEEPSEEK_V2_LITE, DEEPSEEK_V3};

