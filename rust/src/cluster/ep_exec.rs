//! **Executed** expert-parallel sharding — the measured counterpart of
//! [`crate::cluster::sim`]'s analytic EP model.
//!
//! [`ep_forward`] runs the MoE layer forward sharded across R simulated
//! ranks ([`crate::cluster::rank::RankGroup`]): experts are partitioned
//! `Partition::even(E, R)`, tokens `Partition::even(T, R)`, and each
//! top-k slot executes the real dispatch pipeline
//!
//! ```text
//! pack (per src rank: rows → per-destination send buffers)
//!   → in-memory all-to-all (u8 codes + UE8M0 sidecar as two buffers;
//!     dense rows as one — cluster/comm.rs's two-buffer model)
//!   → assemble (per dst rank: rows → [E_local·capacity, d] batch)
//!   → expert FFN (per rank, on its disjoint worker share)
//!   → combine (per-rank unpermute_unpad → reduce → gates)
//! ```
//!
//! with wall-clock timers around every stage, so the comm/compute claims
//! the simulator makes analytically become measurements
//! ([`crate::cluster::sim::ep_measured_vs_modeled`] prints them side by
//! side).
//!
//! **Bit-identity contract**: for any R, the output equals the
//! single-rank [`crate::moe::layer::moe_forward`] bit for bit
//! (`tests/prop_ep_shard.rs`). The pieces that make this hold:
//! per-expert math reads only that expert's `capacity` rows; the UE8M0
//! sidecar reproduces po2 scales exactly (`scale == 2^sexp`); each token
//! appears at most once per top-k slot, so the per-rank combine partials
//! sum (in ascending rank = ascending plan order) to the single-rank
//! scatter result.

use std::ops::Range;
use std::time::Instant;

use crate::cluster::rank::{all_to_all, RankGroup, WireBuf};
use crate::exec::{self, Partition};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::tile::quantize_rowwise_with_threads;
use crate::fp8::{ue8m0, Fp8Format, ScaleMode};
use crate::moe::backward::{
    expert_ffn_bwd, mat_add_assign, router_backward_from_stash, scale_by_gates_with_threads,
    BwdStageTimes, BwdStats, FwdStash, MoeGrads,
};
use crate::moe::layer::{
    combine, expert_ffn, PreparedWeights, RankLocalBatch, Recipe, WirePayload,
};
use crate::moe::permute::permute_pad_plan;
use crate::moe::router::route;
use crate::train::native::{NativeTrainer, TrainMetrics};
use crate::util::json::Json;
use crate::util::mat::Mat;

/// Execution parameters for one EP-sharded forward.
#[derive(Clone, Copy, Debug)]
pub struct EpConfig {
    /// Number of simulated ranks (expert shards).
    pub ranks: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Per-expert row budget.
    pub capacity: usize,
    /// Total worker budget shared by all ranks (0 = resolve via
    /// [`crate::exec::threads`]). Each rank gets a disjoint share.
    pub threads: usize,
}

/// Shape of one executed EP forward — shared by the runtime, the
/// simulator's model ([`crate::cluster::sim::modeled_ep_stages`]) and the
/// `epshard` CLI.
#[derive(Clone, Copy, Debug)]
pub struct EpShape {
    /// Token rows.
    pub tokens: usize,
    /// Model width.
    pub d_model: usize,
    /// Per-expert FFN hidden size.
    pub ffn: usize,
    /// Expert count.
    pub n_experts: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Per-expert row budget.
    pub capacity: usize,
}

impl EpShape {
    /// Derive the shape from an input/weights/config triple.
    pub fn of(x: &Mat, w: &PreparedWeights, cfg: &EpConfig) -> EpShape {
        EpShape {
            tokens: x.rows,
            d_model: x.cols,
            ffn: w.raw.w1[0].cols,
            n_experts: w.raw.n_experts(),
            top_k: cfg.top_k,
            capacity: cfg.capacity,
        }
    }
}

/// Accumulated wall-clock seconds per pipeline stage (summed over the
/// top-k slots; route and entry-quant run once).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// Router seconds.
    pub route_s: f64,
    /// Entry-quantization seconds.
    pub quant_s: f64,
    /// Dispatch (permute + wire) seconds.
    pub dispatch_s: f64,
    /// Expert GEMM seconds.
    pub expert_s: f64,
    /// Combine (wire + unpermute) seconds.
    pub combine_s: f64,
}

impl StageTimes {
    /// Sum of all stages.
    pub fn total_s(&self) -> f64 {
        self.route_s + self.quant_s + self.dispatch_s + self.expert_s + self.combine_s
    }
}

/// Result of one executed EP-sharded forward: the output plus the
/// measurements the simulator can only model.
pub struct EpForward {
    /// Layer output `[t, d]`.
    pub y: Mat,
    /// Load-balancing aux loss.
    pub aux_loss: f32,
    /// Rank count the forward ran with.
    pub ranks: usize,
    /// Per-stage wall-clock seconds.
    pub stages: StageTimes,
    /// Per-rank expert-stage seconds (summed over slots) — the load
    /// imbalance the capacity model hides.
    pub rank_expert_s: Vec<f64>,
    /// Dispatch payload bytes actually shipped (real rows only — padding
    /// never crosses the wire).
    pub dispatch_payload_bytes: usize,
    /// UE8M0 scale sidecar bytes (FP8 wire only).
    pub dispatch_sidecar_bytes: usize,
    /// Number of separate wire buffers (the synchronization-count proxy:
    /// FP8 ships 2 per src→dst pair, BF16 ships 1).
    pub dispatch_buffers: usize,
    /// Combine-path bytes (always BF16-accounted — §3.3 keeps the
    /// combine in BF16 for gradient safety).
    pub combine_bytes: usize,
}

impl EpForward {
    /// Per-stage report as JSON (for `runs/epshard_*.json`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ranks", self.ranks)
            .set("route_ms", self.stages.route_s * 1e3)
            .set("quant_ms", self.stages.quant_s * 1e3)
            .set("dispatch_ms", self.stages.dispatch_s * 1e3)
            .set("expert_ms", self.stages.expert_s * 1e3)
            .set("combine_ms", self.stages.combine_s * 1e3)
            .set("total_ms", self.stages.total_s() * 1e3)
            .set(
                "rank_expert_ms",
                self.rank_expert_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set("dispatch_payload_bytes", self.dispatch_payload_bytes)
            .set("dispatch_sidecar_bytes", self.dispatch_sidecar_bytes)
            .set("dispatch_buffers", self.dispatch_buffers)
            .set("combine_bytes", self.combine_bytes)
            .set("aux_loss", self.aux_loss)
    }
}

/// Run the MoE forward sharded across `cfg.ranks` simulated ranks.
/// Bit-identical to `moe_forward(x, w, cfg.top_k, cfg.capacity)` for any
/// rank count.
pub fn ep_forward(x: &Mat, w: &PreparedWeights, cfg: &EpConfig) -> EpForward {
    let t = x.rows;
    let d = x.cols;
    let e = w.raw.n_experts();
    let r = cfg.ranks;
    assert!(r >= 1, "need at least one rank");
    assert!(e >= r, "cannot shard {e} experts across {r} ranks");
    assert!(t >= 1 && cfg.capacity >= 1);
    let total_workers = if cfg.threads == 0 { exec::threads() } else { cfg.threads };
    let group = RankGroup::new(r, total_workers);
    let ex_part = Partition::even(e, r);
    let tok_part = Partition::even(t, r);
    let token_owner = owner_map(&tok_part, t);

    let mut stages = StageTimes::default();

    let ts = Instant::now();
    let routing = route(x, &w.raw.router, cfg.top_k);
    stages.route_s = ts.elapsed().as_secs_f64();

    // Entry quantization (Fp8Flow's single cast). Row-independent, so
    // quantizing per token-owner rank would be bit-identical; run it
    // once over the batch with the full worker budget.
    let x_q = if w.recipe == Recipe::Fp8Flow {
        let tq = Instant::now();
        let q = quantize_rowwise_with_threads(x, Fp8Format::E4M3, ScaleMode::Po2, total_workers);
        stages.quant_s = tq.elapsed().as_secs_f64();
        Some(q)
    } else {
        None
    };
    let fmt = x_q.as_ref().map(|q| q.fmt);

    let expert_owner = owner_map(&ex_part, e);

    let mut y = Mat::zeros(t, d);
    let mut rank_expert_s = vec![0.0f64; r];
    let (mut payload_b, mut sidecar_b, mut n_bufs, mut combine_b) = (0usize, 0usize, 0usize, 0usize);

    for kk in 0..cfg.top_k {
        let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
        let plan = permute_pad_plan(&expert_of, e, cfg.capacity);
        // Each token appears at most once per slot.
        let serving = serving_map(&plan, &expert_owner, cfg.capacity, t);

        // ---- dispatch: pack → all-to-all → assemble ----
        let td = Instant::now();
        let mailbox = group
            .run_phase(|ctx| {
                let tr = part_range(&tok_part, ctx.rank);
                match &x_q {
                    Some(xq) => pack_fp8(xq, &plan, &tr, &ex_part, cfg.capacity),
                    None => pack_dense(x, &plan, &tr, &ex_part, cfg.capacity),
                }
            })
            .results;
        for row in &mailbox {
            for b in row {
                payload_b += b.payload_bytes();
                sidecar_b += b.sidecar_bytes();
                n_bufs += b.n_buffers();
            }
        }
        let inbox = all_to_all(mailbox);
        let batches = group
            .run_phase(|ctx| {
                let er = ex_part.range(ctx.rank);
                match fmt {
                    Some(f) => assemble_fp8(
                        &inbox[ctx.rank],
                        &plan,
                        er,
                        cfg.capacity,
                        d,
                        &token_owner,
                        f,
                    ),
                    None => assemble_dense(&inbox[ctx.rank], &plan, er, cfg.capacity, d, &token_owner),
                }
            })
            .results;
        stages.dispatch_s += td.elapsed().as_secs_f64();

        // ---- expert FFN: each rank on its disjoint worker share ----
        let te = Instant::now();
        let ph = group.run_phase(|ctx| expert_ffn(&batches[ctx.rank], w, ctx.workers));
        for (i, s) in ph.rank_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }
        let yks = ph.results;
        stages.expert_s += te.elapsed().as_secs_f64();

        // Combine-wire accounting (BF16 rows back to token owners, §3.3)
        // happens outside the timer: bookkeeping must not contaminate
        // the measured combine stage (pack pre-sizes for the same reason).
        combine_b += plan.iter().filter(|&&s| s >= 0).count() * d * 2;

        // ---- combine: per-rank unpermute → reduce → gates ----
        let tc = Instant::now();
        let partials = group
            .run_phase(|ctx| {
                let er = ex_part.range(ctx.rank);
                combine(&yks[ctx.rank], &plan, er, cfg.capacity, t, ctx.workers)
            })
            .results;
        // Reduce + gate, one task per token shard (disjoint y rows).
        // A token has at most one serving rank per slot, every other
        // partial holds exactly +0.0 there, and partial values are never
        // -0.0 (unpermute adds into zeros), so reading the serving
        // partial directly equals the full ascending-rank sum — and the
        // single-rank scatter — bit for bit. Dropped tokens contribute
        // g·(+0.0), which never changes y's bits (y is never -0.0).
        let tasks: Vec<_> = exec::split_parts(&tok_part, d, &mut y.data)
            .into_iter()
            .zip(tok_part.ranges())
            .collect();
        exec::run_tasks(tasks, |(rows, trange)| {
            for tt in trange.clone() {
                let sr = serving[tt];
                if sr == usize::MAX {
                    continue; // dropped by capacity: back row is zero
                }
                let g = routing.gates[tt][kk];
                let o = (tt - trange.start) * d;
                let p = &partials[sr].data;
                for j in 0..d {
                    rows[o + j] += g * p[tt * d + j];
                }
            }
        });
        stages.combine_s += tc.elapsed().as_secs_f64();
    }

    EpForward {
        y,
        aux_loss: routing.aux_loss,
        ranks: r,
        stages,
        rank_expert_s,
        dispatch_payload_bytes: payload_b,
        dispatch_sidecar_bytes: sidecar_b,
        dispatch_buffers: n_bufs,
        combine_bytes: combine_b,
    }
}

/// Result of one executed EP-sharded backward: the gradients plus the
/// wire measurements (the reverse-direction all-to-all).
pub struct EpBackward {
    /// The full layer gradients.
    pub grads: MoeGrads,
    /// Rank count the backward ran with.
    pub ranks: usize,
    /// Per-rank expert-backward seconds (summed over slots).
    pub rank_expert_s: Vec<f64>,
    /// Combine-bwd payload bytes shipped (gate-scaled dy rows; FP8 codes
    /// on the Fp8Flow wire, BF16-accounted rows otherwise).
    pub dy_payload_bytes: usize,
    /// UE8M0 scale sidecar bytes on the combine-bwd wire (FP8 only).
    pub dy_sidecar_bytes: usize,
    /// Separate combine-bwd wire buffers (FP8 ships 2 per src→dst pair).
    pub dy_buffers: usize,
    /// Dispatch-bwd bytes (dX rows back to token owners — accumulator
    /// precision, BF16-accounted, like the forward combine).
    pub dx_bytes: usize,
}

impl EpBackward {
    /// Per-stage report as JSON (for `runs/bwd_*.json`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ranks", self.ranks)
            .set("combine_bwd_ms", self.grads.stages.combine_bwd_s * 1e3)
            .set("expert_bwd_ms", self.grads.stages.expert_bwd_s * 1e3)
            .set("dispatch_bwd_ms", self.grads.stages.dispatch_bwd_s * 1e3)
            .set("total_ms", self.grads.stages.total_s() * 1e3)
            .set(
                "rank_expert_ms",
                self.rank_expert_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set("casts", self.grads.stats.casts)
            .set("requants", self.grads.stats.requants)
            .set("dy_payload_bytes", self.dy_payload_bytes)
            .set("dy_sidecar_bytes", self.dy_sidecar_bytes)
            .set("dy_buffers", self.dy_buffers)
            .set("dx_bytes", self.dx_bytes)
    }
}

/// Run the MoE backward sharded across `cfg.ranks` simulated ranks — the
/// forward pipeline reversed, reusing the same rank group and wire:
///
/// ```text
/// gate-scale dy (+ Q(dy) on the Fp8Flow wire)
///   → pack per token-owner rank → all-to-all → assemble per expert rank
///     (the combine-bwd a2a: same routing as the fwd dispatch)
///   → per-rank expert backward (dgrad + wgrad on its worker share)
///   → per-rank unpermute → serving-rank reduce into the token shards
///     (the dispatch-bwd direction; dX rides in accumulator precision)
/// ```
///
/// Bit-identical to the single-rank [`crate::moe::backward::moe_backward`]
/// for any rank count (`tests/prop_ep_shard.rs`): per-expert math reads
/// only that expert's rows, the UE8M0 sidecar reproduces po2 scales
/// exactly, each expert's weight gradient is owned by exactly one rank,
/// and per-slot each token receives at most one dX row.
pub fn ep_backward(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
) -> EpBackward {
    let t = dy.rows;
    let d = dy.cols;
    let e = w.raw.n_experts();
    let r = cfg.ranks;
    assert!(r >= 1, "need at least one rank");
    assert!(e >= r, "cannot shard {e} experts across {r} ranks");
    assert_eq!(cfg.capacity, stash.capacity, "config/stash capacity mismatch");
    assert_eq!(cfg.top_k, stash.top_k(), "config/stash top_k mismatch");
    assert_eq!((t, d), (stash.y.rows, stash.y.cols), "dy must match the forward output");
    let total_workers = if cfg.threads == 0 { exec::threads() } else { cfg.threads };
    let group = RankGroup::new(r, total_workers);
    let ex_part = Partition::even(e, r);
    let tok_part = Partition::even(t, r);
    let token_owner = owner_map(&tok_part, t);
    let expert_owner = owner_map(&ex_part, e);
    let cap = cfg.capacity;

    let mut dx = Mat::zeros(t, d);
    let mut dw1: Vec<Mat> = w.raw.w1.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw3: Vec<Mat> = w.raw.w3.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw2: Vec<Mat> = w.raw.w2.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut stats = BwdStats::default();
    let mut stages = BwdStageTimes::default();
    let mut rank_expert_s = vec![0.0f64; r];
    let (mut dy_payload_b, mut dy_sidecar_b, mut dy_bufs, mut dx_b) = (0usize, 0, 0, 0usize);

    for (kk, slot) in stash.slots.iter().enumerate() {
        let plan = &slot.plan;
        let serving = serving_map(plan, &expert_owner, cap, t);

        // ---- combine-bwd: gate-scale (+ Q) → pack → a2a → assemble ----
        let tc = Instant::now();
        let dyg = scale_by_gates_with_threads(dy, &stash.routing, kk, total_workers);
        // Row-independent, so quantizing per token-owner rank would be
        // bit-identical; run it once with the full budget (same structure
        // as the forward's entry quantization).
        let dy_q = if w.recipe == Recipe::Fp8Flow {
            stats.casts += 1;
            Some(quantize_rowwise_with_threads(
                &dyg,
                Fp8Format::E4M3,
                ScaleMode::Po2,
                total_workers,
            ))
        } else {
            None
        };
        let mailbox = group
            .run_phase(|ctx| {
                let tr = part_range(&tok_part, ctx.rank);
                match &dy_q {
                    Some(q) => pack_fp8(q, plan, &tr, &ex_part, cap),
                    None => pack_dense(&dyg, plan, &tr, &ex_part, cap),
                }
            })
            .results;
        for row in &mailbox {
            for b in row {
                dy_payload_b += b.payload_bytes();
                dy_sidecar_b += b.sidecar_bytes();
                dy_bufs += b.n_buffers();
            }
        }
        let inbox = all_to_all(mailbox);
        let dyks = group
            .run_phase(|ctx| {
                let er = ex_part.range(ctx.rank);
                match dy_q.as_ref() {
                    Some(q) => {
                        assemble_fp8(&inbox[ctx.rank], plan, er, cap, d, &token_owner, q.fmt)
                    }
                    None => assemble_dense(&inbox[ctx.rank], plan, er, cap, d, &token_owner),
                }
            })
            .results;
        stages.combine_bwd_s += tc.elapsed().as_secs_f64();

        // ---- expert backward: each rank on its disjoint worker share ----
        let te = Instant::now();
        let ph = group.run_phase(|ctx| expert_ffn_bwd(&dyks[ctx.rank], slot, w, ctx.workers));
        for (i, s) in ph.rank_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }
        let ebs = ph.results;
        stages.expert_bwd_s += te.elapsed().as_secs_f64();

        // Weight gradients stay with their expert's owning rank; the
        // global Vec is just the shard union (ascending expert order, one
        // owner per expert ⇒ bitwise the single-rank accumulation).
        for eb in &ebs {
            stats.add(eb.stats);
            for (lx, g) in eb.grads.iter().enumerate() {
                let ge = eb.experts.start + lx;
                mat_add_assign(&mut dw1[ge], &g.dw1);
                mat_add_assign(&mut dw3[ge], &g.dw3);
                mat_add_assign(&mut dw2[ge], &g.dw2);
            }
        }
        // dispatch-bwd wire accounting (real rows only, BF16-accounted;
        // bookkeeping outside the timer, like the forward combine)
        dx_b += plan.iter().filter(|&&s| s >= 0).count() * d * 2;

        // ---- dispatch-bwd: per-rank unpermute → serving-rank reduce ----
        // Same bit-exactness argument as the forward combine: a token has
        // at most one serving rank per slot, partials are never -0.0
        // (unpermute adds into zeros), and dropped tokens contribute +0.0,
        // which never changes dx's bits (dx is never -0.0).
        let td = Instant::now();
        let partials = group
            .run_phase(|ctx| {
                let er = ex_part.range(ctx.rank);
                combine(&ebs[ctx.rank].dxk, plan, er, cap, t, ctx.workers)
            })
            .results;
        let tasks: Vec<_> = exec::split_parts(&tok_part, d, &mut dx.data)
            .into_iter()
            .zip(tok_part.ranges())
            .collect();
        exec::run_tasks(tasks, |(rows, trange)| {
            for tt in trange.clone() {
                let sr = serving[tt];
                if sr == usize::MAX {
                    continue; // dropped by capacity: dX row is zero
                }
                let o = (tt - trange.start) * d;
                let p = &partials[sr].data;
                for j in 0..d {
                    rows[o + j] += p[tt * d + j];
                }
            }
        });
        stages.dispatch_bwd_s += td.elapsed().as_secs_f64();
    }

    EpBackward {
        grads: MoeGrads { dx, dw1, dw3, dw2, d_router: None, stats, stages },
        ranks: r,
        rank_expert_s,
        dy_payload_bytes: dy_payload_b,
        dy_sidecar_bytes: dy_sidecar_b,
        dy_buffers: dy_bufs,
        dx_bytes: dx_b,
    }
}

/// [`ep_backward`] plus the routing path: the gate/aux gradients are
/// dense f32 and replicated (every rank computes the identical result in
/// a real deployment; here they are computed once), so adding them after
/// the sharded expert backward is bitwise the single-rank
/// [`crate::moe::backward::moe_backward_with_router`].
pub fn ep_backward_with_router(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
    aux_coef: f32,
) -> EpBackward {
    let mut out = ep_backward(stash, w, dy, cfg);
    let rb = router_backward_from_stash(stash, w, dy, aux_coef);
    mat_add_assign(&mut out.grads.dx, &rb.dx);
    out.grads.d_router = Some(rb.d_router);
    out
}

/// One **EP-sharded native training step**: the trainer's forward (whose
/// stash is bitwise the sharded forward's, PR 2's invariance theorem),
/// then per-rank backward → gradient reduce across the
/// [`crate::cluster::rank::RankGroup`] ([`ep_backward_with_router`]: the
/// dispatch-bwd serving-rank reduce for dX, the shard union for the
/// expert weight grads, the replicated dense router path), then the
/// **replicated optimizer step** — deterministic f32 over identical
/// reduced gradients, so executing it once stands in for R identical
/// executions — ending in the masters→FP8 weight requantization.
///
/// Bit-identical to [`NativeTrainer::step_batch`] at `ranks = 1` for any
/// rank count (`tests/prop_train.rs`): the two paths share the step core
/// and differ only in the MoE backward closure, whose EP invariance PR 3
/// already proves.
pub fn ep_train_step(tr: &mut NativeTrainer, tokens: &[i32]) -> TrainMetrics {
    let cfg = EpConfig {
        ranks: tr.cfg.ranks,
        top_k: tr.cfg.top_k,
        capacity: tr.cfg.capacity,
        threads: tr.cfg.threads,
    };
    tr.step_with_backward(tokens, move |stash, w, dy, aux_coef| {
        ep_backward_with_router(stash, w, dy, &cfg, aux_coef).grads
    })
}

/// Serving rank per token for one slot's plan (`usize::MAX` = dropped by
/// capacity). Shared by the forward combine reduce and the backward
/// dispatch-bwd reduce — both read exactly one partial per served token.
fn serving_map(
    plan: &[i64],
    expert_owner: &[usize],
    capacity: usize,
    n_tokens: usize,
) -> Vec<usize> {
    let mut serving = vec![usize::MAX; n_tokens];
    for (gd, &src) in plan.iter().enumerate() {
        if src >= 0 {
            serving[src as usize] = expert_owner[gd / capacity];
        }
    }
    serving
}

/// Item → owning rank, from a partition (tokens or experts).
fn owner_map(part: &Partition, n_items: usize) -> Vec<usize> {
    let mut owner = vec![0usize; n_items];
    for (r, range) in part.ranges().enumerate() {
        for i in range {
            owner[i] = r;
        }
    }
    owner
}

/// Range of part `i`, or empty when the partition has fewer parts than
/// ranks (more ranks than tokens).
fn part_range(p: &Partition, i: usize) -> Range<usize> {
    if i < p.len() {
        p.range(i)
    } else {
        0..0
    }
}

/// Rows this source rank ships into one destination's expert segment
/// (= the exact send-buffer size, computed before packing).
fn sent_rows(plan: &[i64], dr: &Range<usize>, capacity: usize, tok: &Range<usize>) -> usize {
    plan[dr.start * capacity..dr.end * capacity]
        .iter()
        .filter(|&&src| src >= 0 && tok.contains(&(src as usize)))
        .count()
}

/// Pack one source rank's FP8 sends: for each destination rank, its
/// tokens' code rows (ascending plan order) plus the UE8M0 sidecar as a
/// second buffer.
fn pack_fp8(
    xq: &Fp8Tensor,
    plan: &[i64],
    tok: &Range<usize>,
    ex_part: &Partition,
    capacity: usize,
) -> Vec<WireBuf> {
    let h = xq.cols;
    let tpr = n_tiles(h);
    assert!(!xq.sexp.is_empty(), "FP8 wire needs po2 scale exponents");
    (0..ex_part.len())
        .map(|dst| {
            let dr = ex_part.range(dst);
            // size the buffers exactly up front: reallocation memmoves
            // would otherwise be charged to the timed dispatch stage
            let n_rows = sent_rows(plan, &dr, capacity, tok);
            let mut codes = Vec::with_capacity(n_rows * h);
            let mut sidecar = Vec::with_capacity(n_rows * tpr);
            for gd in dr.start * capacity..dr.end * capacity {
                let src = plan[gd];
                if src >= 0 && tok.contains(&(src as usize)) {
                    let s = src as usize;
                    codes.extend_from_slice(&xq.data[s * h..(s + 1) * h]);
                    for k in 0..tpr {
                        let e = xq.sexp[s * tpr + k];
                        // Outside UE8M0's exponent range the sidecar would
                        // saturate and silently break the bit-identity
                        // contract — fail loudly, in release builds too.
                        assert!(
                            (-(ue8m0::BIAS)..=(255 - ue8m0::BIAS)).contains(&e),
                            "po2 scale exponent {e} not UE8M0-representable"
                        );
                        sidecar.push(ue8m0::from_exponent(e));
                    }
                }
            }
            WireBuf::Fp8 { codes, sidecar }
        })
        .collect()
}

/// Pack one source rank's dense (BF16-wire) sends.
fn pack_dense(
    x: &Mat,
    plan: &[i64],
    tok: &Range<usize>,
    ex_part: &Partition,
    capacity: usize,
) -> Vec<WireBuf> {
    let h = x.cols;
    (0..ex_part.len())
        .map(|dst| {
            let dr = ex_part.range(dst);
            let mut rows = Vec::with_capacity(sent_rows(plan, &dr, capacity, tok) * h);
            for gd in dr.start * capacity..dr.end * capacity {
                let src = plan[gd];
                if src >= 0 && tok.contains(&(src as usize)) {
                    rows.extend_from_slice(x.row(src as usize));
                }
            }
            WireBuf::Dense(rows)
        })
        .collect()
}

/// Assemble one destination rank's `[E_local·capacity, d]` FP8 batch from
/// its received buffers. Padding rows stay zero codes with scale 1
/// (= 2^0) — exactly `permute_pad_fp8`'s initialization, which the
/// bit-identity contract relies on.
fn assemble_fp8(
    inbox: &[WireBuf],
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    cols: usize,
    token_owner: &[usize],
    fmt: Fp8Format,
) -> RankLocalBatch {
    let tpr = n_tiles(cols);
    let rows = experts.len() * capacity;
    let mut data = vec![0u8; rows * cols];
    let mut scales = vec![1.0f32; rows * tpr];
    let mut sexp = vec![0i32; rows * tpr];
    let mut cur = vec![0usize; inbox.len()];
    for (ld, gd) in (experts.start * capacity..experts.end * capacity).enumerate() {
        let src = plan[gd];
        if src < 0 {
            continue;
        }
        let s_rank = token_owner[src as usize];
        let WireBuf::Fp8 { codes, sidecar } = &inbox[s_rank] else {
            panic!("FP8 assemble received a dense wire buffer");
        };
        let c = cur[s_rank];
        data[ld * cols..(ld + 1) * cols].copy_from_slice(&codes[c * cols..(c + 1) * cols]);
        for k in 0..tpr {
            let b = sidecar[c * tpr + k];
            // scale == 2^sexp (po2 contract): decoding the sidecar byte
            // reproduces the original f32 scale bitwise
            scales[ld * tpr + k] = ue8m0::decode(b);
            sexp[ld * tpr + k] = ue8m0::exponent(b);
        }
        cur[s_rank] += 1;
    }
    let payload = WirePayload::Fp8(Fp8Tensor {
        rows,
        cols,
        fmt,
        mode: ScaleMode::Po2,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    });
    RankLocalBatch { experts, capacity, payload }
}

/// Assemble one destination rank's dense batch.
fn assemble_dense(
    inbox: &[WireBuf],
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    cols: usize,
    token_owner: &[usize],
) -> RankLocalBatch {
    let rows = experts.len() * capacity;
    let mut m = Mat::zeros(rows, cols);
    let mut cur = vec![0usize; inbox.len()];
    for (ld, gd) in (experts.start * capacity..experts.end * capacity).enumerate() {
        let src = plan[gd];
        if src < 0 {
            continue;
        }
        let s_rank = token_owner[src as usize];
        let WireBuf::Dense(buf) = &inbox[s_rank] else {
            panic!("dense assemble received an FP8 wire buffer");
        };
        let c = cur[s_rank];
        m.data[ld * cols..(ld + 1) * cols].copy_from_slice(&buf[c * cols..(c + 1) * cols]);
        cur[s_rank] += 1;
    }
    RankLocalBatch { experts, capacity, payload: WirePayload::Dense(m) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::{moe_forward, MoeWeights};
    use crate::util::prop::assert_mat_bits_eq;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Mat, MoeWeights) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e) = (64, 64, 48, 4);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        (x, w)
    }

    #[test]
    fn sharded_matches_single_rank_all_recipes() {
        let (x, w) = setup(21);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let reference = moe_forward(&x, &pw, 2, 24);
            for ranks in [1usize, 2, 4] {
                let cfg = EpConfig { ranks, top_k: 2, capacity: 24, threads: 0 };
                let out = ep_forward(&x, &pw, &cfg);
                assert_mat_bits_eq(&out.y, &reference.y, &format!("{recipe:?} R={ranks}"));
                assert_eq!(out.aux_loss.to_bits(), reference.aux_loss.to_bits());
            }
        }
    }

    #[test]
    fn fp8_wire_is_lighter_and_doubles_buffer_count() {
        let (x, w) = setup(22);
        let cfg = EpConfig { ranks: 2, top_k: 1, capacity: 32, threads: 2 };
        let flow = ep_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), &cfg);
        let bf16 = ep_forward(&x, &PreparedWeights::new(w, Recipe::Bf16), &cfg);
        // same real rows shipped → FP8 payload is exactly half the BF16 bytes
        assert_eq!(flow.dispatch_payload_bytes * 2, bf16.dispatch_payload_bytes);
        assert!(flow.dispatch_sidecar_bytes > 0);
        assert_eq!(bf16.dispatch_sidecar_bytes, 0);
        // two-buffer model: FP8 ships 2 buffers per src→dst pair, BF16 one
        assert_eq!(flow.dispatch_buffers, 2 * bf16.dispatch_buffers);
        assert_eq!(bf16.dispatch_buffers, 2 * 2); // R² pairs, one slot
        // combine stays BF16 in both recipes
        assert_eq!(flow.combine_bytes, bf16.combine_bytes);
    }

    #[test]
    fn stage_timers_are_populated() {
        let (x, w) = setup(23);
        let cfg = EpConfig { ranks: 2, top_k: 1, capacity: 32, threads: 2 };
        let out = ep_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), &cfg);
        assert!(out.stages.route_s > 0.0);
        assert!(out.stages.quant_s > 0.0);
        assert!(out.stages.dispatch_s > 0.0);
        assert!(out.stages.expert_s > 0.0);
        assert!(out.stages.combine_s > 0.0);
        assert_eq!(out.rank_expert_s.len(), 2);
        assert!(out.stages.total_s() >= out.stages.expert_s);
        let j = out.to_json().render();
        assert!(j.contains("\"dispatch_ms\""), "{j}");
    }

    #[test]
    fn more_ranks_than_tokens_still_exact() {
        let mut rng = Rng::seed_from(24);
        let (t, d, h, e) = (3, 32, 16, 4);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let reference = moe_forward(&x, &pw, 1, 2);
        let out = ep_forward(&x, &pw, &EpConfig { ranks: 4, top_k: 1, capacity: 2, threads: 3 });
        assert_mat_bits_eq(&out.y, &reference.y, "R>T");
    }

    #[test]
    #[should_panic(expected = "cannot shard")]
    fn more_ranks_than_experts_rejected() {
        let (x, w) = setup(25);
        let pw = PreparedWeights::new(w, Recipe::Bf16);
        ep_forward(&x, &pw, &EpConfig { ranks: 8, top_k: 1, capacity: 8, threads: 1 });
    }

    #[test]
    fn sharded_backward_matches_single_rank_all_recipes() {
        use crate::moe::backward::{forward_stash, moe_backward};
        let (x, w) = setup(26);
        let mut rng = Rng::seed_from(27);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let stash = forward_stash(&x, &pw, 2, 24);
            let reference = moe_backward(&stash, &pw, &dy);
            for ranks in [1usize, 2, 4] {
                let cfg = EpConfig { ranks, top_k: 2, capacity: 24, threads: 0 };
                let out = ep_backward(&stash, &pw, &dy, &cfg);
                let tag = format!("{recipe:?} R={ranks}");
                assert_mat_bits_eq(&out.grads.dx, &reference.dx, &format!("{tag} dx"));
                for e in 0..w.n_experts() {
                    assert_mat_bits_eq(&out.grads.dw1[e], &reference.dw1[e], &format!("{tag} dw1[{e}]"));
                    assert_mat_bits_eq(&out.grads.dw3[e], &reference.dw3[e], &format!("{tag} dw3[{e}]"));
                    assert_mat_bits_eq(&out.grads.dw2[e], &reference.dw2[e], &format!("{tag} dw2[{e}]"));
                }
                assert_eq!(out.grads.stats, reference.stats, "{tag} cast audit");
            }
        }
    }

    #[test]
    fn backward_fp8_wire_accounting() {
        use crate::moe::backward::forward_stash;
        let (x, w) = setup(28);
        let mut rng = Rng::seed_from(29);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        let cfg = EpConfig { ranks: 2, top_k: 1, capacity: 32, threads: 2 };
        let pw_f = PreparedWeights::new(w.clone(), Recipe::Fp8Flow);
        let st_f = forward_stash(&x, &pw_f, 1, 32);
        let flow = ep_backward(&st_f, &pw_f, &dy, &cfg);
        let pw_b = PreparedWeights::new(w, Recipe::Bf16);
        let st_b = forward_stash(&x, &pw_b, 1, 32);
        let bf16 = ep_backward(&st_b, &pw_b, &dy, &cfg);
        // same real rows shipped → FP8 dy payload is exactly half the BF16
        // bytes, plus the UE8M0 sidecar in a second buffer per pair
        assert_eq!(flow.dy_payload_bytes * 2, bf16.dy_payload_bytes);
        assert!(flow.dy_sidecar_bytes > 0);
        assert_eq!(bf16.dy_sidecar_bytes, 0);
        assert_eq!(flow.dy_buffers, 2 * bf16.dy_buffers);
        // dX rides in accumulator precision in both recipes
        assert_eq!(flow.dx_bytes, bf16.dx_bytes);
        // and the stage timers are populated
        assert!(flow.grads.stages.combine_bwd_s > 0.0);
        assert!(flow.grads.stages.expert_bwd_s > 0.0);
        assert!(flow.grads.stages.dispatch_bwd_s > 0.0);
        let j = flow.to_json().render();
        assert!(j.contains("\"expert_bwd_ms\""), "{j}");
    }
}
